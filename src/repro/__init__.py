"""MERINDA-X: Model Recovery + LM framework in JAX for TPU.

Reproduction (and beyond-paper optimization) of
"Hardware Software Optimizations for Fast Model Recovery on Reconfigurable
Architectures" (MERINDA), adapted from FPGA dataflow to TPU (Pallas/XLA).
"""

__version__ = "0.1.0"
