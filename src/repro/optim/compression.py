"""Int8 error-feedback gradient compression for the cross-pod reduction.

At 1000-node scale the cross-pod (DCN/ICI-bridge) links are the scarce
resource; we compress the pod-level gradient exchange 4x:

    e      <- error buffer (fp32, sharded like the gradient)
    g'     = g + e
    q      = int8 per-tensor symmetric quantization of g'
    g_hat  = mean over pods of dequant(all_gather(q))     <- int8 on the wire
    e'     = g' - dequant(q)                              <- local error feedback

Expressed with shard_map over the `pod` axis only (data/model stay `auto`,
i.e. GSPMD-partitioned as usual), so the int8 all_gather is visible in the
compiled HLO — the dry-run's collective-bytes accounting sees the compressed
wire format.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_reduce_grads(grads: Any, errors: Any, axis_name: str = "pod"):
    """Inside shard_map: compressed mean-reduce over `axis_name`.

    Returns (reduced_grads fp32-ish, new_errors). grads/errors are pytrees.
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        qs = jax.lax.all_gather(q, axis_name)  # int8 on the wire
        scales = jax.lax.all_gather(scale, axis_name)
        deq = (qs.astype(jnp.float32) * scales.reshape((-1,) + (1,) * g.ndim)).mean(0)
        new_e = g32 - q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def init_error_buffers(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_error_bound(bits: int = 8) -> float:
    """Max relative rounding error of symmetric b-bit quantization (per step,
    before error feedback cancels it across steps): 0.5 / (2^(b-1) - 1)."""
    return 0.5 / (2 ** (bits - 1) - 1)
