from repro.optim.adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine  # noqa: F401
from repro.optim.clip import clip_by_global_norm, global_norm  # noqa: F401
