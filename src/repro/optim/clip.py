"""Gradient clipping utilities."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm
