"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def lr(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1.0 - final_frac) * cos)

    return lr


def linear_warmup_cosine(
    base_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1), final_frac)

    def lr(step):
        step_f = step.astype(jnp.float32)
        warm = base_lr * step_f / max(warmup_steps, 1)
        return jnp.where(step_f < warmup_steps, warm, cos(step - warmup_steps))

    return lr
