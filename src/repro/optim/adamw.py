"""AdamW implemented from scratch (no optax in this environment).

Design notes for scale:
- m/v are fp32 regardless of param dtype (mixed-precision training keeps
  params in bf16 with fp32 master copies handled by the trainer).
- The state is a pytree mirroring params, so the sharding rules that shard a
  parameter shard its optimizer moments identically (ZeRO-1 falls out of the
  FSDP param sharding — see parallel/rules.py).
- Update math follows Loshchilov & Hutter: decoupled weight decay applied to
  the parameter, not the gradient moment.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    m: Any  # pytree like params (fp32)
    v: Any  # pytree like params (fp32)


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: float | jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> tuple[Any, AdamWState]:
    """Returns (new_params, new_state). Params keep their dtype."""
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * jnp.square(g32)
        m_hat = m / bc1
        v_hat = v / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (delta + weight_decay * p32)
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
