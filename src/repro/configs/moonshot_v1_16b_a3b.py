"""moonshot-v1-16b-a3b (Moonlight) — 64-expert top-6 fine-grained MoE
[hf:moonshotai/Moonlight-16B-A3B; hf]. Uniform MoE layers (the real model's
dense first layer is omitted — DESIGN.md)."""

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    d_ff=1408,
    vocab_size=163840,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6),
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    d_ff=96,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=3, group_size=64),
    attn_chunk=32,
)
