"""merinda-gru — the paper's own model family as an LM config: GRU neural-flow
sequence mixers (core/neural_flow.py; kernels/gru_scan on TPU) + SwiGLU MLPs.
Not part of the assigned 40-cell grid; exercised by tests/examples and the
paper benchmarks."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="merinda-gru",
    family="gru",
    num_layers=8,
    d_model=512,
    d_ff=1536,
    vocab_size=32000,
    gru_hidden=512,
)

SMOKE = ModelConfig(
    name="merinda-gru-smoke",
    family="gru",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    gru_hidden=64,
)
