"""zamba2-1.2b — Mamba2 backbone + ONE weight-shared attention block applied
after every 6th mamba layer [arXiv:2411.15242; hf]. SSM state decode ->
long_500k runs (shared-block KV cache seq-shards on `data` at batch=1)."""

from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab_size=32000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=64),
    ssm=SSMConfig(state_dim=64, head_dim=64, num_groups=1),
    attn_period=6,
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke",
    family="hybrid",
    num_layers=5,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    ssm=SSMConfig(state_dim=16, head_dim=16, num_groups=1, chunk=16),
    attn_period=2,
    attn_chunk=32,
)
