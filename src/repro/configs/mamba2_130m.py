"""mamba2-130m — attention-free SSD (state-space duality) [arXiv:2405.21060].
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads, state N=128. Constant-
size state decode -> long_500k runs."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, num_groups=1),
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    d_ff=0,
    vocab_size=512,
    ssm=SSMConfig(state_dim=16, head_dim=16, num_groups=1, chunk=16),
)
