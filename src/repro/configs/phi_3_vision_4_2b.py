"""phi-3-vision-4.2b — phi3-mini backbone + CLIP patch frontend (stub)
[hf:microsoft/Phi-3-vision-128k-instruct; hf]. input_specs() supplies 256
precomputed patch embeddings prepended to the text sequence."""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=32064,
    attn=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=96),
    num_patches=256,
)

SMOKE = ModelConfig(
    name="phi-3-vision-4.2b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    num_patches=8,
    attn_chunk=32,
)
