"""internlm2-20b — dense GQA transformer [arXiv:2403.17297; hf]."""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    d_ff=16384,
    vocab_size=92544,
    attn=AttentionConfig(num_heads=48, num_kv_heads=8, head_dim=128),
)

SMOKE = ModelConfig(
    name="internlm2-20b-smoke",
    family="dense",
    num_layers=2,
    d_model=96,
    d_ff=192,
    vocab_size=512,
    attn=AttentionConfig(num_heads=6, num_kv_heads=2, head_dim=16),
    attn_chunk=32,
)
