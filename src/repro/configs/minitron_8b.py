"""minitron-8b — pruned Nemotron dense GQA transformer [arXiv:2407.14679; hf]."""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=16384,
    vocab_size=256000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128),
)

SMOKE = ModelConfig(
    name="minitron-8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
    attn_chunk=32,
)
