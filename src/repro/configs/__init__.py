from repro.configs.base import (  # noqa: F401
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    SHAPES,
    get_config,
    get_shape,
    list_archs,
    shape_applicable,
)
