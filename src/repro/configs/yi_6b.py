"""yi-6b — llama-architecture dense GQA transformer [arXiv:2403.04652; hf]."""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=11008,
    vocab_size=64000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=4, head_dim=128),
)

SMOKE = ModelConfig(
    name="yi-6b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=160,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
    attn_chunk=32,
)
