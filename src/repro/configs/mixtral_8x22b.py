"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf]. SWA window 4096 -> rolling decode cache -> long_500k
is sub-quadratic (DESIGN.md)."""

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    d_ff=16384,
    vocab_size=32768,
    attn=AttentionConfig(num_heads=48, num_kv_heads=8, head_dim=128, window=4096),
    moe=MoEConfig(num_experts=8, top_k=2),
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16, window=16),
    moe=MoEConfig(num_experts=4, top_k=2, group_size=64),
    attn_chunk=32,
)
