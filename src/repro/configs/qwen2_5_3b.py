"""qwen2.5-3b — dense GQA transformer with QKV bias [hf:Qwen/Qwen2.5; hf]."""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    d_ff=11008,
    vocab_size=151936,
    attn=AttentionConfig(num_heads=16, num_kv_heads=2, head_dim=128, qkv_bias=True),
)

SMOKE = ModelConfig(
    name="qwen2.5-3b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=160,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16, qkv_bias=True),
    attn_chunk=32,
)
