"""seamless-m4t-medium — encoder-decoder multimodal transformer
[arXiv:2308.11596; hf]. "12L" realized as 12 encoder + 12 decoder layers;
vocab 256206 pads to 256256 for even model-axis sharding (DESIGN.md). The
audio frontend is a stub: input_specs() provides precomputed 80-d fbank
frames, projected to d_model by a single learned matrix."""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=256206,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64),
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    attn_chunk=32,
)
