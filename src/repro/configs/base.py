"""Config schema + registry for architectures and input shapes.

Every assigned architecture ships as src/repro/configs/<id>.py exposing
``CONFIG`` (exact published dims) and ``SMOKE`` (reduced same-family config
for CPU smoke tests). ``get_config(name)`` resolves either.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding-window size (SWA); None = full
    rope_theta: float = 1e6


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 512  # tokens per dispatch group (bounds dispatch memory)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int  # N
    head_dim: int = 64  # P
    num_heads: int = 0  # H (0 -> derived: expand*d_model/head_dim)
    num_groups: int = 1  # G (B/C groups)
    conv_width: int = 4
    expand: int = 2
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | gru
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # family extras
    encoder_layers: int = 0  # audio (enc-dec): encoder depth
    attn_period: int = 0  # hybrid: shared attn block after every k ssm layers
    num_patches: int = 0  # vlm: image patch embeddings prepended
    frontend_dim: int = 0  # audio: fbank feature dim (stub projects to d_model)
    gru_hidden: int = 0  # gru family: mixer hidden size (0 -> d_model)
    # common
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"  # full | dots | none
    scan_layers: bool = True
    attn_chunk: int = 1024  # XLA blockwise-attention kv chunk
    logit_chunk: int = 0  # 0 = unchunked cross-entropy

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 for even model-axis sharding."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        if self.ssm.num_heads:
            return self.ssm.num_heads
        return self.ssm.expand * self.d_model // self.ssm.head_dim

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm_heads * self.ssm.head_dim

    def n_params(self) -> int:
        """Analytic parameter count (embedding included, fp-agnostic)."""
        from repro.models.params import count_params  # lazy: avoid cycle

        return count_params(self)

    def n_active_params(self) -> int:
        from repro.models.params import count_params

        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# sub-quadratic-capable archs (SSM state decode or sliding-window cache)
_LONG_OK = {"mamba2-130m", "zamba2-1.2b", "mixtral-8x22b"}

ARCH_IDS = [
    "minitron-8b",
    "internlm2-20b",
    "qwen2.5-3b",
    "yi-6b",
    "mixtral-8x22b",
    "moonshot-v1-16b-a3b",
    "phi-3-vision-4.2b",
    "zamba2-1.2b",
    "seamless-m4t-medium",
    "mamba2-130m",
    # paper's own models (not part of the 40-cell grid)
    "merinda-gru",
]


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped). See DESIGN.md §long_500k applicability."""
    if shape == "long_500k" and arch not in _LONG_OK:
        return False, "full quadratic attention — no sub-quadratic decode path (DESIGN.md)"
    return True, ""


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG
