"""Batched serving driver: continuous-batching decode loop.

Serving structure (vLLM-style, adapted to JAX's static shapes):

- fixed decode batch of ``--slots`` sequences; each slot holds one request's
  state inside the SHARED cache tree (one prefill/decode program, no
  per-request allocation);
- admission: when a slot finishes (EOS or max_len), the next queued request
  is prefilled into that slot (cache rows updated via dynamic_update_slice);
- one compiled prefill program + one compiled decode program, reused for the
  whole run (the "one setup, then continuous streaming" property the paper
  gets from its FPGA pipeline — here it falls out of jit caching).

CPU demo on reduced configs:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --requests 12 --slots 4 --prompt-len 32 --max-new 24
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _slot_update(cache, slot_cache, slot: int):
    """Write one request's prefilled cache rows into batch slot `slot`."""

    def upd(full, one):
        # full: [..., B_slots, ...] with batch at axis of prefill output (hybrid
        # trees keep batch at axis 1 under the layer-stack axis)
        batch_axis = 1
        idx = [slice(None)] * full.ndim
        idx[batch_axis] = slice(slot, slot + 1)
        return full.at[tuple(idx)].set(one)

    return jax.tree.map(upd, cache, slot_cache)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4, help="decode batch size")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--eos", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.base import get_config
    from repro.models import model as M

    cfg = get_config(args.arch, smoke=not args.full)
    key = jax.random.key(args.seed)
    params = M.init_params(key, cfg)
    CL = args.cache_len

    prefill_one = jax.jit(lambda p, b: M.prefill(p, b, cfg, cache_len=CL))
    decode = jax.jit(lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))

    # request queue: synthetic prompts
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(
        1, min(cfg.vocab_size, 1000), size=(args.requests, args.prompt_len)
    ).astype(np.int32)

    # bootstrap: prefill the first `slots` requests as one batch
    B = args.slots
    first = jnp.asarray(prompts[:B])
    logits, cache = prefill_one(params, {"tokens": first})
    next_tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)

    slot_req = list(range(B))  # which request occupies each slot
    slot_pos = np.full(B, args.prompt_len, dtype=np.int64)
    slot_new = np.zeros(B, dtype=np.int64)
    outputs: dict[int, list[int]] = {i: [] for i in range(args.requests)}
    next_req = B
    done = 0
    t0 = time.time()
    steps = 0

    active = np.ones(B, dtype=bool)
    while done < args.requests:
        tokens = next_tok[:, None]
        pos = jnp.asarray(int(slot_pos.max()))  # static-shape demo: common pos
        logits, cache = decode(params, cache, tokens, pos)
        steps += 1
        next_tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        toks = np.asarray(next_tok)
        slot_pos += 1
        slot_new += 1
        for s in range(B):
            if not active[s]:
                continue
            r = slot_req[s]
            outputs[r].append(int(toks[s]))
            if int(toks[s]) == args.eos or slot_new[s] >= args.max_new:
                done += 1
                if next_req < args.requests:  # admit the next request
                    pr = jnp.asarray(prompts[next_req : next_req + 1])
                    lg1, c1 = prefill_one(params, {"tokens": pr})
                    cache = _slot_update(cache, c1, s)
                    nt = jnp.argmax(lg1[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
                    next_tok = next_tok.at[s].set(nt[0])
                    slot_req[s] = next_req
                    slot_pos[s] = args.prompt_len
                    slot_new[s] = 0
                    next_req += 1
                else:
                    active[s] = False
    dt = time.time() - t0
    total_new = sum(len(v) for v in outputs.values())
    print(
        f"[serve] arch={args.arch} requests={args.requests} slots={B} "
        f"decode_steps={steps} new_tokens={total_new} "
        f"throughput={total_new/dt:.1f} tok/s wall={dt:.1f}s"
    )
    for r in list(outputs)[:3]:
        print(f"  req{r}: {outputs[r][:12]}{'...' if len(outputs[r]) > 12 else ''}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
