import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY jax import: jax locks the device
#   count at first init. Only the dry-run sees 512 placeholder host devices;
#   smoke tests and benches see the real device count.

"""Multi-pod dry-run: prove the distribution config is coherent without TPUs.

For every (architecture x input-shape) cell this lowers + compiles the
appropriate step (train_step / prefill_step / serve_step) against abstract
ShapeDtypeStruct inputs on the production mesh:

    single-pod:  (data=16, model=16)          256 chips
    multi-pod:   (pod=2, data=16, model=16)   512 chips

and records, per cell:
    - memory_analysis()     bytes-per-device (proves the cell fits HBM)
    - cost_analysis()       per-device HLO FLOPs / bytes accessed
    - collective stats      parsed from the post-SPMD HLO (analysis/hlo.py)
    - roofline terms        compute / memory / collective seconds + bottleneck

Usage:
    python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --jobs 4
    python -m repro.launch.dryrun --arch mamba2-130m --shape train_4k \
        --variant remat_dots          # perf-hillclimb variants (see VARIANTS)

Results land in artifacts/dryrun/<arch>__<shape>__<mesh>[__<variant>].json;
EXPERIMENTS.md tables are generated from these via benchmarks/roofline.py.
"""

import argparse
import dataclasses
import json
import multiprocessing as mp
import pathlib
import subprocess
import sys
import time
import traceback

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# perf-iteration variants (EXPERIMENTS.md §Perf). "baseline" is paper-faithful
# defaults; the others are single-axis changes so before/after is attributable.
# ---------------------------------------------------------------------------
def _apply_variant(cfg, variant: str):
    """Return (cfg', step_kwargs) for a named variant.

    Compound variants compose with '+': e.g. ``mb2+logit_chunk``.
    """
    kw: dict = {}
    if variant == "baseline":
        return cfg, kw
    if "+" in variant:
        for part in variant.split("+"):
            cfg, kw_part = _apply_variant(cfg, part)
            kw.update(kw_part)
        return cfg, kw
    if variant == "remat_dots":
        return dataclasses.replace(cfg, remat="dots"), kw
    if variant == "remat_none":
        return dataclasses.replace(cfg, remat="none"), kw
    if variant == "logit_chunk":
        return dataclasses.replace(cfg, logit_chunk=8), kw
    if variant == "attn_chunk_2k":
        return dataclasses.replace(cfg, attn_chunk=2048), kw
    if variant == "attn_chunk_4k":
        return dataclasses.replace(cfg, attn_chunk=4096), kw
    if variant.startswith("mb"):  # microbatched grad accumulation (mb2, mb4...)
        kw["microbatch"] = int(variant[2:])
        return cfg, kw
    if variant.startswith("ssm_chunk_"):
        n = int(variant.rsplit("_", 1)[1])
        ssm = dataclasses.replace(cfg.ssm, chunk=n)
        return dataclasses.replace(cfg, ssm=ssm), kw
    if variant == "unscan":
        return dataclasses.replace(cfg, scan_layers=False), kw
    raise ValueError(f"unknown variant {variant!r}")


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    variant: str = "baseline",
    rules_name: str = "default",
    out_dir: pathlib.Path = ART,
    verbose: bool = True,
) -> dict:
    """Lower + compile one cell; write the JSON record; return it."""
    import jax  # noqa: F401  # deferred side effect: XLA_FLAGS already set at module import

    from repro.analysis.hlo import analyze_module, roofline_terms
    from repro.configs.base import get_config, get_shape, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.parallel import rules as rules_mod
    from repro.parallel.steps import make_step_for_shape

    t0 = time.time()
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "rules": rules_name,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}" + (
        f"__{variant}" if variant != "baseline" else ""
    ) + (f"__{rules_name}" if rules_name != "default" else "")
    out_path = out_dir / f"{tag}.json"

    ok, reason = shape_applicable(arch, shape_name)
    if not ok:
        record.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(record, indent=1))
        if verbose:
            print(f"[dryrun] {tag}: SKIPPED ({reason})")
        return record

    try:
        cfg = get_config(arch)
        shape = get_shape(shape_name)
        cfg, step_kw = _apply_variant(cfg, variant)
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        n_dev = mesh.devices.size
        rules = rules_mod.RULE_VARIANTS[rules_name]

        with rules_mod.use_mesh_rules(mesh, rules):
            jitted, abstract_args = make_step_for_shape(cfg, shape, mesh, rules, **step_kw)
            lowered = jitted.lower(*abstract_args)
            compiled = lowered.compile()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # jax <= 0.4.x wraps it in a list
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # trip-count-aware HLO analysis (analysis/hlo.py) — raw cost_analysis()
        # counts scan bodies once, under-reporting L-layer models by ~L x.
        # f32_as_bf16 corrects CPU float-normalization (see analyzer docstring).
        costs = analyze_module(hlo, n_dev, f32_as_bf16=(cfg.dtype == "bfloat16"))

        # model FLOPs: 6*N_active*D for train, 2*N_active*D per generated/scored token
        n_active = cfg.n_active_params()
        tokens = shape.global_batch * (1 if shape.mode == "decode" else shape.seq_len)
        mf = (6 if shape.mode == "train" else 2) * n_active * tokens

        rf = roofline_terms(
            flops_per_dev=costs.flops,
            hbm_bytes_per_dev=costs.hbm_bytes,
            coll_wire_bytes_per_dev=costs.collective_wire_bytes,
            model_flops_global=float(mf),
            n_devices=n_dev,
        )
        record.update(
            status="ok",
            n_devices=n_dev,
            seconds_to_compile=round(time.time() - t0, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
                # donated inputs alias outputs (train: state, decode: cache),
                # so live bytes = temps + max(args, outputs), not their sum
                "peak_bytes_per_device": (
                    max(
                        getattr(mem, "argument_size_in_bytes", 0),
                        getattr(mem, "output_size_in_bytes", 0),
                    )
                    + getattr(mem, "temp_size_in_bytes", 0)
                ),
            },
            cost={k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
            collectives={
                "ops": {k: int(v) for k, v in costs.collective_ops.items()},
                "operand_bytes": costs.collective_operand_bytes,
                "wire_bytes_per_device": costs.collective_wire_bytes,
            },
            roofline=rf.as_dict(),
            n_params=cfg.n_params(),
            n_active_params=n_active,
        )
        if verbose:
            hbm_gib = record["memory"]["peak_bytes_per_device"] / 2**30
            print(
                f"[dryrun] {tag}: OK {record['seconds_to_compile']}s "
                f"mem/dev={hbm_gib:.2f}GiB bottleneck={rf.bottleneck} "
                f"(tc={rf.t_compute*1e3:.2f}ms tm={rf.t_memory*1e3:.2f}ms "
                f"tl={rf.t_collective*1e3:.2f}ms)"
            )
    except Exception as e:  # record the failure — it's a bug to fix, not to hide
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {e}")
    out_path.write_text(json.dumps(record, indent=1))
    return record


def _cells(archs, shapes, meshes):
    from repro.configs.base import ARCH_IDS, SHAPES

    archs = archs or [a for a in ARCH_IDS if a != "merinda-gru"]
    shapes = shapes or list(SHAPES)
    return [(a, s, m) for a in archs for s in shapes for m in meshes]


def _run_subprocess(cell_args) -> tuple[str, bool]:
    """Run one cell in a fresh interpreter (isolation: one compile per proc)."""
    arch, shape, mesh, variant, rules_name = cell_args
    cmd = [
        sys.executable,
        "-m",
        "repro.launch.dryrun",
        "--arch",
        arch,
        "--shape",
        shape,
        "--mesh",
        mesh,
        "--variant",
        variant,
        "--rules",
        rules_name,
    ]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parents[2])
    p = subprocess.run(cmd, capture_output=True, text=True, env=env)
    tail = (p.stdout + p.stderr).strip().splitlines()
    msg = tail[-1] if tail else ""
    return f"{arch}__{shape}__{mesh}", p.returncode == 0 and "ERROR" not in msg


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", help="architecture id (repeatable)")
    ap.add_argument("--shape", action="append", help="shape name (repeatable)")
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--rules", default="default", help="sharding rule variant")
    ap.add_argument("--all", action="store_true", help="all 40 cells")
    ap.add_argument("--jobs", type=int, default=1, help="parallel subprocesses for --all")
    ap.add_argument("--force", action="store_true", help="recompute existing results")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all or (args.arch and len(args.arch) + len(args.shape or "xxxx") > 2):
        cells = _cells(args.arch, args.shape, meshes)
        todo = []
        for a, s, m in cells:
            tag = f"{a}__{s}__{m}" + (f"__{args.variant}" if args.variant != "baseline" else "")
            path = ART / f"{tag}.json"
            if path.exists() and not args.force:
                prev = json.loads(path.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    continue
            todo.append((a, s, m, args.variant, args.rules))
        print(f"[dryrun] {len(todo)} cells to run ({len(cells) - len(todo)} cached)")
        failures = []
        with mp.Pool(args.jobs) as pool:
            for tag, _ok in pool.imap_unordered(_run_subprocess, todo):
                rec = (
                    json.loads((ART / f"{tag}.json").read_text())
                    if (ART / f"{tag}.json").exists()
                    else {}
                )
                status = rec.get("status", "missing")
                print(f"  {tag}: {status}")
                if status not in ("ok", "skipped"):
                    failures.append(tag)
        if failures:
            print(f"[dryrun] FAILURES: {failures}")
            return 1
        print("[dryrun] all cells ok")
        return 0

    rec = run_cell(
        args.arch[0] if args.arch else "minitron-8b",
        args.shape[0] if args.shape else "train_4k",
        meshes[0],
        variant=args.variant,
        rules_name=args.rules,
    )
    return 0 if rec.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
