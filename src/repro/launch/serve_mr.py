"""Online model-recovery service driver: many streams, few slots, one program.

The MR analogue of launch/serve.py's continuous-batching LM decode loop:
``--streams`` dynamical-system streams are queued into ``--slots`` service
slots (core/stream.py); every tick ingests a fresh observation chunk into
each slot's ring buffer and runs ``--steps-per-tick`` scan-jitted recovery
steps for ALL slots inside one donated, jit-cached program. Slots whose
coefficient estimate stops moving (relative delta below ``--delta-tol``) are
evicted and refilled from the queue; evicted params feed a warm-start
registry.

On exit, every recovered Theta is scored against the system's ground truth
(physical units, data/dynamics.embed_true_coef) and must beat the one-shot
baseline tolerance — streaming ingestion must not cost recovery quality.
The tolerance anchors on the per-system MEDIAN one-shot MSE (a single
baseline draw spreads ~10x on chaotic systems, which would flip the check
on baseline luck rather than streaming quality).

CPU demo (the CI acceptance configuration):

    PYTHONPATH=src python -m repro.launch.serve_mr \
        --streams 12 --slots 4 --steps-per-tick 8

``--plan`` builds the service through the declarative surface instead of
hand-plumbed configs: one ``repro.api.RecoverySpec`` (encoder, precision,
fusion, slots, mesh) is compiled by ``api.compile_plan`` into a
``RecoveryPlan``, and this driver becomes a thin consumer. ``--mesh D``
(requires ``--plan``) shards ``SlotState`` over a D-device mesh along the
slot axis; ``--virtual-devices N`` exports
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE jax loads, so
the sharded service runs on CPU virtual devices in CI:

    PYTHONPATH=src python -m repro.launch.serve_mr \
        --plan --mesh 2 --virtual-devices 2 --streams 12 --slots 4

``--fused`` runs every tick's per-window recovery stage through the
stage-fused kernels/mr_step step (encode + RMS-norm + dense head as ONE
dispatch with VMEM-resident hidden state; reference math off-TPU);
``--quant`` additionally serves every evicted stream's coefficients through
the fused fixed-point stage (kernels/mr_step int8: quantized gate + head
weights, PWL activations) — the paper's fixed-point serving configuration
end to end. ``--encoder`` picks the registry row; the multi-substep
families take their fused-solver mr_step variants under ``--fused``, so the
paper's headline LTC baseline runs the acceptance scenario fused:

    PYTHONPATH=src python -m repro.launch.serve_mr \
        --plan --fused --encoder ltc --streams 12 --slots 4

``--tick-kernel banked`` (requires ``--plan``) compiles the one-kernel
banked service tick (kernels/mr_step/tick.py): ring ingest, window
substeps, head and EMA readout as a single slot-banked program with a
packed one-readback status — the CI banked serve scenario:

    PYTHONPATH=src python -m repro.launch.serve_mr \
        --plan --tick-kernel banked --streams 12 --slots 4

``--control device`` (requires ``--plan``) serves through the
device-resident control plane (core/control.py): admission waits in
per-shard on-device rings, eviction and queue refill and the warm-start
gather all run inside the tick program, and the host only reads back a
packed status + event-log snapshot every ``--snapshot-period`` ticks — so
a steady-state tick is ONE donated program with zero readbacks between
snapshots, and admission never re-shards the slot axis. The CI
device-resident sharded serve scenario:

    PYTHONPATH=src python -m repro.launch.serve_mr \
        --plan --control device --mesh 2 --virtual-devices 2 \
        --streams 12 --slots 4

Heavy imports happen inside the entry points (after ``--virtual-devices``
has set XLA_FLAGS), never at module import time.
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

DEFAULT_SYSTEMS = "lorenz,damped_oscillator,controlled_pendulum"


def build_stream_fleet(
    names: list[str],
    n_streams: int,
    n_samples: int,
    noise: float = 0.01,
    seed: int = 0,
):
    """Generate ``n_streams`` trajectories cycling over ``names``, zero-padded
    to the fleet's common (n_state, n_input) dims.

    Returns (spec_per_stream, ys [R, T_total, n], us [R, T_total, m],
    (n_state, n_input, order)). Each stream gets its own noise seed, so two
    streams of the same system are distinct tenants.
    """
    from repro.data.dynamics import generate_trajectory, get_system

    specs = [get_system(n) for n in names]
    dts = {s.dt for s in specs}
    if len(dts) > 1:
        raise ValueError(f"streams must share a sampling dt, got {sorted(dts)}")
    n_max = max(s.state_dim for s in specs)
    m_max = max(s.input_dim for s in specs)
    order = max(s.order for s in specs)
    stream_specs, ys_all, us_all = [], [], []
    for i in range(n_streams):
        spec = specs[i % len(specs)]
        _, ys, us = generate_trajectory(
            spec.name, n_samples=n_samples, noise_std=noise, seed=seed + i
        )
        ys = np.pad(ys, ((0, 0), (0, n_max - spec.state_dim)))
        us = np.pad(us, ((0, 0), (0, m_max - us.shape[-1]))) if m_max else np.zeros((len(ys), 0))
        stream_specs.append(spec)
        ys_all.append(ys)
        us_all.append(us)
    return (
        stream_specs,
        np.stack(ys_all).astype(np.float32),
        np.stack(us_all).astype(np.float32),
        (n_max, m_max, order),
    )


def _theta_mse(theta_phys: np.ndarray, theta_true: np.ndarray) -> float:
    return float(np.mean((theta_phys - theta_true) ** 2))


def run_service(
    service,
    ys: np.ndarray,  # [R, T_total, n]
    us: np.ndarray,  # [R, T_total, m]
    max_ticks: int,
    verbose: bool = True,
) -> dict:
    """Feed all streams through the service until the queue drains.

    Returns {"ticks", "wall_s", "evictions"}. Stream cursors wrap modulo the
    generated trajectory length, so a slow-converging stream never starves.
    """
    n_streams, t_total = ys.shape[:2]
    scfg, cfg = service.scfg, service.cfg
    slots, chunk = service.n_slots, scfg.chunk
    for i in range(n_streams):
        service.submit(i, ys[i, : scfg.buf_len], us[i, : scfg.buf_len])
    service.fill_slots()
    cursors = dict.fromkeys(range(n_streams), scfg.buf_len)
    evictions: list = []
    t0 = time.time()
    while not service.done and service.ticks < max_ticks:
        chunks_y = np.zeros((slots, chunk, cfg.state_dim), np.float32)
        chunks_u = np.zeros((slots, chunk, cfg.input_dim), np.float32)
        for s, sid in enumerate(service.slot_streams()):
            if sid < 0:
                continue
            idx = (cursors[sid] + np.arange(chunk)) % t_total
            chunks_y[s] = ys[sid, idx]
            chunks_u[s] = us[sid, idx]
            cursors[sid] += chunk
        info = service.tick_once(chunks_y, chunks_u)
        for res in info["evicted"]:
            evictions.append(res)
            if verbose:
                print(
                    f"  tick {info['tick']:4d}: evict stream {res.stream_id:3d} "
                    f"({res.reason}, {res.steps} steps) -> admit next; "
                    f"active={info['active']}"
                )
    return {"ticks": service.ticks, "wall_s": time.time() - t0, "evictions": evictions}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--systems", default=DEFAULT_SYSTEMS, metavar="SYS[,SYS...]")
    ap.add_argument("--streams", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--steps-per-tick", type=int, default=8)
    ap.add_argument("--window", type=int, default=32)
    ap.add_argument("--stride", type=int, default=8)
    ap.add_argument("--buf-len", type=int, default=160)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument(
        "--encoder",
        default="gru",
        help="any core/encoders.py registry row (gru, gru_flow, ltc, node, ...); "
        "with --fused the multi-substep families run the fused-solver "
        "kernels/mr_step variants",
    )
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--noise", type=float, default=0.01)
    ap.add_argument("--delta-tol", type=float, default=0.015)
    ap.add_argument("--min-steps", type=int, default=128)
    ap.add_argument("--max-steps", type=int, default=400)
    ap.add_argument("--max-ticks", type=int, default=1200)
    ap.add_argument("--quant", action="store_true", help="int8/PWL kernel readout at eviction")
    ap.add_argument(
        "--fused",
        action="store_true",
        help="stage-fused per-window recovery step (kernels/mr_step) in every tick",
    )
    ap.add_argument(
        "--plan",
        action="store_true",
        help="build the service through repro.api (RecoverySpec -> compile_plan)",
    )
    ap.add_argument(
        "--tick-kernel",
        choices=("auto", "banked", "composite"),
        default="composite",
        help="service-tick structure (requires --plan for non-composite): "
        "'banked' = one-kernel mr_tick serving segment (kernels/mr_step/tick.py), "
        "'auto' resolves from the tick-level VMEM model",
    )
    ap.add_argument(
        "--mesh",
        type=int,
        default=1,
        help="devices sharding the slot axis (requires --plan; 1 = single device)",
    )
    ap.add_argument(
        "--control",
        choices=("host", "device"),
        default="host",
        help="service control plane (requires --plan for 'device'): 'device' "
        "keeps admission queues, eviction and warm-start lookup on-device "
        "(core/control.py), so steady-state ticks run with zero host readbacks "
        "between snapshots and admission never re-shards the slot axis",
    )
    ap.add_argument(
        "--snapshot-period",
        type=int,
        default=1,
        help="device control plane: ticks between status/event-log snapshots. "
        "This driver routes per-stream chunks from the snapshot's slot map, so "
        "the default is 1 (every tick); raise it only when streams share input "
        "feeds and stale routing for N-1 ticks is acceptable",
    )
    ap.add_argument(
        "--queue-capacity",
        type=int,
        default=0,
        help="device control plane: per-shard admission ring capacity "
        "(0 = auto, sized so every stream can wait at once)",
    )
    ap.add_argument(
        "--audit",
        choices=("off", "warn", "error"),
        default="off",
        help="static HLO-contract audit of the compiled plan (requires --plan): "
        "warn prints findings, error refuses to serve a violating plan",
    )
    ap.add_argument(
        "--tune",
        choices=("off", "static", "measured"),
        default="off",
        help="measured-cost autotuning of the plan's lowering (requires --plan; "
        "analysis/tuner.py): 'static' records the candidate table through the "
        "VMEM model, 'measured' lowers + scores every candidate and caches "
        "the decision on disk (warm recompiles pay zero search cost)",
    )
    ap.add_argument(
        "--virtual-devices",
        type=int,
        default=0,
        help="set XLA_FLAGS host-platform device count before jax loads (CPU CI)",
    )
    ap.add_argument(
        "--checkpoint-dir",
        default=None,
        help="service snapshot directory (runtime/resilience.py); with "
        "--checkpoint-period > 0 the service snapshots SlotState + "
        "ControlState + the warm cache there, async and atomic",
    )
    ap.add_argument(
        "--checkpoint-period",
        type=int,
        default=0,
        help="ticks between service snapshots (0 = checkpointing off; "
        "requires --checkpoint-dir)",
    )
    ap.add_argument(
        "--chaos-kill-shard",
        type=int,
        default=-1,
        metavar="TICK",
        help="chaos injection (requires --plan): lose one device at TICK; the "
        "service supervisor re-plans the slot mesh on the survivors, restores "
        "the latest snapshot with resharding and re-submits dropped streams",
    )
    ap.add_argument(
        "--max-restarts",
        type=int,
        default=4,
        help="supervised-restart budget for the chaos/recovery path",
    )
    ap.add_argument(
        "--tol-factor",
        type=float,
        default=3.0,
        help="pass if stream MSE <= factor * per-system MEDIAN one-shot MSE + tol-abs",
    )
    ap.add_argument("--tol-abs", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main() -> int:
    args = build_parser().parse_args()
    if args.virtual_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.virtual_devices} "
            + os.environ.get("XLA_FLAGS", "")
        ).strip()
    if args.mesh > 1 and not args.plan:
        raise SystemExit("--mesh requires --plan (the sharded service is plan-compiled)")
    if args.audit != "off" and not args.plan:
        raise SystemExit("--audit requires --plan (only compiled plans are auditable)")
    if args.tune != "off" and not args.plan:
        raise SystemExit("--tune requires --plan (only compiled plans are tunable)")
    if args.tick_kernel != "composite" and not args.plan:
        raise SystemExit(
            "--tick-kernel requires --plan (the tick program is plan-compiled; "
            "the legacy service binds the composite tick internally)"
        )
    if args.control == "device" and not args.plan:
        raise SystemExit(
            "--control device requires --plan (the control-plane programs are "
            "plan-compiled; the legacy service is host-driven)"
        )
    if args.chaos_kill_shard >= 0 and not args.plan:
        raise SystemExit(
            "--chaos-kill-shard requires --plan (the supervisor recompiles the "
            "plan on the surviving mesh)"
        )

    # jax loads HERE, after the virtual-device environment is pinned
    from repro import api
    from repro.core.stream import RecoveryService, StreamConfig
    from repro.data.dynamics import embed_true_coef

    names = [s.strip() for s in args.systems.split(",") if s.strip()]
    # enough samples that max_steps' worth of ticks never wraps mid-stream
    n_samples = args.buf_len + args.chunk * (args.max_steps // args.steps_per_tick + 2)
    specs, ys, us, (n_state, n_input, order) = build_stream_fleet(
        names, args.streams, n_samples, noise=args.noise, seed=args.seed
    )
    scfg = StreamConfig(
        buf_len=args.buf_len,
        window=args.window,
        stride=args.stride,
        chunk=args.chunk,
        steps_per_tick=args.steps_per_tick,
        lr=args.lr,
        delta_tol=args.delta_tol,
        min_steps=args.min_steps,
        max_steps=args.max_steps,
    )
    ckpt_dir, ckpt_period = args.checkpoint_dir, args.checkpoint_period
    if args.chaos_kill_shard >= 0:
        # the chaos path needs snapshots to restore from: default a temp
        # directory + a 2-tick cadence when the flags don't pin them
        import tempfile

        ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="serve_mr_ckpt_")
        ckpt_period = ckpt_period or 2
    spec = api.RecoverySpec(
        state_dim=n_state,
        input_dim=n_input,
        order=order,
        hidden=args.hidden,
        dense_hidden=2 * args.hidden,
        dt=specs[0].dt,
        encoder=args.encoder,
        precision="int8_pwl" if args.quant else "fp32",
        fused=args.fused,
        mode="stream",
        lr=args.lr,
        seed=args.seed,
        n_slots=args.slots,
        stream=scfg,
        # the loose tick flags are a thin mapping onto TickSpec: geometry
        # (steps_per_tick/ema) mirrors the StreamConfig above, the kernel
        # choice is the only new degree of freedom
        tick=api.TickSpec(
            steps_per_tick=args.steps_per_tick,
            tick_kernel=args.tick_kernel,
            control=args.control,
            queue_capacity=args.queue_capacity or max(args.streams, 1),
            snapshot_period=args.snapshot_period,
            checkpoint_period=ckpt_period,
            checkpoint_dir=ckpt_dir,
        ),
        mesh_slots=args.mesh,
    )
    supervisor = None
    if args.chaos_kill_shard >= 0:
        from repro.runtime import ServiceSupervisor, kill_shard_once

        supervisor = ServiceSupervisor(
            spec,
            ckpt_dir,
            checkpoint_period=ckpt_period,
            max_restarts=args.max_restarts,
            chaos=kill_shard_once(args.chaos_kill_shard),
        )
        service = supervisor.service
        print(f"[serve_mr] plan lowering: {supervisor.plan.lowering}")
    elif args.plan:
        plan = api.compile_plan(spec, audit=args.audit, tune=args.tune)
        service = plan.make_service()
        print(f"[serve_mr] plan lowering: {plan.lowering}")
    else:
        # legacy construction path (deprecated; kept for compatibility) —
        # same declarative record, direct service construction
        service = RecoveryService(
            spec.to_mr_config(), scfg, args.slots, seed=args.seed, quant=args.quant
        )
    cfg = service.cfg
    print(
        f"[serve_mr] streams={args.streams} slots={args.slots} "
        f"K={args.steps_per_tick} windows/slot={scfg.n_windows} "
        f"library={cfg.n_terms}x{cfg.state_dim} encoder={args.encoder} "
        f"fused={args.fused} quant={args.quant} mesh={args.mesh if args.plan else 1}"
    )
    if supervisor is not None:
        t0 = time.time()
        summary = supervisor.serve(ys, us if n_input else None, max_ticks=args.max_ticks)
        service = supervisor.service
        results = summary["results"]
        stats = {"ticks": summary["ticks"], "wall_s": time.time() - t0}
        tick_ms = [t for h in supervisor.history for t in h["tick_ms"]]
        straggler_flags = summary["straggler_flags"]
        print(
            f"[serve_mr] chaos: {summary['restarts']} restart(s), final mesh "
            f"{summary['final_mesh']}, recovered_streams_fraction="
            f"{summary['recovered_streams_fraction']:.2f}"
        )
    else:
        stats = run_service(service, ys, us, args.max_ticks)
        results = service.results
        tick_ms = service.tick_ms
        straggler_flags = service.straggler_flags
    n_done = len(results)
    print(
        f"[serve_mr] {n_done}/{args.streams} streams recovered in {stats['ticks']} ticks "
        f"({stats['wall_s']:.1f}s, {stats['ticks'] / max(stats['wall_s'], 1e-9):.1f} ticks/s)"
    )
    if tick_ms:
        print(
            f"[serve_mr] tick latency: p50={float(np.percentile(tick_ms, 50)):.1f}ms "
            f"p99={float(np.percentile(tick_ms, 99)):.1f}ms; "
            f"stragglers={','.join(straggler_flags) or 'none'}"
        )
    if service.sync_log:
        print(
            f"[serve_mr] host boundary ({args.control if args.plan else 'host'} "
            f"control plane): {service.counters['host_syncs']} syncs, "
            f"{service.counters['reshards']} reshards; "
            f"median {float(np.median(service.sync_log)):.1f} syncs/tick"
        )
    if n_done < args.streams:
        print(f"[serve_mr] FAIL: {args.streams - n_done} streams never recovered")
        return 1

    # one-shot baseline: a batch-mode plan over each stream's initial history,
    # same step budget — the quality bar streaming ingestion must not fall below
    import dataclasses

    from repro.core.library import denormalize_theta
    from repro.data.windows import make_windows

    yw_b, uw_b, norms = [], [], []
    for i, sysspec in enumerate(specs):
        hist_y = ys[i, : scfg.buf_len, : sysspec.state_dim]
        hist_u = us[i, : scfg.buf_len] if n_input else None
        yw, uw, norm = make_windows(hist_y, hist_u, window=scfg.window, stride=scfg.stride)
        yw = np.pad(yw, ((0, 0), (0, 0), (0, n_state - sysspec.state_dim)))
        yw_b.append(yw)
        if n_input:
            uw_b.append(uw if uw is not None else np.zeros(yw.shape[:2] + (n_input,), np.float32))
        norms.append(norm)
    base_spec = dataclasses.replace(
        spec,
        mode="batch",
        precision="fp32",
        steps=scfg.max_steps,
        stream=None,
        tick=None,
        mesh_slots=1,
    )
    base_plan = api.compile_plan(base_spec)
    t0 = time.time()
    theta_base = np.asarray(
        base_plan.run_batch(np.stack(yw_b), np.stack(uw_b) if n_input else None)
    )
    print(f"[serve_mr] one-shot batch-plan baseline: {time.time() - t0:.1f}s")

    n_vars = n_state + n_input
    mse_srv, mse_base = [], []
    for i, sysspec in enumerate(specs):
        truth = embed_true_coef(sysspec, n_state, n_input, order)
        res = results[i]
        th_srv = denormalize_theta(
            res.theta, res.mean, res.scale, n_vars=n_vars, order=order, n_state=n_state
        )
        th_base = denormalize_theta(
            theta_base[i],
            norms[i]["mean"],
            norms[i]["scale"],
            n_vars=n_vars,
            order=order,
            n_state=n_state,
        )
        mse_srv.append(_theta_mse(th_srv, truth))
        mse_base.append(_theta_mse(th_base, truth))
    # tolerance anchors on the PER-SYSTEM MEDIAN baseline: one-shot MSE on a
    # chaotic system spreads ~10x across noise draws (measured 3.6-46 for
    # lorenz), so a per-stream anchor flips the check on a single lucky
    # baseline draw even when the streaming estimates are tightly clustered
    med_base = {
        s.name: float(np.median([b for sp, b in zip(specs, mse_base) if sp.name == s.name]))
        for s in specs
    }
    failures = 0
    for i, sysspec in enumerate(specs):
        res = results[i]
        mse_s, mse_b = mse_srv[i], mse_base[i]
        tol = args.tol_factor * med_base[sysspec.name] + args.tol_abs
        ok = mse_s <= tol
        failures += not ok
        print(
            f"  stream {i:3d} {sysspec.name:22s} mse={mse_s:8.4f} "
            f"baseline={mse_b:8.4f} tol={tol:8.4f} steps={res.steps:4d} "
            f"{res.reason:9s} {'ok' if ok else 'FAIL'}"
        )
    if failures:
        print(f"[serve_mr] FAIL: {failures}/{args.streams} streams above baseline tolerance")
        return 1
    print(f"[serve_mr] OK: all {args.streams} streams within baseline tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
