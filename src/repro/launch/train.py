"""Distributed LM training driver.

Wires together: configs registry -> sharded train step (parallel/steps.py) ->
deterministic data pipeline (data/pipeline.py) -> supervisor with
checkpoint/restart + elastic re-mesh (runtime/supervisor.py).

On the CPU container this trains REDUCED (smoke) configs for real — the same
code path the production mesh would run; pass --full only on a TPU slice.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 60 --batch 8 --seq 128 --data 2 --model 2

Failure drill (kills a "host" mid-run, supervisor re-meshes + restores):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 40 --chaos-step 20 --data 2 --model 2

Model-recovery mode (the paper's workload, scan-jitted engine — one compiled
program for the whole run; comma-separate systems to recover a fleet in one
vmapped call via core/engine.recover_many):

    PYTHONPATH=src python -m repro.launch.train \
        --recover lorenz,damped_oscillator,controlled_pendulum --steps 300
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np


def run_recover(systems: list[str], steps: int, lr: float) -> int:
    """Streaming-recovery driver: one vmapped scan-jitted program recovers
    coefficients for every requested system (core/engine.py)."""
    from repro.core import engine
    from repro.core.library import denormalize_theta

    t0 = time.time()
    ys_b, us_b, norms, cfg = engine.stack_systems(systems)
    thetas = engine.recover_many(cfg, ys_b, us_b, steps=steps, lr=lr, batch_size=64)
    thetas = np.asarray(jax.block_until_ready(thetas))
    dt = time.time() - t0
    print(
        f"[recover] {len(systems)} systems x {steps} steps in {dt:.1f}s "
        f"(one compiled program; library order {cfg.order}, {cfg.n_terms} terms)"
    )
    for name, th, norm in zip(systems, thetas, norms):
        # report in PHYSICAL units — spurious terms can hide in z-scored
        # coordinates (see merinda.recover_physical_coefficients)
        th_phys = denormalize_theta(
            th,
            norm["mean"],
            norm["scale"],
            n_vars=cfg.state_dim + cfg.input_dim,
            order=cfg.order,
            n_state=cfg.state_dim,
        )
        nz = int((np.abs(th_phys) > 0.05).sum())
        print(f"  {name:22s} |theta|_max={np.abs(th_phys).max():.3f} active_terms~{nz}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--recover", default=None, metavar="SYS[,SYS...]",
                    help="model-recovery mode: comma-separated systems from "
                         "data/dynamics.SYSTEMS (skips LM training entirely)")
    ap.add_argument("--full", action="store_true", help="full config (TPU only)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--lr", type=float, default=None,
                    help="default 3e-4 (LM training) / 3e-3 (--recover mode)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--chaos-step", type=int, default=0, help="simulate failure at step")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--rules", default="default",
                    help="sharding rules variant (parallel/rules.RULE_VARIANTS)")
    args = ap.parse_args()

    if args.recover:
        systems = [s.strip() for s in args.recover.split(",") if s.strip()]
        return run_recover(systems, args.steps, args.lr if args.lr is not None else 3e-3)

    logging.basicConfig(level=logging.INFO, format="%(name)s %(message)s")
    from repro.configs.base import ShapeConfig, get_config
    from repro.data.pipeline import PipelineConfig, SyntheticLM, device_put_batch
    from repro.parallel import rules as rules_mod
    from repro.parallel.steps import make_train_step, train_state_specs
    from repro.models.params import materialize
    from repro.runtime import SimulatedFailure, Supervisor
    from repro.runtime.elastic import plan_mesh
    from repro.runtime.supervisor import SupervisorConfig

    cfg = get_config(args.arch, smoke=not args.full)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    pipe = SyntheticLM(
        PipelineConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    )

    def build_step(mesh):
        rules = rules_mod.RULE_VARIANTS[args.rules]
        with rules_mod.use_mesh_rules(mesh, rules):
            jitted, state_sh, batch_sh, _ = make_train_step(
                cfg, shape, mesh, rules,
                lr=args.lr if args.lr is not None else 3e-4, donate=False
            )

        def init_state():
            from repro.parallel.steps import TrainState
            import jax.numpy as jnp

            specs = train_state_specs(cfg)
            key = jax.random.key(0)
            with rules_mod.use_mesh_rules(mesh, rules):
                params = materialize(key, specs.params)
                zeros_like = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
                state = TrainState(
                    params=params,
                    m=zeros_like,
                    v=jax.tree.map(jnp.copy, zeros_like),
                    step=jnp.zeros((), jnp.int32),
                )
                state = jax.device_put(state, state_sh)
            return state

        def step_fn(state, batch):
            with rules_mod.use_mesh_rules(mesh, rules):
                batch = device_put_batch(batch, batch_sh)
                return jitted(state, batch)

        return step_fn, None, init_state  # shardings=None: save/restore re-places

    def next_batch(step, mesh):
        return pipe.batch_at(step)

    chaos = None
    if args.chaos_step:
        fired = {"done": False}

        def chaos(step):
            if step == args.chaos_step and not fired["done"]:
                fired["done"] = True
                raise SimulatedFailure(n_lost=len(jax.devices()) // 2)

    sup = Supervisor(
        build_step,
        next_batch,
        args.ckpt_dir,
        SupervisorConfig(max_steps=args.steps, save_every=args.save_every),
        chaos=chaos,
    )
    plan = plan_mesh(len(jax.devices()), model=args.model, max_data=args.data)
    t0 = time.time()
    result = sup.run(plan)
    dt = time.time() - t0

    losses = [h["loss"] for h in result["history"] if np.isfinite(h["loss"])]
    print(
        f"[train] arch={args.arch} steps={result['final_step']} "
        f"restarts={result['restarts']} mesh={result['final_mesh']} "
        f"loss {losses[0]:.3f} -> {losses[-1]:.3f} ({dt:.0f}s)"
    )
    for h in result["history"][:: max(1, args.log_every)]:
        print(f"  step {h['step']:4d} mesh={h['mesh']} loss={h['loss']:.4f} {h['t']*1e3:.0f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
