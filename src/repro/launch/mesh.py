"""Production mesh factory.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. The dry-run entry point (dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else sees the real device count.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic variant: any (pods, data, model) whose product <= devices.

    Used by the fault-tolerance runtime to re-mesh onto the surviving device
    set after a failure (runtime/elastic.py).
    """
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
