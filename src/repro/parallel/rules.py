"""Logical-axis sharding rules -> mesh PartitionSpecs.

One rule table covers every (arch x shape x mesh) cell via two safety
properties applied *per tensor* at spec-resolution time:

1. divisibility fallback — a candidate mesh assignment is taken only if the
   dimension is divisible by the product of the candidate's mesh-axis sizes;
   otherwise the next candidate (or replication) is used. E.g. kv_heads=8 on
   a model=16 axis replicates instead of forcing GSPMD padding.
2. conflict resolution — earlier tensor dims claim mesh axes first; later
   dims fall back. E.g. decode batch=128 claims `data`; the cache seq dim
   then replicates. With batch=1 (long_500k) the batch dim fails
   divisibility, so the cache seq dim claims `data` — sequence parallelism
   falls out of the same table.

Default placement strategy (MaxText-style fsdp x tensor):
  weights' d_model dim -> data (FSDP / ZeRO-3), heads/ffn/vocab/expert dim
  -> model (TP/EP); activations' batch -> (pod, data).
"""

from __future__ import annotations

import contextlib
import functools
import math
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Candidate = tuple[str, ...]

# logical axis -> ordered candidates (each a tuple of mesh axes)
DEFAULT_RULES: dict[str, list[Candidate]] = {
    # activations
    "batch": [("pod", "data"), ("data",)],
    "seq": [],  # replicated by default; "seq_sharded" opts in
    # Megatron-style sequence parallelism: the residual stream between blocks
    # is seq-sharded over `model`, turning the per-layer TP all-reduce into a
    # reduce-scatter + all-gather pair (equal wire bytes, Nx less live memory)
    "seq_sharded": [("model",), ("data",)],
    # KV cache length: `data` when free (long_500k, batch=1), else `model`
    # (decode_32k, batch takes data) — never replicated, or big caches OOM
    "cache_seq": [("data",), ("model",)],
    "act_embed": [],
    "act_heads": [("model",)],
    "act_mlp": [("model",)],
    "act_vocab": [("model",)],
    "act_expert": [("model",)],
    # parameters
    "embed": [("data",)],  # FSDP dim of weight matrices
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [],
    "mlp": [("model",)],
    "expert": [("model",)],
    "ssm_heads": [("model",)],
    "ssm_groups": [],
    "ssm_state": [],
    "ssm_inner": [("model",)],
    "conv": [],
    "layers": [],
    "frontend": [],
    # pipeline (only present on pp meshes)
    "stage": [("stage",)],
}


# Named rule-table variants for the perf hillclimb (dryrun --rules <name>).
# Each is a full table; cells are compiled under exactly one variant so
# before/after deltas are attributable to the sharding change alone.
def _variant(**overrides) -> dict[str, list[Candidate]]:
    table = dict(DEFAULT_RULES)
    table.update(overrides)
    return table


RULE_VARIANTS: dict[str, dict[str, list[Candidate]]] = {
    "default": DEFAULT_RULES,
    # pure tensor parallelism: no FSDP gather on the embed dim (weights
    # replicated across `data`) — trades memory for zero weight all-gathers
    "tp_only": _variant(embed=[]),
    # megatron-style sequence sharding of activations between layers
    "no_seq": _variant(seq_sharded=[]),
    # shard the cache over model axis too when data is taken (decode)
    "cache_model": _variant(cache_seq=[("data",), ("model",)]),
    # expert-parallel first: experts claim `data` too when model is taken
    "ep_wide": _variant(expert=[("model",), ("data",)]),
    # 2-D FSDP / pure data parallelism: batch spreads over BOTH mesh axes and
    # weights are ZeRO-3 sharded over both; TP rules starve automatically via
    # conflict resolution (model axis already used by batch). The right
    # regime for models whose per-layer weights are small relative to the
    # per-device activation footprint (mamba2-130m, qwen-3b class) — all
    # per-layer TP/SP collectives vanish, leaving only the (small) weight
    # all-gathers and gradient reduce-scatters.
    "fsdp2d": _variant(
        batch=[("pod", "data", "model"), ("data", "model"), ("pod", "data"), ("data",)],
        embed=[("data", "model"), ("data",)],
        seq_sharded=[],
    ),
}


def partition_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: dict[str, list[Candidate]] | None = None,
) -> PartitionSpec:
    """Resolve logical axes to a PartitionSpec for this mesh (see module doc)."""
    rules = rules or _active_rules() or DEFAULT_RULES
    assert len(shape) == len(axes), (shape, axes)
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set[str] = set()
    out: list = []
    for dim, ax in zip(shape, axes):
        assignment = None
        for cand in rules.get(ax, []) if ax else []:
            if not all(a in mesh_sizes for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            prod = math.prod(mesh_sizes[a] for a in cand)
            if dim % prod != 0:
                continue
            assignment = cand if len(cand) > 1 else cand[0]
            used.update(cand)
            break
        out.append(assignment)
    # trim trailing Nones for readability
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def named_sharding(
    mesh: Mesh,
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: dict[str, list[Candidate]] | None = None,
) -> NamedSharding:
    return NamedSharding(mesh, partition_spec(shape, axes, mesh, rules))


# ---------------------------------------------------------------------------
# logical sharding-constraint context (used inside model code)
# ---------------------------------------------------------------------------
_ctx = threading.local()


def _active() -> tuple[Mesh, dict] | None:
    return getattr(_ctx, "mesh_rules", None)


def _active_rules() -> dict | None:
    mr = _active()
    return mr[1] if mr else None


def _mesh_context(mesh: Mesh):
    """API-drift shim: jax.set_mesh(mesh) is the context-manager form on
    jax >= 0.7; on older releases the Mesh object itself is the context
    manager that activates it."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: dict[str, list[Candidate]] | None = None):
    """Activate logical sharding constraints for model code traced within."""
    prev = _active()
    _ctx.mesh_rules = (mesh, rules or DEFAULT_RULES)
    try:
        with _mesh_context(mesh):
            yield
    finally:
        _ctx.mesh_rules = prev


def constraint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical axes; identity when no mesh active.

    Inside a hybrid shard_map (e.g. manual over `pod`, auto over data/model —
    the compressed-gradient path) constraints must be expressed against the
    CURRENT abstract mesh and must not mention manual axes (those dims are
    already local); both are handled here so model code stays oblivious.
    """
    mr = _active()
    if mr is None:
        return x
    mesh, rules = mr
    spec = partition_spec(x.shape, axes, mesh, rules)
    # get_abstract_mesh is jax >= 0.5-only; older releases have no abstract-
    # mesh tracking, so the rules-table mesh is authoritative there
    cur = getattr(jax.sharding, "get_abstract_mesh", lambda: None)()
    manual: set[str] = set()
    use_mesh = mesh
    if cur is not None and not getattr(cur, "empty", True) and tuple(
        getattr(cur, "axis_names", ())
    ) == tuple(mesh.axis_names):
        use_mesh = cur
        try:
            for name, ty in zip(cur.axis_names, cur.axis_types):
                if "Manual" in str(ty):
                    manual.add(name)
        except Exception:
            pass
    if manual:

        def strip(entry):
            if entry is None:
                return None
            names = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(n for n in names if n not in manual)
            return kept[0] if len(kept) == 1 else (kept or None)

        spec = PartitionSpec(*(strip(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(use_mesh, spec))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def constraint_vjp(x: jax.Array, fwd_axes: tuple, bwd_axes: tuple) -> jax.Array:
    """Sharding constraint with an independent cotangent constraint.

    with_sharding_constraint's transpose re-applies the FORWARD sharding to
    the cotangent. At a sequence-parallel boundary that is exactly wrong: the
    forward is an all-gather (seq-sharded -> replicated), so the transpose
    constraint forces the partial-sum cotangent to replicate — a full
    all-reduce — where a reduce-scatter (cotangent constrained back to
    seq-sharded) moves 2n/(n-1)~2x fewer wire bytes and lands already
    sharded. Semantically both are identity functions, so any cotangent
    sharding is valid; this picks the cheap one.
    """
    return constraint(x, fwd_axes)


def _cvjp_fwd(x, fwd_axes, bwd_axes):
    return constraint_vjp(x, fwd_axes, bwd_axes), None


def _cvjp_bwd(fwd_axes, bwd_axes, _, ct):
    return (constraint(ct, bwd_axes),)


constraint_vjp.defvjp(_cvjp_fwd, _cvjp_bwd)


def sp_gather(x: jax.Array) -> jax.Array:
    """Sequence-parallel boundary: gather seq shards fwd, reduce-scatter bwd."""
    return constraint_vjp(x, ("batch", "seq", "act_embed"), ("batch", "seq_sharded", "act_embed"))


def predict_tick_collectives(mesh: Mesh | None) -> dict[str, int]:
    """Predicted collective set of the slot-sharded streaming tick: EMPTY.

    Every SlotState leaf is sharded on its leading slot axis only
    (stream.SLOT_RULES) and the tick's computation is independent per slot —
    the vmapped recovery steps, readout and eviction signals never contract
    or permute across slots — so a correctly-sharded tick compiles with ZERO
    collectives regardless of mesh size. The device-resident control plane
    (core/control.py) preserves this census: ControlState leaves carry a
    leading per-shard axis sharded the same way, and eviction, queue refill
    and the warm-start gather inside ``tick_device`` are computed per shard
    (the [slots] -> [shards, slots_per_shard] reshape is a local relabeling
    of the already-sharded axis, not a permutation across devices). Rule R5
    (analysis/rules.py) holds the compiled HLO to this prediction: any
    all-reduce/all-gather appearing in a sharded tick means a sharding rule
    regressed (e.g. a replicated operand forcing a gather) and the service
    would pay cross-mesh wire bytes on every tick.
    """
    del mesh
    return {}
