"""Distributed train / prefill / decode steps with explicit shardings.

``make_train_step`` / ``make_prefill_step`` / ``make_decode_step`` return
(jitted_fn, arg_shardings, abstract_args) so the same builders serve:
  - the multi-pod dry-run (.lower().compile() on abstract args),
  - real training on the host devices (examples/train_lm.py),
  - the serving driver (launch/serve.py).

TrainState = (params bf16, AdamW m/v fp32 sharded like params, step). Gradient
all-reduce across `pod` is optionally int8-compressed with error feedback
(optim/compression.py) via shard_map over the pod axis with data/model auto.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.models.params import ParamSpec, abstract, shardings, tree_map_specs
from repro.optim import adamw_update, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    m: Any
    v: Any
    step: jnp.ndarray
    errors: Any = None  # compression error-feedback buffers (optional)


def _opt_spec_like(spec_tree):
    """m/v specs: same shape/axes as params, fp32."""
    return tree_map_specs(
        lambda s: ParamSpec(s.shape, s.axes, dtype="float32", init="zeros"), spec_tree
    )


def train_state_specs(cfg: ModelConfig, compress: bool = False) -> TrainState:
    ps = M.param_specs(cfg)
    opt = _opt_spec_like(ps)
    errors = _opt_spec_like(ps) if compress else None
    step = ParamSpec((), (), dtype="int32", init="zeros")
    return TrainState(params=ps, m=opt, v=jax.tree.map(lambda s: s, opt), step=step, errors=errors)


def _tree_shardings(spec_tree, mesh, rules=None):
    return shardings(spec_tree, mesh, rules)


def make_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    rules=None,
    lr: float = 3e-4,
    weight_decay: float = 0.1,
    compress_pod_grads: bool = False,
    donate: bool = True,
    microbatch: int = 1,
):
    """Returns (step_fn_jitted, state_shardings, batch_shardings, abstract_args).

    microbatch k > 1: gradient accumulation over k sequential microbatches
    (lax.scan) — live activation memory drops ~k x while arithmetic and
    per-token collective volume are unchanged. This is the standard fit knob
    for large global batches (mixtral train_4k pushes 1M tokens/step).
    """
    state_specs = train_state_specs(cfg, compress=compress_pod_grads)
    in_specs = M.input_specs(cfg, shape)
    state_sh = _tree_shardings(state_specs, mesh, rules)
    batch_sh = _tree_shardings(in_specs, mesh, rules)

    multi_pod = "pod" in mesh.axis_names

    def loss_fn(params, batch):
        loss, metrics = M.train_loss(params, batch, cfg)
        return loss, metrics

    def grads_of(params, batch):
        """(loss, metrics), grads — microbatched when microbatch > 1."""
        if microbatch <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        B = shape.global_batch
        assert B % microbatch == 0, (B, microbatch)

        def split(x):  # [B, ...] -> [k, B/k, ...]
            return x.reshape(microbatch, B // microbatch, *x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(acc, one):
            (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, one)
            acc = jax.tree.map(jnp.add, acc, g)
            return acc, (loss, metrics)

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, (losses, metrics) = jax.lax.scan(body, zeros, mb)
        grads = jax.tree.map(lambda g: (g / microbatch), acc)
        mean_metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        return (jnp.mean(losses), mean_metrics), grads

    def apply_update(state: TrainState, grads, metrics):
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        from repro.optim.adamw import AdamWState

        opt = AdamWState(step=state.step, m=state.m, v=state.v)
        params, opt = adamw_update(grads, opt, state.params, lr=lr, weight_decay=weight_decay)
        new_state = TrainState(params=params, m=opt.m, v=opt.v, step=opt.step, errors=state.errors)
        return new_state, dict(metrics, grad_norm=gnorm)

    # NOTE on compress_pod_grads: cross-pod gradient compression is a DCN
    # (host-driven) concern, not an ICI one — see runtime/multislice.py for
    # the int8+error-feedback exchange between pod-local steps. An earlier
    # in-XLA formulation (hybrid shard_map: manual over `pod`, auto inside)
    # check-fails in the CPU SPMD partitioner on subgroup collectives, and
    # compressing ICI collectives is the wrong layer anyway.
    del compress_pod_grads

    def step_fn(state: TrainState, batch):
        (loss, metrics), grads = grads_of(state.params, batch)
        new_state, metrics = apply_update(state, grads, metrics)
        return new_state, dict(metrics, loss=loss)

    jitted = jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,) if donate else (),
    )
    abstract_args = (abstract(state_specs), abstract(in_specs))
    return jitted, state_sh, batch_sh, abstract_args


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules=None):
    in_specs = M.input_specs(cfg, shape)
    batch_sh = _tree_shardings(in_specs, mesh, rules)
    param_sh = _tree_shardings(M.param_specs(cfg), mesh, rules)
    cache_sh = _tree_shardings(M.cache_specs(cfg, shape.global_batch, shape.seq_len), mesh, rules)

    def prefill_fn(params, batch):
        return M.prefill(params, batch, cfg, cache_len=shape.seq_len)

    jitted = jax.jit(
        prefill_fn,
        in_shardings=(param_sh, batch_sh),
        out_shardings=(None, cache_sh),
    )
    abstract_args = (abstract(M.param_specs(cfg)), abstract(in_specs))
    return jitted, param_sh, batch_sh, abstract_args


def make_decode_step(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules=None, donate: bool = True
):
    """serve_step: ONE new token against a cache of seq_len (decode_*/long_*)."""
    in_specs = M.input_specs(cfg, shape)  # tokens, pos, cache
    param_specs_tree = M.param_specs(cfg)
    param_sh = _tree_shardings(param_specs_tree, mesh, rules)
    tok_sh = _tree_shardings(in_specs["tokens"], mesh, rules)
    pos_sh = NamedSharding(mesh, PartitionSpec())
    cache_sh = _tree_shardings(in_specs["cache"], mesh, rules)

    def decode_fn(params, cache, tokens, pos):
        return M.decode_step(params, cache, tokens, pos, cfg)

    jitted = jax.jit(
        decode_fn,
        in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,) if donate else (),
    )
    abstract_args = (
        abstract(param_specs_tree),
        abstract(in_specs["cache"]),
        abstract(in_specs["tokens"]),
        abstract(in_specs["pos"]),
    )
    return jitted, param_sh, cache_sh, abstract_args


def make_step_for_shape(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules=None, **kw):
    """Dispatch on the shape's mode: train_step / prefill_step / serve_step."""
    if shape.mode == "train":
        jitted, _, _, args = make_train_step(cfg, shape, mesh, rules, **kw)
    elif shape.mode == "prefill":
        jitted, _, _, args = make_prefill_step(cfg, shape, mesh, rules)
    else:
        jitted, _, _, args = make_decode_step(cfg, shape, mesh, rules)
    return jitted, args
