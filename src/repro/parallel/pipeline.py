"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

The layer stack [L, ...] is split into S contiguous stages (stage s owns
layers [s*L/S, (s+1)*L/S)). Execution runs inside ``shard_map`` over the
stage axis: every device holds only its stage's weights, activations move
stage->stage with ``jax.lax.ppermute`` (collective_permute on the wire — the
cheapest collective: one neighbor hop per microbatch per stage boundary).

Schedule: classic GPipe. M microbatches flow through S stages in M + S - 1
ticks; the bubble fraction is (S-1)/(M+S-1). Backward is obtained by JAX AD
through the scan + ppermute (ppermute's transpose is the reverse permute),
which reproduces the standard reverse-schedule wave.

This is an optional execution mode (``--mesh pp`` in the launcher): the
production 40-cell grid uses DP x TP (see DESIGN.md §5); PP becomes necessary
when layer weights no longer fit a TP group, and the same stage axis extends
to (pod, stage, data, model) at real scale.

API:
    pipeline_spmd(layer_fn, stacked, x_mb, mesh) -> y_mb
        layer_fn(lp, x) -> x        one layer's forward
        stacked: pytree, leaves [L, ...]
        x_mb:    [M, mb, S, D]      microbatched activations
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 moved shard_map out of experimental
    from jax import shard_map as _shard_map_mod  # type: ignore

    _shard_map = (
        _shard_map_mod.shard_map if hasattr(_shard_map_mod, "shard_map") else _shard_map_mod
    )
except Exception:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore

import inspect as _inspect

# the replication-check kwarg was renamed check_rep -> check_vma in jax 0.7
_CHECK_KW = (
    "check_vma"
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_replication: bool = True):
    kw = {_CHECK_KW: check_replication}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def split_stages(stacked, n_stages: int):
    """[L, ...] leaves -> [S, L/S, ...] (stage-major) for stage sharding."""

    def f(a):
        L = a.shape[0]
        assert L % n_stages == 0, f"{L} layers not divisible into {n_stages} stages"
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])

    return jax.tree.map(f, stacked)


def pipeline_spmd(layer_fn, stacked, x_mb: jnp.ndarray, mesh: Mesh, axis: str = "stage"):
    """Run x_mb [M, mb, ...] through the stage-split stack. Returns [M, mb, ...].

    Correctness contract (tested): equals the sequential application of all L
    layers to each microbatch, for forward AND gradients.
    """
    S = mesh.shape[axis]
    M = x_mb.shape[0]
    staged = split_stages(stacked, S)  # [S, L/S, ...]

    def per_stage(stage_params, xs):
        # stage_params: [1, L/S, ...] (this stage's slice); xs: [M, mb, ...]
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        idx = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)
        outs = jnp.zeros_like(xs)

        def apply_stage(x):
            def body(c, lp):
                return layer_fn(lp, c), None

            y, _ = jax.lax.scan(body, x, stage_params)
            return y

        fwd = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            state, outs = carry
            # stage 0 ingests microbatch t while t < M
            take = jnp.clip(t, 0, M - 1)
            inject = jnp.where((idx == 0) & (t < M), 1.0, 0.0).astype(xs.dtype)
            keep = jnp.where(idx == 0, 0.0, 1.0).astype(xs.dtype)
            state = inject * xs[take] + keep * state
            state = apply_stage(state)
            # last stage emits microbatch t - (S-1) when valid
            out_i = jnp.clip(t - (S - 1), 0, M - 1)
            emit = ((idx == S - 1) & (t >= S - 1)).astype(xs.dtype)
            outs = jax.lax.dynamic_update_slice(
                outs,
                (emit * state + (1 - emit) * jax.lax.dynamic_slice(
                    outs, (out_i,) + (0,) * len(mb_shape), (1,) + mb_shape
                )[0])[None],
                (out_i,) + (0,) * len(mb_shape),
            )
            # hand activations to the next stage
            state = jax.lax.ppermute(state, axis, fwd)
            return (state, outs), None

        (state, outs), _ = jax.lax.scan(tick, (state, outs), jnp.arange(M + S - 1))
        # outputs live on the last stage; broadcast to every stage so the
        # caller (loss on replicated head) sees the full tensor
        outs = jax.lax.psum(jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    in_specs = (
        jax.tree.map(lambda _: P(axis), staged),
        P(),  # microbatches replicated across stages
    )
    fn = shard_map(per_stage, mesh=mesh, in_specs=in_specs, out_specs=P(), check_replication=False)
    return fn(staged, x_mb)


def make_pp_mesh(n_stages: int = 4, data: int = 1):
    """(stage, data) mesh for the pipeline execution mode."""
    return jax.make_mesh((n_stages, data), ("stage", "data"))


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
