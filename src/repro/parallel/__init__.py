from repro.parallel.rules import (  # noqa: F401
    DEFAULT_RULES,
    constraint,
    named_sharding,
    partition_spec,
    use_mesh_rules,
)
