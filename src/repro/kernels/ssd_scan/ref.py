"""Pure-jnp oracles for the Mamba2 SSD scan.

Two formulations:
- ``ssd_recurrent``: the literal per-step recurrence (ground truth; O(T) scan)
- ``ssd_chunked``:   the chunked/state-passing formulation (identical math,
                     the layout the Pallas kernel implements; also the XLA
                     model path used by models/mamba2.py)

Semantics (SSD, Dao & Gu 2024):
    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t (outer) x_t
    y_t = C_t @ S_t + D_h * x_t
with multi-head x (heads H, head dim P) and grouped B/C (groups G, state N).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(bm: jnp.ndarray, H: int) -> jnp.ndarray:
    """[B,T,G,N] -> [B,T,H,N] by repeating each group over its heads."""
    G = bm.shape[2]
    return jnp.repeat(bm, H // G, axis=2)


def ssd_recurrent(
    x: jnp.ndarray,  # [B, T, H, P]
    dt: jnp.ndarray,  # [B, T, H] (positive)
    A: jnp.ndarray,  # [H] (negative)
    bm: jnp.ndarray,  # [B, T, G, N]
    cm: jnp.ndarray,  # [B, T, G, N]
    D: jnp.ndarray,  # [H]
    initial_state: jnp.ndarray | None = None,  # [B, H, N, P]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,T,H,P], final_state [B,H,N,P])."""
    B, T, H, P = x.shape
    N = bm.shape[-1]
    bm_h = _expand_groups(bm, H)
    cm_h = _expand_groups(cm, H)
    S0 = initial_state if initial_state is not None else jnp.zeros((B, H, N, P), jnp.float32)

    def step(S, inp):
        x_t, dt_t, b_t, c_t = inp  # [B,H,P], [B,H], [B,H,N], [B,H,N]
        decay = jnp.exp(dt_t * A)[..., None, None]  # [B,H,1,1]
        inject = (dt_t[..., None, None] * b_t[..., :, None]) * x_t[..., None, :]  # [B,H,N,P]
        S = decay * S + inject
        y_t = jnp.einsum("bhn,bhnp->bhp", c_t, S)
        return S, y_t

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(bm_h, 1, 0),
        jnp.moveaxis(cm_h, 1, 0),
    )
    S_final, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1) + D[None, None, :, None] * x
    return y.astype(x.dtype), S_final


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    bm: jnp.ndarray,
    cm: jnp.ndarray,
    D: jnp.ndarray,
    chunk: int = 128,
    initial_state: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD: intra-chunk (quadratic in chunk) + sequential state pass."""
    B, T, H, P = x.shape
    N = bm.shape[-1]
    assert T % chunk == 0, f"T={T} not divisible by chunk={chunk}"
    nc = T // chunk
    bm_h = _expand_groups(bm, H)
    cm_h = _expand_groups(cm, H)

    # [B, nc, L, H, ...] views
    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    bc = bm_h.reshape(B, nc, chunk, H, N)
    cc = cm_h.reshape(B, nc, chunk, H, N)

    a = dtc * A[None, None, None, :]  # [B,nc,L,H] (negative)
    cum = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk
    total = cum[:, :, -1:, :]  # [B,nc,1,H]

    # intra-chunk: scores[i,j] = (c_i . b_j) * exp(cum_i - cum_j) * dt_j, j <= i
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,L,L,H]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    decay_mat = jnp.where(causal, jnp.exp(seg), 0.0)
    scores = jnp.einsum("bclhn,bcmhn->bclmh", cc, bc) * decay_mat * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", scores, xc)

    # state contribution of each chunk: S_c = sum_j exp(total - cum_j) dt_j b_j (x) x_j
    w = jnp.exp(total - cum) * dtc  # [B,nc,L,H]
    S_chunk = jnp.einsum("bclh,bclhn,bclhp->bchnp", w, bc, xc)  # [B,nc,H,N,P]
    chunk_decay = jnp.exp(total[:, :, 0, :])  # [B,nc,H]

    # sequential pass of states across chunks
    S0 = initial_state if initial_state is not None else jnp.zeros((B, H, N, P), jnp.float32)

    def pass_state(S, inp):
        s_c, dec = inp  # [B,H,N,P], [B,H]
        S_in = S  # state entering this chunk
        S = dec[..., None, None] * S + s_c
        return S, S_in

    S_final, S_enter = jax.lax.scan(
        pass_state, S0, (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    S_enter = jnp.moveaxis(S_enter, 0, 1)  # [B,nc,H,N,P]

    # inter-chunk: y_i += exp(cum_i) * (c_i @ S_enter)
    y_inter = jnp.einsum("bclhn,bchnp->bclhp", cc * jnp.exp(cum)[..., None], S_enter)

    y = (y_intra + y_inter).reshape(B, T, H, P) + D[None, None, :, None] * x
    return y.astype(x.dtype), S_final


def ssd_decode_step(
    x: jnp.ndarray,  # [B, H, P] one token
    dt: jnp.ndarray,  # [B, H]
    A: jnp.ndarray,  # [H]
    b: jnp.ndarray,  # [B, G, N]
    c: jnp.ndarray,  # [B, G, N]
    D: jnp.ndarray,  # [H]
    state: jnp.ndarray,  # [B, H, N, P]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token state update for serving. Returns (y [B,H,P], new state)."""
    H = x.shape[1]
    G = b.shape[1]
    b_h = jnp.repeat(b, H // G, axis=1)
    c_h = jnp.repeat(c, H // G, axis=1)
    decay = jnp.exp(dt * A)[..., None, None]
    state = decay * state + (dt[..., None, None] * b_h[..., :, None]) * x[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", c_h, state) + D[None, :, None] * x
    return y.astype(x.dtype), state
