"""Public wrapper for the SSD scan: Pallas on TPU, interpret elsewhere,
chunked-jnp reference on demand (also the XLA model path).

Differentiability: pallas_call has no JVP rule, so the kernel path carries a
custom_vjp — fused kernel on the forward pass, backward by recomputation
through the chunked-jnp oracle (the flash-attention pattern: residuals are
the small primal inputs, never the O(T) intermediate states)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime as rt
from repro.kernels.ssd_scan import kernel as _k
from repro.kernels.ssd_scan import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _ssd_kernel_cvjp(x, dt, A, bm, cm, D, chunk):
    return _k.ssd_scan_pallas(x, dt, A, bm, cm, D, chunk=chunk, interpret=not rt.on_tpu())


def _ssd_fwd(x, dt, A, bm, cm, D, chunk):
    out = _ssd_kernel_cvjp(x, dt, A, bm, cm, D, chunk)
    return out, (x, dt, A, bm, cm, D)


def _ssd_bwd(chunk, res, cts):
    x, dt, A, bm, cm, D = res
    _, vjp = jax.vjp(lambda *a: _ref.ssd_chunked(*a, chunk=chunk), x, dt, A, bm, cm, D)
    return vjp(cts)


_ssd_kernel_cvjp.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(
    x: jnp.ndarray,  # [B, T, H, P]
    dt: jnp.ndarray,  # [B, T, H]
    A: jnp.ndarray,  # [H]
    bm: jnp.ndarray,  # [B, T, G, N]
    cm: jnp.ndarray,  # [B, T, G, N]
    D: jnp.ndarray,  # [H]
    chunk: int = 128,
    force_reference: bool = False,
    initial_state: jnp.ndarray | None = None,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,T,H,P], final_state [B,H,N,P]).

    Dispatch: Pallas kernel on TPU; chunked-jnp reference elsewhere (same
    algorithm — the dry-run HLO then reflects the real chunked dataflow, not
    the interpret-mode emulation). Tests pass interpret=True to execute the
    kernel body on CPU for correctness sweeps."""
    T = x.shape[1]
    pad = (-T) % chunk
    if pad:
        # zero-pad to a chunk multiple. Padded steps use dt=0 so the decay is
        # exp(0)=1 and the injection is 0 — the carried state is exact.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # the kernel always starts from S=0; prefills with a carried state (rare)
    # are a reference-only feature, folded into force_reference here
    force_reference = force_reference or initial_state is not None
    if rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE:
        y, s = _ref.ssd_chunked(x, dt, A, bm, cm, D, chunk=chunk, initial_state=initial_state)
    else:
        y, s = _ssd_kernel_cvjp(x, dt, A, bm, cm, D, chunk)
    return (y[:, :T] if pad else y), s
