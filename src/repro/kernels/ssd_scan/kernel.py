"""Chunked SSD (Mamba2) scan — Pallas TPU kernel.

Applies the paper's locality methodology to the SSD recurrence: the (N, P)
state stays resident in VMEM across the whole sequence (the "BRAM-resident
hidden state"), chunks stream through one DMA at a time, and all heavy math is
MXU matmuls over (L, N) / (L, L) / (L, P) tiles with L = chunk = 128.

Grid = (B, H, n_chunks); the chunk dimension is innermost/sequential
(ARBITRARY), batch x head are PARALLEL, so each (b, h) pair completes its
state pass with the same scratch buffer (re-initialized at chunk 0).

The in-chunk cumulative decay is computed with a lower-triangular ones matmul
(MXU) instead of lax.cumsum — Mosaic-friendly and contributes negligible
FLOPs at L=128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime as rt


def _ssd_chunk_kernel(
    x_ref,  # [1, L, 1, P]
    dt_ref,  # [1, L, 1]
    a_ref,  # [1, 1]  A[h]
    b_ref,  # [1, L, 1, N]
    c_ref,  # [1, L, 1, N]
    d_ref,  # [1, 1]  D[h]
    y_ref,  # [1, L, 1, P] out
    s_out_ref,  # [1, 1, N, P] out (final state; persists via constant index map)
    s_scr,  # VMEM [N, P] f32 — resident state
    *,
    chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    f32 = jnp.float32
    L = chunk
    x = x_ref[0, :, 0, :].astype(f32)  # [L, P]
    dt = dt_ref[0, :, 0].astype(f32)  # [L]
    bm = b_ref[0, :, 0, :].astype(f32)  # [L, N]
    cm = c_ref[0, :, 0, :].astype(f32)  # [L, N]
    A = a_ref[0, 0]
    Dh = d_ref[0, 0]

    a = dt * A  # [L] negative
    # inclusive cumsum via lower-triangular matmul (MXU, Mosaic-safe)
    tril = jnp.tril(jnp.ones((L, L), f32))
    cum = jax.lax.dot_general(tril, a[:, None], (((1,), (0,)), ((), ())),
                              preferred_element_type=f32)[:, 0]  # [L]
    total = cum[L - 1]

    # intra-chunk attention-like term
    seg = cum[:, None] - cum[None, :]  # [L, L]
    causal = jnp.tril(jnp.ones((L, L), jnp.bool_))
    decay_mat = jnp.where(causal, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32)  # [L, L] c_i . b_j
    scores = scores * decay_mat * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)  # [L, P]

    # inter-chunk: contribution of the state entering this chunk
    s_in = s_scr[...]
    c_dec = cm * jnp.exp(cum)[:, None]  # [L, N]
    y = y + jax.lax.dot_general(c_dec, s_in, (((1,), (0,)), ((), ())), preferred_element_type=f32)

    # state update: S = exp(total) * S_in + sum_j exp(total - cum_j) dt_j b_j (x) x_j
    w = jnp.exp(total - cum) * dt  # [L]
    bw = bm * w[:, None]  # [L, N]
    s_new = jnp.exp(total) * s_in + jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )  # [N, P]
    s_scr[...] = s_new

    y_ref[0, :, 0, :] = (y + Dh * x).astype(y_ref.dtype)
    s_out_ref[0, 0, :, :] = s_new  # last chunk's write is the final state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jnp.ndarray,  # [B, T, H, P]
    dt: jnp.ndarray,  # [B, T, H] positive
    A: jnp.ndarray,  # [H] negative
    bm: jnp.ndarray,  # [B, T, G, N]
    cm: jnp.ndarray,  # [B, T, G, N]
    D: jnp.ndarray,  # [H]
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,T,H,P], final_state [B,H,N,P])."""
    B, T, H, P = x.shape
    G, N = bm.shape[2], bm.shape[3]
    assert T % chunk == 0, f"T={T} % chunk={chunk} != 0"
    nc = T // chunk
    kernel = functools.partial(_ssd_chunk_kernel, chunk=chunk)

    grid = (B, H, nc)
    y, s_final = rt.pallas_call_compat(
        kernel,
        grid=grid,
        in_specs=[
            ((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            ((1, chunk, 1), lambda b, h, c: (b, c, h)),
            ((1, 1), lambda b, h, c: (h, 0)),
            ((1, chunk, 1, N), lambda b, h, c: (b, c, h * G // H, 0)),
            ((1, chunk, 1, N), lambda b, h, c: (b, c, h * G // H, 0)),
            ((1, 1), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            ((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            ((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[((N, P), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="ssd_scan",
    )(x, dt, A.reshape(-1, 1), bm, cm, D.reshape(-1, 1))
    return y, s_final
