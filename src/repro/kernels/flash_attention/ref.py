"""Dense-softmax oracle for the flash attention kernel (GQA, causal, SWA)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_reference(
    q: jnp.ndarray,  # [B, QH, Sq, Dh]
    k: jnp.ndarray,  # [B, KH, Sk, Dh]
    v: jnp.ndarray,  # [B, KH, Sk, Dh]
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """q_offset: absolute position of q[0] (for decode/chunked prefill)."""
    B, QH, Sq, Dh = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    group = QH // KH
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v).astype(q.dtype)
