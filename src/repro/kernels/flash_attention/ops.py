"""Public wrapper: Pallas flash attention on TPU, jnp reference elsewhere.

Differentiability: custom_vjp — fused kernel forward, backward recomputes
attention through the jnp oracle (flash-style: residuals are q/k/v only;
the O(S^2) score matrix is never materialized on the forward pass).
Tests pass interpret=True to execute the kernel body on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime as rt
from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fa_kernel_cvjp(q, k, v, causal, window, q_offset, block_q, block_k):
    return _k.flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        interpret=not rt.on_tpu(),
    )


def _fa_fwd(q, k, v, causal, window, q_offset, block_q, block_k):
    return _fa_kernel_cvjp(q, k, v, causal, window, q_offset, block_q, block_k), (q, k, v)


def _fa_bwd(causal, window, q_offset, block_q, block_k, res, ct):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: _ref.attention_reference(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        ),
        q,
        k,
        v,
    )
    return vjp(ct)


_fa_kernel_cvjp.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(
    q: jnp.ndarray,  # [B, S, QH, Dh] — model layout
    k: jnp.ndarray,  # [B, S, KH, Dh]
    v: jnp.ndarray,  # [B, S, KH, Dh]
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    force_reference: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Public API takes the model layout [B, S, H, D]; the kernel and its
    oracle work head-major [B, H, S, D] (grid = batch x head x blocks)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE:
        out = _ref.attention_reference(qt, kt, vt, causal=causal, window=window, q_offset=q_offset)
    else:
        out = _fa_kernel_cvjp(qt, kt, vt, causal, window, q_offset, block_q, block_k)
    return jnp.swapaxes(out, 1, 2)
