"""Blockwise (flash) attention forward — Pallas TPU kernel.

Same co-design methodology as the GRU kernel, applied to the prefill
hot-spot: KV blocks stream through VMEM while the online-softmax accumulator
(acc, m, l) stays resident in VMEM scratch — the II~=1 "accumulate every
cycle" structure of the paper, with HBM traffic O(S) per query block instead
of the O(S^2) score materialization of the naive path.

Grid = (B, QH, num_q_blocks, num_kv_blocks); kv innermost (ARBITRARY) so the
scratch accumulator carries across kv blocks for one (b, h, q-block).
GQA is handled in the index map (kv head = q head * KH // QH). Causal and
sliding-window masks are applied per-element inside the block; fully-masked
blocks produce exp(-inf)=0 contributions and are skipped via pl.when on the
block-level bound.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime as rt

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, 1, bq, Dh]
    k_ref,  # [1, 1, bk, Dh]
    v_ref,  # [1, 1, bk, Dh]
    o_ref,  # [1, 1, bq, Dh]
    acc_scr,  # VMEM [bq, Dh] f32
    m_scr,  # VMEM [bq, 1] f32
    l_scr,  # VMEM [bq, 1] f32
    *,
    bq: int,
    bk: int,
    causal: bool,
    window: int | None,
    q_offset: int,
    num_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q_start = iq * bq + q_offset
    k_start = ik * bk

    # block-level relevance: skip fully-masked kv blocks (causal: block starts
    # after the last query; window: block ends before the window's left edge)
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window is not None:
        relevant &= (k_start + bk - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        f32 = jnp.float32
        q = q_ref[0, 0].astype(f32)
        k = k_ref[0, 0].astype(f32)
        v = v_ref[0, 0].astype(f32)
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], f32))
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32) * scale  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=f32
        )
        m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [B, QH, Sq, Dh]
    k: jnp.ndarray,  # [B, KH, Sk, Dh]
    v: jnp.ndarray,  # [B, KH, Sk, Dh]
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, QH, Sq, Dh = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    kernel = functools.partial(
        _flash_kernel,
        bq=bq,
        bk=bk,
        causal=causal,
        window=window,
        q_offset=q_offset,
        num_kv_blocks=nk,
    )
    return rt.pallas_call_compat(
        kernel,
        grid=(B, QH, nq, nk),
        in_specs=[
            ((1, 1, bq, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            ((1, 1, bk, Dh), lambda b, h, iq, ik: (b, h * KH // QH, ik, 0)),
            ((1, 1, bk, Dh), lambda b, h, iq, ik: (b, h * KH // QH, ik, 0)),
        ],
        out_specs=((1, 1, bq, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, QH, Sq, Dh), q.dtype),
        scratch_shapes=[
            ((bq, Dh), jnp.float32),
            ((bq, 1), jnp.float32),
            ((bq, 1), jnp.float32),
        ],
        dimension_semantics=(rt.PARALLEL, rt.PARALLEL, rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
