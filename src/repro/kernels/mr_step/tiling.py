"""VMEM residency model + batch-tile (``block_b``) auto-selection.

One model of what the stage-fused ``mr_step`` kernel pins in VMEM — gate
weights, head weights, the per-tile activation blocks, PWL tables when int8
— shared by two consumers:

- ``benchmarks/bench_stagemap._vmem_bytes`` (the paper Table 7 analogue)
  delegates here, so the design-space sweep and the runtime tiling decision
  can never disagree about residency;
- ``repro.api.compile_plan`` resolves ``RecoverySpec.block_b="auto"`` by
  walking the divisor tiles of the batch and picking the largest one whose
  residency fits the configured VMEM budget (the ROADMAP "pick block_b from
  ``_vmem_bytes`` against the VMEM budget" item). Without a budget the full
  batch is used — the pre-auto behaviour.

The numbers mirror the kernel's actual BlockSpecs (kernel.py): weights are
resident across the whole grid, activations are tiled by ``block_b`` rows.
"""

from __future__ import annotations

# ~16 MB of VMEM per TPU core (v4/v5 family); the auto policy budgets
# against a caller-supplied fraction of this, never the constant directly.
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024


def vmem_bytes(
    B: int,
    D: int,
    H: int,
    Dh: int = 128,
    K: int = 32,
    *,
    int8: bool,
    n_seg: int,
    block_b: int,
    fused: bool = True,
) -> int:
    """Exact VMEM residency of the fused kernel's BlockSpecs (kernel.py).

    ``block_b=0`` means the full batch is one tile. ``fused=False`` models
    the bare gru_scan kernel (no head residency) — the configuration the
    unfused two-dispatch pipeline runs.
    """
    wbytes = 1 if int8 else 4
    bb = block_b or B
    vm = (D * 3 * H + H * 3 * H) * wbytes  # resident gate weights
    vm += 3 * H * 4 * (3 if int8 else 1)  # bias (+2 scale rows when int8)
    vm += bb * D * 4 + bb * H * 4 * 2  # x_t block + h scratch + h_t/out tile
    vm += H * 4 + 4  # time_scale + dt
    if int8:
        vm += 2 * 2 * n_seg * 4  # sigmoid/tanh PWL tables (slopes+intercepts)
    if fused:
        # head weights are VMEM-resident next to the gate weights
        vm += (H * Dh + Dh * K) * wbytes  # w1 + w2
        vm += (Dh + K) * 4  # b1 + b2
        vm += bb * K * 4  # out tile (theta ++ shifts)
        if int8:
            vm += (Dh + K) * 4  # per-channel dequant scale rows
    return vm


def config_vmem_bytes(cfg, batch: int, *, block_b: int | None = None, n_seg: int = 16) -> int:
    """Residency of the fused stage for one ``MRConfig`` at a given batch."""
    return vmem_bytes(
        batch,
        cfg.state_dim + cfg.input_dim,
        cfg.hidden,
        cfg.dense_hidden,
        cfg.n_coef + cfg.n_shifts,
        int8=cfg.quant is not None,
        n_seg=n_seg,
        block_b=block_b or 0,
    )


def auto_block_b(
    cfg,
    batch: int | None,
    vmem_budget_bytes: int | None,
    *,
    min_block: int = 8,
    n_seg: int = 16,
) -> int | None:
    """Largest batch tile whose fused-stage residency fits the VMEM budget.

    Walks the proper divisors of ``batch`` from largest to smallest (down to
    ``min_block``) — the tile must divide the batch exactly (kernel.py
    asserts ``B % block_b == 0``) — and returns the first one that fits.
    ``None`` (= full batch, no tiling) when no budget is configured OR the
    batch is unknown at compile time OR the full batch already fits; the
    smallest legal divisor when nothing fits, so a too-tight budget degrades
    to maximum tiling instead of failing.
    """
    if vmem_budget_bytes is None or batch is None:
        return None  # documented fallback: full batch
    if config_vmem_bytes(cfg, batch, block_b=None, n_seg=n_seg) <= vmem_budget_bytes:
        return None
    divisors = [d for d in range(min_block, batch) if batch % d == 0]
    for bb in reversed(divisors):
        if config_vmem_bytes(cfg, batch, block_b=bb, n_seg=n_seg) <= vmem_budget_bytes:
            return bb  # largest fitting divisor: first hit walking downward
    # nothing fits: the smallest legal tile is the best we can do
    return divisors[0] if divisors else None
