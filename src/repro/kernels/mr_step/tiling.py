"""VMEM residency model + batch-tile (``block_b``) auto-selection.

One model of what the stage-fused ``mr_step`` kernels pin in VMEM — encoder
weights, head weights, the per-tile activation blocks, the per-substep
working set of the multi-substep cells, PWL tables when int8 — shared by
two consumers:

- ``benchmarks/bench_stagemap._vmem_bytes`` (the paper Table 7 analogue)
  delegates here, so the design-space sweep and the runtime tiling decision
  can never disagree about residency;
- ``repro.api.compile_plan`` resolves ``RecoverySpec.block_b="auto"`` by
  walking the divisor tiles of the batch and picking the largest one whose
  residency fits the VMEM budget (the ROADMAP "pick block_b from
  ``_vmem_bytes`` against the VMEM budget" item). The budget is the spec's
  explicit ``vmem_budget_bytes`` when given, else :func:`detect_vmem_budget`
  resolves it from the local device (platform table + ``memory_stats()``
  when the runtime exposes a VMEM figure).

``config_vmem_bytes`` dispatches on the encoder family: the GRU(-flow)
model (``vmem_bytes``), the LTC fused-solver model (``ltc_vmem_bytes``) or
the NODE/ODE-RNN model (``node_vmem_bytes``). The numbers mirror each
kernel's actual BlockSpecs (kernel.py): weights are resident across the
whole grid, activations are tiled by ``block_b`` rows, and the substep
loops REUSE their temporaries (residency is substep-count-invariant — the
kernels unroll the loop over one working set, they do not allocate K
copies).
"""

from __future__ import annotations

# ~16 MB of VMEM per TPU core (v4/v5 family); the auto policy budgets
# against a fraction of this, never the constant directly.
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024

# Conservative fraction of raw VMEM the auto tile may claim: Mosaic
# double-buffers the streamed x_t blocks and needs headroom for spills, so
# budgeting the full physical size would thrash.
VMEM_BUDGET_FRACTION = 0.5

# device_kind substring -> VMEM bytes/core (first match wins, checked in
# order). Every currently-shipping TPU core carries 16 MiB of VMEM except
# Trillium-class parts; unknown kinds (CPU hosts, GPUs) fall back to the
# v4/v5 figure so CPU CI resolves the same budget a v5e deployment would.
PLATFORM_VMEM_BYTES: tuple[tuple[str, int], ...] = (
    ("v6", 32 * 1024 * 1024),  # Trillium
    ("v5", VMEM_BYTES_PER_CORE),
    ("v4", VMEM_BYTES_PER_CORE),
)


def resolve_vmem_budget(device=None, *, fraction: float = VMEM_BUDGET_FRACTION) -> tuple[int, str]:
    """(budget bytes, source) for the local accelerator's fused-stage VMEM.

    Resolution order: ``device.memory_stats()``'s VMEM figure when the
    runtime exposes one (source ``"memory_stats"``), else the platform table
    keyed on ``device_kind`` (source ``"platform:<key>"``), else the v4/v5
    default (source ``"default"``). The result is ``fraction`` of the raw
    size (headroom for Mosaic double-buffering). Deterministic on CPU: no
    entry matches, so the default applies. The source string lands in
    ``plan.lowering.vmem_budget_source`` so an R2 residency finding is
    attributable to the budget that produced the tile.
    """
    import jax

    if device is None:
        devices = jax.local_devices()
        device = devices[0] if devices else None
    size, source = None, "default"
    if device is not None:
        stats_fn = getattr(device, "memory_stats", None)
        if callable(stats_fn):
            try:
                stats = stats_fn() or {}
            except Exception:  # backends without stats raise, not return {}
                stats = {}
            size = stats.get("vmem_size_bytes")
            if size is not None:
                source = "memory_stats"
        if size is None:
            kind = (getattr(device, "device_kind", "") or "").lower()
            for key, nbytes in PLATFORM_VMEM_BYTES:
                if key in kind:
                    size, source = nbytes, f"platform:{key}"
                    break
    if size is None:
        size = VMEM_BYTES_PER_CORE
    return int(size * fraction), source


def detect_vmem_budget(device=None, *, fraction: float = VMEM_BUDGET_FRACTION) -> int:
    """Usable fused-stage VMEM budget in bytes (see resolve_vmem_budget)."""
    return resolve_vmem_budget(device, fraction=fraction)[0]


# Per-family tolerance bands for the R2 residency audit (analysis/rules.py):
# the parsed per-input-step traffic of the compiled fused stage, divided by
# this model's predicted residency, must land inside [lo, hi]. The bands are
# wide on purpose — the CPU lowering re-streams weights per scan trip where
# the kernel holds them resident, and the NODE field does two H x H mats per
# Euler substep — so they catch an order-of-magnitude model drift (a new
# resident buffer the model misses, a dropped term) without flaking on
# backend lowering details. Measured per-step ratios on CPU jax 0.4.37:
# gru 1.40, ltc 1.34, node 3.25.
RESIDENCY_BANDS: dict[str, tuple[float, float]] = {
    "gru": (0.25, 8.0),
    "ltc": (0.25, 8.0),
    "node": (0.25, 16.0),
}


def residency_tolerance(family: str) -> tuple[float, float]:
    """(lo, hi) acceptance band for parsed-per-step/predicted residency."""
    return RESIDENCY_BANDS.get(family, RESIDENCY_BANDS["gru"])


# R2 band for a MEASURED-tuned plan: the tuner stamped the parsed per-step
# traffic of the chosen candidate's own compiled HLO into
# ``plan.lowering.measured_bytes``, so the audit re-measures against that
# figure instead of the static residency model. Self-consistency of two
# parses of the same program tolerates only lowering drift (batch geometry of
# the audited program vs the tuned one), hence much tighter than the
# per-family model bands above.
TUNED_RESIDENCY_BAND: tuple[float, float] = (0.5, 2.0)


def vmem_bytes(
    B: int,
    D: int,
    H: int,
    Dh: int = 128,
    K: int = 32,
    *,
    int8: bool,
    n_seg: int,
    block_b: int,
    fused: bool = True,
) -> int:
    """Exact VMEM residency of the fused kernel's BlockSpecs (kernel.py).

    ``block_b=0`` means the full batch is one tile. ``fused=False`` models
    the bare gru_scan kernel (no head residency) — the configuration the
    unfused two-dispatch pipeline runs.
    """
    wbytes = 1 if int8 else 4
    bb = block_b or B
    vm = (D * 3 * H + H * 3 * H) * wbytes  # resident gate weights
    vm += 3 * H * 4 * (3 if int8 else 1)  # bias (+2 scale rows when int8)
    vm += bb * D * 4 + bb * H * 4 * 2  # x_t block + h scratch + h_t/out tile
    vm += H * 4 + 4  # time_scale + dt
    if int8:
        vm += 2 * 2 * n_seg * 4  # sigmoid/tanh PWL tables (slopes+intercepts)
    if fused:
        # head weights are VMEM-resident next to the gate weights
        vm += (H * Dh + Dh * K) * wbytes  # w1 + w2
        vm += (Dh + K) * 4  # b1 + b2
        vm += bb * K * 4  # out tile (theta ++ shifts)
        if int8:
            vm += (Dh + K) * 4  # per-channel dequant scale rows
    return vm


def _head_vmem_bytes(H: int, Dh: int, K: int, bb: int, *, int8: bool) -> int:
    """Head-stage residency shared by every fused variant (see vmem_bytes)."""
    wbytes = 1 if int8 else 4
    vm = (H * Dh + Dh * K) * wbytes  # w1 + w2, resident
    vm += (Dh + K) * 4  # b1 + b2
    vm += bb * K * 4  # out tile (theta ++ shifts)
    if int8:
        vm += (Dh + K) * 4  # per-channel dequant scale rows
    return vm


def ltc_vmem_bytes(
    B: int,
    D: int,
    H: int,
    Dh: int = 128,
    K: int = 32,
    *,
    int8: bool,
    n_seg: int,
    block_b: int,
    n_substeps: int = 6,
) -> int:
    """VMEM residency of the fused multi-substep LTC kernel's BlockSpecs.

    ``n_substeps`` does NOT scale the residency: the unrolled substep loop
    reuses one [bb, H] working set (drive is loop-invariant, f/num/den are
    rewritten every substep) — which is exactly why the fused variant fits
    where K separate XLA substep dispatches would each re-stream operands.
    """
    del n_substeps  # residency is substep-count-invariant (see docstring)
    wbytes = 1 if int8 else 4
    bb = block_b or B
    vm = (D * H + H * H) * wbytes  # w_in + w_rec, resident
    vm += 3 * H * 4  # bias + a + inv_tau rows
    if int8:
        vm += 2 * H * 4  # per-channel dequant scale rows (w_in, w_rec)
        vm += 2 * n_seg * 4  # sigmoid PWL table (slopes + intercepts)
    vm += bb * D * 4  # x_t block
    vm += bb * H * 4 * 2  # h scratch + the per-substep drive/f working set
    vm += _head_vmem_bytes(H, Dh, K, bb, int8=int8)
    return vm


def node_vmem_bytes(
    B: int,
    D: int,
    H: int,
    Dh: int = 128,
    K: int = 32,
    *,
    block_b: int,
    n_substeps: int = 6,
) -> int:
    """VMEM residency of the fused multi-substep NODE (ODE-RNN) kernel.

    fp32 only (no int8 variant: the tanh-MLP vector field has no PWL
    serving mapping). Substep temporaries are reused (see ltc_vmem_bytes).
    """
    del n_substeps
    vm = (2 * H * H + D * H) * 4  # w_f1 + w_f2 + w_in, resident
    vm += 3 * H * 4  # b_f1 + b_f2 + b_in rows
    bb = block_b or B
    vm += bb * D * 4  # x_t block
    vm += bb * H * 4 * 2  # h scratch + the per-substep z working set
    vm += _head_vmem_bytes(H, Dh, K, bb, int8=False)
    return vm


def _encoder_family(name: str) -> str:
    """The mr_step kernel family a registry row lowers to (see EncoderSpec)."""
    from repro.core import encoders

    try:
        return encoders.get_encoder(name).family
    except ValueError:
        return "gru"  # unregistered name: the model the default rows use


def config_vmem_bytes(cfg, batch: int, *, block_b: int | None = None, n_seg: int = 16) -> int:
    """Residency of the fused stage for one ``MRConfig`` at a given batch.

    Dispatches on the registry row's ``family`` — the SAME field
    ``kernels/mr_step/ops.py`` dispatches the kernels on — so
    ``block_b="auto"`` budgets against the variant the config actually
    lowers to.
    """
    family = _encoder_family(cfg.encoder)
    D = cfg.state_dim + cfg.input_dim
    K = cfg.n_coef + cfg.n_shifts
    if family == "ltc":
        return ltc_vmem_bytes(
            batch,
            D,
            cfg.hidden,
            cfg.dense_hidden,
            K,
            int8=cfg.quant is not None,
            n_seg=n_seg,
            block_b=block_b or 0,
            n_substeps=cfg.ltc_substeps,
        )
    if family == "node":
        return node_vmem_bytes(
            batch,
            D,
            cfg.hidden,
            cfg.dense_hidden,
            K,
            block_b=block_b or 0,
            n_substeps=cfg.ltc_substeps,
        )
    return vmem_bytes(
        batch,
        D,
        cfg.hidden,
        cfg.dense_hidden,
        K,
        int8=cfg.quant is not None,
        n_seg=n_seg,
        block_b=block_b or 0,
    )


def block_b_candidates(batch: int | None, *, min_block: int = 8) -> list[int | None]:
    """Every legal batch tile for ``batch``, largest residency first.

    The SHARED candidate enumeration behind both lowering paths: the static
    heuristic (:func:`auto_block_b`) and the measured-cost autotuner
    (``analysis/tuner.py``) walk this exact list, so the two can never
    disagree about which tiles exist. ``None`` (full batch, no tiling) leads;
    the proper divisors >= ``min_block`` follow in descending order; divisors
    BELOW ``min_block`` trail as a degraded tail — they are legal (kernel.py
    only asserts divisibility) but waste lane occupancy, so they are only
    reached when nothing larger exists (the non-power-of-two batches whose
    divisor ladder skips the [min_block, batch) range entirely, e.g.
    batch=12 with min_block=8).
    """
    if batch is None or batch < 1:
        return [None]
    preferred = [d for d in range(batch - 1, min_block - 1, -1) if batch % d == 0]
    degraded = [d for d in range(min(min_block, batch) - 1, 0, -1) if batch % d == 0]
    return [None, *preferred, *degraded]


def auto_block_b(
    cfg,
    batch: int | None,
    vmem_budget_bytes: int | None,
    *,
    min_block: int = 8,
    n_seg: int = 16,
) -> int | None:
    """Largest batch tile whose fused-stage residency fits the VMEM budget.

    Walks :func:`block_b_candidates` — full batch first, then the proper
    divisors of ``batch`` from largest to smallest (the tile must divide the
    batch exactly; kernel.py asserts ``B % block_b == 0``) — and returns the
    FIRST (largest) candidate that fits, so the choice is order-independent
    of how the divisors were generated. ``None`` (= full batch, no tiling)
    when no budget is configured OR the batch is unknown at compile time OR
    the full batch already fits. When nothing fits, the smallest enumerated
    tile is returned — including the sub-``min_block`` divisors of
    non-power-of-two batches (batch=12 has no divisor >= 8; the old walk
    returned None = full batch there even with the budget blown) — so a
    too-tight budget degrades to maximum tiling instead of failing.
    """
    if vmem_budget_bytes is None or batch is None:
        return None  # documented fallback: full batch
    candidates = block_b_candidates(batch, min_block=min_block)
    for bb in candidates:
        if config_vmem_bytes(cfg, batch, block_b=bb, n_seg=n_seg) <= vmem_budget_bytes:
            return bb  # largest fitting tile: first hit walking downward
    # nothing fits: maximum tiling — the smallest preferred divisor, or the
    # LARGEST degraded one (smaller only shrinks occupancy, not residency
    # headroom, once below min_block)
    preferred = [bb for bb in candidates if bb is not None and bb >= min_block]
    if preferred:
        return preferred[-1]
    degraded = [bb for bb in candidates if bb is not None]
    return degraded[0] if degraded else None


# ---------------------------------------------------------------------------
# banked one-kernel tick (kernels/mr_step/tick.py): slots-per-bank residency
# ---------------------------------------------------------------------------
# R2 acceptance band for the banked tick program: parsed per-window-step
# traffic of the compiled serve tick vs tick_vmem_bytes with every local
# slot resident (the CPU lowering re-streams the whole working set per scan
# trip). Wide for the same reason as RESIDENCY_BANDS; measured per-step
# ratios on CPU jax 0.4.37 (tiny audit-matrix shapes): 0.97 fp32 gru,
# 1.87 int8/PWL (dequant widens the parsed traffic vs the s8 residency).
TICK_RESIDENCY_BAND: tuple[float, float] = (0.25, 8.0)


def tick_vmem_bytes(cfg, scfg, *, slots_per_bank: int = 1, int8: bool = False, n_seg: int = 16) -> int:
    """VMEM residency of one ``mr_tick`` bank (tick.py BlockSpecs).

    Everything a bank pins at once: the slots' ring buffers (in + rolled
    out), the tick chunk, the materialized window set, the hidden state for
    all windows of the bank's slots, and the per-slot gate + head weights.
    Window count does scale the working set (all N windows of a slot run as
    one batch through the unrolled substeps), which is why the bank size is
    the budget knob compile_plan resolves.
    """
    n, m = cfg.state_dim, cfg.input_dim
    D, H, Dh = n + m, cfg.hidden, cfg.dense_hidden
    Ko = cfg.n_coef + cfg.n_shifts
    L, C, T, N = scfg.buf_len, scfg.chunk, scfg.window, scfg.n_windows
    wbytes = 1 if int8 else 4
    per_slot = L * (n + m) * 4 * 2  # ring buffer block in + rolled out
    per_slot += C * (n + m) * 4  # tick chunk
    per_slot += N * T * D * 4  # materialized window set
    per_slot += N * H * 4  # hidden state across the unrolled substeps
    per_slot += (D + H) * 3 * H * wbytes + 3 * H * 4  # gate weights + bias
    per_slot += H * 4  # time_scale (fp32) / spare scale row (int8)
    per_slot += (H * Dh + Dh * Ko) * wbytes + (Dh + Ko) * 4  # head weights
    per_slot += 2 * n * 4  # frozen mean/scale rows
    per_slot += cfg.n_coef * 4 * 2 + 3 * 4  # theta in/out + seed/active/delta
    if int8:
        per_slot += (2 * 3 * H + Dh + Ko) * 4  # per-channel dequant scales
    vm = slots_per_bank * per_slot
    if int8:
        vm += 2 * 2 * n_seg * 4  # shared sigmoid/tanh PWL tables
    return vm


def slots_per_bank_candidates(n_slots: int) -> list[int]:
    """Every legal bank size for ``n_slots``, largest residency first.

    The shared enumeration behind :func:`auto_slots_per_bank` and the
    measured-cost autotuner's tick-stage search (``analysis/tuner.py``):
    the divisors of ``n_slots`` from all-in-one-bank down to 1.
    """
    if n_slots < 1:
        return []
    return sorted((d for d in range(1, n_slots + 1) if n_slots % d == 0), reverse=True)


def auto_slots_per_bank(
    cfg, scfg, n_slots: int, vmem_budget_bytes: int | None, *, int8: bool = False
) -> int:
    """Largest divisor of ``n_slots`` whose banked-tick residency fits.

    Walks :func:`slots_per_bank_candidates` from largest (all slots in one
    bank — no grid streaming at all) down to 1; returns 0 when even a single
    slot's working set exceeds the budget — the caller (``compile_plan``
    resolving ``tick_kernel="auto"``) falls back to the composite tick then.
    With no budget configured the full slot set is one bank, mirroring
    auto_block_b.
    """
    if n_slots < 1:
        return 0
    if vmem_budget_bytes is None:
        return n_slots
    for bank in slots_per_bank_candidates(n_slots):
        if tick_vmem_bytes(cfg, scfg, slots_per_bank=bank, int8=int8) <= vmem_budget_bytes:
            return bank
    return 0
