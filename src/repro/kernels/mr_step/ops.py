"""Public jit'd wrappers for the stage-fused MR per-window step.

``mr_step`` is the fused replacement for merinda's encode -> RMS-norm ->
dense-head stage sequence; ``mr_step_int8`` is the fixed-point serving
variant (int8 gate AND head weights, PWL activations). Both resolve their
backend through kernels/runtime.resolve_dispatch — compiled Pallas kernel on
TPU, kernel body under the interpreter for CPU correctness sweeps, the
pure-JAX reference otherwise — so every consumer (engine epoch scan, stream
tick, serve_mr) shares one code path regardless of backend.

Gradients flow through a custom_vjp whose backward is the reference program
(same structure as kernels/gru_scan.ops), so the fused stage trains inside
the scan-jitted engine exactly like the unfused one.
"""

from __future__ import annotations

import functools as _functools

import jax
import jax.numpy as jnp

from repro.core import encoders
from repro.core.quant import make_sigmoid_table, make_tanh_table, quantize_int8
from repro.kernels import runtime as rt
from repro.kernels.mr_step import kernel as _k
from repro.kernels.mr_step import ref as _ref


@_functools.partial(jax.custom_vjp, nondiff_argnums=(11, 12, 13))
def _mr_step_cvjp(xs, h0, wx, wh, b, time_scale, dts, w1, b1, w2, b2, flow, act_bits, block_b):
    return _k.mr_step_pallas(
        xs,
        h0,
        wx,
        wh,
        b,
        time_scale,
        dts,
        w1,
        b1,
        w2,
        b2,
        flow=flow,
        act_bits=act_bits,
        block_b=block_b,
        interpret=not rt.on_tpu(),
    )


def _mr_fwd(xs, h0, wx, wh, b, time_scale, dts, w1, b1, w2, b2, flow, act_bits, block_b):
    out = _mr_step_cvjp(xs, h0, wx, wh, b, time_scale, dts, w1, b1, w2, b2, flow, act_bits, block_b)
    return out, (xs, h0, wx, wh, b, time_scale, dts, w1, b1, w2, b2)


def _mr_bwd(flow, act_bits, block_b, res, ct):
    _, vjp = jax.vjp(lambda *a: _ref.mr_step_reference(*a, flow=flow, act_bits=act_bits), *res)
    return vjp(ct)


_mr_step_cvjp.defvjp(_mr_fwd, _mr_bwd)


def _split_gru(params, cfg):
    """(wx, wh, b, time_scale) with the QAT weight fake-quant applied."""
    enc = encoders.quantized_gru_params(params.encoder, cfg)
    d_in = cfg.state_dim + cfg.input_dim
    return enc.w[:d_in], enc.w[d_in:], enc.b, enc.time_scale


def _head_weights(params, cfg):
    """(w1, b1, w2, b2) with the shared QAT weight treatment applied."""
    from repro.core.quant import qat_weight

    w1 = qat_weight(params.head_w1, cfg.quant)
    w2 = qat_weight(params.head_w2, cfg.quant)
    return w1, params.head_b1, w2, params.head_b2


def _fusable_spec(cfg, *, int8: bool) -> encoders.EncoderSpec:
    spec = encoders.get_encoder(cfg.encoder)
    if not spec.fusable:
        raise ValueError(
            f"fused mr_step supports the GRU encoder families, got {cfg.encoder!r} "
            f"(fusable: {[n for n in encoders.encoder_names() if encoders.get_encoder(n).fusable]})"
        )
    if int8 and spec.flow:
        raise ValueError(
            f"int8 mr_step requires encoder='gru' (standard cell, paper Eq. 12-15), "
            f"got {cfg.encoder!r}"
        )
    return spec


def _split_out(out, cfg):
    theta = out[..., : cfg.n_coef].reshape(out.shape[0], cfg.n_terms, cfg.state_dim)
    return theta, out[..., cfg.n_coef :]


def _legal_block_b(block_b: int | None, B: int) -> int | None:
    """Drop a tile the batch can't take. A plan resolves ``block_b`` against
    its COMPILE-TIME batch (e.g. the training minibatch), but the same config
    also serves full-window readouts whose batch differs; the kernel asserts
    ``B % block_b == 0``, so a non-dividing tile falls back to full batch
    here (a per-shape static decision — jit retraces per batch shape)."""
    return block_b if block_b and B % block_b == 0 else None


def mr_step(
    params,  # merinda.MRParams (GRU-family encoder)
    cfg,  # merinda.MRConfig
    xs: jnp.ndarray,  # [B, T, n+m] normalized (+ activation-quantized) windows
    dts: jnp.ndarray | None = None,
    block_b: int | None = None,
    force_reference: bool = False,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused per-window recovery stage. Returns (theta [B, n_terms, n], shifts [B, q]).

    Dispatch: Pallas kernel on TPU; reference (identical math) elsewhere.
    Tests pass interpret=True to execute the kernel body on CPU.
    """
    spec = _fusable_spec(cfg, int8=False)
    B, T, _ = xs.shape
    block_b = _legal_block_b(block_b, B)
    h0 = jnp.zeros((B, cfg.hidden), xs.dtype)
    if dts is None:
        dts = jnp.ones((T,), xs.dtype)
    wx, wh, b, time_scale = _split_gru(params, cfg)
    w1, b1, w2, b2 = _head_weights(params, cfg)
    act_bits = None
    if cfg.quant is not None:
        act_bits = (cfg.quant.act_int_bits, cfg.quant.act_frac_bits)
    if rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE:
        out = _ref.mr_step_reference(
            xs,
            h0,
            wx,
            wh,
            b,
            time_scale,
            dts,
            w1,
            b1,
            w2,
            b2,
            flow=spec.flow,
            act_bits=act_bits,
        )
    else:
        out = _mr_step_cvjp(
            xs,
            h0,
            wx,
            wh,
            b,
            time_scale,
            dts,
            w1,
            b1,
            w2,
            b2,
            spec.flow,
            act_bits,
            block_b,
        )
    return _split_out(out, cfg)


def mr_step_int8(
    params,
    cfg,
    xs: jnp.ndarray,
    dts: jnp.ndarray | None = None,
    n_seg: int = 16,
    block_b: int | None = None,
    force_reference: bool = False,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-point serving stage: int8 gate + head weights, PWL activations.

    Quantizes on the fly from float params (production would cache the int8
    tensors; the kernel signature takes them pre-quantized). Standard GRU
    only — the int8 kernel implements paper Eq. 12-15.
    """
    _fusable_spec(cfg, int8=True)
    B, T, _ = xs.shape
    block_b = _legal_block_b(block_b, B)
    d_in = cfg.state_dim + cfg.input_dim
    h0 = jnp.zeros((B, cfg.hidden), xs.dtype)
    if dts is None:
        dts = jnp.ones((T,), jnp.float32)
    wxq = quantize_int8(params.encoder.w[:d_in], axis=-1)
    whq = quantize_int8(params.encoder.w[d_in:], axis=-1)
    w1q = quantize_int8(params.head_w1, axis=-1)
    w2q = quantize_int8(params.head_w2, axis=-1)
    sig_t, tanh_t = make_sigmoid_table(n_seg), make_tanh_table(n_seg)
    if rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE:
        out = _ref.mr_step_int8_reference(
            xs,
            h0,
            wxq.values,
            whq.values,
            wxq.scale,
            whq.scale,
            params.encoder.b,
            dts,
            w1q.values,
            w1q.scale,
            params.head_b1,
            w2q.values,
            w2q.scale,
            params.head_b2,
            sig_t,
            tanh_t,
        )
    else:
        out = _k.mr_step_pallas_int8(
            xs,
            h0,
            wxq.values,
            whq.values,
            wxq.scale.reshape(-1),
            whq.scale.reshape(-1),
            params.encoder.b,
            dts,
            jnp.stack([sig_t.slopes, sig_t.intercepts]),
            jnp.stack([tanh_t.slopes, tanh_t.intercepts]),
            w1q.values,
            w1q.scale.reshape(-1),
            params.head_b1,
            w2q.values,
            w2q.scale.reshape(-1),
            params.head_b2,
            block_b=block_b,
            interpret=not rt.on_tpu(),
            n_seg=n_seg,
        )
    return _split_out(out, cfg)
