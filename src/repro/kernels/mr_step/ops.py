"""Public jit'd wrappers for the stage-fused MR per-window step.

``mr_step`` is the fused replacement for merinda's encode -> RMS-norm ->
dense-head stage sequence; ``mr_step_int8`` is the fixed-point serving
variant (int8 weights + PWL activations). Both dispatch on the encoder
registry row: the GRU(-flow) families take the single-update kernels, the
multi-substep families (``ltc``, ``node``) take the fused-solver kernels
that keep the hidden state, cell constants and head weights VMEM-resident
across all K solver substeps of every input step. Every variant resolves
its backend through kernels/runtime.resolve_dispatch — compiled Pallas
kernel on TPU, kernel body under the interpreter for CPU correctness
sweeps, the pure-JAX reference otherwise — so every consumer (engine epoch
scan, stream tick, serve_mr) shares one code path regardless of backend.

Gradients flow through a custom_vjp whose backward is the reference program
(same structure as kernels/gru_scan.ops), so every fused stage trains
inside the scan-jitted engine exactly like the unfused one.
"""

from __future__ import annotations

import functools as _functools

import jax
import jax.numpy as jnp

from repro.core import encoders
from repro.core.quant import make_sigmoid_table, make_tanh_table, quantize_int8
from repro.kernels import runtime as rt
from repro.kernels.mr_step import kernel as _k
from repro.kernels.mr_step import ref as _ref


@_functools.partial(jax.custom_vjp, nondiff_argnums=(11, 12, 13))
def _mr_step_cvjp(xs, h0, wx, wh, b, time_scale, dts, w1, b1, w2, b2, flow, act_bits, block_b):
    return _k.mr_step_pallas(
        xs,
        h0,
        wx,
        wh,
        b,
        time_scale,
        dts,
        w1,
        b1,
        w2,
        b2,
        flow=flow,
        act_bits=act_bits,
        block_b=block_b,
        interpret=not rt.on_tpu(),
    )


def _mr_fwd(xs, h0, wx, wh, b, time_scale, dts, w1, b1, w2, b2, flow, act_bits, block_b):
    out = _mr_step_cvjp(xs, h0, wx, wh, b, time_scale, dts, w1, b1, w2, b2, flow, act_bits, block_b)
    return out, (xs, h0, wx, wh, b, time_scale, dts, w1, b1, w2, b2)


def _mr_bwd(flow, act_bits, block_b, res, ct):
    _, vjp = jax.vjp(lambda *a: _ref.mr_step_reference(*a, flow=flow, act_bits=act_bits), *res)
    return vjp(ct)


_mr_step_cvjp.defvjp(_mr_fwd, _mr_bwd)


# -- multi-substep LTC: fused-solver substeps, reference backward ------------
@_functools.partial(jax.custom_vjp, nondiff_argnums=(11, 12, 13, 14))
def _mr_step_ltc_cvjp(
    xs, h0, w_in, w_rec, bias, a, inv_tau, w1, b1, w2, b2, dt, n_substeps, act_bits, block_b
):
    return _k.mr_step_ltc_pallas(
        xs,
        h0,
        w_in,
        w_rec,
        bias,
        a,
        inv_tau,
        w1,
        b1,
        w2,
        b2,
        dt=dt,
        n_substeps=n_substeps,
        act_bits=act_bits,
        block_b=block_b,
        interpret=not rt.on_tpu(),
    )


def _ltc_fwd(xs, h0, w_in, w_rec, bias, a, inv_tau, w1, b1, w2, b2, dt, n_substeps, act_bits, bb):
    out = _mr_step_ltc_cvjp(
        xs, h0, w_in, w_rec, bias, a, inv_tau, w1, b1, w2, b2, dt, n_substeps, act_bits, bb
    )
    return out, (xs, h0, w_in, w_rec, bias, a, inv_tau, w1, b1, w2, b2)


def _ltc_bwd(dt, n_substeps, act_bits, block_b, res, ct):
    _, vjp = jax.vjp(
        lambda *args: _ref.mr_step_ltc_reference(
            *args, dt=dt, n_substeps=n_substeps, act_bits=act_bits
        ),
        *res,
    )
    return vjp(ct)


_mr_step_ltc_cvjp.defvjp(_ltc_fwd, _ltc_bwd)


# -- multi-substep NODE (ODE-RNN): Euler substeps, reference backward --------
@_functools.partial(jax.custom_vjp, nondiff_argnums=(12, 13, 14, 15))
def _mr_step_node_cvjp(
    xs, h0, w_f1, b_f1, w_f2, b_f2, w_in, b_in, w1, b1, w2, b2, dt, n_substeps, act_bits, block_b
):
    return _k.mr_step_node_pallas(
        xs,
        h0,
        w_f1,
        b_f1,
        w_f2,
        b_f2,
        w_in,
        b_in,
        w1,
        b1,
        w2,
        b2,
        dt=dt,
        n_substeps=n_substeps,
        act_bits=act_bits,
        block_b=block_b,
        interpret=not rt.on_tpu(),
    )


def _node_fwd(xs, h0, w_f1, b_f1, w_f2, b_f2, w_in, b_in, w1, b1, w2, b2, dt, n_sub, ab, bb):
    out = _mr_step_node_cvjp(
        xs, h0, w_f1, b_f1, w_f2, b_f2, w_in, b_in, w1, b1, w2, b2, dt, n_sub, ab, bb
    )
    return out, (xs, h0, w_f1, b_f1, w_f2, b_f2, w_in, b_in, w1, b1, w2, b2)


def _node_bwd(dt, n_substeps, act_bits, block_b, res, ct):
    _, vjp = jax.vjp(
        lambda *args: _ref.mr_step_node_reference(
            *args, dt=dt, n_substeps=n_substeps, act_bits=act_bits
        ),
        *res,
    )
    return vjp(ct)


_mr_step_node_cvjp.defvjp(_node_fwd, _node_bwd)


def _split_gru(params, cfg):
    """(wx, wh, b, time_scale) with the QAT weight fake-quant applied."""
    enc = encoders.quantized_gru_params(params.encoder, cfg)
    d_in = cfg.state_dim + cfg.input_dim
    return enc.w[:d_in], enc.w[d_in:], enc.b, enc.time_scale


def _head_weights(params, cfg):
    """(w1, b1, w2, b2) with the shared QAT weight treatment applied."""
    from repro.core.quant import qat_weight

    w1 = qat_weight(params.head_w1, cfg.quant)
    w2 = qat_weight(params.head_w2, cfg.quant)
    return w1, params.head_b1, w2, params.head_b2


def _fusable_spec(cfg, *, int8: bool) -> encoders.EncoderSpec:
    spec = encoders.get_encoder(cfg.encoder)
    if not spec.fusable:
        raise ValueError(
            f"fused mr_step has no stage for encoder {cfg.encoder!r} "
            f"(fusable: {encoders.fusable_names()})"
        )
    if int8 and not spec.int8:
        raise ValueError(
            f"int8 mr_step implements the fixed-point cells with a PWL activation "
            f"mapping — encoder='gru' (standard cell, paper Eq. 12-15) or "
            f"encoder='ltc' (sigmoid-only substep) — got {cfg.encoder!r} "
            f"(int8-capable: {encoders.int8_names()})"
        )
    return spec


def _split_out(out, cfg):
    theta = out[..., : cfg.n_coef].reshape(out.shape[0], cfg.n_terms, cfg.state_dim)
    return theta, out[..., cfg.n_coef :]


def _legal_block_b(block_b: int | None, B: int) -> int | None:
    """Drop a tile the batch can't take. A plan resolves ``block_b`` against
    its COMPILE-TIME batch (e.g. the training minibatch), but the same config
    also serves full-window readouts whose batch differs; the kernel asserts
    ``B % block_b == 0``, so a non-dividing tile falls back to full batch
    here (a per-shape static decision — jit retraces per batch shape)."""
    return block_b if block_b and B % block_b == 0 else None


def mr_step(
    params,  # merinda.MRParams (any fusable registry encoder)
    cfg,  # merinda.MRConfig
    xs: jnp.ndarray,  # [B, T, n+m] normalized (+ activation-quantized) windows
    dts: jnp.ndarray | None = None,
    block_b: int | None = None,
    force_reference: bool = False,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused per-window recovery stage. Returns (theta [B, n_terms, n], shifts [B, q]).

    Dispatches on the encoder registry row: GRU(-flow) takes the
    single-update kernel, ``ltc``/``node`` take the multi-substep kernels
    (``dts`` applies to the GRU families only; the substep cells integrate
    on ``cfg.dt`` with ``cfg.ltc_substeps`` solver substeps, matching their
    unfused scans). Backend: Pallas kernel on TPU; reference (identical
    math) elsewhere. Tests pass interpret=True to run the kernel body on CPU.
    """
    spec = _fusable_spec(cfg, int8=False)
    B, T, _ = xs.shape
    block_b = _legal_block_b(block_b, B)
    h0 = jnp.zeros((B, cfg.hidden), xs.dtype)
    w1, b1, w2, b2 = _head_weights(params, cfg)
    act_bits = None
    if cfg.quant is not None:
        act_bits = (cfg.quant.act_int_bits, cfg.quant.act_frac_bits)
    reference = rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE

    if spec.family == "ltc":
        enc = params.encoder
        args = (xs, h0, enc.w_in, enc.w_rec, enc.bias, enc.a, enc.inv_tau, w1, b1, w2, b2)
        if reference:
            out = _ref.mr_step_ltc_reference(
                *args,
                dt=cfg.dt,
                n_substeps=cfg.ltc_substeps,
                act_bits=act_bits,
                unroll=cfg.substep_unroll,
            )
        else:
            out = _mr_step_ltc_cvjp(*args, cfg.dt, cfg.ltc_substeps, act_bits, block_b)
        return _split_out(out, cfg)

    if spec.family == "node":
        enc = params.encoder
        args = (
            xs,
            h0,
            enc.w_f1,
            enc.b_f1,
            enc.w_f2,
            enc.b_f2,
            enc.w_in,
            enc.b_in,
            w1,
            b1,
            w2,
            b2,
        )
        if reference:
            out = _ref.mr_step_node_reference(
                *args,
                dt=cfg.dt,
                n_substeps=cfg.ltc_substeps,
                act_bits=act_bits,
                unroll=cfg.substep_unroll,
            )
        else:
            out = _mr_step_node_cvjp(*args, cfg.dt, cfg.ltc_substeps, act_bits, block_b)
        return _split_out(out, cfg)

    if dts is None:
        dts = jnp.ones((T,), xs.dtype)
    wx, wh, b, time_scale = _split_gru(params, cfg)
    if reference:
        out = _ref.mr_step_reference(
            xs,
            h0,
            wx,
            wh,
            b,
            time_scale,
            dts,
            w1,
            b1,
            w2,
            b2,
            flow=spec.flow,
            act_bits=act_bits,
            unroll=cfg.substep_unroll,
        )
    else:
        out = _mr_step_cvjp(
            xs,
            h0,
            wx,
            wh,
            b,
            time_scale,
            dts,
            w1,
            b1,
            w2,
            b2,
            spec.flow,
            act_bits,
            block_b,
        )
    return _split_out(out, cfg)


def mr_step_int8(
    params,
    cfg,
    xs: jnp.ndarray,
    dts: jnp.ndarray | None = None,
    n_seg: int = 16,
    block_b: int | None = None,
    force_reference: bool = False,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-point serving stage: int8 substep + head weights, PWL activations.

    Quantizes on the fly from float params (production would cache the int8
    tensors; the kernel signatures take them pre-quantized). Implemented for
    the families whose cell nonlinearities have a PWL mapping: the standard
    GRU (paper Eq. 12-15) and the LTC substep cell (sigmoid-only).
    """
    spec = _fusable_spec(cfg, int8=True)
    B, T, _ = xs.shape
    block_b = _legal_block_b(block_b, B)
    d_in = cfg.state_dim + cfg.input_dim
    h0 = jnp.zeros((B, cfg.hidden), xs.dtype)
    if dts is None:
        dts = jnp.ones((T,), jnp.float32)

    if spec.family == "ltc":
        return _mr_step_ltc_int8(
            params,
            cfg,
            xs,
            h0,
            n_seg=n_seg,
            block_b=block_b,
            force_reference=force_reference,
            interpret=interpret,
        )
    wxq = quantize_int8(params.encoder.w[:d_in], axis=-1)
    whq = quantize_int8(params.encoder.w[d_in:], axis=-1)
    w1q = quantize_int8(params.head_w1, axis=-1)
    w2q = quantize_int8(params.head_w2, axis=-1)
    sig_t, tanh_t = make_sigmoid_table(n_seg), make_tanh_table(n_seg)
    if rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE:
        out = _ref.mr_step_int8_reference(
            xs,
            h0,
            wxq.values,
            whq.values,
            wxq.scale,
            whq.scale,
            params.encoder.b,
            dts,
            w1q.values,
            w1q.scale,
            params.head_b1,
            w2q.values,
            w2q.scale,
            params.head_b2,
            sig_t,
            tanh_t,
        )
    else:
        out = _k.mr_step_pallas_int8(
            xs,
            h0,
            wxq.values,
            whq.values,
            wxq.scale.reshape(-1),
            whq.scale.reshape(-1),
            params.encoder.b,
            dts,
            jnp.stack([sig_t.slopes, sig_t.intercepts]),
            jnp.stack([tanh_t.slopes, tanh_t.intercepts]),
            w1q.values,
            w1q.scale.reshape(-1),
            params.head_b1,
            w2q.values,
            w2q.scale.reshape(-1),
            params.head_b2,
            block_b=block_b,
            interpret=not rt.on_tpu(),
            n_seg=n_seg,
        )
    return _split_out(out, cfg)


def _mr_step_ltc_int8(
    params,
    cfg,
    xs: jnp.ndarray,
    h0: jnp.ndarray,
    *,
    n_seg: int,
    block_b: int | None,
    force_reference: bool,
    interpret: bool | None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-point fused LTC serving stage (int8 weights + PWL sigmoid)."""
    enc = params.encoder
    w_inq = quantize_int8(enc.w_in, axis=-1)
    w_recq = quantize_int8(enc.w_rec, axis=-1)
    w1q = quantize_int8(params.head_w1, axis=-1)
    w2q = quantize_int8(params.head_w2, axis=-1)
    sig_t = make_sigmoid_table(n_seg)
    if rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE:
        out = _ref.mr_step_ltc_int8_reference(
            xs,
            h0,
            w_inq.values,
            w_inq.scale,
            w_recq.values,
            w_recq.scale,
            enc.bias,
            enc.a,
            enc.inv_tau,
            w1q.values,
            w1q.scale,
            params.head_b1,
            w2q.values,
            w2q.scale,
            params.head_b2,
            sig_t,
            dt=cfg.dt,
            n_substeps=cfg.ltc_substeps,
        )
    else:
        out = _k.mr_step_ltc_pallas_int8(
            xs,
            h0,
            w_inq.values,
            w_inq.scale.reshape(-1),
            w_recq.values,
            w_recq.scale.reshape(-1),
            enc.bias,
            enc.a,
            enc.inv_tau,
            jnp.stack([sig_t.slopes, sig_t.intercepts]),
            w1q.values,
            w1q.scale.reshape(-1),
            params.head_b1,
            w2q.values,
            w2q.scale.reshape(-1),
            params.head_b2,
            dt=cfg.dt,
            n_substeps=cfg.ltc_substeps,
            block_b=block_b,
            interpret=not rt.on_tpu(),
            n_seg=n_seg,
        )
    return _split_out(out, cfg)
