"""Stage-fused MR per-window step Pallas kernel (the 4th kernel family).

Fuses the whole per-window recovery stage map of merinda.mr_forward —
GRU(-flow) sequence scan, RMS normalization, and the dense coefficient head
— into ONE ``pallas_call``. This is the TPU re-derivation of the paper's
stage-fused FPGA dataflow (§4, Table 8) one level above kernels/gru_scan:

  FPGA mechanism                      ->  this kernel
  -------------------------------------   -----------------------------------
  no inter-stage synchronization       ->  encoder, norm and head execute in
  (stage outputs stream directly           one kernel body; the hidden state
  into the next stage)                     and the head input NEVER round-trip
                                           HBM between stages
  BRAM-resident hidden state           ->  h carried in a VMEM scratch across
                                           the whole (scan + head) stage map
  pruned dense layer fed on-chip       ->  head weights VMEM-resident next to
                                           the gate weights; the head GEMM
                                           issues the cycle after the last
                                           scan step retires
  fixed-point + LUT configuration      ->  int8 gate AND head weights with
                                           per-channel scales + PWL
                                           sigmoid/tanh (quant variant)

Per sequence the only HBM traffic is x_t in and theta out — the [B, T, H]
hidden-state tensor that the unfused pipeline materializes between the scan
and head dispatches simply does not exist.

Grid/layout mirrors kernels/gru_scan: grid = (batch_tiles, T), batch tiles
outer (PARALLEL), time inner (ARBITRARY); the head fires under
``pl.when(t == T-1)`` and writes the per-window head output tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.merinda import RMS_EPS
from repro.core.quant import quantize_fixed
from repro.kernels import runtime as rt
from repro.kernels.gru_scan.kernel import _gru_step_math, _gru_q_step_math


def _head_math(h, w1, b1, w2, b2, act_bits):
    """merinda.head_math in Pallas dot_general spellings (shared RMS_EPS);
    parity-tested against the shared helper in tests/test_kernels_mr_step.py."""
    f32 = jnp.float32
    h = h * jax.lax.rsqrt(jnp.mean(jnp.square(h), axis=-1, keepdims=True) + RMS_EPS)
    if act_bits is not None:
        # pure-jnp Qm.n grid; the STE wrapper is irrelevant here (the fused
        # op's backward runs through the reference, ops._mr_bwd)
        h = quantize_fixed(h, *act_bits)
    z = jax.lax.dot_general(h, w1, (((1,), (0,)), ((), ())), preferred_element_type=f32)
    z = jnp.maximum(z + b1, 0.0)
    out = jax.lax.dot_general(z, w2, (((1,), (0,)), ((), ())), preferred_element_type=f32)
    return out + b2


def _mr_step_kernel(
    # inputs
    xs_ref,  # [bb, 1, D]   x_t tile (double-buffered by Mosaic)
    h0_ref,  # [bb, H]
    wx_ref,  # [D, 3H]      VMEM-resident across the whole stage map
    wh_ref,  # [H, 3H]
    b_ref,  # [1, 3H]
    ts_ref,  # [1, H]
    dts_ref,  # [1, 1]
    w1_ref,  # [H, Dh]      head weights, VMEM-resident
    b1_ref,  # [1, Dh]
    w2_ref,  # [Dh, K]
    b2_ref,  # [1, K]
    # outputs
    out_ref,  # [bb, K]     per-window head output (theta ++ shifts)
    # scratch
    h_scr,  # VMEM [bb, H] f32 — BRAM-resident hidden state analogue
    *,
    flow: bool,
    hidden: int,
    act_bits: tuple[int, int] | None,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    h_new = _gru_step_math(
        xs_ref[:, 0, :],
        h_scr[...],
        wx_ref[...],
        wh_ref[...],
        b_ref[0, :],
        ts_ref[0, :],
        dts_ref[0, 0],
        flow=flow,
        hidden=hidden,
    )
    h_scr[...] = h_new

    # stage handoff without synchronization: the head consumes h straight
    # from VMEM the step the scan retires — no [B, T, H] HBM materialization
    @pl.when(t == pl.num_programs(1) - 1)
    def _head():
        out = _head_math(h_new, w1_ref[...], b1_ref[0, :], w2_ref[...], b2_ref[0, :], act_bits)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("flow", "act_bits", "block_b", "interpret"))
def mr_step_pallas(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    wx: jnp.ndarray,  # [D, 3H]
    wh: jnp.ndarray,  # [H, 3H]
    b: jnp.ndarray,  # [3H]
    time_scale: jnp.ndarray,  # [H]
    dts: jnp.ndarray,  # [T]
    w1: jnp.ndarray,  # [H, Dh]
    b1: jnp.ndarray,  # [Dh]
    w2: jnp.ndarray,  # [Dh, K]
    b2: jnp.ndarray,  # [K]
    flow: bool = True,
    act_bits: tuple[int, int] | None = None,
    block_b: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns the per-window head output [B, K] (K = n_coef + n_shifts)."""
    B, T, D = xs.shape
    H = h0.shape[-1]
    Dh = w1.shape[-1]
    K = w2.shape[-1]
    bb = block_b or B
    assert B % bb == 0, f"batch {B} not divisible by block_b {bb}"
    nb = B // bb

    kernel = functools.partial(_mr_step_kernel, flow=flow, hidden=H, act_bits=act_bits)
    return rt.pallas_call_compat(
        kernel,
        grid=(nb, T),
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),  # xs: stream x_t
            ((bb, H), lambda ib, t: (ib, 0)),  # h0
            ((D, 3 * H), lambda ib, t: (0, 0)),  # wx: resident
            ((H, 3 * H), lambda ib, t: (0, 0)),  # wh: resident
            ((1, 3 * H), lambda ib, t: (0, 0)),  # b
            ((1, H), lambda ib, t: (0, 0)),  # time_scale
            ((1, 1), lambda ib, t: (t, 0)),  # dt_t
            ((H, Dh), lambda ib, t: (0, 0)),  # head w1: resident
            ((1, Dh), lambda ib, t: (0, 0)),  # head b1
            ((Dh, K), lambda ib, t: (0, 0)),  # head w2: resident
            ((1, K), lambda ib, t: (0, 0)),  # head b2
        ],
        out_specs=((bb, K), lambda ib, t: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="mr_step_fused",
    )(
        xs,
        h0,
        wx,
        wh,
        b.reshape(1, -1),
        time_scale.reshape(1, -1),
        dts.reshape(-1, 1),
        w1,
        b1.reshape(1, -1),
        w2,
        b2.reshape(1, -1),
    )


# ---------------------------------------------------------------------------
# int8 + piecewise-linear variant — fixed-point weights through BOTH stages
# ---------------------------------------------------------------------------
def _mr_step_q_kernel(
    xs_ref,
    h0_ref,
    wxq_ref,  # int8 [D, 3H]
    whq_ref,  # int8 [H, 3H]
    wx_scale_ref,  # [1, 3H]
    wh_scale_ref,  # [1, 3H]
    b_ref,
    dts_ref,
    sig_tab_ref,  # [2, n_seg]
    tanh_tab_ref,  # [2, n_seg]
    w1q_ref,  # int8 [H, Dh]
    w1_scale_ref,  # [1, Dh]
    b1_ref,
    w2q_ref,  # int8 [Dh, K]
    w2_scale_ref,  # [1, K]
    b2_ref,
    out_ref,
    h_scr,
    *,
    hidden: int,
    n_seg: int,
):
    """Standard-GRU scan + head, int8 weights + PWL activations end to end."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    f32 = jnp.float32
    h_new = _gru_q_step_math(
        xs_ref[:, 0, :].astype(f32),
        h_scr[...],
        wxq_ref[...],
        whq_ref[...],
        wx_scale_ref[0, :],
        wh_scale_ref[0, :],
        b_ref[0, :],
        sig_tab_ref[...],
        tanh_tab_ref[...],
        hidden=hidden,
        n_seg=n_seg,
    )
    h_scr[...] = h_new

    @pl.when(t == pl.num_programs(1) - 1)
    def _head():
        w1 = w1q_ref[...].astype(f32) * w1_scale_ref[0, :]
        w2 = w2q_ref[...].astype(f32) * w2_scale_ref[0, :]
        out = _head_math(h_new, w1, b1_ref[0, :], w2, b2_ref[0, :], None)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret", "n_seg"))
def mr_step_pallas_int8(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    wxq: jnp.ndarray,  # int8 [D, 3H]
    whq: jnp.ndarray,  # int8 [H, 3H]
    wx_scale: jnp.ndarray,  # [3H]
    wh_scale: jnp.ndarray,  # [3H]
    b: jnp.ndarray,  # [3H]
    dts: jnp.ndarray,  # [T]
    sig_tab: jnp.ndarray,  # [2, n_seg]
    tanh_tab: jnp.ndarray,  # [2, n_seg]
    w1q: jnp.ndarray,  # int8 [H, Dh]
    w1_scale: jnp.ndarray,  # [Dh]
    b1: jnp.ndarray,  # [Dh]
    w2q: jnp.ndarray,  # int8 [Dh, K]
    w2_scale: jnp.ndarray,  # [K]
    b2: jnp.ndarray,  # [K]
    block_b: int | None = None,
    interpret: bool = False,
    n_seg: int = 16,
) -> jnp.ndarray:
    B, T, D = xs.shape
    H = h0.shape[-1]
    Dh = w1q.shape[-1]
    K = w2q.shape[-1]
    bb = block_b or B
    assert B % bb == 0
    nb = B // bb
    kernel = functools.partial(_mr_step_q_kernel, hidden=H, n_seg=n_seg)
    return rt.pallas_call_compat(
        kernel,
        grid=(nb, T),
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),
            ((bb, H), lambda ib, t: (ib, 0)),
            ((D, 3 * H), lambda ib, t: (0, 0)),
            ((H, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 1), lambda ib, t: (t, 0)),
            ((2, n_seg), lambda ib, t: (0, 0)),
            ((2, n_seg), lambda ib, t: (0, 0)),
            ((H, Dh), lambda ib, t: (0, 0)),
            ((1, Dh), lambda ib, t: (0, 0)),
            ((1, Dh), lambda ib, t: (0, 0)),
            ((Dh, K), lambda ib, t: (0, 0)),
            ((1, K), lambda ib, t: (0, 0)),
            ((1, K), lambda ib, t: (0, 0)),
        ],
        out_specs=((bb, K), lambda ib, t: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="mr_step_fused_int8_pwl",
    )(
        xs,
        h0,
        wxq,
        whq,
        wx_scale.reshape(1, -1),
        wh_scale.reshape(1, -1),
        b.reshape(1, -1),
        dts.reshape(-1, 1),
        sig_tab,
        tanh_tab,
        w1q,
        w1_scale.reshape(1, -1),
        b1.reshape(1, -1),
        w2q,
        w2_scale.reshape(1, -1),
        b2.reshape(1, -1),
    )
