"""Stage-fused MR per-window step Pallas kernels (the 4th kernel family).

Fuses the whole per-window recovery stage map of merinda.mr_forward —
encoder sequence scan, RMS normalization, and the dense coefficient head —
into ONE ``pallas_call``. Four encoder variants share the structure:

  GRU(-flow)      one gated update per input step (``mr_step_pallas`` +
                  the int8/PWL serving twin ``mr_step_pallas_int8``)
  LTC             the paper's PRIMARY baseline: K fused-solver semi-implicit
                  substeps per input step (``mr_step_ltc_pallas`` + int8/PWL
                  twin) — the iterative-solver loop of paper Table 2 kept
                  entirely VMEM-resident instead of K XLA dispatch hops
  NODE (ODE-RNN)  K fixed-step Euler substeps of a learned vector field per
                  input step (``mr_step_node_pallas``) — paper Table 1's
                  "ODE solver = 88% of the forward pass" hot loop, fused

For the multi-substep cells the substep loop is unrolled INSIDE the kernel
body (K is static): every substep's matvec + update chain runs against
VMEM-resident weights and the VMEM hidden-state scratch, so the sequential
dependency the paper profiles costs VMEM-hop latency instead of an XLA
dispatch + HBM round-trip per substep. This is the TPU re-derivation of the
paper's stage-fused FPGA dataflow (§4, Table 8) one level above
kernels/gru_scan:

  FPGA mechanism                      ->  this kernel
  -------------------------------------   -----------------------------------
  no inter-stage synchronization       ->  encoder, norm and head execute in
  (stage outputs stream directly           one kernel body; the hidden state
  into the next stage)                     and the head input NEVER round-trip
                                           HBM between stages
  BRAM-resident hidden state           ->  h carried in a VMEM scratch across
                                           the whole (scan + head) stage map
  pruned dense layer fed on-chip       ->  head weights VMEM-resident next to
                                           the gate weights; the head GEMM
                                           issues the cycle after the last
                                           scan step retires
  fixed-point + LUT configuration      ->  int8 gate AND head weights with
                                           per-channel scales + PWL
                                           sigmoid/tanh (quant variant)

Per sequence the only HBM traffic is x_t in and theta out — the [B, T, H]
hidden-state tensor that the unfused pipeline materializes between the scan
and head dispatches simply does not exist.

Grid/layout mirrors kernels/gru_scan: grid = (batch_tiles, T), batch tiles
outer (PARALLEL), time inner (ARBITRARY); the head fires under
``pl.when(t == T-1)`` and writes the per-window head output tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.merinda import RMS_EPS
from repro.core.quant import quantize_fixed
from repro.kernels import runtime as rt
from repro.kernels.gru_scan.kernel import _gru_step_math, _gru_q_step_math, _pwl_eval


def _head_math(h, w1, b1, w2, b2, act_bits):
    """merinda.head_math in Pallas dot_general spellings (shared RMS_EPS);
    parity-tested against the shared helper in tests/test_kernels_mr_step.py."""
    f32 = jnp.float32
    h = h * jax.lax.rsqrt(jnp.mean(jnp.square(h), axis=-1, keepdims=True) + RMS_EPS)
    if act_bits is not None:
        # pure-jnp Qm.n grid; the STE wrapper is irrelevant here (the fused
        # op's backward runs through the reference, ops._mr_bwd)
        h = quantize_fixed(h, *act_bits)
    z = jax.lax.dot_general(h, w1, (((1,), (0,)), ((), ())), preferred_element_type=f32)
    z = jnp.maximum(z + b1, 0.0)
    out = jax.lax.dot_general(z, w2, (((1,), (0,)), ((), ())), preferred_element_type=f32)
    return out + b2


def _mr_step_kernel(
    # inputs
    xs_ref,  # [bb, 1, D]   x_t tile (double-buffered by Mosaic)
    h0_ref,  # [bb, H]
    wx_ref,  # [D, 3H]      VMEM-resident across the whole stage map
    wh_ref,  # [H, 3H]
    b_ref,  # [1, 3H]
    ts_ref,  # [1, H]
    dts_ref,  # [1, 1]
    w1_ref,  # [H, Dh]      head weights, VMEM-resident
    b1_ref,  # [1, Dh]
    w2_ref,  # [Dh, K]
    b2_ref,  # [1, K]
    # outputs
    out_ref,  # [bb, K]     per-window head output (theta ++ shifts)
    # scratch
    h_scr,  # VMEM [bb, H] f32 — BRAM-resident hidden state analogue
    *,
    flow: bool,
    hidden: int,
    act_bits: tuple[int, int] | None,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    h_new = _gru_step_math(
        xs_ref[:, 0, :],
        h_scr[...],
        wx_ref[...],
        wh_ref[...],
        b_ref[0, :],
        ts_ref[0, :],
        dts_ref[0, 0],
        flow=flow,
        hidden=hidden,
    )
    h_scr[...] = h_new

    # stage handoff without synchronization: the head consumes h straight
    # from VMEM the step the scan retires — no [B, T, H] HBM materialization
    @pl.when(t == pl.num_programs(1) - 1)
    def _head():
        out = _head_math(h_new, w1_ref[...], b1_ref[0, :], w2_ref[...], b2_ref[0, :], act_bits)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("flow", "act_bits", "block_b", "interpret"))
def mr_step_pallas(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    wx: jnp.ndarray,  # [D, 3H]
    wh: jnp.ndarray,  # [H, 3H]
    b: jnp.ndarray,  # [3H]
    time_scale: jnp.ndarray,  # [H]
    dts: jnp.ndarray,  # [T]
    w1: jnp.ndarray,  # [H, Dh]
    b1: jnp.ndarray,  # [Dh]
    w2: jnp.ndarray,  # [Dh, K]
    b2: jnp.ndarray,  # [K]
    flow: bool = True,
    act_bits: tuple[int, int] | None = None,
    block_b: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns the per-window head output [B, K] (K = n_coef + n_shifts)."""
    B, T, D = xs.shape
    H = h0.shape[-1]
    Dh = w1.shape[-1]
    K = w2.shape[-1]
    bb = block_b or B
    assert B % bb == 0, f"batch {B} not divisible by block_b {bb}"
    nb = B // bb

    kernel = functools.partial(_mr_step_kernel, flow=flow, hidden=H, act_bits=act_bits)
    return rt.pallas_call_compat(
        kernel,
        grid=(nb, T),
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),  # xs: stream x_t
            ((bb, H), lambda ib, t: (ib, 0)),  # h0
            ((D, 3 * H), lambda ib, t: (0, 0)),  # wx: resident
            ((H, 3 * H), lambda ib, t: (0, 0)),  # wh: resident
            ((1, 3 * H), lambda ib, t: (0, 0)),  # b
            ((1, H), lambda ib, t: (0, 0)),  # time_scale
            ((1, 1), lambda ib, t: (t, 0)),  # dt_t
            ((H, Dh), lambda ib, t: (0, 0)),  # head w1: resident
            ((1, Dh), lambda ib, t: (0, 0)),  # head b1
            ((Dh, K), lambda ib, t: (0, 0)),  # head w2: resident
            ((1, K), lambda ib, t: (0, 0)),  # head b2
        ],
        out_specs=((bb, K), lambda ib, t: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="mr_step_fused",
    )(
        xs,
        h0,
        wx,
        wh,
        b.reshape(1, -1),
        time_scale.reshape(1, -1),
        dts.reshape(-1, 1),
        w1,
        b1.reshape(1, -1),
        w2,
        b2.reshape(1, -1),
    )


# ---------------------------------------------------------------------------
# int8 + piecewise-linear variant — fixed-point weights through BOTH stages
# ---------------------------------------------------------------------------
def _mr_step_q_kernel(
    xs_ref,
    h0_ref,
    wxq_ref,  # int8 [D, 3H]
    whq_ref,  # int8 [H, 3H]
    wx_scale_ref,  # [1, 3H]
    wh_scale_ref,  # [1, 3H]
    b_ref,
    dts_ref,
    sig_tab_ref,  # [2, n_seg]
    tanh_tab_ref,  # [2, n_seg]
    w1q_ref,  # int8 [H, Dh]
    w1_scale_ref,  # [1, Dh]
    b1_ref,
    w2q_ref,  # int8 [Dh, K]
    w2_scale_ref,  # [1, K]
    b2_ref,
    out_ref,
    h_scr,
    *,
    hidden: int,
    n_seg: int,
):
    """Standard-GRU scan + head, int8 weights + PWL activations end to end."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    f32 = jnp.float32
    h_new = _gru_q_step_math(
        xs_ref[:, 0, :].astype(f32),
        h_scr[...],
        wxq_ref[...],
        whq_ref[...],
        wx_scale_ref[0, :],
        wh_scale_ref[0, :],
        b_ref[0, :],
        sig_tab_ref[...],
        tanh_tab_ref[...],
        hidden=hidden,
        n_seg=n_seg,
    )
    h_scr[...] = h_new

    @pl.when(t == pl.num_programs(1) - 1)
    def _head():
        w1 = w1q_ref[...].astype(f32) * w1_scale_ref[0, :]
        w2 = w2q_ref[...].astype(f32) * w2_scale_ref[0, :]
        out = _head_math(h_new, w1, b1_ref[0, :], w2, b2_ref[0, :], None)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret", "n_seg"))
def mr_step_pallas_int8(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    wxq: jnp.ndarray,  # int8 [D, 3H]
    whq: jnp.ndarray,  # int8 [H, 3H]
    wx_scale: jnp.ndarray,  # [3H]
    wh_scale: jnp.ndarray,  # [3H]
    b: jnp.ndarray,  # [3H]
    dts: jnp.ndarray,  # [T]
    sig_tab: jnp.ndarray,  # [2, n_seg]
    tanh_tab: jnp.ndarray,  # [2, n_seg]
    w1q: jnp.ndarray,  # int8 [H, Dh]
    w1_scale: jnp.ndarray,  # [Dh]
    b1: jnp.ndarray,  # [Dh]
    w2q: jnp.ndarray,  # int8 [Dh, K]
    w2_scale: jnp.ndarray,  # [K]
    b2: jnp.ndarray,  # [K]
    block_b: int | None = None,
    interpret: bool = False,
    n_seg: int = 16,
) -> jnp.ndarray:
    B, T, D = xs.shape
    H = h0.shape[-1]
    Dh = w1q.shape[-1]
    K = w2q.shape[-1]
    bb = block_b or B
    assert B % bb == 0
    nb = B // bb
    kernel = functools.partial(_mr_step_q_kernel, hidden=H, n_seg=n_seg)
    return rt.pallas_call_compat(
        kernel,
        grid=(nb, T),
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),
            ((bb, H), lambda ib, t: (ib, 0)),
            ((D, 3 * H), lambda ib, t: (0, 0)),
            ((H, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 1), lambda ib, t: (t, 0)),
            ((2, n_seg), lambda ib, t: (0, 0)),
            ((2, n_seg), lambda ib, t: (0, 0)),
            ((H, Dh), lambda ib, t: (0, 0)),
            ((1, Dh), lambda ib, t: (0, 0)),
            ((1, Dh), lambda ib, t: (0, 0)),
            ((Dh, K), lambda ib, t: (0, 0)),
            ((1, K), lambda ib, t: (0, 0)),
            ((1, K), lambda ib, t: (0, 0)),
        ],
        out_specs=((bb, K), lambda ib, t: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="mr_step_fused_int8_pwl",
    )(
        xs,
        h0,
        wxq,
        whq,
        wx_scale.reshape(1, -1),
        wh_scale.reshape(1, -1),
        b.reshape(1, -1),
        dts.reshape(-1, 1),
        sig_tab,
        tanh_tab,
        w1q,
        w1_scale.reshape(1, -1),
        b1.reshape(1, -1),
        w2q,
        w2_scale.reshape(1, -1),
        b2.reshape(1, -1),
    )


# ---------------------------------------------------------------------------
# multi-substep variants — LTC (fused-solver) and NODE (fixed-step Euler)
# ---------------------------------------------------------------------------
def _ltc_step_math(x, h, w_in, w_rec, bias, a, inv_tau, *, sub_dt: float, n_substeps: int):
    """One LTC input step = n_substeps semi-implicit fused-solver iterations.

    Matches core.ltc.ltc_cell: the input drive is loop-invariant; each
    substep's recurrent sigmoid + sum + fused Euler update (the profiled
    hotspots of paper Table 2) depends on the previous substep. The loop is
    a static unroll — h and all weights stay VMEM-resident for the whole
    chain, so the sequential dependency costs VMEM hops, not XLA dispatches.
    """
    f32 = jnp.float32
    drive = (
        jax.lax.dot_general(x, w_in, (((1,), (0,)), ((), ())), preferred_element_type=f32) + bias
    )
    for _ in range(n_substeps):
        f = jax.nn.sigmoid(
            drive
            + jax.lax.dot_general(h, w_rec, (((1,), (0,)), ((), ())), preferred_element_type=f32)
        )
        num = h + sub_dt * f * a
        den = 1.0 + sub_dt * (inv_tau + f)
        h = num / den
    return h


def _mr_step_ltc_kernel(
    # inputs
    xs_ref,  # [bb, 1, D]   x_t tile
    h0_ref,  # [bb, H]
    w_in_ref,  # [D, H]     VMEM-resident across the whole stage map
    w_rec_ref,  # [H, H]
    bias_ref,  # [1, H]
    a_ref,  # [1, H]
    inv_tau_ref,  # [1, H]
    w1_ref,  # [H, Dh]      head weights, VMEM-resident
    b1_ref,  # [1, Dh]
    w2_ref,  # [Dh, K]
    b2_ref,  # [1, K]
    # outputs
    out_ref,  # [bb, K]
    # scratch
    h_scr,  # VMEM [bb, H] f32
    *,
    sub_dt: float,
    n_substeps: int,
    act_bits: tuple[int, int] | None,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    h_new = _ltc_step_math(
        xs_ref[:, 0, :],
        h_scr[...],
        w_in_ref[...],
        w_rec_ref[...],
        bias_ref[0, :],
        a_ref[0, :],
        inv_tau_ref[0, :],
        sub_dt=sub_dt,
        n_substeps=n_substeps,
    )
    h_scr[...] = h_new

    @pl.when(t == pl.num_programs(1) - 1)
    def _head():
        out = _head_math(h_new, w1_ref[...], b1_ref[0, :], w2_ref[...], b2_ref[0, :], act_bits)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("dt", "n_substeps", "act_bits", "block_b", "interpret")
)
def mr_step_ltc_pallas(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    w_in: jnp.ndarray,  # [D, H]
    w_rec: jnp.ndarray,  # [H, H]
    bias: jnp.ndarray,  # [H]
    a: jnp.ndarray,  # [H]
    inv_tau: jnp.ndarray,  # [H]
    w1: jnp.ndarray,  # [H, Dh]
    b1: jnp.ndarray,  # [Dh]
    w2: jnp.ndarray,  # [Dh, K]
    b2: jnp.ndarray,  # [K]
    dt: float = 1.0,
    n_substeps: int = 6,
    act_bits: tuple[int, int] | None = None,
    block_b: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused multi-substep LTC stage. Returns the head output [B, K]."""
    B, T, D = xs.shape
    H = h0.shape[-1]
    Dh = w1.shape[-1]
    K = w2.shape[-1]
    bb = block_b or B
    assert B % bb == 0, f"batch {B} not divisible by block_b {bb}"
    nb = B // bb

    kernel = functools.partial(
        _mr_step_ltc_kernel,
        sub_dt=dt / n_substeps,
        n_substeps=n_substeps,
        act_bits=act_bits,
    )
    return rt.pallas_call_compat(
        kernel,
        grid=(nb, T),
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),  # xs: stream x_t
            ((bb, H), lambda ib, t: (ib, 0)),  # h0
            ((D, H), lambda ib, t: (0, 0)),  # w_in: resident
            ((H, H), lambda ib, t: (0, 0)),  # w_rec: resident
            ((1, H), lambda ib, t: (0, 0)),  # bias
            ((1, H), lambda ib, t: (0, 0)),  # a
            ((1, H), lambda ib, t: (0, 0)),  # inv_tau
            ((H, Dh), lambda ib, t: (0, 0)),  # head w1: resident
            ((1, Dh), lambda ib, t: (0, 0)),  # head b1
            ((Dh, K), lambda ib, t: (0, 0)),  # head w2: resident
            ((1, K), lambda ib, t: (0, 0)),  # head b2
        ],
        out_specs=((bb, K), lambda ib, t: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="mr_step_fused_ltc",
    )(
        xs,
        h0,
        w_in,
        w_rec,
        bias.reshape(1, -1),
        a.reshape(1, -1),
        inv_tau.reshape(1, -1),
        w1,
        b1.reshape(1, -1),
        w2,
        b2.reshape(1, -1),
    )


def _node_step_math(x, h, w_f1, b_f1, w_f2, b_f2, w_in, b_in, *, sub_dt: float, n_substeps: int):
    """One ODE-RNN input step: n_substeps Euler substeps + input injection.

    Matches core.node_mr.node_scan (multi_step_solver_cell with
    method="euler"): h += sub_dt * f_theta(h) per substep, then the linear
    observation injection. Static unroll, all operands VMEM-resident.
    """
    f32 = jnp.float32

    def dot(p, q):
        return jax.lax.dot_general(p, q, (((1,), (0,)), ((), ())), preferred_element_type=f32)

    for _ in range(n_substeps):
        z = jnp.tanh(dot(h, w_f1) + b_f1)
        h = h + sub_dt * (dot(z, w_f2) + b_f2)
    return h + dot(x, w_in) + b_in


def _mr_step_node_kernel(
    xs_ref,  # [bb, 1, D]
    h0_ref,  # [bb, H]
    w_f1_ref,  # [H, H]     vector-field MLP, VMEM-resident
    b_f1_ref,  # [1, H]
    w_f2_ref,  # [H, H]
    b_f2_ref,  # [1, H]
    w_in_ref,  # [D, H]     observation injection
    b_in_ref,  # [1, H]
    w1_ref,  # [H, Dh]
    b1_ref,  # [1, Dh]
    w2_ref,  # [Dh, K]
    b2_ref,  # [1, K]
    out_ref,  # [bb, K]
    h_scr,  # VMEM [bb, H] f32
    *,
    sub_dt: float,
    n_substeps: int,
    act_bits: tuple[int, int] | None,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    h_new = _node_step_math(
        xs_ref[:, 0, :],
        h_scr[...],
        w_f1_ref[...],
        b_f1_ref[0, :],
        w_f2_ref[...],
        b_f2_ref[0, :],
        w_in_ref[...],
        b_in_ref[0, :],
        sub_dt=sub_dt,
        n_substeps=n_substeps,
    )
    h_scr[...] = h_new

    @pl.when(t == pl.num_programs(1) - 1)
    def _head():
        out = _head_math(h_new, w1_ref[...], b1_ref[0, :], w2_ref[...], b2_ref[0, :], act_bits)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("dt", "n_substeps", "act_bits", "block_b", "interpret")
)
def mr_step_node_pallas(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    w_f1: jnp.ndarray,  # [H, H]
    b_f1: jnp.ndarray,  # [H]
    w_f2: jnp.ndarray,  # [H, H]
    b_f2: jnp.ndarray,  # [H]
    w_in: jnp.ndarray,  # [D, H]
    b_in: jnp.ndarray,  # [H]
    w1: jnp.ndarray,  # [H, Dh]
    b1: jnp.ndarray,  # [Dh]
    w2: jnp.ndarray,  # [Dh, K]
    b2: jnp.ndarray,  # [K]
    dt: float = 1.0,
    n_substeps: int = 6,
    act_bits: tuple[int, int] | None = None,
    block_b: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused multi-substep NODE (ODE-RNN) stage. Returns [B, K]."""
    B, T, D = xs.shape
    H = h0.shape[-1]
    Dh = w1.shape[-1]
    K = w2.shape[-1]
    bb = block_b or B
    assert B % bb == 0, f"batch {B} not divisible by block_b {bb}"
    nb = B // bb

    kernel = functools.partial(
        _mr_step_node_kernel,
        sub_dt=dt / n_substeps,
        n_substeps=n_substeps,
        act_bits=act_bits,
    )
    return rt.pallas_call_compat(
        kernel,
        grid=(nb, T),
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),
            ((bb, H), lambda ib, t: (ib, 0)),
            ((H, H), lambda ib, t: (0, 0)),  # w_f1: resident
            ((1, H), lambda ib, t: (0, 0)),
            ((H, H), lambda ib, t: (0, 0)),  # w_f2: resident
            ((1, H), lambda ib, t: (0, 0)),
            ((D, H), lambda ib, t: (0, 0)),  # w_in: resident
            ((1, H), lambda ib, t: (0, 0)),
            ((H, Dh), lambda ib, t: (0, 0)),  # head w1: resident
            ((1, Dh), lambda ib, t: (0, 0)),
            ((Dh, K), lambda ib, t: (0, 0)),  # head w2: resident
            ((1, K), lambda ib, t: (0, 0)),
        ],
        out_specs=((bb, K), lambda ib, t: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="mr_step_fused_node",
    )(
        xs,
        h0,
        w_f1,
        b_f1.reshape(1, -1),
        w_f2,
        b_f2.reshape(1, -1),
        w_in,
        b_in.reshape(1, -1),
        w1,
        b1.reshape(1, -1),
        w2,
        b2.reshape(1, -1),
    )


def _ltc_q_step_math(
    x, h, w_in, w_rec, bias, a, inv_tau, sig_tab, *, sub_dt: float, n_substeps: int, n_seg: int
):
    """Int8-dequant + PWL-sigmoid LTC step (weights pre-dequantized by the
    kernel body once per grid step; the PWL segment-select chain reuses
    gru_scan's branch-free evaluator)."""
    f32 = jnp.float32
    drive = (
        jax.lax.dot_general(x, w_in, (((1,), (0,)), ((), ())), preferred_element_type=f32) + bias
    )
    for _ in range(n_substeps):
        pre = drive + jax.lax.dot_general(
            h, w_rec, (((1,), (0,)), ((), ())), preferred_element_type=f32
        )
        f = _pwl_eval(pre, sig_tab[0, :], sig_tab[1, :], -8.0, 8.0, n_seg, 0.0, 1.0)
        num = h + sub_dt * f * a
        den = 1.0 + sub_dt * (inv_tau + f)
        h = num / den
    return h


def _mr_step_ltc_q_kernel(
    xs_ref,
    h0_ref,
    w_inq_ref,  # int8 [D, H]
    w_in_scale_ref,  # [1, H]
    w_recq_ref,  # int8 [H, H]
    w_rec_scale_ref,  # [1, H]
    bias_ref,  # [1, H]
    a_ref,  # [1, H]
    inv_tau_ref,  # [1, H]
    sig_tab_ref,  # [2, n_seg]
    w1q_ref,  # int8 [H, Dh]
    w1_scale_ref,  # [1, Dh]
    b1_ref,
    w2q_ref,  # int8 [Dh, K]
    w2_scale_ref,  # [1, K]
    b2_ref,
    out_ref,
    h_scr,
    *,
    sub_dt: float,
    n_substeps: int,
    n_seg: int,
):
    """LTC substep scan + head, int8 weights + PWL sigmoid end to end."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    f32 = jnp.float32
    w_in = w_inq_ref[...].astype(f32) * w_in_scale_ref[0, :]
    w_rec = w_recq_ref[...].astype(f32) * w_rec_scale_ref[0, :]
    h_new = _ltc_q_step_math(
        xs_ref[:, 0, :].astype(f32),
        h_scr[...],
        w_in,
        w_rec,
        bias_ref[0, :],
        a_ref[0, :],
        inv_tau_ref[0, :],
        sig_tab_ref[...],
        sub_dt=sub_dt,
        n_substeps=n_substeps,
        n_seg=n_seg,
    )
    h_scr[...] = h_new

    @pl.when(t == pl.num_programs(1) - 1)
    def _head():
        w1 = w1q_ref[...].astype(f32) * w1_scale_ref[0, :]
        w2 = w2q_ref[...].astype(f32) * w2_scale_ref[0, :]
        out = _head_math(h_new, w1, b1_ref[0, :], w2, b2_ref[0, :], None)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("dt", "n_substeps", "block_b", "interpret", "n_seg")
)
def mr_step_ltc_pallas_int8(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    w_inq: jnp.ndarray,  # int8 [D, H]
    w_in_scale: jnp.ndarray,  # [H]
    w_recq: jnp.ndarray,  # int8 [H, H]
    w_rec_scale: jnp.ndarray,  # [H]
    bias: jnp.ndarray,  # [H]
    a: jnp.ndarray,  # [H]
    inv_tau: jnp.ndarray,  # [H]
    sig_tab: jnp.ndarray,  # [2, n_seg]
    w1q: jnp.ndarray,  # int8 [H, Dh]
    w1_scale: jnp.ndarray,  # [Dh]
    b1: jnp.ndarray,  # [Dh]
    w2q: jnp.ndarray,  # int8 [Dh, K]
    w2_scale: jnp.ndarray,  # [K]
    b2: jnp.ndarray,  # [K]
    dt: float = 1.0,
    n_substeps: int = 6,
    block_b: int | None = None,
    interpret: bool = False,
    n_seg: int = 16,
) -> jnp.ndarray:
    """Fixed-point fused LTC stage: int8 substep + head weights, PWL sigmoid."""
    B, T, D = xs.shape
    H = h0.shape[-1]
    Dh = w1q.shape[-1]
    K = w2q.shape[-1]
    bb = block_b or B
    assert B % bb == 0
    nb = B // bb
    kernel = functools.partial(
        _mr_step_ltc_q_kernel, sub_dt=dt / n_substeps, n_substeps=n_substeps, n_seg=n_seg
    )
    return rt.pallas_call_compat(
        kernel,
        grid=(nb, T),
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),
            ((bb, H), lambda ib, t: (ib, 0)),
            ((D, H), lambda ib, t: (0, 0)),
            ((1, H), lambda ib, t: (0, 0)),
            ((H, H), lambda ib, t: (0, 0)),
            ((1, H), lambda ib, t: (0, 0)),
            ((1, H), lambda ib, t: (0, 0)),
            ((1, H), lambda ib, t: (0, 0)),
            ((1, H), lambda ib, t: (0, 0)),
            ((2, n_seg), lambda ib, t: (0, 0)),
            ((H, Dh), lambda ib, t: (0, 0)),
            ((1, Dh), lambda ib, t: (0, 0)),
            ((1, Dh), lambda ib, t: (0, 0)),
            ((Dh, K), lambda ib, t: (0, 0)),
            ((1, K), lambda ib, t: (0, 0)),
            ((1, K), lambda ib, t: (0, 0)),
        ],
        out_specs=((bb, K), lambda ib, t: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="mr_step_fused_ltc_int8_pwl",
    )(
        xs,
        h0,
        w_inq,
        w_in_scale.reshape(1, -1),
        w_recq,
        w_rec_scale.reshape(1, -1),
        bias.reshape(1, -1),
        a.reshape(1, -1),
        inv_tau.reshape(1, -1),
        sig_tab,
        w1q,
        w1_scale.reshape(1, -1),
        b1.reshape(1, -1),
        w2q,
        w2_scale.reshape(1, -1),
        b2.reshape(1, -1),
    )
