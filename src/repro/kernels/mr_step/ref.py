"""Pure-jnp oracle for the fused MR per-window step (scan + norm + head).

Single source of truth for the stage math: the GRU(-flow) scan delegates to
core.neural_flow.gru_scan_ref, the multi-substep variants delegate to
core.ltc.ltc_scan / core.node_mr.node_scan, and the head block IS
merinda.head_math (one shared function — RMS-normalize, optional activation
fake-quant, relu MLP — not a hand-synced copy). The Pallas kernels
(kernel.py) are tested against this module; the weight-side QAT fake-quant
is applied by ops.py BEFORE either path so both consume identical weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ltc import LTCParams, ltc_scan
from repro.core.merinda import head_math
from repro.core.neural_flow import GRUParams, gru_scan_ref
from repro.core.node_mr import NodeEncoderParams, node_scan
from repro.core.quant import PWLTable, pwl_apply
from repro.kernels.gru_scan.ref import gru_scan_int8_reference

# the head stage of the fused oracle is literally the unfused head math
head_reference = head_math


def mr_step_reference(
    xs: jnp.ndarray,  # [B, T, D] (already normalized / activation-quantized)
    h0: jnp.ndarray,  # [B, H]
    wx: jnp.ndarray,  # [D, 3H]
    wh: jnp.ndarray,  # [H, 3H]
    b: jnp.ndarray,  # [3H]
    time_scale: jnp.ndarray,  # [H]
    dts: jnp.ndarray,  # [T]
    w1: jnp.ndarray,  # [H, Dh]
    b1: jnp.ndarray,  # [Dh]
    w2: jnp.ndarray,  # [Dh, K]
    b2: jnp.ndarray,  # [K]
    flow: bool = True,
    act_bits: tuple[int, int] | None = None,
    unroll: int = 1,
) -> jnp.ndarray:
    """Fused-stage oracle. Returns the raw head output [B, K]."""
    params = GRUParams(w=jnp.concatenate([wx, wh], axis=0), b=b, time_scale=time_scale)
    h_T, _ = gru_scan_ref(params, xs, h0, dts=dts, flow=flow, unroll=unroll)
    return head_math(h_T, w1, b1, w2, b2, act_bits=act_bits)


def mr_step_ltc_reference(
    xs: jnp.ndarray,  # [B, T, D] (already normalized)
    h0: jnp.ndarray,  # [B, H]
    w_in: jnp.ndarray,  # [D, H]
    w_rec: jnp.ndarray,  # [H, H]
    bias: jnp.ndarray,  # [H]
    a: jnp.ndarray,  # [H]   equilibrium target
    inv_tau: jnp.ndarray,  # [H]
    w1: jnp.ndarray,  # [H, Dh]
    b1: jnp.ndarray,  # [Dh]
    w2: jnp.ndarray,  # [Dh, K]
    b2: jnp.ndarray,  # [K]
    *,
    dt: float = 1.0,
    n_substeps: int = 6,
    act_bits: tuple[int, int] | None = None,
    unroll: int = 1,
) -> jnp.ndarray:
    """Fused multi-substep LTC oracle (semi-implicit fused-solver substeps).

    Delegates the substep math to core.ltc.ltc_scan — identical semantics to
    the unfused ``encoder="ltc"`` stage sequence. Returns the raw head
    output [B, K].
    """
    params = LTCParams(w_in=w_in, w_rec=w_rec, bias=bias, a=a, inv_tau=inv_tau)
    h_T, _ = ltc_scan(params, xs, h0, dt=dt, n_substeps=n_substeps, unroll=unroll)
    return head_math(h_T, w1, b1, w2, b2, act_bits=act_bits)


def mr_step_node_reference(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    w_f1: jnp.ndarray,  # [H, H]  vector-field MLP
    b_f1: jnp.ndarray,  # [H]
    w_f2: jnp.ndarray,  # [H, H]
    b_f2: jnp.ndarray,  # [H]
    w_in: jnp.ndarray,  # [D, H]  observation injection
    b_in: jnp.ndarray,  # [H]
    w1: jnp.ndarray,  # [H, Dh]
    b1: jnp.ndarray,  # [Dh]
    w2: jnp.ndarray,  # [Dh, K]
    b2: jnp.ndarray,  # [K]
    *,
    dt: float = 1.0,
    n_substeps: int = 6,
    act_bits: tuple[int, int] | None = None,
    unroll: int = 1,
) -> jnp.ndarray:
    """Fused multi-substep NODE (ODE-RNN) oracle: fixed-step Euler substeps.

    Delegates to core.node_mr.node_scan — identical semantics to the unfused
    ``encoder="node"`` stage sequence. Returns the raw head output [B, K].
    """
    params = NodeEncoderParams(
        w_f1=w_f1, b_f1=b_f1, w_f2=w_f2, b_f2=b_f2, w_in=w_in, b_in=b_in
    )
    h_T, _ = node_scan(params, xs, h0, dt=dt, n_substeps=n_substeps, unroll=unroll)
    return head_math(h_T, w1, b1, w2, b2, act_bits=act_bits)


def ltc_scan_int8_reference(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    w_inq: jnp.ndarray,  # int8 [D, H]
    w_in_scale: jnp.ndarray,
    w_recq: jnp.ndarray,  # int8 [H, H]
    w_rec_scale: jnp.ndarray,
    bias: jnp.ndarray,
    a: jnp.ndarray,
    inv_tau: jnp.ndarray,
    sig_table: PWLTable,
    *,
    dt: float = 1.0,
    n_substeps: int = 6,
) -> jnp.ndarray:
    """Int8-dequant + PWL-sigmoid LTC scan oracle (float32 math)."""
    f32 = jnp.float32
    w_in = w_inq.astype(f32) * w_in_scale
    w_rec = w_recq.astype(f32) * w_rec_scale
    sub_dt = dt / n_substeps

    def cell(h, x):
        drive = x.astype(f32) @ w_in + bias

        def substep(h, _):
            f = pwl_apply(sig_table, drive + h @ w_rec)
            num = h + sub_dt * f * a
            den = 1.0 + sub_dt * (inv_tau + f)
            return num / den, None

        h, _ = jax.lax.scan(substep, h, None, length=n_substeps)
        return h, None

    h_T, _ = jax.lax.scan(cell, h0.astype(f32), jnp.swapaxes(xs, 0, 1))
    return h_T


def mr_step_ltc_int8_reference(
    xs: jnp.ndarray,
    h0: jnp.ndarray,
    w_inq: jnp.ndarray,  # int8 [D, H]
    w_in_scale: jnp.ndarray,
    w_recq: jnp.ndarray,  # int8 [H, H]
    w_rec_scale: jnp.ndarray,
    bias: jnp.ndarray,
    a: jnp.ndarray,
    inv_tau: jnp.ndarray,
    w1q: jnp.ndarray,  # int8 [H, Dh]
    w1_scale: jnp.ndarray,
    b1: jnp.ndarray,
    w2q: jnp.ndarray,  # int8 [Dh, K]
    w2_scale: jnp.ndarray,
    b2: jnp.ndarray,
    sig_table: PWLTable,
    *,
    dt: float = 1.0,
    n_substeps: int = 6,
) -> jnp.ndarray:
    """Fixed-point fused LTC oracle: int8 substep AND head weights + PWL."""
    f32 = jnp.float32
    h_T = ltc_scan_int8_reference(
        xs,
        h0,
        w_inq,
        w_in_scale,
        w_recq,
        w_rec_scale,
        bias,
        a,
        inv_tau,
        sig_table,
        dt=dt,
        n_substeps=n_substeps,
    )
    w1 = w1q.astype(f32) * w1_scale
    w2 = w2q.astype(f32) * w2_scale
    return head_math(h_T, w1, b1, w2, b2)


def mr_step_int8_reference(
    xs: jnp.ndarray,
    h0: jnp.ndarray,
    wxq: jnp.ndarray,  # int8 [D, 3H]
    whq: jnp.ndarray,  # int8 [H, 3H]
    wx_scale: jnp.ndarray,
    wh_scale: jnp.ndarray,
    b: jnp.ndarray,
    dts: jnp.ndarray,
    w1q: jnp.ndarray,  # int8 [H, Dh]
    w1_scale: jnp.ndarray,
    b1: jnp.ndarray,
    w2q: jnp.ndarray,  # int8 [Dh, K]
    w2_scale: jnp.ndarray,
    b2: jnp.ndarray,
    sig_table: PWLTable,
    tanh_table: PWLTable,
) -> jnp.ndarray:
    """Int8-dequant + PWL oracle (standard GRU + int8 head, float32 math)."""
    f32 = jnp.float32
    hs = gru_scan_int8_reference(
        xs, h0, wxq, whq, wx_scale, wh_scale, b, dts, sig_table, tanh_table
    )
    w1 = w1q.astype(f32) * w1_scale
    w2 = w2q.astype(f32) * w2_scale
    return head_math(hs[:, -1, :], w1, b1, w2, b2)


# ---------------------------------------------------------------------------
# banked one-kernel tick oracles (serve-only; see kernels/mr_step/tick.py)
# ---------------------------------------------------------------------------
def _tick_ema_delta(raw, theta0, seed, active, ema):
    """EMA blend + relative coefficient delta (core/stream.tick readout math)."""
    theta = jnp.where(seed[:, None], raw, ema * theta0 + (1.0 - ema) * raw)
    change = jnp.max(jnp.abs(theta - theta0), axis=-1)
    delta = change / (jnp.max(jnp.abs(theta), axis=-1) + 1e-3)
    return theta, jnp.where(active, delta, jnp.inf)


def mr_tick_reference(
    buf_y: jnp.ndarray,  # [S, L, n] pre-roll ring buffers
    new_y: jnp.ndarray,  # [S, C, n]
    mean: jnp.ndarray,  # [S, n]
    scale: jnp.ndarray,  # [S, n]
    theta0: jnp.ndarray,  # [S, Kc] previous readout, flattened
    seed: jnp.ndarray,  # [S] bool
    active: jnp.ndarray,  # [S] bool
    wx: jnp.ndarray,  # [S, D, 3H] per-slot gate weights
    wh: jnp.ndarray,  # [S, H, 3H]
    b: jnp.ndarray,  # [S, 3H]
    time_scale: jnp.ndarray,  # [S, H]
    w1: jnp.ndarray,  # [S, H, Dh]
    b1: jnp.ndarray,  # [S, Dh]
    w2: jnp.ndarray,  # [S, Dh, Ko]
    b2: jnp.ndarray,  # [S, Ko]
    buf_u: jnp.ndarray | None = None,  # [S, L, m] when m > 0
    new_u: jnp.ndarray | None = None,
    *,
    flow: bool,
    window: int,
    stride: int,
    ema: float,
):
    """Banked-tick oracle: the EXISTING ingest/step/readout composition —
    data/windows roll + window views, ``mr_step_reference`` per slot, EMA +
    delta — returning (buf_y, theta [S, Kc], delta [S][, buf_u]) in the
    kernel's output order."""
    from repro.data.windows import roll_buffer, window_views

    buf_y = roll_buffer(buf_y, new_y)
    has_u = buf_u is not None
    if has_u:
        buf_u = roll_buffer(buf_u, new_u)
    n_coef = theta0.shape[-1]
    hidden = wh.shape[1]
    dts = jnp.ones((window,), jnp.float32)

    def one(y, u, mu, sd, wx_s, wh_s, b_s, ts_s, w1_s, b1_s, w2_s, b2_s):
        xs = window_views((y - mu) / sd, window, stride)
        if u is not None:
            xs = jnp.concatenate([xs, window_views(u, window, stride)], axis=-1)
        h0 = jnp.zeros((xs.shape[0], hidden), jnp.float32)
        out = mr_step_reference(
            xs, h0, wx_s, wh_s, b_s, ts_s, dts, w1_s, b1_s, w2_s, b2_s, flow=flow
        )
        return jnp.mean(out[:, :n_coef], axis=0)

    if has_u:
        raw = jax.vmap(one)(buf_y, buf_u, mean, scale, wx, wh, b, time_scale, w1, b1, w2, b2)
    else:
        raw = jax.vmap(lambda y, mu, sd, *w: one(y, None, mu, sd, *w))(
            buf_y, mean, scale, wx, wh, b, time_scale, w1, b1, w2, b2
        )
    theta, delta = _tick_ema_delta(raw, theta0, seed, active, ema)
    return (buf_y, theta, delta, buf_u) if has_u else (buf_y, theta, delta)


def mr_tick_int8_reference(
    buf_y: jnp.ndarray,
    new_y: jnp.ndarray,
    mean: jnp.ndarray,
    scale: jnp.ndarray,
    theta0: jnp.ndarray,
    seed: jnp.ndarray,
    active: jnp.ndarray,
    wxq: jnp.ndarray,  # int8 [S, D, 3H]
    whq: jnp.ndarray,  # int8 [S, H, 3H]
    wx_scale: jnp.ndarray,  # [S, 1, 3H]
    wh_scale: jnp.ndarray,  # [S, 1, 3H]
    b: jnp.ndarray,  # [S, 3H]
    w1q: jnp.ndarray,  # int8 [S, H, Dh]
    w1_scale: jnp.ndarray,  # [S, 1, Dh]
    b1: jnp.ndarray,  # [S, Dh]
    w2q: jnp.ndarray,  # int8 [S, Dh, Ko]
    w2_scale: jnp.ndarray,  # [S, 1, Ko]
    b2: jnp.ndarray,  # [S, Ko]
    sig_table: PWLTable,
    tanh_table: PWLTable,
    buf_u: jnp.ndarray | None = None,
    new_u: jnp.ndarray | None = None,
    *,
    window: int,
    stride: int,
    ema: float,
):
    """Int8/PWL banked-tick oracle (serving twin of ``mr_tick_reference``)."""
    from repro.data.windows import roll_buffer, window_views

    buf_y = roll_buffer(buf_y, new_y)
    has_u = buf_u is not None
    if has_u:
        buf_u = roll_buffer(buf_u, new_u)
    n_coef = theta0.shape[-1]
    hidden = whq.shape[1]
    dts = jnp.ones((window,), jnp.float32)

    def one(y, u, mu, sd, wxq_s, whq_s, wxs, whs, b_s, w1q_s, w1s, b1_s, w2q_s, w2s, b2_s):
        xs = window_views((y - mu) / sd, window, stride)
        if u is not None:
            xs = jnp.concatenate([xs, window_views(u, window, stride)], axis=-1)
        h0 = jnp.zeros((xs.shape[0], hidden), jnp.float32)
        out = mr_step_int8_reference(
            xs,
            h0,
            wxq_s,
            whq_s,
            wxs,
            whs,
            b_s,
            dts,
            w1q_s,
            w1s,
            b1_s,
            w2q_s,
            w2s,
            b2_s,
            sig_table,
            tanh_table,
        )
        return jnp.mean(out[:, :n_coef], axis=0)

    args = (wxq, whq, wx_scale, wh_scale, b, w1q, w1_scale, b1, w2q, w2_scale, b2)
    if has_u:
        raw = jax.vmap(one)(buf_y, buf_u, mean, scale, *args)
    else:
        raw = jax.vmap(lambda y, mu, sd, *w: one(y, None, mu, sd, *w))(buf_y, mean, scale, *args)
    theta, delta = _tick_ema_delta(raw, theta0, seed, active, ema)
    return (buf_y, theta, delta, buf_u) if has_u else (buf_y, theta, delta)
