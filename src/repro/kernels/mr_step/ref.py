"""Pure-jnp oracle for the fused MR per-window step (scan + norm + head).

Single source of truth for the stage math: the GRU(-flow) scan delegates to
core.neural_flow.gru_scan_ref and the head block IS merinda.head_math (one
shared function — RMS-normalize, optional activation fake-quant, relu MLP —
not a hand-synced copy). The Pallas kernel (kernel.py) is tested against
this module; the weight-side QAT fake-quant is applied by ops.py BEFORE
either path so both consume identical weights.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.merinda import head_math
from repro.core.neural_flow import GRUParams, gru_scan_ref
from repro.core.quant import PWLTable
from repro.kernels.gru_scan.ref import gru_scan_int8_reference

# the head stage of the fused oracle is literally the unfused head math
head_reference = head_math


def mr_step_reference(
    xs: jnp.ndarray,  # [B, T, D] (already normalized / activation-quantized)
    h0: jnp.ndarray,  # [B, H]
    wx: jnp.ndarray,  # [D, 3H]
    wh: jnp.ndarray,  # [H, 3H]
    b: jnp.ndarray,  # [3H]
    time_scale: jnp.ndarray,  # [H]
    dts: jnp.ndarray,  # [T]
    w1: jnp.ndarray,  # [H, Dh]
    b1: jnp.ndarray,  # [Dh]
    w2: jnp.ndarray,  # [Dh, K]
    b2: jnp.ndarray,  # [K]
    flow: bool = True,
    act_bits: tuple[int, int] | None = None,
) -> jnp.ndarray:
    """Fused-stage oracle. Returns the raw head output [B, K]."""
    params = GRUParams(w=jnp.concatenate([wx, wh], axis=0), b=b, time_scale=time_scale)
    h_T, _ = gru_scan_ref(params, xs, h0, dts=dts, flow=flow)
    return head_math(h_T, w1, b1, w2, b2, act_bits=act_bits)


def mr_step_int8_reference(
    xs: jnp.ndarray,
    h0: jnp.ndarray,
    wxq: jnp.ndarray,  # int8 [D, 3H]
    whq: jnp.ndarray,  # int8 [H, 3H]
    wx_scale: jnp.ndarray,
    wh_scale: jnp.ndarray,
    b: jnp.ndarray,
    dts: jnp.ndarray,
    w1q: jnp.ndarray,  # int8 [H, Dh]
    w1_scale: jnp.ndarray,
    b1: jnp.ndarray,
    w2q: jnp.ndarray,  # int8 [Dh, K]
    w2_scale: jnp.ndarray,
    b2: jnp.ndarray,
    sig_table: PWLTable,
    tanh_table: PWLTable,
) -> jnp.ndarray:
    """Int8-dequant + PWL oracle (standard GRU + int8 head, float32 math)."""
    f32 = jnp.float32
    hs = gru_scan_int8_reference(
        xs, h0, wxq, whq, wx_scale, wh_scale, b, dts, sig_table, tanh_table
    )
    w1 = w1q.astype(f32) * w1_scale
    w2 = w2q.astype(f32) * w2_scale
    return head_math(hs[:, -1, :], w1, b1, w2, b2)
