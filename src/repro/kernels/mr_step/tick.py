"""Banked one-kernel service tick: the ``mr_tick`` kernel family.

The composite service tick (core/stream.tick) executes the serving side of a
tick as a sequence of XLA ops — ring-buffer roll, per-slot window gather +
normalization, the per-window encoder scan, the head readout, the EMA/delta
update — with the intermediate tensors round-tripping HBM between stages.
``mr_tick`` is the paper's banked-BRAM dataflow applied one level above the
per-window step: ONE ``pallas_call`` whose grid banks the S service slots
(``slots_per_bank`` slots per grid step, kernels/mr_step/tiling.py sizes the
bank against ``detect_vmem_budget``) and whose body runs, per bank,

  1. ring-buffer window ingest  — the roll (drop the oldest ``chunk`` rows,
     append the tick's chunk), the frozen-at-admission normalization and the
     static window slicing happen in-kernel; the rolled buffer is written
     back as a kernel output, so buffer maintenance and readout share one
     program;
  2. K unrolled recovery substeps — the T encoder gate updates of every
     window run as a static unroll over the VMEM-resident hidden state
     (``_gru_step_math``, the exact math of the fused per-window step);
  3. the EMA Theta readout      — head MLP, mean over windows, EMA blend
     with the previous readout (first-tick seeding included) and the
     relative coefficient delta the eviction policy watches.

Because every input block is indexed by the bank grid axis, Mosaic
double-buffers the streamed blocks automatically: bank ``i+1``'s window
buffer and weights DMA into VMEM while bank ``i`` computes — the ping-pong
window DMA of the paper's streaming pipeline, with no hand-written
semaphores. The tick is serve-only (the K optimizer steps of a training
tick stay in the XLA train scan, core/stream.tick_banked), so no
``custom_vjp`` is needed.

Variants: fp32 GRU(-flow) (``mr_tick_pallas``) and the int8/PWL serving
twin (``mr_tick_pallas_int8``: int8 gate + head weights with per-slot
per-channel scales, PWL sigmoid/tanh — standard GRU cell only, matching
``mr_step_pallas_int8``). ``mr_tick`` is the dispatch wrapper (compiled
kernel on TPU, interpret for CPU correctness sweeps, the ``ref.py`` oracle
otherwise); the oracle delegates to the existing ingest/step/readout
composition (data/windows.py + ``mr_step_reference``).

Control-plane composition contract (core/control.tick_device): under
``TickSpec(control="device")`` the banked tick body runs INSIDE the
device-resident control-plane program — the kernel's packed ``[S, 4]``
status block feeds the in-program eviction mask, queue refill and
warm-start push directly, with no intermediate host readback. The kernel
therefore must stay (a) shape-stable in the slot axis (eviction/refill
rewrite slot rows in place, never resize), (b) collective-free when the
slot axis is sharded (rules.predict_tick_collectives stays empty — audit
rule R5 covers the composed program), and (c) side-effect-free beyond its
declared outputs, so the surrounding program's donation of SlotState and
ControlState holds (audit rule R1)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import encoders
from repro.core.quant import make_sigmoid_table, make_tanh_table, quantize_int8
from repro.data.windows import roll_buffer
from repro.kernels import runtime as rt
from repro.kernels.gru_scan.kernel import _gru_q_step_math, _gru_step_math
from repro.kernels.mr_step import ref as _ref
from repro.kernels.mr_step.kernel import _head_math
from repro.kernels.mr_step.ops import _head_weights


def tick_supported(cfg, *, int8: bool = False) -> bool:
    """True when the banked tick kernel implements ``cfg``'s encoder cell.

    v1 banks the GRU(-flow) families (single gated update per window step);
    the multi-substep cells (ltc/node) stay on the composite tick —
    ``compile_plan`` resolves ``tick_kernel="auto"`` through this predicate.
    The int8 twin additionally needs the PWL cell mapping (standard GRU).
    """
    spec = encoders.get_encoder(cfg.encoder)
    if spec.family != "gru":
        return False
    return bool(spec.int8) if int8 else True


# ---------------------------------------------------------------------------
# fp32 banked tick kernel
# ---------------------------------------------------------------------------
def _mr_tick_kernel(
    *refs,
    bank: int,
    window: int,
    stride: int,
    n_windows: int,
    n_coef: int,
    flow: bool,
    hidden: int,
    ema: float,
    has_u: bool,
):
    """One grid step = one bank of ``bank`` slots, ingest through readout."""
    (buf_y, new_y, mean, scale, theta0, seed, active, wx, wh, b, ts, w1, b1, w2, b2) = refs[:15]
    i = 15
    if has_u:
        buf_u, new_u = refs[i], refs[i + 1]
        i += 2
    buf_y_out, theta_out, delta_out = refs[i], refs[i + 1], refs[i + 2]
    if has_u:
        buf_u_out = refs[i + 3]

    # 1. ring-buffer window ingest: roll in-kernel, write the buffer back
    chunk = new_y.shape[1]
    rolled_y = jnp.concatenate([buf_y[:, chunk:, :], new_y[...]], axis=1)
    buf_y_out[...] = rolled_y
    if has_u:
        rolled_u = jnp.concatenate([buf_u[:, chunk:, :], new_u[...]], axis=1)
        buf_u_out[...] = rolled_u

    for s in range(bank):  # static unroll: the bank's slots share the VMEM stay
        xn = (rolled_y[s] - mean[s, :][None, :]) / scale[s, :][None, :]
        x = jnp.concatenate([xn, rolled_u[s]], axis=-1) if has_u else xn
        # static window slices of the rolled buffer (data/windows semantics)
        xs = jnp.stack([x[w * stride : w * stride + window] for w in range(n_windows)])
        # 2. K unrolled recovery substeps over the VMEM-resident hidden state
        h = jnp.zeros((n_windows, hidden), jnp.float32)
        for t in range(window):
            h = _gru_step_math(
                xs[:, t, :],
                h,
                wx[s],
                wh[s],
                b[s, :],
                ts[s, :],
                jnp.float32(1.0),
                flow=flow,
                hidden=hidden,
            )
        # 3. EMA Theta readout + relative delta (the eviction signal)
        out = _head_math(h, w1[s], b1[s, :], w2[s], b2[s, :], None)
        raw = jnp.mean(out[:, :n_coef], axis=0)
        prev = theta0[s, :]
        theta = jnp.where(seed[s, 0] > 0, raw, ema * prev + (1.0 - ema) * raw)
        delta = jnp.max(jnp.abs(theta - prev)) / (jnp.max(jnp.abs(theta)) + 1e-3)
        theta_out[s, :] = theta
        delta_out[s, 0] = jnp.where(active[s, 0] > 0, delta, jnp.inf)


@functools.partial(
    jax.jit, static_argnames=("flow", "window", "stride", "ema", "slots_per_bank", "interpret")
)
def mr_tick_pallas(
    buf_y: jnp.ndarray,  # [S, L, n] pre-roll ring buffers
    new_y: jnp.ndarray,  # [S, C, n] this tick's chunk
    mean: jnp.ndarray,  # [S, n] frozen admission stats
    scale: jnp.ndarray,  # [S, n]
    theta0: jnp.ndarray,  # [S, Kc] previous EMA readout (flattened)
    seed: jnp.ndarray,  # [S, 1] f32, 1.0 = seed the EMA this tick
    active: jnp.ndarray,  # [S, 1] f32
    wx: jnp.ndarray,  # [S, D, 3H] per-slot gate weights
    wh: jnp.ndarray,  # [S, H, 3H]
    b: jnp.ndarray,  # [S, 3H]
    time_scale: jnp.ndarray,  # [S, H]
    w1: jnp.ndarray,  # [S, H, Dh] per-slot head weights
    b1: jnp.ndarray,  # [S, Dh]
    w2: jnp.ndarray,  # [S, Dh, Ko]
    b2: jnp.ndarray,  # [S, Ko]
    buf_u: jnp.ndarray | None = None,  # [S, L, m] when m > 0
    new_u: jnp.ndarray | None = None,  # [S, C, m]
    *,
    flow: bool,
    window: int,
    stride: int,
    ema: float,
    slots_per_bank: int = 1,
    interpret: bool = False,
):
    """Banked tick. Returns (buf_y, theta [S, Kc], delta [S, 1][, buf_u])."""
    S, L, n = buf_y.shape
    C = new_y.shape[1]
    H = wh.shape[1]
    Dh = w1.shape[-1]
    Ko = w2.shape[-1]
    Kc = theta0.shape[-1]
    D = wx.shape[1]
    N = (L - window) // stride + 1
    bank = slots_per_bank
    assert S % bank == 0, f"{S} slots not divisible by slots_per_bank {bank}"
    has_u = buf_u is not None

    def blk(*shape):
        return ((bank, *shape), lambda ib: (ib,) + (0,) * len(shape))

    in_specs = [
        blk(L, n),  # buf_y: streamed per bank (Mosaic ping-pongs the DMA)
        blk(C, n),  # new_y
        blk(n),  # mean
        blk(n),  # scale
        blk(Kc),  # theta0
        blk(1),  # seed
        blk(1),  # active
        blk(D, 3 * H),  # wx: the bank's slots resident together
        blk(H, 3 * H),  # wh
        blk(3 * H),  # b
        blk(H),  # time_scale
        blk(H, Dh),  # head w1
        blk(Dh),  # head b1
        blk(Dh, Ko),  # head w2
        blk(Ko),  # head b2
    ]
    operands = [buf_y, new_y, mean, scale, theta0, seed, active, wx, wh, b, time_scale]
    operands += [w1, b1, w2, b2]
    out_specs = [blk(L, n), blk(Kc), blk(1)]
    out_shape = [
        jax.ShapeDtypeStruct((S, L, n), jnp.float32),
        jax.ShapeDtypeStruct((S, Kc), jnp.float32),
        jax.ShapeDtypeStruct((S, 1), jnp.float32),
    ]
    if has_u:
        m = buf_u.shape[-1]
        in_specs += [blk(L, m), blk(C, m)]
        operands += [buf_u, new_u]
        out_specs.append(blk(L, m))
        out_shape.append(jax.ShapeDtypeStruct((S, L, m), jnp.float32))

    kernel = functools.partial(
        _mr_tick_kernel,
        bank=bank,
        window=window,
        stride=stride,
        n_windows=N,
        n_coef=Kc,
        flow=flow,
        hidden=H,
        ema=ema,
        has_u=has_u,
    )
    return rt.pallas_call_compat(
        kernel,
        grid=(S // bank,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        dimension_semantics=(rt.PARALLEL,),
        interpret=interpret,
        name="mr_tick_banked",
    )(*operands)


# ---------------------------------------------------------------------------
# int8 + PWL serving twin (standard GRU cell)
# ---------------------------------------------------------------------------
def _mr_tick_q_kernel(
    *refs,
    bank: int,
    window: int,
    stride: int,
    n_windows: int,
    n_coef: int,
    hidden: int,
    ema: float,
    n_seg: int,
    has_u: bool,
):
    (buf_y, new_y, mean, scale, theta0, seed, active) = refs[:7]
    (wxq, whq, wx_scale, wh_scale, b, sig_tab, tanh_tab) = refs[7:14]
    (w1q, w1_scale, b1, w2q, w2_scale, b2) = refs[14:20]
    i = 20
    if has_u:
        buf_u, new_u = refs[i], refs[i + 1]
        i += 2
    buf_y_out, theta_out, delta_out = refs[i], refs[i + 1], refs[i + 2]
    if has_u:
        buf_u_out = refs[i + 3]

    chunk = new_y.shape[1]
    rolled_y = jnp.concatenate([buf_y[:, chunk:, :], new_y[...]], axis=1)
    buf_y_out[...] = rolled_y
    if has_u:
        rolled_u = jnp.concatenate([buf_u[:, chunk:, :], new_u[...]], axis=1)
        buf_u_out[...] = rolled_u

    f32 = jnp.float32
    for s in range(bank):
        xn = (rolled_y[s] - mean[s, :][None, :]) / scale[s, :][None, :]
        x = jnp.concatenate([xn, rolled_u[s]], axis=-1) if has_u else xn
        xs = jnp.stack([x[w * stride : w * stride + window] for w in range(n_windows)])
        h = jnp.zeros((n_windows, hidden), f32)
        for t in range(window):
            h = _gru_q_step_math(
                xs[:, t, :].astype(f32),
                h,
                wxq[s],
                whq[s],
                wx_scale[s, :],
                wh_scale[s, :],
                b[s, :],
                sig_tab[...],
                tanh_tab[...],
                hidden=hidden,
                n_seg=n_seg,
            )
        w1 = w1q[s].astype(f32) * w1_scale[s, :]
        w2 = w2q[s].astype(f32) * w2_scale[s, :]
        out = _head_math(h, w1, b1[s, :], w2, b2[s, :], None)
        raw = jnp.mean(out[:, :n_coef], axis=0)
        prev = theta0[s, :]
        theta = jnp.where(seed[s, 0] > 0, raw, ema * prev + (1.0 - ema) * raw)
        delta = jnp.max(jnp.abs(theta - prev)) / (jnp.max(jnp.abs(theta)) + 1e-3)
        theta_out[s, :] = theta
        delta_out[s, 0] = jnp.where(active[s, 0] > 0, delta, jnp.inf)


@functools.partial(
    jax.jit, static_argnames=("window", "stride", "ema", "slots_per_bank", "interpret", "n_seg")
)
def mr_tick_pallas_int8(
    buf_y: jnp.ndarray,
    new_y: jnp.ndarray,
    mean: jnp.ndarray,
    scale: jnp.ndarray,
    theta0: jnp.ndarray,
    seed: jnp.ndarray,
    active: jnp.ndarray,
    wxq: jnp.ndarray,  # int8 [S, D, 3H]
    whq: jnp.ndarray,  # int8 [S, H, 3H]
    wx_scale: jnp.ndarray,  # [S, 3H] per-slot per-channel scales
    wh_scale: jnp.ndarray,  # [S, 3H]
    b: jnp.ndarray,  # [S, 3H]
    sig_tab: jnp.ndarray,  # [2, n_seg] shared PWL tables
    tanh_tab: jnp.ndarray,  # [2, n_seg]
    w1q: jnp.ndarray,  # int8 [S, H, Dh]
    w1_scale: jnp.ndarray,  # [S, Dh]
    b1: jnp.ndarray,  # [S, Dh]
    w2q: jnp.ndarray,  # int8 [S, Dh, Ko]
    w2_scale: jnp.ndarray,  # [S, Ko]
    b2: jnp.ndarray,  # [S, Ko]
    buf_u: jnp.ndarray | None = None,
    new_u: jnp.ndarray | None = None,
    *,
    window: int,
    stride: int,
    ema: float,
    slots_per_bank: int = 1,
    interpret: bool = False,
    n_seg: int = 16,
):
    S, L, n = buf_y.shape
    C = new_y.shape[1]
    H = whq.shape[1]
    Dh = w1q.shape[-1]
    Ko = w2q.shape[-1]
    Kc = theta0.shape[-1]
    D = wxq.shape[1]
    N = (L - window) // stride + 1
    bank = slots_per_bank
    assert S % bank == 0, f"{S} slots not divisible by slots_per_bank {bank}"
    has_u = buf_u is not None

    def blk(*shape):
        return ((bank, *shape), lambda ib: (ib,) + (0,) * len(shape))

    tab = ((2, n_seg), lambda ib: (0, 0))
    in_specs = [blk(L, n), blk(C, n), blk(n), blk(n), blk(Kc), blk(1), blk(1)]
    in_specs += [blk(D, 3 * H), blk(H, 3 * H), blk(3 * H), blk(3 * H), blk(3 * H), tab, tab]
    in_specs += [blk(H, Dh), blk(Dh), blk(Dh), blk(Dh, Ko), blk(Ko), blk(Ko)]
    operands = [buf_y, new_y, mean, scale, theta0, seed, active]
    operands += [wxq, whq, wx_scale, wh_scale, b, sig_tab, tanh_tab]
    operands += [w1q, w1_scale, b1, w2q, w2_scale, b2]
    out_specs = [blk(L, n), blk(Kc), blk(1)]
    out_shape = [
        jax.ShapeDtypeStruct((S, L, n), jnp.float32),
        jax.ShapeDtypeStruct((S, Kc), jnp.float32),
        jax.ShapeDtypeStruct((S, 1), jnp.float32),
    ]
    if has_u:
        m = buf_u.shape[-1]
        in_specs += [blk(L, m), blk(C, m)]
        operands += [buf_u, new_u]
        out_specs.append(blk(L, m))
        out_shape.append(jax.ShapeDtypeStruct((S, L, m), jnp.float32))

    kernel = functools.partial(
        _mr_tick_q_kernel,
        bank=bank,
        window=window,
        stride=stride,
        n_windows=N,
        n_coef=Kc,
        hidden=H,
        ema=ema,
        n_seg=n_seg,
        has_u=has_u,
    )
    return rt.pallas_call_compat(
        kernel,
        grid=(S // bank,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        dimension_semantics=(rt.PARALLEL,),
        interpret=interpret,
        name="mr_tick_banked_int8_pwl",
    )(*operands)


# ---------------------------------------------------------------------------
# dispatch wrapper
# ---------------------------------------------------------------------------
def mr_tick(
    params,  # slot-stacked MRParams (every leaf has leading axis S)
    cfg,  # merinda.MRConfig (GRU-family encoder)
    scfg,  # stream.StreamConfig (window/stride/chunk/ema geometry)
    buf_y: jnp.ndarray,  # [S, L, n] pre-roll buffers
    buf_u: jnp.ndarray,  # [S, L, m] (m may be 0)
    new_y: jnp.ndarray,  # [S, C, n]
    new_u: jnp.ndarray,  # [S, C, m]
    mean: jnp.ndarray,  # [S, n]
    scale: jnp.ndarray,  # [S, n]
    theta_prev: jnp.ndarray,  # [S, n_terms, n] previous EMA readout
    seed: jnp.ndarray,  # [S] bool: seed the EMA this tick
    active: jnp.ndarray,  # [S] bool
    *,
    quant: bool = False,
    slots_per_bank: int = 1,
    n_seg: int = 16,
    force_reference: bool = False,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-kernel serve tick: ingest + window substeps + EMA readout + delta.

    Returns ``(buf_y, buf_u, theta [S, n_terms, n], delta [S])`` — the rolled
    buffers and the post-EMA readout/eviction signal, all produced by one
    banked program. ``quant=True`` serves through the int8/PWL twin.
    Backend policy matches ops.mr_step: Pallas kernel on TPU, interpret for
    CPU correctness sweeps, the ref.py oracle otherwise.
    """
    spec = encoders.get_encoder(cfg.encoder)
    if not tick_supported(cfg, int8=quant):
        raise ValueError(
            f"mr_tick banks the GRU families only (int8 twin: standard 'gru' cell); "
            f"got encoder={cfg.encoder!r} quant={quant} — use the composite tick"
        )
    S = buf_y.shape[0]
    d_in = cfg.state_dim + cfg.input_dim
    theta0 = theta_prev.reshape(S, cfg.n_coef)
    has_u = cfg.input_dim > 0
    disp = rt.resolve_dispatch(force_reference, interpret)
    interp = disp is rt.Dispatch.INTERPRET
    u_args = (buf_u, new_u) if has_u else (None, None)
    kw = dict(window=scfg.window, stride=scfg.stride, ema=scfg.ema)

    if quant:
        wxq = jax.vmap(lambda w: quantize_int8(w, axis=-1))(params.encoder.w[:, :d_in])
        whq = jax.vmap(lambda w: quantize_int8(w, axis=-1))(params.encoder.w[:, d_in:])
        w1q = jax.vmap(lambda w: quantize_int8(w, axis=-1))(params.head_w1)
        w2q = jax.vmap(lambda w: quantize_int8(w, axis=-1))(params.head_w2)
        sig_t, tanh_t = make_sigmoid_table(n_seg), make_tanh_table(n_seg)
        if disp is rt.Dispatch.REFERENCE:
            out = _ref.mr_tick_int8_reference(
                buf_y,
                new_y,
                mean,
                scale,
                theta0,
                seed,
                active,
                wxq.values,
                whq.values,
                wxq.scale,
                whq.scale,
                params.encoder.b,
                w1q.values,
                w1q.scale,
                params.head_b1,
                w2q.values,
                w2q.scale,
                params.head_b2,
                sig_t,
                tanh_t,
                *u_args,
                **kw,
            )
        else:
            out = mr_tick_pallas_int8(
                buf_y,
                new_y,
                mean,
                scale,
                theta0,
                seed.astype(jnp.float32).reshape(S, 1),
                active.astype(jnp.float32).reshape(S, 1),
                wxq.values,
                whq.values,
                wxq.scale.reshape(S, -1),
                whq.scale.reshape(S, -1),
                params.encoder.b,
                jnp.stack([sig_t.slopes, sig_t.intercepts]),
                jnp.stack([tanh_t.slopes, tanh_t.intercepts]),
                w1q.values,
                w1q.scale.reshape(S, -1),
                params.head_b1,
                w2q.values,
                w2q.scale.reshape(S, -1),
                params.head_b2,
                *u_args,
                slots_per_bank=slots_per_bank,
                interpret=interp,
                n_seg=n_seg,
                **kw,
            )
    else:
        enc = encoders.quantized_gru_params(params.encoder, cfg)
        wx, wh = enc.w[:, :d_in], enc.w[:, d_in:]
        w1, b1, w2, b2 = _head_weights(params, cfg)
        if disp is rt.Dispatch.REFERENCE:
            out = _ref.mr_tick_reference(
                buf_y,
                new_y,
                mean,
                scale,
                theta0,
                seed,
                active,
                wx,
                wh,
                enc.b,
                enc.time_scale,
                w1,
                b1,
                w2,
                b2,
                *u_args,
                flow=spec.flow,
                **kw,
            )
        else:
            out = mr_tick_pallas(
                buf_y,
                new_y,
                mean,
                scale,
                theta0,
                seed.astype(jnp.float32).reshape(S, 1),
                active.astype(jnp.float32).reshape(S, 1),
                wx,
                wh,
                enc.b,
                enc.time_scale,
                w1,
                b1,
                w2,
                b2,
                *u_args,
                flow=spec.flow,
                slots_per_bank=slots_per_bank,
                interpret=interp,
                **kw,
            )

    buf_y2, theta_flat, delta = out[0], out[1], out[2]
    buf_u2 = out[3] if has_u else roll_buffer(buf_u, new_u)
    theta = theta_flat.reshape(S, cfg.n_terms, cfg.state_dim)
    return buf_y2, buf_u2, theta, delta.reshape(S)
