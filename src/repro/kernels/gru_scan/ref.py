"""Pure-jnp oracle for the fused GRU scan kernel.

Delegates to core.neural_flow.gru_scan_ref (single source of truth for the
step math) and adds the int8/PWL reference path.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.neural_flow import GRUParams, gru_scan_ref
from repro.core.quant import PWLTable, pwl_apply


def gru_scan_reference(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    wx: jnp.ndarray,  # [D, 3H]
    wh: jnp.ndarray,  # [H, 3H]
    b: jnp.ndarray,  # [3H]
    time_scale: jnp.ndarray,  # [H]
    dts: jnp.ndarray,  # [T]
    flow: bool = True,
) -> jnp.ndarray:
    params = GRUParams(w=jnp.concatenate([wx, wh], axis=0), b=b, time_scale=time_scale)
    _, hs = gru_scan_ref(params, xs, h0, dts=dts, flow=flow)
    return hs


def gru_scan_int8_reference(
    xs, h0, wxq, whq, wx_scale, wh_scale, b, dts, sig_table: PWLTable, tanh_table: PWLTable
) -> jnp.ndarray:
    """Int8-dequant + PWL-activation oracle (standard GRU, float32 math)."""
    import jax

    f32 = jnp.float32
    wx = wxq.astype(f32) * wx_scale
    wh = whq.astype(f32) * wh_scale
    H = h0.shape[-1]

    def cell(h, x):
        gx = x.astype(f32) @ wx
        gh = h @ wh[:, : 2 * H]
        r = pwl_apply(sig_table, gx[:, :H] + gh[:, :H] + b[:H])
        z = pwl_apply(sig_table, gx[:, H : 2 * H] + gh[:, H:] + b[H : 2 * H])
        c = pwl_apply(tanh_table, gx[:, 2 * H :] + (r * h) @ wh[:, 2 * H :] + b[2 * H :])
        h = (1.0 - z) * c + z * h
        return h, h

    _, hs = jax.lax.scan(cell, h0.astype(f32), jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1)
