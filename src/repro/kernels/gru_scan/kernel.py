"""Fused GRU(-flow) sequence-scan Pallas kernel — the MERINDA core kernel.

TPU re-derivation of the paper's FPGA dataflow (§5):

  FPGA mechanism                      ->  this kernel
  -------------------------------------   -----------------------------------
  one setup, then continuous streaming ->  ONE pallas_call per sequence;
  (no per-step kernel launches)            grid = (batch_tiles, T); zero
                                           per-step dispatch overhead
  BRAM-resident weights, banked for    ->  gate weights live in VMEM for the
  per-cycle operand supply                 whole scan (BlockSpec index map is
                                           constant in t); the three gate
                                           affines are FUSED into one wide
                                           [D,3H] / [H,2H] GEMM pair so each
                                           MXU pass streams full tiles
  DATAFLOW stage overlap (II ~= 1)     ->  sequential grid over t: Mosaic
                                           double-buffers the x_t DMA against
                                           the step-(t-1) MXU compute
  LUT sigmoid/tanh                     ->  VPU transcendentals (float path) or
                                           unrolled piecewise-linear segments
                                           (int8/PWL path, quant variant)
  hidden state held on-chip           ->   h carried in a VMEM scratch across
                                           grid steps (never round-trips HBM)

Layouts: xs is batch-major [B, T, D]; the grid iterates batch tiles in the
OUTER dimension so each tile completes its full time scan with the same
scratch buffer (t==0 re-initializes from h0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.neural_flow import INV_LIPSCHITZ_ALPHA
from repro.kernels import runtime as rt


def _gru_step_math(x, h, wx, wh, b, time_scale, dt, *, flow: bool, hidden: int):
    """Shared step math (f32 accumulation). x:[bb,D] h:[bb,H] -> new h."""
    f32 = jnp.float32
    gx = jax.lax.dot_general(  # fused input affine for all three gates
        x, wx, (((1,), (0,)), ((), ())), preferred_element_type=f32
    )  # [bb, 3H]
    gh = jax.lax.dot_general(  # fused recurrent affine for r,z
        h, wh[:, : 2 * hidden], (((1,), (0,)), ((), ())), preferred_element_type=f32
    )  # [bb, 2H]
    r = jax.nn.sigmoid(gx[:, :hidden] + gh[:, :hidden] + b[:hidden])
    z = jax.nn.sigmoid(gx[:, hidden : 2 * hidden] + gh[:, hidden:] + b[hidden : 2 * hidden])
    ch = jax.lax.dot_general(
        (r * h).astype(wh.dtype),
        wh[:, 2 * hidden :],
        (((1,), (0,)), ((), ())),
        preferred_element_type=f32,
    )
    c = jnp.tanh(gx[:, 2 * hidden :] + ch + b[2 * hidden :])
    if flow:
        phi = jnp.tanh(jax.nn.softplus(time_scale) * dt)  # phi(0)=0 flow gate
        return h + phi * INV_LIPSCHITZ_ALPHA * (1.0 - z) * (c - h)
    return (1.0 - z) * c + z * h


def _gru_scan_kernel(
    # inputs
    xs_ref,  # [bb, 1, D]   x_t tile (double-buffered by Mosaic)
    h0_ref,  # [bb, H]
    wx_ref,  # [D, 3H]      VMEM-resident across the whole scan
    wh_ref,  # [H, 3H]
    b_ref,  # [1, 3H]
    ts_ref,  # [1, H]       time-gate log-scales
    dts_ref,  # [1, 1]      dt_t
    # outputs
    hs_ref,  # [bb, 1, H]
    # scratch
    h_scr,  # VMEM [bb, H] f32 — the on-chip hidden state ("BRAM" analogue)
    *,
    flow: bool,
    hidden: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    x = xs_ref[:, 0, :]
    h = h_scr[...]
    h_new = _gru_step_math(
        x,
        h,
        wx_ref[...],
        wh_ref[...],
        b_ref[0, :],
        ts_ref[0, :],
        dts_ref[0, 0],
        flow=flow,
        hidden=hidden,
    )
    h_scr[...] = h_new
    hs_ref[:, 0, :] = h_new.astype(hs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("flow", "block_b", "interpret"))
def gru_scan_pallas(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    wx: jnp.ndarray,  # [D, 3H]
    wh: jnp.ndarray,  # [H, 3H]
    b: jnp.ndarray,  # [3H]
    time_scale: jnp.ndarray,  # [H]
    dts: jnp.ndarray,  # [T]
    flow: bool = True,
    block_b: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns hs [B, T, H]."""
    B, T, D = xs.shape
    H = h0.shape[-1]
    bb = block_b or B
    assert B % bb == 0, f"batch {B} not divisible by block_b {bb}"
    nb = B // bb

    grid = (nb, T)
    kernel = functools.partial(_gru_scan_kernel, flow=flow, hidden=H)
    out = rt.pallas_call_compat(
        kernel,
        grid=grid,
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),  # xs: stream x_t
            ((bb, H), lambda ib, t: (ib, 0)),  # h0
            ((D, 3 * H), lambda ib, t: (0, 0)),  # wx: resident
            ((H, 3 * H), lambda ib, t: (0, 0)),  # wh: resident
            ((1, 3 * H), lambda ib, t: (0, 0)),  # b
            ((1, H), lambda ib, t: (0, 0)),  # time_scale
            ((1, 1), lambda ib, t: (t, 0)),  # dt_t
        ],
        out_specs=((bb, 1, H), lambda ib, t: (ib, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H), xs.dtype),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="gru_scan",
    )(
        xs,
        h0,
        wx,
        wh,
        b.reshape(1, -1),
        time_scale.reshape(1, -1),
        dts.reshape(-1, 1),
    )
    return out


# ---------------------------------------------------------------------------
# int8 + piecewise-linear variant — the paper's fixed-point/LUT configuration
# ---------------------------------------------------------------------------
def _pwl_eval(x, slopes, intercepts, x_min, x_max, n_seg, left, right):
    """Branch-free PWL evaluation, unrolled over segments (no gather needed —
    the segment-select chain vectorizes on the VPU; n_seg is small/static)."""
    width = (x_max - x_min) / n_seg
    idx = jnp.clip(((x - x_min) / width).astype(jnp.int32), 0, n_seg - 1)
    y = jnp.zeros_like(x)
    for s in range(n_seg):  # static unroll — becomes selects/FMAs
        y = jnp.where(idx == s, slopes[s] * x + intercepts[s], y)
    y = jnp.where(x < x_min, left, y)
    return jnp.where(x > x_max, right, y)


def _gru_q_step_math(x, h, wxq, whq, wx_scale, wh_scale, b, sig_tab, tanh_tab, *, hidden, n_seg):
    """Shared int8+PWL step math (standard GRU; f32 accumulation).

    Single source of truth for the fixed-point serving cell — used by the
    gru_scan int8 kernel below AND the fused mr_step int8 kernel
    (kernels/mr_step). Dequantizes once per step; weights stay int8 in VMEM
    (2x density vs bf16, the ap_fixed analogue). Per-output-channel scales.
    """
    f32 = jnp.float32
    wx = wxq.astype(f32) * wx_scale
    wh = whq.astype(f32) * wh_scale
    gx = jax.lax.dot_general(x, wx, (((1,), (0,)), ((), ())), preferred_element_type=f32)
    gh = jax.lax.dot_general(
        h, wh[:, : 2 * hidden], (((1,), (0,)), ((), ())), preferred_element_type=f32
    )

    def sig(v):
        return _pwl_eval(v, sig_tab[0, :], sig_tab[1, :], -8.0, 8.0, n_seg, 0.0, 1.0)

    def tnh(v):
        return _pwl_eval(v, tanh_tab[0, :], tanh_tab[1, :], -4.0, 4.0, n_seg, -1.0, 1.0)

    r = sig(gx[:, :hidden] + gh[:, :hidden] + b[:hidden])
    z = sig(gx[:, hidden : 2 * hidden] + gh[:, hidden:] + b[hidden : 2 * hidden])
    ch = jax.lax.dot_general(
        r * h, wh[:, 2 * hidden :], (((1,), (0,)), ((), ())), preferred_element_type=f32
    )
    c = tnh(gx[:, 2 * hidden :] + ch + b[2 * hidden :])
    return (1.0 - z) * c + z * h


def _gru_scan_q_kernel(
    xs_ref,
    h0_ref,
    wxq_ref,  # int8 [D, 3H]
    whq_ref,  # int8 [H, 3H]
    wx_scale_ref,  # [1, 3H]
    wh_scale_ref,  # [1, 3H]
    b_ref,
    dts_ref,
    sig_tab_ref,  # [2, n_seg]  (slopes; intercepts)
    tanh_tab_ref,  # [2, n_seg]
    hs_ref,
    h_scr,
    *,
    hidden: int,
    n_seg: int,
):
    """Standard-GRU int8 weights + PWL activations (serving configuration)."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    h_new = _gru_q_step_math(
        xs_ref[:, 0, :].astype(jnp.float32),
        h_scr[...],
        wxq_ref[...],
        whq_ref[...],
        wx_scale_ref[0, :],
        wh_scale_ref[0, :],
        b_ref[0, :],
        sig_tab_ref[...],
        tanh_tab_ref[...],
        hidden=hidden,
        n_seg=n_seg,
    )
    h_scr[...] = h_new
    hs_ref[:, 0, :] = h_new.astype(hs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret", "n_seg"))
def gru_scan_pallas_int8(
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    wxq: jnp.ndarray,  # int8 [D, 3H]
    whq: jnp.ndarray,  # int8 [H, 3H]
    wx_scale: jnp.ndarray,  # [3H]
    wh_scale: jnp.ndarray,  # [3H]
    b: jnp.ndarray,  # [3H]
    dts: jnp.ndarray,  # [T]
    sig_tab: jnp.ndarray,  # [2, n_seg]
    tanh_tab: jnp.ndarray,  # [2, n_seg]
    block_b: int | None = None,
    interpret: bool = False,
    n_seg: int = 16,
) -> jnp.ndarray:
    B, T, D = xs.shape
    H = h0.shape[-1]
    bb = block_b or B
    assert B % bb == 0
    nb = B // bb
    kernel = functools.partial(_gru_scan_q_kernel, hidden=H, n_seg=n_seg)
    return rt.pallas_call_compat(
        kernel,
        grid=(nb, T),
        in_specs=[
            ((bb, 1, D), lambda ib, t: (ib, t, 0)),
            ((bb, H), lambda ib, t: (ib, 0)),
            ((D, 3 * H), lambda ib, t: (0, 0)),
            ((H, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 3 * H), lambda ib, t: (0, 0)),
            ((1, 1), lambda ib, t: (t, 0)),
            ((2, n_seg), lambda ib, t: (0, 0)),
            ((2, n_seg), lambda ib, t: (0, 0)),
        ],
        out_specs=((bb, 1, H), lambda ib, t: (ib, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H), jnp.float32),
        scratch_shapes=[((bb, H), jnp.float32)],
        dimension_semantics=(rt.PARALLEL, rt.ARBITRARY),
        interpret=interpret,
        name="gru_scan_int8_pwl",
    )(
        xs,
        h0,
        wxq,
        whq,
        wx_scale.reshape(1, -1),
        wh_scale.reshape(1, -1),
        b.reshape(1, -1),
        dts.reshape(-1, 1),
        sig_tab,
        tanh_tab,
    )
