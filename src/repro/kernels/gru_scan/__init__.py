from repro.kernels.gru_scan.ops import gru_scan  # noqa: F401
