"""Public jit'd wrapper for the fused GRU scan.

Dispatch policy lives in kernels/runtime.resolve_dispatch (shared by all
kernel families): Pallas kernel on TPU, kernel body under the interpreter
when explicitly requested (CPU correctness sweeps), lax.scan oracle
otherwise or when ``force_reference`` is set.
"""

from __future__ import annotations

import functools as _functools

import jax
import jax.numpy as jnp

from repro.core.neural_flow import GRUParams
from repro.core.quant import make_sigmoid_table, make_tanh_table, quantize_int8
from repro.kernels import runtime as rt
from repro.kernels.gru_scan import kernel as _k
from repro.kernels.gru_scan import ref as _ref


@_functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8))
def _gru_kernel_cvjp(xs, h0, wx, wh, b, time_scale, dts, flow, block_b):
    return _k.gru_scan_pallas(
        xs,
        h0,
        wx,
        wh,
        b,
        time_scale,
        dts,
        flow=flow,
        block_b=block_b,
        interpret=not rt.on_tpu(),
    )


def _gru_fwd(xs, h0, wx, wh, b, time_scale, dts, flow, block_b):
    out = _gru_kernel_cvjp(xs, h0, wx, wh, b, time_scale, dts, flow, block_b)
    return out, (xs, h0, wx, wh, b, time_scale, dts)


def _gru_bwd(flow, block_b, res, ct):
    xs, h0, wx, wh, b, time_scale, dts = res
    _, vjp = jax.vjp(
        lambda *a: _ref.gru_scan_reference(*a, flow=flow), xs, h0, wx, wh, b, time_scale, dts
    )
    return vjp(ct)


_gru_kernel_cvjp.defvjp(_gru_fwd, _gru_bwd)


def gru_scan(
    params: GRUParams,
    xs: jnp.ndarray,  # [B, T, D]
    h0: jnp.ndarray,  # [B, H]
    dts: jnp.ndarray | None = None,
    flow: bool = True,
    block_b: int | None = None,
    force_reference: bool = False,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused GRU(-flow) scan. Returns (h_final [B,H], hs [B,T,H]).

    Dispatch: Pallas kernel on TPU; lax.scan reference elsewhere. Tests pass
    interpret=True to execute the kernel body on CPU."""
    B, T, D = xs.shape
    H = params.hidden
    if dts is None:
        dts = jnp.ones((T,), xs.dtype)
    if rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE:
        hs = _ref.gru_scan_reference(
            xs, h0, params.w[:D], params.w[D:], params.b, params.time_scale, dts, flow=flow
        )
    else:
        hs = _gru_kernel_cvjp(
            xs,
            h0,
            params.w[:D],
            params.w[D:],
            params.b,
            params.time_scale,
            dts,
            flow,
            block_b,
        )
    return hs[:, -1, :], hs


def gru_scan_int8(
    params: GRUParams,
    xs: jnp.ndarray,
    h0: jnp.ndarray,
    dts: jnp.ndarray | None = None,
    n_seg: int = 16,
    block_b: int | None = None,
    force_reference: bool = False,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Serving path: int8 weights + PWL activations (standard GRU).

    Quantizes on the fly from float params — production would cache the
    quantized weights; the kernel signature takes them pre-quantized.
    """
    B, T, D = xs.shape
    if dts is None:
        dts = jnp.ones((T,), jnp.float32)
    wxq = quantize_int8(params.w[:D], axis=-1)
    whq = quantize_int8(params.w[D:], axis=-1)
    sig_t = make_sigmoid_table(n_seg)
    tanh_t = make_tanh_table(n_seg)
    sig_tab = jnp.stack([sig_t.slopes, sig_t.intercepts])
    tanh_tab = jnp.stack([tanh_t.slopes, tanh_t.intercepts])
    if rt.resolve_dispatch(force_reference, interpret) is rt.Dispatch.REFERENCE:
        hs = _ref.gru_scan_int8_reference(
            xs, h0, wxq.values, whq.values, wxq.scale, whq.scale, params.b, dts, sig_t, tanh_t
        )
    else:
        hs = _k.gru_scan_pallas_int8(
            xs,
            h0,
            wxq.values,
            whq.values,
            wxq.scale.reshape(-1),
            whq.scale.reshape(-1),
            params.b,
            dts,
            sig_tab,
            tanh_tab,
            block_b=block_b,
            interpret=not rt.on_tpu(),
            n_seg=n_seg,
        )
    return hs[:, -1, :], hs
