"""Pallas TPU kernels for the paper's compute hot-spots.

- gru_scan:        fused GRU(-flow) sequence scan — the MERINDA core kernel.
                   TPU analogue of the paper's DSP/LUT/BRAM-banked FPGA dataflow.
- mr_step:         stage-FUSED per-window recovery step: GRU scan + RMS-norm +
                   dense head in one pallas_call (fp32 + int8/PWL) — the
                   paper's "no inter-stage synchronization" dataflow, one
                   level above gru_scan.
- ssd_scan:        Mamba2 SSD chunked recurrence (same locality methodology).
- flash_attention: blockwise causal/sliding-window attention for prefill.

Each kernel package ships kernel.py (pallas kernel body + VMEM tiling),
ops.py (jit'd public wrapper with interpret/XLA fallbacks) and ref.py (pure-jnp
oracle used by the allclose test sweeps).

runtime.py is the shared kernel runtime: Pallas API-drift shims
(CompilerParams/TPUCompilerParams, BlockSpec argument order, VMEM scratch)
behind one pallas_call_compat entry point, plus the TPU/interpret/reference
dispatch policy every ops.py consults.
"""
