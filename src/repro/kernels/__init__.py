"""Pallas TPU kernels for the paper's compute hot-spots.

- gru_scan:        fused GRU(-flow) sequence scan — the MERINDA core kernel.
                   TPU analogue of the paper's DSP/LUT/BRAM-banked FPGA dataflow.
- ssd_scan:        Mamba2 SSD chunked recurrence (same locality methodology).
- flash_attention: blockwise causal/sliding-window attention for prefill.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper with interpret/XLA fallbacks) and ref.py (pure-jnp
oracle used by the allclose test sweeps).
"""
