"""Kernel runtime: Pallas API-drift shims + the shared dispatch decision.

Every kernel family (gru_scan, flash_attention, ssd_scan) goes through this
module instead of touching ``pl.pallas_call`` directly. It owns the three
places where the Pallas TPU API has drifted across JAX releases, plus the
TPU/interpret/reference dispatch policy that used to be copy-pasted into all
three ``ops.py`` files:

1. Compiler params class name.  ``pltpu.TPUCompilerParams`` (JAX <= 0.4.x)
   was renamed to ``pltpu.CompilerParams`` (JAX >= 0.5).  ``compiler_params``
   resolves whichever spelling the installed JAX exposes.
2. BlockSpec argument order.  Old JAX took ``BlockSpec(index_map,
   block_shape)``; modern JAX takes ``BlockSpec(block_shape, index_map)``.
   ``block_spec`` inspects the installed signature once and builds specs in
   the right order.
3. VMEM scratch spelling.  ``vmem_scratch`` wraps ``pltpu.VMEM(shape,
   dtype)`` (raising a clear error if a future release moves it again).

``pallas_call_compat`` is the single entry point: kernels hand it the kernel
body, grid, (block_shape, index_map) spec pairs, output shapes, scratch
shapes and dimension semantics, and it assembles a version-correct
``pl.pallas_call``.

``resolve_dispatch`` centralizes the backend decision: the compiled kernel on
TPU, the kernel body under the Pallas interpreter when explicitly requested
(CPU correctness sweeps), and the pure-JAX reference everywhere else.
"""

from __future__ import annotations

import enum
import inspect
from typing import Any, Callable, Sequence

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Grid-dimension semantics: plain strings on every JAX we support; prefer the
# module constants when present so we track any future enum migration.
PARALLEL = getattr(pltpu, "PARALLEL", "parallel")
ARBITRARY = getattr(pltpu, "ARBITRARY", "arbitrary")

_COMPILER_PARAMS_SPELLINGS = ("CompilerParams", "TPUCompilerParams")


def resolve_compiler_params_cls(ns: Any = pltpu) -> type:
    """The compiler-params class under whichever name ``ns`` exposes it.

    ``ns`` is injectable so the regression tests can pin the resolution
    against namespaces carrying only one of the two historical spellings.
    """
    for name in _COMPILER_PARAMS_SPELLINGS:
        cls = getattr(ns, name, None)
        if cls is not None:
            return cls
    raise AttributeError(
        f"Pallas TPU module {ns!r} exposes none of {_COMPILER_PARAMS_SPELLINGS}; "
        "unsupported JAX version — extend kernels/runtime.py"
    )


def compiler_params(dimension_semantics: Sequence[str] | None = None, ns: Any = pltpu, **kw) -> Any:
    """Version-correct compiler-params object (CompilerParams/TPUCompilerParams)."""
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    return resolve_compiler_params_cls(ns)(**kw)


def blockspec_block_shape_first(cls: type = pl.BlockSpec) -> bool:
    """True when ``cls(block_shape, index_map)`` is the installed order."""
    try:
        params = [p for p in inspect.signature(cls.__init__).parameters if p != "self"]
    except (TypeError, ValueError):  # C-accelerated/builtin signature
        return True
    return not (params and params[0] == "index_map")


_BLOCK_SHAPE_FIRST = blockspec_block_shape_first()


def block_spec(block_shape: tuple[int, ...], index_map: Callable | None = None) -> pl.BlockSpec:
    """BlockSpec with the argument order the installed JAX expects."""
    if _BLOCK_SHAPE_FIRST:
        return pl.BlockSpec(tuple(block_shape), index_map)
    return pl.BlockSpec(index_map, tuple(block_shape))


def vmem_scratch(shape: tuple[int, ...], dtype) -> Any:
    """VMEM scratch allocation (f32 accumulators, resident state, ...)."""
    vmem = getattr(pltpu, "VMEM", None)
    if vmem is None:
        raise AttributeError(
            "pltpu.VMEM missing; unsupported JAX version — extend kernels/runtime.py"
        )
    return vmem(tuple(shape), dtype)


class Dispatch(enum.Enum):
    """Where a kernel-family call executes."""

    KERNEL = "kernel"  # compiled Pallas kernel (TPU)
    INTERPRET = "interpret"  # kernel body under the Pallas interpreter (CPU tests)
    REFERENCE = "reference"  # pure-JAX oracle (lax.scan / jnp)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_dispatch(
    force_reference: bool = False,
    interpret: bool | None = None,
    backend: str | None = None,
) -> Dispatch:
    """The shared dispatch policy for all kernel families.

    - ``force_reference`` always wins (callers use it for oracle comparisons
      and for features only the reference implements, e.g. carried state).
    - On TPU the compiled kernel runs.
    - Off TPU, ``interpret=True`` runs the kernel body under the interpreter
      (semantics-identical to the TPU kernel — what the CPU test sweeps use);
      otherwise the reference runs.
    """
    if force_reference:
        return Dispatch.REFERENCE
    backend = backend if backend is not None else jax.default_backend()
    if backend == "tpu":
        return Dispatch.KERNEL
    if interpret:
        return Dispatch.INTERPRET
    return Dispatch.REFERENCE


def pallas_call_compat(
    kernel: Callable,
    *,
    grid: tuple[int, ...],
    in_specs: Sequence[tuple[tuple[int, ...], Callable | None]],
    out_specs,
    out_shape,
    scratch_shapes: Sequence[Any] = (),
    dimension_semantics: Sequence[str] | None = None,
    interpret: bool = False,
    name: str | None = None,
    **compiler_kw,
):
    """The one ``pl.pallas_call`` constructor for every kernel family.

    ``in_specs``/``out_specs`` are (block_shape, index_map) pairs — this
    module turns them into BlockSpecs in the installed argument order.
    Convention: a single-output kernel passes ``out_specs`` as ONE tuple pair;
    a multi-output kernel passes a LIST of pairs (mirroring ``out_shape``).
    ``scratch_shapes`` entries may be (shape, dtype) pairs (VMEM implied) or
    prebuilt scratch objects.
    """

    def to_spec(s):
        if isinstance(s, tuple) and len(s) == 2 and not isinstance(s, pl.BlockSpec):
            return block_spec(s[0], s[1])
        return s

    def to_scratch(s):
        if isinstance(s, tuple) and len(s) == 2 and isinstance(s[0], tuple):
            return vmem_scratch(s[0], s[1])
        return s

    if isinstance(out_specs, list):
        out_specs_built = [to_spec(s) for s in out_specs]
    else:
        out_specs_built = to_spec(out_specs)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[to_spec(s) for s in in_specs],
        out_specs=out_specs_built,
        out_shape=out_shape,
        scratch_shapes=[to_scratch(s) for s in scratch_shapes],
        compiler_params=compiler_params(dimension_semantics, **compiler_kw),
        interpret=interpret,
        name=name,
    )
