"""RecoverySpec: one declarative record of WHAT to recover and HOW to run it.

The paper's deployment story is "configure the pipeline once, then stream":
every execution decision — encoder family, precision, stage fusion, batch
tiling, slot sharding — is made at setup time and baked into a dataflow that
then runs untouched. ``RecoverySpec`` is that setup record for this repo:
one frozen dataclass covering the model/library shape, the numerics
(``fp32`` vs ``int8_pwl`` serving, optional QAT), the execution mode
(``offline`` | ``batch`` | ``stream``) and the placement (slot count, mesh
size, ``block_b`` tiling policy).

``repro.api.compile_plan`` lowers a spec into a :class:`RecoveryPlan`; the
legacy entry points (``merinda.train_mr``, ``engine.recover_many``,
``stream.RecoveryService``) remain as wrappers that build a spec internally.

Validation happens in two stages, mirroring compile pipelines:

- literal validation (mode/precision spellings, positive dims, ``block_b``
  form) in ``__post_init__`` — a bad spec never constructs;
- environment validation (encoder registry, fusability, device count vs
  mesh) in ``validate()``, called by ``compile_plan``.
"""

from __future__ import annotations

import dataclasses

from repro.core.merinda import MRConfig
from repro.core.quant import QuantConfig
from repro.core.stream import StreamConfig

MODES = ("offline", "batch", "stream")
PRECISIONS = ("fp32", "int8_pwl")
TICK_KERNELS = ("banked", "composite", "auto")
CONTROL_PLANES = ("host", "device")


@dataclasses.dataclass(frozen=True)
class TickSpec:
    """Declarative service-tick request (stream mode).

    ``tick_kernel`` picks the tick's serving structure: ``"composite"`` is
    the stage-sequence tick (``core/stream.tick``: ingest, K vmapped
    recovery steps and the EMA readout as separate XLA ops — the
    bitwise-stable legacy default), ``"banked"`` the one-kernel banked tick
    (``kernels/mr_step/tick.py``: ingest + window substeps + EMA readout in
    a single slot-banked ``pallas_call``, packed per-slot status for a
    single host readback), and ``"auto"`` lets ``compile_plan`` resolve from
    the encoder family and the tick-level VMEM model
    (``tiling.auto_slots_per_bank`` against ``detect_vmem_budget``); the
    resolved choice and slots-per-bank land in ``plan.lowering``.

    ``steps_per_tick=0`` is a pure serve/monitor tick: no optimizer steps,
    just ingest + readout — the configuration the banked kernel serves as
    one program.

    ``control`` picks the service's control plane: ``"host"`` is the
    reference orchestrator (admission deque, per-tick status readbacks, an
    ``admit`` program + reshard per admission), ``"device"`` moves admission
    queues, the eviction mask, slot refill and warm-start lookup inside ONE
    donated tick program (``core/control.py``) so a steady-state tick has
    zero host readbacks and admission never reshards the slot axis. The
    device plane's capacities — per-shard admission ``queue_capacity``, the
    on-device warm-cache size ``warm_capacity`` (also bounds the host-path
    LRU registry) and the host ``snapshot_period`` (drain status + eviction
    events every N ticks) — are baked into the compiled shapes and recorded
    in ``plan.lowering``.
    """

    steps_per_tick: int = 8  # K optimizer steps per slot per tick (0 = serve-only)
    ema_decay: float = 0.9  # smoothing for the per-tick Theta readout
    tick_kernel: str = "composite"  # "banked" | "composite" | "auto"
    control: str = "host"  # "host" | "device" (device-resident control plane)
    queue_capacity: int = 8  # pending admissions per shard (device plane)
    snapshot_period: int = 1  # ticks between host status/event drains
    warm_capacity: int = 32  # warm-start cache entries (per shard on device)
    # -- resilience (runtime/resilience.py) ----------------------------------
    # checkpoint_period > 0 turns on periodic async service snapshots
    # (SlotState + ControlState + warm LRU) every N ticks into
    # checkpoint_dir; 0 disables checkpointing (the default — snapshot
    # staging reads back state, so it is strictly opt-in and never taxes the
    # zero-readback steady state on non-snapshot ticks).
    checkpoint_period: int = 0
    checkpoint_dir: str | None = None
    # bounded host-side overflow queue for device-plane admissions when the
    # per-shard rings are full; submit() returns OVERFLOW (and later drains)
    # up to this many queued streams, REJECTED beyond.
    overflow_capacity: int = 16

    def __post_init__(self):
        if self.tick_kernel not in TICK_KERNELS:
            raise ValueError(f"tick_kernel must be one of {TICK_KERNELS}, got {self.tick_kernel!r}")
        if self.steps_per_tick < 0:
            raise ValueError(f"steps_per_tick must be >= 0, got {self.steps_per_tick}")
        if not 0.0 <= self.ema_decay < 1.0:
            raise ValueError(f"ema_decay must be in [0, 1), got {self.ema_decay}")
        if self.control not in CONTROL_PLANES:
            raise ValueError(f"control must be one of {CONTROL_PLANES}, got {self.control!r}")
        if self.queue_capacity < 1:
            raise ValueError(f"queue_capacity must be >= 1, got {self.queue_capacity}")
        if self.snapshot_period < 1:
            raise ValueError(f"snapshot_period must be >= 1, got {self.snapshot_period}")
        if self.warm_capacity < 1:
            raise ValueError(f"warm_capacity must be >= 1, got {self.warm_capacity}")
        if self.checkpoint_period < 0:
            raise ValueError(f"checkpoint_period must be >= 0, got {self.checkpoint_period}")
        if self.checkpoint_period > 0 and not self.checkpoint_dir:
            raise ValueError("checkpoint_period > 0 requires checkpoint_dir")
        if self.overflow_capacity < 0:
            raise ValueError(f"overflow_capacity must be >= 0, got {self.overflow_capacity}")


@dataclasses.dataclass(frozen=True)
class RecoverySpec:
    """Declarative recovery request; see the module docstring.

    Hashable (all fields are frozen dataclasses or scalars), so a spec can
    key jit caches and plan registries directly.
    """

    # -- model / library shape ---------------------------------------------
    state_dim: int  # n = |Y|
    input_dim: int = 0  # m = |U|
    order: int = 2  # library polynomial order
    hidden: int = 32  # encoder width V
    dense_hidden: int | None = None  # head width (None = 2 * hidden)
    n_shifts: int = 0  # q input-shift outputs
    dt: float = 0.05
    solver: str = "rk4"
    ltc_substeps: int = 6
    lambda_sparse: float = 1e-3
    recon_weight: float = 1.0

    # -- numerics / lowering -----------------------------------------------
    encoder: str = "gru_flow"  # any name registered in core/encoders.py
    precision: str = "fp32"  # serving readout: "fp32" | "int8_pwl"
    qat: QuantConfig | None = None  # fixed-point fake-quant during training
    fused: bool = False  # stage-fused per-window step (kernels/mr_step)
    block_b: int | str | None = None  # fused batch tile: int, None, or "auto"
    # budget the "auto" tile fits into; None = auto-detect from the local
    # device (kernels/mr_step/tiling.resolve_vmem_budget: platform table +
    # memory_stats when available) — the explicit override always wins, and
    # plan.lowering.vmem_budget_source records which source was used
    # ("explicit" | "memory_stats" | "platform:<key>" | "default")
    vmem_budget_bytes: int | None = None
    # scan-unroll factor for the sequential loops of the reference/XLA step
    # lowering (MRConfig.substep_unroll): 1 = no unrolling (the bitwise
    # default). compile_plan(tune="static"|"measured") may resolve a larger
    # factor; the resolved value lands in plan.lowering.substep_unroll.
    substep_unroll: int = 1

    # -- execution ----------------------------------------------------------
    mode: str = "offline"  # "offline" | "batch" | "stream"
    steps: int = 500  # optimizer steps (offline/batch)
    lr: float = 3e-3
    batch_size: int | None = None  # windows per optimizer step (None = all)
    seed: int = 0
    n_active: int | None = None  # magnitude-prune readout to this many terms

    # -- stream mode ---------------------------------------------------------
    n_slots: int = 4
    stream: StreamConfig | None = None  # None = StreamConfig() defaults
    tick: TickSpec | None = None  # None = TickSpec() defaults (composite)

    # -- placement -----------------------------------------------------------
    mesh_slots: int = 1  # devices sharding the slot axis (1 = trivial mesh)

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision must be one of {PRECISIONS}, got {self.precision!r}")
        if self.state_dim < 1 or self.input_dim < 0 or self.order < 1:
            raise ValueError(
                f"bad library shape: state_dim={self.state_dim} "
                f"input_dim={self.input_dim} order={self.order}"
            )
        if isinstance(self.block_b, str):
            if self.block_b != "auto":
                raise ValueError(f'block_b must be an int, None or "auto", got {self.block_b!r}')
        elif self.block_b is not None and self.block_b < 1:
            raise ValueError(f"block_b must be >= 1, got {self.block_b}")
        if self.vmem_budget_bytes is not None and self.block_b != "auto":
            # a budget with a fixed (or default full-batch) tile would be
            # silently ignored — the exact misconfiguration "auto" exists for
            raise ValueError(
                'vmem_budget_bytes requires block_b="auto" (a fixed tile ignores the budget)'
            )
        if self.substep_unroll < 1:
            raise ValueError(f"substep_unroll must be >= 1, got {self.substep_unroll}")
        if self.mesh_slots < 1:
            raise ValueError(f"mesh_slots must be >= 1, got {self.mesh_slots}")
        if self.mode == "stream":
            if self.n_slots < 1:
                raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
            if self.n_slots % self.mesh_slots != 0:
                raise ValueError(
                    f"n_slots ({self.n_slots}) must divide evenly over the mesh "
                    f"({self.mesh_slots} devices) for a balanced slot shard"
                )
            if self.stream is not None and (
                self.stream.lr != self.lr or self.stream.batch_size != self.batch_size
            ):
                # the tick trains with StreamConfig's copies; a diverging
                # spec-level value would be silently ignored — one record,
                # one source of truth
                raise ValueError(
                    f"stream-mode lr/batch_size conflict: spec has "
                    f"(lr={self.lr}, batch_size={self.batch_size}) but stream= has "
                    f"(lr={self.stream.lr}, batch_size={self.stream.batch_size}); "
                    f"set them equal (the StreamConfig governs the tick)"
                )
            if (
                self.tick is not None
                and self.stream is not None
                and (
                    self.stream.steps_per_tick != self.tick.steps_per_tick
                    or self.stream.ema != self.tick.ema_decay
                )
            ):
                # same one-record rule as lr/batch_size above: the compiled
                # tick trains with StreamConfig's copies, so a diverging
                # TickSpec would be silently ignored
                raise ValueError(
                    f"stream-mode tick conflict: tick= has (steps_per_tick="
                    f"{self.tick.steps_per_tick}, ema_decay={self.tick.ema_decay}) but "
                    f"stream= has (steps_per_tick={self.stream.steps_per_tick}, "
                    f"ema={self.stream.ema}); set them equal"
                )
        else:
            if self.mesh_slots != 1:
                raise ValueError(f"mesh_slots > 1 requires mode='stream', got mode={self.mode!r}")
            if self.tick is not None:
                raise ValueError(f"tick= requires mode='stream', got mode={self.mode!r}")

    # -- bridges to the legacy config objects --------------------------------
    def to_mr_config(
        self, block_b: int | None = None, substep_unroll: int | None = None
    ) -> MRConfig:
        """The MRConfig this spec lowers to. ``block_b`` is the RESOLVED tile
        (compile_plan turns "auto" into an int before building the config);
        ``substep_unroll`` likewise overrides the spec's factor when the
        tuner resolved a different one."""
        if block_b is None and isinstance(self.block_b, int):
            block_b = self.block_b
        return MRConfig(
            state_dim=self.state_dim,
            input_dim=self.input_dim,
            order=self.order,
            hidden=self.hidden,
            dense_hidden=self.dense_hidden or 2 * self.hidden,
            encoder=self.encoder,
            n_shifts=self.n_shifts,
            dt=self.dt,
            solver=self.solver,
            ltc_substeps=self.ltc_substeps,
            lambda_sparse=self.lambda_sparse,
            recon_weight=self.recon_weight,
            quant=self.qat,
            fused=self.fused,
            block_b=block_b,
            substep_unroll=self.substep_unroll if substep_unroll is None else substep_unroll,
        )

    def stream_config(self) -> StreamConfig:
        if self.stream is not None:
            return self.stream  # __post_init__ pinned lr/batch_size/tick agreement
        kw = dict(lr=self.lr, batch_size=self.batch_size)
        if self.tick is not None:
            kw.update(steps_per_tick=self.tick.steps_per_tick, ema=self.tick.ema_decay)
        return StreamConfig(**kw)

    def tick_spec(self) -> TickSpec:
        """The resolved TickSpec (mirrors stream_config when ``tick`` is None,
        so the two records can never disagree about the tick geometry)."""
        if self.tick is not None:
            return self.tick
        scfg = self.stream_config()
        return TickSpec(steps_per_tick=scfg.steps_per_tick, ema_decay=scfg.ema)

    @classmethod
    def from_mr_config(cls, cfg: MRConfig, **overrides) -> "RecoverySpec":
        """Bridge for the deprecated entry points: spec fields from an
        existing MRConfig, with execution fields supplied as overrides."""
        return cls(
            state_dim=cfg.state_dim,
            input_dim=cfg.input_dim,
            order=cfg.order,
            hidden=cfg.hidden,
            dense_hidden=cfg.dense_hidden,
            encoder=cfg.encoder,
            n_shifts=cfg.n_shifts,
            dt=cfg.dt,
            solver=cfg.solver,
            ltc_substeps=cfg.ltc_substeps,
            lambda_sparse=cfg.lambda_sparse,
            recon_weight=cfg.recon_weight,
            qat=cfg.quant,
            fused=cfg.fused,
            block_b=cfg.block_b,
            substep_unroll=cfg.substep_unroll,
            **overrides,
        )
