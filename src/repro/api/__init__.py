"""repro.api — the declarative plan/compile/run surface for model recovery.

    from repro import api

    spec = api.RecoverySpec(state_dim=3, mode="batch", encoder="gru", fused=True)
    plan = api.compile_plan(spec)
    theta = plan.run_batch(ys_batch)

One :class:`RecoverySpec` declares WHAT to recover and HOW to execute it
(encoder, precision, fusion, tiling, mode, slots, mesh); ``compile_plan``
resolves every execution decision once into a :class:`RecoveryPlan` (see
``plan.Lowering``) and hands back the jitted donated programs for offline,
batched and sharded streaming recovery. The legacy entry points remain as
deprecated wrappers that build a spec internally.
"""

from repro.api.plan import Lowering, RecoveryPlan, compile_plan
from repro.api.spec import MODES, PRECISIONS, TICK_KERNELS, RecoverySpec, TickSpec
from repro.core.engine import history_from_metrics
from repro.core.merinda import prune_theta

__all__ = [
    "MODES",
    "PRECISIONS",
    "Lowering",
    "RecoveryPlan",
    "RecoverySpec",
    "TICK_KERNELS",
    "TickSpec",
    "compile_plan",
    "history_from_metrics",
    "prune_theta",
]
