"""compile_plan: lower a RecoverySpec into an executable RecoveryPlan.

MERINDA's central claim is compile-once / stream-forever: all execution
decisions are made at setup time, after which recovery is a fixed dataflow
with no per-step decisions. ``compile_plan`` is the host-side compiler for
that story. It takes one declarative :class:`RecoverySpec` and produces a
:class:`RecoveryPlan` holding

- the resolved :class:`Lowering` record — every decision that used to be
  scattered across call sites (``fused``, ``use_kernel``-era encoder
  backends, quantized serving, the ``block_b`` batch tile, backend
  dispatch) in ONE place;
- the jitted, donated programs for the spec's execution mode (the engine's
  epoch scan, the vmapped multi-system recovery, the streaming tick);
- for stream mode, a device mesh over the slot axis — ``SlotState`` is
  sharded across it (``jax.set_mesh`` shim + the ``parallel/`` rule table),
  with ``mesh_slots=1`` degenerating to the single-device path — so one
  service scales past a single chip's VMEM/HBM.

Compile-time failures are ValueErrors raised here (unknown encoder, fused
with a non-fusable family, int8 serving with a flow encoder, mesh larger
than the device count) — never mid-trace errors inside a jitted scan.

The legacy entry points (``merinda.train_mr``, ``engine.train_mr_scan``,
``engine.recover_many``, direct ``RecoveryService`` construction) remain as
deprecated wrappers that build a spec internally and run through a plan.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import RecoverySpec
from repro.core import encoders, engine
from repro.core import stream as stream_mod
from repro.core.merinda import MRConfig, init_mr, prune_theta
from repro.core.stream import RecoveryService, StreamConfig
from repro.kernels import runtime as rt
from repro.kernels.mr_step import tiling
from repro.optim import adamw_init


@dataclasses.dataclass(frozen=True)
class Lowering:
    """Every resolved execution decision, in one record.

    ``dispatch`` names where the per-window recovery stage executes:
    ``"pallas"`` (compiled kernel on TPU), ``"reference"`` (identical math
    as pure JAX off-TPU — what kernel-backed and fused requests resolve to
    on CPU/GPU), or ``"xla"`` (plain lax.scan encoders that never route
    through a kernel family).
    """

    encoder: str
    fused: bool
    kernel: bool  # encoder row routes through a Pallas kernel family
    dispatch: str  # "pallas" | "reference" | "xla"
    quant_serving: bool  # int8/PWL fused readout at serving time
    qat: bool  # fixed-point fake-quant during training
    block_b: int | None  # resolved fused-stage batch tile (None = full batch)
    vmem_bytes: int | None  # modeled fused-stage VMEM residency at block_b
    vmem_budget_bytes: int | None  # resolved budget the "auto" tile fit into
    mesh_shape: tuple[int, ...]  # device mesh over the slot axis (stream mode)
    # which source resolved vmem_budget_bytes: "explicit" (spec override),
    # "memory_stats", "platform:<key>" or "default" (tiling.resolve_vmem_budget)
    vmem_budget_source: str | None = None
    # measured-cost autotuner (analysis/tuner.py): the scan-unroll factor the
    # resolved lowering carries; how the lowering was chosen ("static" |
    # "measured" | "measured:cached", None = untuned static policy); the
    # on-disk cache key the measured decision persists under; and the chosen
    # candidate's cost evidence — the VMEM model's predicted residency vs the
    # per-input-step HBM traffic parsed from the candidate's own compiled HLO
    # (the figure the R2 audit re-measures a tuned plan against, with
    # tiling.TUNED_RESIDENCY_BAND)
    substep_unroll: int = 1
    tuned: str | None = None
    tune_cache_key: str | None = None
    predicted_bytes: int | None = None
    measured_bytes: float | None = None
    audit: str | None = None  # audit verdict stamp ("pass:R1,R3,..."/"fail:R2")
    # stream mode: the resolved tick structure — "banked" (one-kernel mr_tick
    # serving segment) or "composite" (stage-sequence tick), and the bank size
    # the tick-level VMEM model settled on (None for composite)
    tick_kernel: str | None = None
    tick_slots_per_bank: int | None = None
    # stream mode: the resolved control plane ("host" reference orchestrator
    # or "device" zero-readback tick, core/control.py) and the capacities
    # baked into the compiled control-state shapes (None outside stream mode;
    # queue/snapshot fields None on the host plane, which has no rings)
    control_plane: str | None = None
    tick_queue_capacity: int | None = None
    tick_snapshot_period: int | None = None
    warm_capacity: int | None = None
    # stream mode: service resilience (runtime/resilience.py) — snapshot
    # cadence/destination for the SlotState+ControlState checkpointer (0/None
    # = checkpointing off) and the bounded host overflow queue that backs the
    # typed submit() backpressure signal
    checkpoint_period: int | None = None
    checkpoint_dir: str | None = None
    overflow_capacity: int | None = None


class RecoveryPlan:
    """A compiled recovery dataflow: spec + lowering + jitted programs.

    Built by :func:`compile_plan`; consumers call the mode's run method and
    never re-make execution decisions:

    - ``run_offline(ys, us, norm)``  -> (params, metrics)     [mode=offline]
    - ``run_batch(ys_batch, us_b)``  -> theta [S, n_terms, n] [mode=batch]
    - ``make_service(seed)``         -> RecoveryService       [mode=stream]
    - ``readout(params, yw, uw)``    -> theta through the spec's precision
    """

    def __init__(
        self,
        spec: RecoverySpec,
        cfg: MRConfig,
        scfg: StreamConfig,
        lowering: Lowering,
        mesh,
        programs: dict,
    ):
        self.spec = spec
        self.cfg = cfg
        self.scfg = scfg
        self.lowering = lowering
        self.mesh = mesh  # jax Mesh over ("slots",) or None (trivial mesh)
        self.programs = programs  # name -> jitted donated program

    def _require_mode(self, mode: str):
        if self.spec.mode != mode:
            raise ValueError(
                f"this plan was compiled for mode={self.spec.mode!r}; "
                f"recompile with RecoverySpec(mode={mode!r})"
            )

    # -- offline: one system, one compiled training run ----------------------
    def run_offline(
        self, ys: jnp.ndarray, us: jnp.ndarray | None = None, norm: dict | None = None
    ) -> tuple:
        """Train one system's recovery model: ys [N, T, n] -> (params, metrics).

        One donated lax.scan program over all optimizer steps (the engine's
        epoch scan); ``norm`` applies the L1 penalty in physical units.
        """
        self._require_mode("offline")
        key = jax.random.key(self.spec.seed)
        params = init_mr(key, self.cfg)
        opt_state = adamw_init(params)
        phys = engine.make_phys(self.cfg, norm)
        params, _, metrics = self.programs["epoch"](
            params, opt_state, ys, us, key, self.spec.lr, phys
        )
        return params, metrics

    # -- batch: a fleet of systems, one vmapped program -----------------------
    def run_batch(self, ys_batch: jnp.ndarray, us_batch: jnp.ndarray | None = None) -> jnp.ndarray:
        """Recover S distinct systems in one compiled vmapped call.

        ys_batch [S, N, T, n] -> theta_batch [S, n_terms, n] (normalized
        coordinates; pruned to ``spec.n_active`` when set).
        """
        self._require_mode("batch")
        keys = engine.system_keys(self.spec.seed, ys_batch.shape[0])
        return self.programs["recover_many"](ys_batch, us_batch, keys, self.spec.lr)

    # -- stream: the slot-based online service --------------------------------
    @property
    def tick(self):
        """The compiled tick program (stream mode): ``(state, new_y, new_u,
        key)`` with cfg/scfg/kernel choice pre-bound. Composite returns the
        next SlotState; banked returns ``(state, status[S, 4])`` — the packed
        per-slot ``[delta, loss, steps, active]`` read back in one sync."""
        self._require_mode("stream")
        return self.programs["tick"]

    def make_service(self, seed: int | None = None) -> RecoveryService:
        """The online multi-tenant service, with SlotState sharded over the
        plan's mesh (trivial on mesh_slots=1). On ``control="device"`` the
        service also carries the compiled ControlPlane (core/control.py): the
        zero-readback tick, enqueue, pump and snapshot-drain programs."""
        self._require_mode("stream")
        control = None
        if self.lowering.control_plane == "device":
            from repro.core import control as control_mod

            control = control_mod.ControlPlane(
                queue_capacity=self.lowering.tick_queue_capacity,
                snapshot_period=self.lowering.tick_snapshot_period,
                warm_capacity=self.lowering.warm_capacity,
                shards=self.spec.mesh_slots,
                tick=self.programs["tick_device"],
                enqueue=self.programs["enqueue"],
                pump=self.programs["pump"],
                drain=self.programs["drain"],
            )
        service = RecoveryService(
            self.cfg,
            self.scfg,
            self.spec.n_slots,
            seed=self.spec.seed if seed is None else seed,
            quant=self.lowering.quant_serving,
            mesh=self.mesh,
            tick_program=self.programs["tick"],
            control=control,
            warm_capacity=self.lowering.warm_capacity or 32,
            overflow_capacity=self.lowering.overflow_capacity
            if self.lowering.overflow_capacity is not None
            else 16,
        )
        if self.lowering.checkpoint_period and self.lowering.checkpoint_dir:
            # lazy import: resilience pulls checkpoint/elastic; plan.py stays
            # importable without them on the critical path
            from repro.runtime.resilience import ServiceCheckpointer

            service.checkpointer = ServiceCheckpointer(
                self.lowering.checkpoint_dir,
                period=self.lowering.checkpoint_period,
            )
        return service

    # -- readout: the spec's serving precision --------------------------------
    def readout(
        self,
        params,
        yw: jnp.ndarray,
        uw: jnp.ndarray | None = None,
        norm: dict | None = None,
        n_active: int | None = None,
    ) -> np.ndarray:
        """Aggregate Theta through the spec's serving precision.

        fp32 runs the (possibly fused) forward; int8_pwl serves through the
        fused fixed-point stage (kernels/mr_step int8). ``norm`` maps the
        result back to physical units; ``n_active`` (default: the spec's)
        magnitude-prunes.
        """
        theta = stream_mod.readout_theta(
            params, self.cfg, yw, uw, quant=self.lowering.quant_serving
        )
        theta = np.asarray(theta)
        if norm is not None:
            from repro.core.library import denormalize_theta

            theta = denormalize_theta(
                theta,
                norm["mean"],
                norm["scale"],
                n_vars=self.cfg.state_dim + self.cfg.input_dim,
                order=self.cfg.order,
                n_state=self.cfg.state_dim,
            )
        n_active = self.spec.n_active if n_active is None else n_active
        if n_active is not None:
            theta = prune_theta(theta, n_active)
        return theta


def _resolve_lowering(
    spec: RecoverySpec, row: encoders.EncoderSpec, tune_report=None
) -> Lowering:
    """All execution decisions for one spec, resolved once.

    ``tune_report`` (analysis/tuner.TuneReport, from ``compile_plan``'s
    ``tune=`` modes) replaces the static policy with the tuner's winning
    candidate: the fused/unfused dispatch, the batch tile and the scan-unroll
    factor come from the candidate, and its cost evidence (predicted vs
    measured per-step bytes, the cache key) is stamped into the record.
    """
    quant_serving = spec.precision == "int8_pwl"
    chosen = tune_report.chosen.candidate if tune_report is not None else None
    fused = chosen.fused if chosen is not None else spec.fused
    routes_kernel = fused or row.kernel or quant_serving
    if routes_kernel:
        dispatch = "pallas" if rt.on_tpu() else "reference"
    else:
        dispatch = "xla"
    block_b, vmem, budget, budget_src = None, None, None, None
    if chosen is not None and fused:
        batch = _compile_time_batch(spec)
        block_b = chosen.block_b
        budget, budget_src = tune_report.budget_bytes, tune_report.budget_source
        if batch is not None:
            vmem = tiling.config_vmem_bytes(spec.to_mr_config(), batch, block_b=block_b)
    elif spec.fused:
        batch = _compile_time_batch(spec)
        if spec.block_b == "auto":
            # explicit override wins; otherwise the budget is auto-detected
            # from the local device (platform table + memory_stats when the
            # runtime exposes a VMEM figure) — ROADMAP "auto-detect the
            # budget" item. The resolved figure AND which source produced it
            # land in the Lowering record.
            if spec.vmem_budget_bytes is not None:
                budget, budget_src = spec.vmem_budget_bytes, "explicit"
            else:
                budget, budget_src = tiling.resolve_vmem_budget()
            block_b = tiling.auto_block_b(spec.to_mr_config(), batch, budget)
        elif isinstance(spec.block_b, int):
            if batch is not None and batch % spec.block_b != 0:
                # the kernel would silently drop a non-dividing tile at run
                # time (ops._legal_block_b) while this record claimed it —
                # a validatable request fails HERE like every other one
                raise ValueError(
                    f"block_b={spec.block_b} does not divide the compile-time "
                    f"batch ({batch}); the fused kernel requires B % block_b == 0"
                )
            block_b = spec.block_b
        if batch is not None:
            vmem = tiling.config_vmem_bytes(spec.to_mr_config(), batch, block_b=block_b)
    tuned = cache_key = predicted = measured = None
    if tune_report is not None:
        tuned = "measured:cached" if tune_report.cache_hit else tune_report.mode
        cache_key = tune_report.cache_key
        predicted = tune_report.chosen.predicted_bytes
        measured = tune_report.chosen.parsed_bytes
    return Lowering(
        encoder=spec.encoder,
        fused=fused,
        kernel=row.kernel,
        dispatch=dispatch,
        quant_serving=quant_serving,
        qat=spec.qat is not None,
        block_b=block_b,
        vmem_bytes=vmem,
        vmem_budget_bytes=budget,
        mesh_shape=(spec.mesh_slots,) if spec.mode == "stream" else (),
        vmem_budget_source=budget_src,
        substep_unroll=chosen.substep_unroll if chosen is not None else spec.substep_unroll,
        tuned=tuned,
        tune_cache_key=cache_key,
        predicted_bytes=predicted,
        measured_bytes=measured,
    )


def _resolve_tick_kernel(
    spec: RecoverySpec, cfg: MRConfig, scfg: StreamConfig, lowering: Lowering
) -> tuple[str, int | None]:
    """Resolve ``TickSpec.tick_kernel`` -> ("banked"|"composite", slots_per_bank).

    ``"composite"`` short-circuits (the bitwise-stable default). ``"banked"``
    is an explicit request: an unsupported family is a compile-time
    ValueError, and a budget the model can't fit still runs at bank size 1
    (the user overrode the heuristic). ``"auto"`` picks banked only when the
    family supports it AND ``tiling.auto_slots_per_bank`` finds a bank size
    whose residency fits the resolved VMEM budget — otherwise composite.
    The int8 serving twin is engaged only for pure serve ticks
    (``steps_per_tick == 0`` with int8_pwl serving), matching what the
    compiled program will actually run.
    """
    from repro.kernels.mr_step import tick as tick_mod

    requested = spec.tick_spec().tick_kernel
    if requested == "composite":
        return "composite", None
    quant_tick = lowering.quant_serving and scfg.steps_per_tick == 0
    supported = tick_mod.tick_supported(cfg, int8=quant_tick)
    if not supported:
        if requested == "banked":
            raise ValueError(
                f"tick_kernel='banked' requires a GRU-family encoder "
                f"(kernels/mr_step/tick.py banks the gru cell); got "
                f"encoder={spec.encoder!r} — use 'composite' or 'auto'"
            )
        return "composite", None
    if spec.vmem_budget_bytes is not None:
        budget = spec.vmem_budget_bytes
    else:
        budget, _ = tiling.resolve_vmem_budget()
    local_slots = spec.n_slots // spec.mesh_slots  # the per-device slot shard
    spb = tiling.auto_slots_per_bank(cfg, scfg, local_slots, budget, int8=quant_tick)
    if spb < 1:
        if requested == "banked":
            return "banked", 1  # explicit request: run anyway, smallest bank
        return "composite", None
    return "banked", spb


def _compile_time_batch(spec: RecoverySpec) -> int | None:
    """The fused-stage batch dimension knowable at compile time.

    stream: windows per slot (the tick's per-slot forward batch);
    offline/batch: the optimizer minibatch when configured, else unknown
    (None) — the auto tile then falls back to full batch, the documented
    no-budget behaviour.
    """
    if spec.mode == "stream":
        return spec.stream_config().n_windows
    return spec.batch_size


AUDIT_MODES = ("off", "warn", "error")
TUNE_MODES = ("off", "static", "measured")


def compile_plan(spec: RecoverySpec, audit: str = "off", tune: str = "off") -> RecoveryPlan:
    """Validate + lower a RecoverySpec; see the module docstring.

    ``audit`` runs the static HLO-contract auditor (analysis/audit.py) over
    the compiled programs: ``"off"`` skips it, ``"warn"`` emits a warning
    per finding, ``"error"`` raises :class:`repro.analysis.audit.AuditError`
    on any finding. Either audited mode stamps the verdict into
    ``plan.lowering.audit``.

    ``tune`` closes the loop from HLO cost analysis to the lowering choice
    (analysis/tuner.py): ``"off"`` keeps the static policy, ``"static"``
    replays the candidate table through the VMEM model only (no extra
    compiles — the decision matches the static policy, the evidence is
    recorded), ``"measured"`` lowers every candidate, scores the optimized
    HLO against ``Compiled.cost_analysis()`` and picks the roofline winner.
    Measured decisions persist in an on-disk cache keyed by (spec
    fingerprint, device kind, mesh shape), so a warm recompile performs ZERO
    candidate lowerings — the chosen candidate and its cost evidence land in
    ``plan.lowering`` (``tuned``, ``tune_cache_key``, ``predicted_bytes``,
    ``measured_bytes``).
    """
    if audit not in AUDIT_MODES:
        raise ValueError(f"audit must be one of {AUDIT_MODES}, got {audit!r}")
    if tune not in TUNE_MODES:
        raise ValueError(f"tune must be one of {TUNE_MODES}, got {tune!r}")
    row = encoders.get_encoder(spec.encoder)  # unknown name fails here
    if spec.precision == "int8_pwl" and not row.int8:
        raise ValueError(
            f"precision='int8_pwl' serves through a fixed-point fused stage, "
            f"implemented for the families with a PWL activation mapping "
            f"({encoders.int8_names()}); got {spec.encoder!r}"
        )
    if spec.qat is not None and row.flow is None:
        raise ValueError(
            f"qat (fixed-point fake-quant) is implemented for the GRU families, "
            f"got encoder={spec.encoder!r}"
        )
    tune_report = None
    if tune != "off":
        # lazy import: the tuner pulls hlo/encoders/merinda; plan.py stays
        # cheap to import and tune="off" pays nothing
        from repro.analysis import tuner as tuner_mod

        tune_report = tuner_mod.tune(spec, mode=tune)
    lowering = _resolve_lowering(spec, row, tune_report)
    cfg = spec.to_mr_config(block_b=lowering.block_b, substep_unroll=lowering.substep_unroll)
    if cfg.fused != lowering.fused:
        # the tuner may flip the fused dispatch (identical math, different
        # lowering) for families that implement both paths
        cfg = dataclasses.replace(cfg, fused=lowering.fused)
    # ONE source of truth for encoder-level invariants (registered name,
    # fused x fusable) — the same check the legacy entry points run
    encoders.validate_config(cfg)
    scfg = spec.stream_config()

    mesh = None
    if spec.mode == "stream" and spec.mesh_slots > 1:
        n_dev = len(jax.devices())
        if spec.mesh_slots > n_dev:
            raise ValueError(
                f"mesh_slots={spec.mesh_slots} exceeds the {n_dev} visible "
                f"device(s); set XLA_FLAGS=--xla_force_host_platform_device_count "
                f"for CPU virtual devices"
            )
        mesh = jax.make_mesh((spec.mesh_slots,), ("slots",))

    # the jitted donated programs for this spec's mode — static arguments are
    # bound NOW so every later call hits the same executable
    programs: dict = {}
    if spec.mode == "offline":
        programs["epoch"] = functools.partial(
            engine.run_epoch, cfg=cfg, steps=spec.steps, batch_size=spec.batch_size
        )
    elif spec.mode == "batch":
        programs["recover_many"] = functools.partial(
            engine._recover_many_jit,
            cfg=cfg,
            steps=spec.steps,
            batch_size=spec.batch_size,
            n_active=spec.n_active,
        )
    else:  # stream
        tick_kernel, spb = _resolve_tick_kernel(spec, cfg, scfg, lowering)
        if (
            tune_report is not None
            and tick_kernel == "banked"
            and tune_report.chosen_tick is not None
            and tune_report.chosen_tick.candidate.slots_per_bank
        ):
            # the measured tick search ranked the bank sizes; its winner
            # replaces the static auto_slots_per_bank choice
            spb = tune_report.chosen_tick.candidate.slots_per_bank
        tspec = spec.tick_spec()
        lowering = dataclasses.replace(
            lowering,
            tick_kernel=tick_kernel,
            tick_slots_per_bank=spb,
            control_plane=tspec.control,
            tick_queue_capacity=tspec.queue_capacity if tspec.control == "device" else None,
            tick_snapshot_period=tspec.snapshot_period if tspec.control == "device" else None,
            warm_capacity=tspec.warm_capacity,
            checkpoint_period=tspec.checkpoint_period,
            checkpoint_dir=tspec.checkpoint_dir,
            overflow_capacity=tspec.overflow_capacity,
        )
        quant_tick = lowering.quant_serving and scfg.steps_per_tick == 0
        if tick_kernel == "banked":
            programs["tick"] = functools.partial(
                stream_mod.tick_banked,
                cfg=cfg,
                scfg=scfg,
                quant=quant_tick,
                slots_per_bank=spb,
            )
        else:
            programs["tick"] = functools.partial(stream_mod.tick, cfg=cfg, scfg=scfg)
        if tspec.control == "device":
            # the zero-readback control-plane programs (core/control.py):
            # all statics bound NOW so every later call hits one executable
            from repro.core import control as control_mod

            programs["tick_device"] = functools.partial(
                control_mod.tick_device,
                cfg=cfg,
                scfg=scfg,
                kernel=tick_kernel,
                quant=quant_tick,
                slots_per_bank=spb or 1,
                shards=spec.mesh_slots,
            )
            programs["enqueue"] = control_mod.enqueue
            programs["pump"] = functools.partial(control_mod.pump, shards=spec.mesh_slots)
            programs["drain"] = control_mod.drain_events
    plan = RecoveryPlan(spec, cfg, scfg, lowering, mesh, programs)

    if audit != "off":
        # lazy import: the auditor pulls engine/stream/kernels; rules/hlo
        # stay importable without jax and plan.py stays cheap to import
        from repro.analysis import audit as audit_mod

        report = audit_mod.audit_plan(plan)
        plan.lowering = dataclasses.replace(lowering, audit=report.verdict)
        if report.findings:
            if audit == "error":
                raise audit_mod.AuditError(report)
            import warnings

            for f in report.findings:
                warnings.warn(f"plan audit: {f}", stacklevel=2)
    return plan
