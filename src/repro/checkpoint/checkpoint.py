"""Sharded, async, atomic checkpoints with reshard-on-restore.

Layout (one directory per step):

    <root>/step_00001000.tmp/        # staged writes
    <root>/step_00001000/            # atomic rename when complete
        manifest.json                # step, tree paths, shapes, dtypes,
                                     # mesh shape/axes, wall time, leaf digests
        <leaf-path>.npy              # one file per pytree leaf (global value)

Properties required at 1000-node scale, realized on this host:

- sharded write: each leaf is fetched shard-by-shard from its devices
  (``jax.device_get`` per addressable shard) and assembled into the global
  array — no single-device gather allocation on an accelerator.
- async: ``save_checkpoint(..., block=False)`` stages the device->host copy
  synchronously (cheap) and runs file I/O on a background thread; training
  continues. ``CheckpointManager.wait()`` joins before the next save.
- atomic: writes land in ``step_N.tmp`` and are renamed to ``step_N`` only
  after the manifest (written last) is fsynced. A crash mid-write leaves a
  ``.tmp`` directory that restore ignores.
- reshard-on-restore: restore takes the CURRENT mesh + sharding tree and
  ``jax.device_put``s each leaf with the new sharding — a checkpoint written
  on (pod=2, data=16, model=16) restores onto any surviving mesh
  (runtime/elastic.py chooses it).
- retention: ``keep`` newest checkpoints are preserved, older ones deleted.
- integrity: per-leaf CRC32 digests verified on restore.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import time
import zlib
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")

# ml_dtypes types numpy can't np.save natively: stored as same-width uint bits
_EXOTIC_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _logical_view(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _EXOTIC_DTYPES:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, logical_dtype)))
    return arr


def _flatten(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        out.append((key, leaf))
    return out


def _fetch_global(x) -> np.ndarray:
    """Assemble the global value of a (possibly sharded) jax.Array."""
    if isinstance(x, np.ndarray):
        return x
    if not hasattr(x, "addressable_shards"):
        return np.asarray(x)
    shards = x.addressable_shards
    if len(shards) == 1 and shards[0].data.shape == x.shape:
        return np.asarray(shards[0].data)
    out = np.empty(x.shape, dtype=x.dtype)
    for s in shards:  # shard-by-shard assembly (no device-side gather)
        out[s.index] = np.asarray(s.data)
    return out


def save_checkpoint(
    root: str | os.PathLike,
    step: int,
    state,
    mesh=None,
    keep: int = 3,
    block: bool = True,
) -> threading.Thread | None:
    """Write state under root/step_{step}. See module doc for semantics."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f"step_{step:08d}.tmp"
    final = root / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    # synchronous part: device -> host (must happen before params are donated)
    leaves = [(k, _fetch_global(v)) for k, v in _flatten(state)]

    manifest = {
        "step": int(step),
        "time": time.time(),
        "mesh": {
            "shape": list(mesh.devices.shape) if mesh is not None else None,
            "axes": list(mesh.axis_names) if mesh is not None else None,
        },
        "leaves": {},
    }

    def _write():
        for key, arr in leaves:
            fn = key.replace("/", "__") + ".npy"
            logical_dtype = str(arr.dtype)
            store = arr
            if arr.dtype.kind == "V" or logical_dtype in _EXOTIC_DTYPES:
                # numpy can't serialize ml_dtypes (bfloat16, fp8): store bits
                store = arr.view(_EXOTIC_DTYPES.get(logical_dtype, np.uint16))
            with open(tmp / fn, "wb") as f:
                np.save(f, store)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][key] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": logical_dtype,
                "crc32": zlib.crc32(store.tobytes()) & 0xFFFFFFFF,
            }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        _apply_retention(root, keep)

    if block:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _apply_retention(root: pathlib.Path, keep: int):
    steps = sorted(
        (int(m.group(1)), p)
        for p in root.iterdir()
        if p.is_dir() and (m := _STEP_RE.match(p.name))
    )
    for _, p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(root: str | os.PathLike) -> int | None:
    root = pathlib.Path(root)
    if not root.exists():
        return None
    steps = [
        int(m.group(1))
        for p in root.iterdir()
        if p.is_dir() and (m := _STEP_RE.match(p.name))
        and (p / "manifest.json").exists()  # ignore torn .tmp and unpublished
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    root: str | os.PathLike,
    step: int,
    like,
    shardings=None,
    verify: bool = True,
    expect_axes: tuple[str, ...] | None = None,
):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs), placing each leaf with ``shardings`` (same-structure
    pytree of NamedSharding) — this is where cross-mesh resharding happens.

    ``expect_axes`` names the mesh axes the restoring plan shards over; when
    both it and the manifest's recorded axes are present and disagree, the
    restore fails up front with a clear error instead of a shape mismatch
    deep inside ``device_put``. ``None`` on either side (unsharded save or
    caller that doesn't care) is compatible with anything.
    """
    root = pathlib.Path(root)
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    saved_axes = (manifest.get("mesh") or {}).get("axes")
    if expect_axes is not None and saved_axes is not None:
        if tuple(saved_axes) != tuple(expect_axes):
            raise ValueError(
                f"checkpoint {d} was written on mesh axes {tuple(saved_axes)} "
                f"but the restoring plan shards over {tuple(expect_axes)}; "
                "snapshots only reshard within the same logical axes "
                "(size may change, names may not)"
            )

    flat_like = _flatten(like)
    flat_sh = dict(_flatten(shardings)) if shardings is not None else {}
    out_leaves = []
    for key, ref in flat_like:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint {d} missing leaf {key!r}")
        arr = np.load(d / meta["file"])
        if verify and (zlib.crc32(arr.tobytes()) & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {key!r} ({meta['file']})")
        arr = _logical_view(arr, meta["dtype"])
        expect = tuple(getattr(ref, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"{key!r}: checkpoint shape {arr.shape} != expected {expect}")
        sh = flat_sh.get(key)
        out_leaves.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(like)
    return treedef.unflatten(out_leaves), manifest


class CheckpointManager:
    """Owns a checkpoint directory: async saves, retention, restart logic."""

    def __init__(self, root: str | os.PathLike, keep: int = 3, save_every: int = 100):
        self.root = pathlib.Path(root)
        self.keep = keep
        self.save_every = save_every
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, state, mesh=None, force: bool = False):
        if not force and (self.save_every <= 0 or step % self.save_every != 0):
            return
        self.wait()  # at most one in-flight async save
        self._pending = save_checkpoint(
            self.root, step, state, mesh=mesh, keep=self.keep, block=False
        )

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest(self) -> int | None:
        return latest_step(self.root)

    def restore_latest(self, like, shardings=None, expect_axes=None):
        step = self.latest()
        if step is None:
            return None, None
        state, manifest = restore_checkpoint(
            self.root, step, like, shardings, expect_axes=expect_axes
        )
        return state, manifest
