"""Mamba2 (SSD) layer: projections + depthwise conv + chunked SSD scan.

The scan itself is the paper-methodology kernel (kernels/ssd_scan) on TPU and
its chunked-jnp oracle elsewhere. Decode keeps (conv window, SSD state) as the
constant-size cache — this is why the ssm/hybrid archs run long_500k.

Simplification vs the reference CUDA implementation (noted in DESIGN.md): the
short causal conv is applied to the x stream only (not B/C), and z-gating uses
silu; both preserve the layer's compute/memory shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec
from repro.parallel.rules import constraint
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_decode_step


def mamba_specs(cfg: ModelConfig, dtype: str) -> dict:
    s = cfg.ssm
    d, H, P, G, N = cfg.d_model, cfg.ssm_heads, s.head_dim, s.num_groups, s.state_dim
    si = 1.0 / (d**0.5)
    return {
        "wz": ParamSpec((d, H, P), ("embed", "ssm_heads", "head_dim"), dtype=dtype, scale=si),
        "wx": ParamSpec((d, H, P), ("embed", "ssm_heads", "head_dim"), dtype=dtype, scale=si),
        "wb": ParamSpec((d, G, N), ("embed", "ssm_groups", "ssm_state"), dtype=dtype, scale=si),
        "wc": ParamSpec((d, G, N), ("embed", "ssm_groups", "ssm_state"), dtype=dtype, scale=si),
        "wdt": ParamSpec((d, H), ("embed", "ssm_heads"), dtype=dtype, scale=si),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), dtype="float32", init="const", scale=-2.0),
        "a_log": ParamSpec((H,), ("ssm_heads",), dtype="float32", init="zeros"),
        "d_skip": ParamSpec((H,), ("ssm_heads",), dtype="float32", init="ones"),
        "conv": ParamSpec(
            (s.conv_width, H, P), ("conv", "ssm_heads", "head_dim"), dtype=dtype, scale=0.5
        ),
        "norm": ParamSpec((H, P), ("ssm_heads", "head_dim"), dtype=dtype, init="ones"),
        "out": ParamSpec((H, P, d), ("ssm_heads", "head_dim", "embed"), dtype=dtype, scale=si),
    }


def _proj(params, x):
    z = jnp.einsum("bsd,dhp->bshp", x, params["wz"])
    xin = jnp.einsum("bsd,dhp->bshp", x, params["wx"])
    bm = jnp.einsum("bsd,dgn->bsgn", x, params["wb"])
    cm = jnp.einsum("bsd,dgn->bsgn", x, params["wc"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["wdt"]).astype(jnp.float32) + params["dt_bias"]
    )
    return z, xin, bm, cm, dt


def _causal_conv(xin: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over seq. xin: [B,S,H,P], w: [cw,H,P]."""
    cw = w.shape[0]
    pad = jnp.pad(xin, ((0, 0), (cw - 1, 0), (0, 0), (0, 0)))
    out = jnp.zeros_like(xin, dtype=jnp.float32)
    for i in range(cw):  # static unroll, cw=4
        out = out + pad[:, i : i + xin.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(xin.dtype)


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(z.dtype)


def mamba_forward(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence SSD mixer. x: [B, S, D] -> [B, S, D]."""
    s = cfg.ssm
    z, xin, bm, cm, dt = _proj(params, x)
    xin = _causal_conv(xin, params["conv"])
    xin = constraint(xin, ("batch", "seq", "ssm_heads", None))
    A = -jnp.exp(params["a_log"])
    y, _ = ssd_scan(xin, dt, A, bm, cm, params["d_skip"], chunk=s.chunk)
    y = _gated_norm(y, z, params["norm"], cfg.norm_eps)
    return jnp.einsum("bshp,hpd->bsd", y, params["out"])


def mamba_prefill(params, x: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """Forward + cache {conv: [B,cw-1,H,P] (pre-activation tail), state: [B,H,N,P]}."""
    s = cfg.ssm
    z, xin, bm, cm, dt = _proj(params, x)
    conv_tail = xin[:, -(s.conv_width - 1) :]  # raw (pre-conv) inputs
    xc = _causal_conv(xin, params["conv"])
    A = -jnp.exp(params["a_log"])
    y, state = ssd_scan(xc, dt, A, bm, cm, params["d_skip"], chunk=s.chunk)
    y = _gated_norm(y, z, params["norm"], cfg.norm_eps)
    out = jnp.einsum("bshp,hpd->bsd", y, params["out"])
    return out, {"conv": conv_tail, "state": state.astype(jnp.float32)}


def mamba_decode(params, x: jnp.ndarray, cache: dict, cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """Single-token step. x: [B, 1, D]."""
    s = cfg.ssm
    z, xin, bm, cm, dt = _proj(params, x)  # seq dim = 1
    hist = jnp.concatenate([cache["conv"], xin], axis=1)  # [B, cw, H, P]
    w = params["conv"]
    xc = jax.nn.silu(
        sum(hist[:, i].astype(jnp.float32) * w[i].astype(jnp.float32) for i in range(s.conv_width))
    ).astype(x.dtype)
    A = -jnp.exp(params["a_log"])
    y, state = ssd_decode_step(
        xc, dt[:, 0], A, bm[:, 0], cm[:, 0], params["d_skip"], cache["state"]
    )
    y = _gated_norm(y[:, None], z, params["norm"], cfg.norm_eps)
    out = jnp.einsum("bshp,hpd->bsd", y, params["out"])
    return out, {"conv": hist[:, 1:], "state": state}


def mamba_cache_shapes(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    H, P, N = cfg.ssm_heads, s.head_dim, s.state_dim
    return {
        "conv": ((batch, s.conv_width - 1, H, P), cfg.dtype, ("batch", None, "ssm_heads", None)),
        "state": ((batch, H, N, P), "float32", ("batch", "ssm_heads", None, None)),
    }
