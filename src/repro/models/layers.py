"""Shared building blocks: RMSNorm, embedding, SwiGLU MLP, cross-entropy."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec
from repro.parallel.rules import constraint, sp_gather


# --- RMSNorm ----------------------------------------------------------------
def rmsnorm_specs(d: int, dtype: str):
    return {"scale": ParamSpec((d,), (None,), dtype=dtype, init="ones")}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --- Embedding / LM head -----------------------------------------------------
def embed_specs(vocab_padded: int, d: int, dtype: str):
    return {"tokens": ParamSpec((vocab_padded, d), ("vocab", "embed"), dtype=dtype, scale=0.02)}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["tokens"][tokens]  # gather over sharded vocab
    return constraint(x, ("batch", "seq", "act_embed"))


def lm_head_specs(d: int, vocab_padded: int, dtype: str):
    return {"w": ParamSpec((d, vocab_padded), ("embed", "vocab"), dtype=dtype, scale=0.02)}


def lm_head(params, x: jnp.ndarray) -> jnp.ndarray:
    logits = x @ params["w"]
    return constraint(logits, ("batch", "seq", "act_vocab"))


def cross_entropy(
    logits: jnp.ndarray,  # [B, S, Vp]
    labels: jnp.ndarray,  # [B, S] int32; -1 = ignore
    vocab_size: int,
    chunk: int = 0,
) -> jnp.ndarray:
    """Mean CE over valid positions; padded vocab tail masked out.

    chunk > 0 computes the loss in seq chunks via lax.map (bounds the fp32
    logsumexp working set for long sequences — a §Perf memory-term knob).
    """

    def ce(lg, lb):
        lg = lg.astype(jnp.float32)
        vp = lg.shape[-1]
        if vp > vocab_size:
            mask = jnp.arange(vp) < vocab_size
            lg = jnp.where(mask, lg, -1e30)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        valid = (lb >= 0).astype(jnp.float32)
        return ((lse - gold) * valid).sum(), valid.sum()

    if chunk and logits.shape[1] > chunk and logits.shape[1] % chunk == 0:
        nseg = logits.shape[1] // chunk
        lg = logits.reshape(logits.shape[0], nseg, chunk, -1).swapaxes(0, 1)
        lb = labels.reshape(labels.shape[0], nseg, chunk).swapaxes(0, 1)
        tot, cnt = jax.lax.map(lambda args: ce(*args), (lg, lb))
        return tot.sum() / jnp.maximum(cnt.sum(), 1.0)
    tot, cnt = ce(logits, labels)
    return tot / jnp.maximum(cnt, 1.0)


# --- SwiGLU MLP ---------------------------------------------------------------
def mlp_specs(d: int, f: int, dtype: str):
    si, sf = 1.0 / (d**0.5), 1.0 / (f**0.5)
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp"), dtype=dtype, scale=si),
        "w_up": ParamSpec((d, f), ("embed", "mlp"), dtype=dtype, scale=si),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), dtype=dtype, scale=sf),
    }


def mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    # SP boundary: seq all-gather fwd / reduce-scatter bwd (rules.sp_gather)
    x = sp_gather(x)
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = constraint(h, ("batch", "seq", "act_mlp"))
    return h @ params["w_down"]
