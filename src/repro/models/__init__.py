"""LM-family model zoo: parameter-spec system + family implementations."""
