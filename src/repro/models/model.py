"""Model zoo public API.

Families: dense | moe | vlm | ssm | hybrid | audio | gru

Entry points (all functional, params = pytree of arrays):
    param_specs(cfg)                  -> pytree[ParamSpec]      (no allocation)
    init_params(key, cfg)             -> pytree[Array]
    train_loss(params, batch, cfg)    -> (loss, metrics)
    prefill(params, batch, cfg, cache_len) -> (logits_last [B,Vp], cache)
    decode_step(params, cache, tokens, pos, cfg) -> (logits [B,Vp], cache)
    input_specs(cfg, shape)           -> dict[str, ParamSpec]   (dry-run inputs)
    cache_specs(cfg, batch, cache_len)-> pytree[ParamSpec]

Layer stacks are scanned (one traced layer body, stacked params) with
configurable remat — required to keep 56-layer compiles tractable and the
backward memory bounded. The residual stream is sequence-sharded between
layers (Megatron-style SP) when cfg allows; see parallel/rules.py.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (
    cross_entropy,
    embed,
    embed_specs,
    lm_head,
    lm_head_specs,
    mlp,
    mlp_specs,
    rmsnorm,
    rmsnorm_specs,
)
from repro.models.params import ParamSpec, materialize, stack_layer, tree_map_specs
from repro.parallel.rules import constraint

AUDIO_SRC_LEN = 4096  # encoder frame count for the audio enc-dec family
AUDIO_FEAT = 80  # fbank feature dim supplied by the (stub) frontend


# ===========================================================================
# parameter specs
# ===========================================================================
def _decoder_layer_specs(cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    specs: dict[str, Any] = {
        "ln1": rmsnorm_specs(d, dt),
        "ln2": rmsnorm_specs(d, dt),
        "attn": attn_mod.attn_specs(cfg.attn, d, dt),
    }
    if cfg.family == "moe":
        specs["moe"] = moe_mod.moe_specs(cfg.moe, d, cfg.d_ff, dt)
    else:
        specs["mlp"] = mlp_specs(d, cfg.d_ff, dt)
    return specs


def _gru_layer_specs(cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    h = cfg.gru_hidden or d
    s = 1.0 / ((d + h) ** 0.5)
    return {
        "ln1": rmsnorm_specs(d, dt),
        "ln2": rmsnorm_specs(d, dt),
        "gru": {
            "w": ParamSpec((d + h, 3 * h), ("embed", "mlp"), dtype=dt, scale=s),
            "b": ParamSpec((3 * h,), (None,), dtype="float32", init="zeros"),
            "time_scale": ParamSpec((h,), (None,), dtype="float32", init="zeros"),
            "out": ParamSpec((h, d), ("mlp", "embed"), dtype=dt, scale=1.0 / (h**0.5)),
        },
        "mlp": mlp_specs(d, cfg.d_ff, dt),
    }


def param_specs(cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    specs: dict[str, Any] = {
        "embed": embed_specs(cfg.vocab_padded, d, dt),
        "final_norm": rmsnorm_specs(d, dt),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = lm_head_specs(d, cfg.vocab_padded, dt)

    if cfg.family in ("dense", "moe", "vlm"):
        layer = _decoder_layer_specs(cfg)
        specs["layers"] = tree_map_specs(lambda s: stack_layer(s, cfg.num_layers), layer)
    elif cfg.family == "ssm":
        layer = {"ln": rmsnorm_specs(d, dt), "mamba": mamba_mod.mamba_specs(cfg, dt)}
        specs["layers"] = tree_map_specs(lambda s: stack_layer(s, cfg.num_layers), layer)
    elif cfg.family == "hybrid":
        layer = {"ln": rmsnorm_specs(d, dt), "mamba": mamba_mod.mamba_specs(cfg, dt)}
        specs["layers"] = tree_map_specs(lambda s: stack_layer(s, cfg.num_layers), layer)
        specs["shared_attn"] = {  # ONE weight-shared transformer block (zamba2)
            "ln1": rmsnorm_specs(d, dt),
            "ln2": rmsnorm_specs(d, dt),
            "attn": attn_mod.attn_specs(cfg.attn, d, dt),
            "mlp": mlp_specs(d, cfg.d_ff, dt),
        }
    elif cfg.family == "audio":
        specs["frontend"] = {
            "w": ParamSpec((AUDIO_FEAT, d), ("frontend", "embed"), dtype=dt, scale=AUDIO_FEAT**-0.5)
        }
        enc_layer = {
            "ln1": rmsnorm_specs(d, dt),
            "ln2": rmsnorm_specs(d, dt),
            "attn": attn_mod.attn_specs(cfg.attn, d, dt),
            "mlp": mlp_specs(d, cfg.d_ff, dt),
        }
        specs["enc_layers"] = tree_map_specs(
            lambda s: stack_layer(s, cfg.encoder_layers), enc_layer
        )
        specs["enc_norm"] = rmsnorm_specs(d, dt)
        dec_layer = {
            "ln1": rmsnorm_specs(d, dt),
            "ln2": rmsnorm_specs(d, dt),
            "ln3": rmsnorm_specs(d, dt),
            "attn": attn_mod.attn_specs(cfg.attn, d, dt),
            "cross": attn_mod.cross_attn_specs(cfg.attn, d, dt),
            "mlp": mlp_specs(d, cfg.d_ff, dt),
        }
        specs["layers"] = tree_map_specs(lambda s: stack_layer(s, cfg.num_layers), dec_layer)
    elif cfg.family == "gru":
        layer = _gru_layer_specs(cfg)
        specs["layers"] = tree_map_specs(lambda s: stack_layer(s, cfg.num_layers), layer)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return specs


def init_params(key: jax.Array, cfg: ModelConfig):
    return materialize(key, param_specs(cfg))


# ===========================================================================
# layer forwards (full-sequence)
# ===========================================================================
def _residual_constraint(x, cfg: ModelConfig):
    return constraint(x, ("batch", "seq_sharded", "act_embed"))


def _dense_layer_fwd(lp, x, positions, cfg: ModelConfig):
    h = attn_mod.attention(
        lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), positions, cfg.attn, chunk=cfg.attn_chunk
    )
    x = _residual_constraint(x + h, cfg)
    if cfg.family == "moe":
        h, aux = moe_mod.moe_ffn(lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.moe)
    else:
        h, aux = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps)), jnp.zeros((), jnp.float32)
    x = _residual_constraint(x + h, cfg)
    return x, aux


def _ssm_layer_fwd(lp, x, cfg: ModelConfig):
    h = mamba_mod.mamba_forward(lp["mamba"], rmsnorm(lp["ln"], x, cfg.norm_eps), cfg)
    return _residual_constraint(x + h, cfg)


def _shared_block_fwd(sp, x, positions, cfg: ModelConfig):
    h = attn_mod.attention(
        sp["attn"], rmsnorm(sp["ln1"], x, cfg.norm_eps), positions, cfg.attn, chunk=cfg.attn_chunk
    )
    x = _residual_constraint(x + h, cfg)
    h = mlp(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps))
    return _residual_constraint(x + h, cfg)


def _gru_layer_fwd(lp, x, cfg: ModelConfig):
    from repro.core.neural_flow import GRUParams, gru_scan_ref

    g = lp["gru"]
    gp = GRUParams(w=g["w"].astype(jnp.float32), b=g["b"], time_scale=g["time_scale"])
    xin = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    h0 = jnp.zeros((x.shape[0], g["time_scale"].shape[0]), jnp.float32)
    _, hs = gru_scan_ref(gp, xin.astype(jnp.float32), h0, flow=True)
    x = _residual_constraint(x + (hs.astype(x.dtype) @ g["out"]), cfg)
    h = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return _residual_constraint(x + h, cfg)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # full


def _scan_stack(stacked, x, body, cfg: ModelConfig):
    """Run x through stacked layer params; body(lp, x) -> (x, aux_scalar)."""

    def step(carry, lp):
        x = carry
        x, aux = body(lp, x)
        return x, aux

    step = _remat(step, cfg)
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(step, x, stacked)
        return x, jnp.sum(auxs)
    n = jax.tree.leaves(stacked)[0].shape[0]
    total = jnp.zeros((), jnp.float32)
    for i in range(n):
        lp = jax.tree.map(lambda a, _i=i: a[_i], stacked)
        x, aux = step(x, lp)
        total = total + aux
    return x, total


def _segment_bounds(cfg: ModelConfig) -> list[tuple[int, int, bool]]:
    """Hybrid (zamba2) scheduling: [(lo, hi, shared_attn_after), ...]."""
    k = cfg.attn_period
    out = []
    lo = 0
    while lo < cfg.num_layers:
        hi = min(lo + k, cfg.num_layers)
        out.append((lo, hi, hi - lo == k))
        lo = hi
    return out


def _tree_slice(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


# ===========================================================================
# forward (training) per family
# ===========================================================================
def _backbone(params, x, positions, cfg: ModelConfig):
    """Token/frame embeddings -> final hidden states. Returns (x, moe_aux)."""
    if cfg.family in ("dense", "moe", "vlm"):
        body = lambda lp, x: _dense_layer_fwd(lp, x, positions, cfg)
        return _scan_stack(params["layers"], x, body, cfg)
    if cfg.family == "ssm":
        body = lambda lp, x: (_ssm_layer_fwd(lp, x, cfg), jnp.zeros((), jnp.float32))
        return _scan_stack(params["layers"], x, body, cfg)
    if cfg.family == "hybrid":
        body = lambda lp, x: (_ssm_layer_fwd(lp, x, cfg), jnp.zeros((), jnp.float32))
        for lo, hi, with_attn in _segment_bounds(cfg):
            x, _ = _scan_stack(_tree_slice(params["layers"], lo, hi), x, body, cfg)
            if with_attn:
                x = _shared_block_fwd(params["shared_attn"], x, positions, cfg)
        return x, jnp.zeros((), jnp.float32)
    if cfg.family == "gru":
        body = lambda lp, x: (_gru_layer_fwd(lp, x, cfg), jnp.zeros((), jnp.float32))
        return _scan_stack(params["layers"], x, body, cfg)
    raise ValueError(cfg.family)


def _encode_audio(params, frames, cfg: ModelConfig):
    x = frames.astype(jnp.dtype(cfg.dtype)) @ params["frontend"]["w"]
    positions = jnp.arange(frames.shape[1])

    def body(lp, x):
        h = attn_mod.attention(
            lp["attn"],
            rmsnorm(lp["ln1"], x, cfg.norm_eps),
            positions,
            cfg.attn,
            causal=False,
            chunk=cfg.attn_chunk,
        )
        x = _residual_constraint(x + h, cfg)
        h = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return _residual_constraint(x + h, cfg), jnp.zeros((), jnp.float32)

    x, _ = _scan_stack(params["enc_layers"], x, body, cfg)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _decoder_audio(params, x, enc_out, positions, cfg: ModelConfig):
    def body(lp, x):
        h = attn_mod.attention(
            lp["attn"],
            rmsnorm(lp["ln1"], x, cfg.norm_eps),
            positions,
            cfg.attn,
            chunk=cfg.attn_chunk,
        )
        x = _residual_constraint(x + h, cfg)
        kv = attn_mod.cross_kv(lp["cross"], enc_out, cfg.attn)
        h = attn_mod.cross_attention(
            lp["cross"], rmsnorm(lp["ln2"], x, cfg.norm_eps), kv, cfg.attn, chunk=cfg.attn_chunk
        )
        x = _residual_constraint(x + h, cfg)
        h = mlp(lp["mlp"], rmsnorm(lp["ln3"], x, cfg.norm_eps))
        return _residual_constraint(x + h, cfg), jnp.zeros((), jnp.float32)

    x, _ = _scan_stack(params["layers"], x, body, cfg)
    return x


def _assemble_inputs(params, batch, cfg: ModelConfig):
    """Family-specific input embedding. Returns (x [B,S,D], positions [S])."""
    if cfg.family == "vlm":
        tok_x = embed(params["embed"], batch["tokens"])
        x = jnp.concatenate([batch["patches"].astype(tok_x.dtype), tok_x], axis=1)
    elif cfg.family == "audio":
        x = embed(params["embed"], batch["tokens"])
    else:
        x = embed(params["embed"], batch["tokens"])
    positions = jnp.arange(x.shape[1])
    return x, positions


def _logits(params, x, cfg: ModelConfig):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return constraint(x @ params["embed"]["tokens"].T, ("batch", "seq", "act_vocab"))
    return lm_head(params["lm_head"], x)


def train_loss(params, batch, cfg: ModelConfig):
    """Teacher-forced CE (+ MoE load-balance aux). batch: tokens/labels (+extras)."""
    x, positions = _assemble_inputs(params, batch, cfg)
    if cfg.family == "audio":
        enc_out = _encode_audio(params, batch["frames"], cfg)
        x = _decoder_audio(params, x, enc_out, positions, cfg)
        moe_aux = jnp.zeros((), jnp.float32)
    else:
        x, moe_aux = _backbone(params, x, positions, cfg)
    logits = _logits(params, x, cfg)
    labels = batch["labels"]
    if cfg.family == "vlm":  # patch positions carry no labels
        pad = jnp.full((labels.shape[0], cfg.num_patches), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = cross_entropy(logits, labels, cfg.vocab_size, chunk=cfg.logit_chunk)
    loss = ce + 0.01 * moe_aux
    return loss, {"ce": ce, "moe_aux": moe_aux}


# ===========================================================================
# serving: prefill + decode
# ===========================================================================
def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """Abstract cache tree (ParamSpec leaves) for decode dry-runs."""
    L = cfg.num_layers
    specs: dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm"):
        shape = attn_mod.cache_shape(cfg.attn, batch, cache_len)
        axes = ("layers", "batch", "cache_seq", "kv_heads", None)
        kv = ParamSpec((L, *shape), axes, dtype=cfg.dtype, init="zeros")
        specs["layers"] = {"k": kv, "v": kv}
    elif cfg.family in ("ssm", "hybrid"):
        sh = mamba_mod.mamba_cache_shapes(cfg, batch)
        specs["layers"] = {
            name: ParamSpec((L, *shape), ("layers", *axes), dtype=dt, init="zeros")
            for name, (shape, dt, axes) in sh.items()
        }
        if cfg.family == "hybrid":
            n_app = sum(1 for *_, w in _segment_bounds(cfg) if w)
            shape = attn_mod.cache_shape(cfg.attn, batch, cache_len)
            axes = ("layers", "batch", "cache_seq", "kv_heads", None)
            kv = ParamSpec((n_app, *shape), axes, dtype=cfg.dtype, init="zeros")
            specs["shared_attn"] = {"k": kv, "v": kv}
    elif cfg.family == "audio":
        shape = attn_mod.cache_shape(cfg.attn, batch, cache_len)
        axes = ("layers", "batch", "cache_seq", "kv_heads", None)
        kv = ParamSpec((L, *shape), axes, dtype=cfg.dtype, init="zeros")
        cross_shape = (L, batch, AUDIO_SRC_LEN, cfg.attn.num_kv_heads, cfg.attn.head_dim)
        ckv = ParamSpec(cross_shape, axes, dtype=cfg.dtype, init="zeros")
        specs["layers"] = {"k": kv, "v": kv, "cross_k": ckv, "cross_v": ckv}
    elif cfg.family == "gru":
        h = cfg.gru_hidden or cfg.d_model
        specs["layers"] = {
            "state": ParamSpec(
                (L, batch, h), ("layers", "batch", None), dtype="float32", init="zeros"
            )
        }
    else:
        raise ValueError(cfg.family)
    return specs


def prefill(params, batch, cfg: ModelConfig, cache_len: int):
    """Process the prompt; returns (last-token logits [B, Vp], cache)."""
    x, positions = _assemble_inputs(params, batch, cfg)
    caches: dict[str, Any] = {}

    if cfg.family in ("dense", "moe", "vlm"):

        def body(carry, lp):
            x = carry
            h, kv = attn_mod.prefill_attention(
                lp["attn"],
                rmsnorm(lp["ln1"], x, cfg.norm_eps),
                positions,
                cfg.attn,
                cache_len,
                chunk=cfg.attn_chunk,
            )
            x = _residual_constraint(x + h, cfg)
            if cfg.family == "moe":
                # dropless: prefill must route like decode (see moe_ffn)
                h, _ = moe_mod.moe_ffn(
                    lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.moe, dropless=True
                )
            else:
                h = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
            x = _residual_constraint(x + h, cfg)
            return x, kv

        x, kvs = jax.lax.scan(body, x, params["layers"])
        caches["layers"] = kvs
    elif cfg.family in ("ssm", "hybrid"):

        def body(carry, lp):
            x = carry
            h, cache = mamba_mod.mamba_prefill(lp["mamba"], rmsnorm(lp["ln"], x, cfg.norm_eps), cfg)
            return _residual_constraint(x + h, cfg), cache

        if cfg.family == "ssm":
            x, caches["layers"] = jax.lax.scan(body, x, params["layers"])
        else:
            segs, attn_caches = _segment_bounds(cfg), []
            layer_caches = []
            for lo, hi, with_attn in segs:
                x, c = jax.lax.scan(body, x, _tree_slice(params["layers"], lo, hi))
                layer_caches.append(c)
                if with_attn:
                    sp = params["shared_attn"]
                    h, kv = attn_mod.prefill_attention(
                        sp["attn"],
                        rmsnorm(sp["ln1"], x, cfg.norm_eps),
                        positions,
                        cfg.attn,
                        cache_len,
                        chunk=cfg.attn_chunk,
                    )
                    x = _residual_constraint(x + h, cfg)
                    h = mlp(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps))
                    x = _residual_constraint(x + h, cfg)
                    attn_caches.append(kv)
            caches["layers"] = jax.tree.map(lambda *a: jnp.concatenate(a, 0), *layer_caches)
            caches["shared_attn"] = jax.tree.map(lambda *a: jnp.stack(a, 0), *attn_caches)
    elif cfg.family == "audio":
        enc_out = _encode_audio(params, batch["frames"], cfg)

        def body(carry, lp):
            x = carry
            h, kv = attn_mod.prefill_attention(
                lp["attn"],
                rmsnorm(lp["ln1"], x, cfg.norm_eps),
                positions,
                cfg.attn,
                cache_len,
                chunk=cfg.attn_chunk,
            )
            x = _residual_constraint(x + h, cfg)
            ckv = attn_mod.cross_kv(lp["cross"], enc_out, cfg.attn)
            h = attn_mod.cross_attention(
                lp["cross"],
                rmsnorm(lp["ln2"], x, cfg.norm_eps),
                ckv,
                cfg.attn,
                chunk=cfg.attn_chunk,
            )
            x = _residual_constraint(x + h, cfg)
            h = mlp(lp["mlp"], rmsnorm(lp["ln3"], x, cfg.norm_eps))
            x = _residual_constraint(x + h, cfg)
            return x, {"k": kv["k"], "v": kv["v"], "cross_k": ckv["k"], "cross_v": ckv["v"]}

        x, caches["layers"] = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "gru":
        from repro.core.neural_flow import GRUParams, gru_scan_ref

        def body(carry, lp):
            x = carry
            g = lp["gru"]
            gp = GRUParams(w=g["w"].astype(jnp.float32), b=g["b"], time_scale=g["time_scale"])
            xin = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h0 = jnp.zeros((x.shape[0], g["time_scale"].shape[0]), jnp.float32)
            h_T, hs = gru_scan_ref(gp, xin.astype(jnp.float32), h0, flow=True)
            x = _residual_constraint(x + (hs.astype(x.dtype) @ g["out"]), cfg)
            h = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
            x = _residual_constraint(x + h, cfg)
            return x, {"state": h_T}

        x, caches["layers"] = jax.lax.scan(body, x, params["layers"])
    else:
        raise ValueError(cfg.family)

    logits = _logits(params, x[:, -1:, :], cfg)
    return logits[:, 0], caches


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One token through the stack with caches. tokens: [B,1]; pos: scalar."""
    x = embed(params["embed"], tokens)

    if cfg.family in ("dense", "moe", "vlm"):

        def body(carry, scan_in):
            x = carry
            lp, kv = scan_in
            h, kv = attn_mod.decode_attention(
                lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), pos, kv, cfg.attn
            )
            x = x + h
            if cfg.family == "moe":
                h, _ = moe_mod.moe_ffn(
                    lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.moe, dropless=True
                )
            else:
                h = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
            return x + h, kv

        x, kvs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = dict(cache, layers=kvs)
    elif cfg.family in ("ssm", "hybrid"):

        def body(carry, scan_in):
            x = carry
            lp, c = scan_in
            h, c = mamba_mod.mamba_decode(lp["mamba"], rmsnorm(lp["ln"], x, cfg.norm_eps), c, cfg)
            return x + h, c

        if cfg.family == "ssm":
            x, new_c = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            cache = dict(cache, layers=new_c)
        else:
            segs = _segment_bounds(cfg)
            new_layer_caches, new_attn_caches = [], []
            app = 0
            for lo, hi, with_attn in segs:
                x, c = jax.lax.scan(
                    body,
                    x,
                    (_tree_slice(params["layers"], lo, hi), _tree_slice(cache["layers"], lo, hi)),
                )
                new_layer_caches.append(c)
                if with_attn:
                    sp = params["shared_attn"]
                    kv = jax.tree.map(lambda a: a[app], cache["shared_attn"])
                    h, kv = attn_mod.decode_attention(
                        sp["attn"], rmsnorm(sp["ln1"], x, cfg.norm_eps), pos, kv, cfg.attn
                    )
                    x = x + h
                    x = x + mlp(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps))
                    new_attn_caches.append(kv)
                    app += 1
            cache = dict(
                cache,
                layers=jax.tree.map(lambda *a: jnp.concatenate(a, 0), *new_layer_caches),
                shared_attn=jax.tree.map(lambda *a: jnp.stack(a, 0), *new_attn_caches),
            )
    elif cfg.family == "audio":

        def body(carry, scan_in):
            x = carry
            lp, c = scan_in
            h, kv = attn_mod.decode_attention(
                lp["attn"],
                rmsnorm(lp["ln1"], x, cfg.norm_eps),
                pos,
                {"k": c["k"], "v": c["v"]},
                cfg.attn,
            )
            x = x + h
            ckv = {"k": c["cross_k"], "v": c["cross_v"]}
            h = attn_mod.cross_attention(
                lp["cross"],
                rmsnorm(lp["ln2"], x, cfg.norm_eps),
                ckv,
                cfg.attn,
                chunk=cfg.attn_chunk,
            )
            x = x + h
            x = x + mlp(lp["mlp"], rmsnorm(lp["ln3"], x, cfg.norm_eps))
            return x, dict(c, k=kv["k"], v=kv["v"])

        x, new_c = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = dict(cache, layers=new_c)
    elif cfg.family == "gru":
        from repro.core.neural_flow import GRUParams, gru_flow_cell

        def body(carry, scan_in):
            x = carry
            lp, c = scan_in
            g = lp["gru"]
            gp = GRUParams(w=g["w"].astype(jnp.float32), b=g["b"], time_scale=g["time_scale"])
            xin = rmsnorm(lp["ln1"], x, cfg.norm_eps)[:, 0].astype(jnp.float32)
            h = gru_flow_cell(gp, xin, c["state"], 1.0)
            x = x + (h.astype(x.dtype) @ g["out"])[:, None]
            x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
            return x, {"state": h}

        x, new_c = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = dict(cache, layers=new_c)
    else:
        raise ValueError(cfg.family)

    logits = _logits(params, x, cfg)
    return logits[:, 0], cache


# ===========================================================================
# dry-run input specs
# ===========================================================================
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins (as ParamSpec) for every model input."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda b, s: ParamSpec((b, s), ("batch", "seq"), dtype="int32", init="zeros")
    specs: dict[str, Any] = {}
    if shape.mode == "train":
        if cfg.family == "vlm":
            text = S - cfg.num_patches
            specs["tokens"] = tok(B, text)
            specs["labels"] = tok(B, text)
            specs["patches"] = ParamSpec(
                (B, cfg.num_patches, cfg.d_model), ("batch", None, "act_embed"), dtype=cfg.dtype
            )
        elif cfg.family == "audio":
            specs["tokens"] = tok(B, S)
            specs["labels"] = tok(B, S)
            specs["frames"] = ParamSpec(
                (B, AUDIO_SRC_LEN, AUDIO_FEAT), ("batch", None, None), dtype="float32"
            )
        else:
            specs["tokens"] = tok(B, S)
            specs["labels"] = tok(B, S)
    elif shape.mode == "prefill":
        if cfg.family == "vlm":
            specs["tokens"] = tok(B, S - cfg.num_patches)
            specs["patches"] = ParamSpec(
                (B, cfg.num_patches, cfg.d_model), ("batch", None, "act_embed"), dtype=cfg.dtype
            )
        elif cfg.family == "audio":
            specs["tokens"] = tok(B, S)
            specs["frames"] = ParamSpec(
                (B, AUDIO_SRC_LEN, AUDIO_FEAT), ("batch", None, None), dtype="float32"
            )
        else:
            specs["tokens"] = tok(B, S)
    else:  # decode
        specs["tokens"] = tok(B, 1)
        specs["pos"] = ParamSpec((), (), dtype="int32", init="zeros")
        specs["cache"] = cache_specs(cfg, B, S)
    return specs
