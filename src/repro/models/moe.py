"""Mixture-of-Experts FFN: top-k router + capacity-bounded einsum dispatch.

Dropped-token dispatch in the Mesh-TF/MaxText style, with one refinement that
bounds dispatch memory independently of expert count: tokens are dispatched in
groups of ``group_size``, so the one-hot dispatch tensor is
[groups, group_size, E, C] with C = ceil(group_size * top_k * cf / E) —
total size O(tokens * group_size * top_k * cf), independent of E.

Sharding: expert dim -> model axis when divisible (moonshot 64e), else the
per-expert ffn dim -> model axis (mixtral 8e on a 16-way axis) — resolved
automatically by the rules table (parallel/rules.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.params import ParamSpec
from repro.parallel.rules import constraint, sp_gather


def moe_specs(m: MoEConfig, d: int, f: int, dtype: str) -> dict:
    si, sf = 1.0 / (d**0.5), 1.0 / (f**0.5)
    return {
        "router": ParamSpec((d, m.num_experts), ("embed", "expert"), dtype="float32", scale=si),
        "w_gate": ParamSpec(
            (m.num_experts, d, f), ("expert", "embed", "mlp"), dtype=dtype, scale=si
        ),
        "w_up": ParamSpec((m.num_experts, d, f), ("expert", "embed", "mlp"), dtype=dtype, scale=si),
        "w_down": ParamSpec(
            (m.num_experts, f, d), ("expert", "mlp", "embed"), dtype=dtype, scale=sf
        ),
    }


def expert_capacity(m: MoEConfig, group_size: int) -> int:
    c = math.ceil(group_size * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, min(c, group_size))


def moe_ffn(
    params, x: jnp.ndarray, m: MoEConfig, dropless: bool = False
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    aux_loss is the standard load-balancing loss (mean expert fraction x mean
    router prob, scaled by E).

    dropless=True is the INFERENCE path (prefill + decode): every (token,
    expert choice) is honored, so the layer is a pure per-token function of
    its input. The capacity-bounded training path drops tokens that overflow
    an expert's queue — that makes a token's output depend on which other
    tokens share its dispatch group, which breaks prefill/decode parity (a
    decoded token is alone in its group and never dropped; the same token
    inside a prefill competes with the whole prompt). Dropless inference
    computes all experts densely and combines with the routing weights —
    E/top_k extra FLOPs, fine for smoke-scale eval; production serving would
    use a gather-based dispatch instead.
    """
    # SP boundary: seq all-gather fwd / reduce-scatter bwd (rules.sp_gather)
    x = sp_gather(x)
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    g = min(m.group_size, B * S)
    tokens = x.reshape(-1, D)
    n_tok = tokens.shape[0]
    pad = (-n_tok) % g  # pad to a group multiple; padded rows sliced off below
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    ng = tokens.shape[0] // g
    C = expert_capacity(m, g)

    xt = tokens.reshape(ng, g, D)
    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # [ng, g, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # [ng, g, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # [ng, g, K, E]

    # load-balance auxiliary loss (same for both dispatch modes)
    frac_tokens = jnp.mean(onehot.sum(2), axis=1)  # [ng, E] fraction routed
    frac_prob = jnp.mean(probs, axis=1)  # [ng, E]
    aux = (E * jnp.mean(jnp.sum(frac_tokens * frac_prob, axis=-1))).astype(jnp.float32)

    if dropless:
        # same sharding story as the capacity path below: token dim carries
        # batch, expert or per-expert ffn dim carries model (rules fallback) —
        # the dense [ng, g, E, F] activation otherwise replicates per device
        comb_e = jnp.einsum("ngk,ngke->nge", top_p, onehot)  # routing weights
        h = jax.nn.silu(jnp.einsum("ngd,edf->ngef", xt, params["w_gate"]))
        h = h * jnp.einsum("ngd,edf->ngef", xt, params["w_up"])
        h = constraint(h, ("batch", None, "act_expert", "act_mlp"))
        out_e = jnp.einsum("ngef,efd->nged", h, params["w_down"])
        out_e = constraint(out_e, ("batch", None, "act_expert", "act_embed"))
        out = jnp.einsum("nge,nged->ngd", comb_e.astype(x.dtype), out_e)
        out = constraint(out, ("batch", None, "act_embed"))
        out = out.reshape(-1, D)[:n_tok]
        return out.reshape(B, S, D), aux

    # position of each (token, choice) within its expert queue
    flat = onehot.reshape(ng, g * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0  # [ng, g*K, E]
    pos = (pos * flat).reshape(ng, g, K, E).sum(-1)  # [ng, g, K] queue slot
    expert_of = top_e
    keep = pos < C

    # dispatch/combine tensors: [ng, g, E, C]
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("ngke,ngkc->ngec", onehot, pos_oh)  # {0,1}
    comb = jnp.einsum("ngk,ngke,ngkc->ngec", top_p, onehot, pos_oh)
    del expert_of

    # Sharding: the group (token) dim carries the batch sharding — without it
    # every dispatch tensor replicates whenever E < model (mixtral 8e on a
    # 16-way axis) and expert_in alone is O(tokens*D) per DEVICE. The expert
    # dim takes `model` when divisible (moonshot 64e); otherwise the per-
    # expert ffn dim does (rules fallback), so one of the two always shards.
    disp = constraint(disp.astype(x.dtype), ("batch", None, "act_expert", None))
    expert_in = jnp.einsum("ngec,ngd->necd", disp, xt.astype(x.dtype))  # [ng,E,C,D]
    expert_in = constraint(expert_in, ("batch", "act_expert", None, "act_embed"))
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", expert_in, params["w_gate"]))
    h = h * jnp.einsum("necd,edf->necf", expert_in, params["w_up"])
    h = constraint(h, ("batch", "act_expert", None, "act_mlp"))
    expert_out = jnp.einsum("necf,efd->necd", h, params["w_down"])  # [ng,E,C,D]
    expert_out = constraint(expert_out, ("batch", "act_expert", None, "act_embed"))
    out = jnp.einsum("ngec,necd->ngd", comb.astype(x.dtype), expert_out)
    out = constraint(out, ("batch", None, "act_embed"))
    out = out.reshape(-1, D)[:n_tok]
    return out.reshape(B, S, D), aux
