"""GQA attention: blockwise (flash-style) XLA path, decode caches, cross-attn.

The train/prefill path is a lax.scan over KV chunks with an online softmax —
the same algorithm as kernels/flash_attention (which is the TPU fast path),
expressed in pure jnp so it compiles on any backend and keeps the memory term
O(S * chunk) instead of O(S^2).

Decode supports two cache layouts:
- standard:  cache length = seq_len, append at `pos`
- rolling:   cache length = window (SWA) with modular writes — this is what
             makes mixtral's long_500k cell sub-quadratic (DESIGN.md)
Keys are stored post-RoPE (rotated at their global position).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.params import ParamSpec
from repro.models.rope import apply_rope
from repro.parallel.rules import constraint, sp_gather

NEG_INF = -1e30


def attn_specs(a: AttentionConfig, d: int, dtype: str) -> dict:
    s = 1.0 / (d**0.5)
    so = 1.0 / ((a.num_heads * a.head_dim) ** 0.5)
    specs = {
        "wq": ParamSpec(
            (d, a.num_heads, a.head_dim), ("embed", "heads", "head_dim"), dtype=dtype, scale=s
        ),
        "wk": ParamSpec(
            (d, a.num_kv_heads, a.head_dim),
            ("embed", "kv_heads", "head_dim"),
            dtype=dtype,
            scale=s,
        ),
        "wv": ParamSpec(
            (d, a.num_kv_heads, a.head_dim),
            ("embed", "kv_heads", "head_dim"),
            dtype=dtype,
            scale=s,
        ),
        "wo": ParamSpec(
            (a.num_heads, a.head_dim, d), ("heads", "head_dim", "embed"), dtype=dtype, scale=so
        ),
    }
    if a.qkv_bias:
        specs["bq"] = ParamSpec(
            (a.num_heads, a.head_dim), ("heads", "head_dim"), dtype=dtype, init="zeros"
        )
        specs["bk"] = ParamSpec(
            (a.num_kv_heads, a.head_dim), ("kv_heads", "head_dim"), dtype=dtype, init="zeros"
        )
        specs["bv"] = ParamSpec(
            (a.num_kv_heads, a.head_dim), ("kv_heads", "head_dim"), dtype=dtype, init="zeros"
        )
    return specs


def _qkv(params, x, a: AttentionConfig, positions, rope: bool = True):
    # explicit SP boundary: gather the residual's seq shards HERE (fwd), with
    # the cotangent reduce-scattered back to seq shards (bwd) — see
    # rules.sp_gather. Without it GSPMD all-reduces the full residual per
    # layer in the backward pass (~2x n/(n-1) more wire than RS).
    x = sp_gather(x)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if a.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if rope:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    q = constraint(q, ("batch", "seq", "act_heads", None))
    k = constraint(k, ("batch", "seq", "act_heads", None))
    return q, k, v


def _blockwise_attn(
    q: jnp.ndarray,  # [B, Sq, QH, Dh]
    k: jnp.ndarray,  # [B, Sk, KH, Dh]
    v: jnp.ndarray,  # [B, Sk, KH, Dh]
    causal: bool,
    window: int | None,
    q_offset: int,
    chunk: int,
) -> jnp.ndarray:
    """Online-softmax over KV chunks, f32 accumulators.

    GQA layout note: we repeat KV up to the FULL query-head dim rather than
    grouping q as [KH, G, ...] — QH (e.g. 32) divides the model axis while KH
    (e.g. 8) does not, so this keeps every attention activation TP-shardable
    and avoids GSPMD's involuntary full-rematerialization fallback. The repeat
    is a local slice of the (replicated) KV heads, not extra wire traffic.
    """
    B, Sq, QH, Dh = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = QH // KH
    scale = 1.0 / (Dh**0.5)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = constraint(k, ("batch", None, "act_heads", None))
    v = constraint(v, ("batch", None, "act_heads", None))
    qg = q.astype(jnp.float32) * scale

    chunk = min(chunk, Sk)
    if Sk % chunk:
        pad = chunk - Sk % chunk  # pad kv to a chunk multiple; padded = masked
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Sk_p = Sk + pad
    else:
        Sk_p = Sk
    nk = Sk_p // chunk
    kc = jnp.moveaxis(k.reshape(B, nk, chunk, QH, Dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, chunk, QH, Dh), 1, 0)

    qpos = (jnp.arange(Sq) + q_offset)[:, None]  # [Sq, 1]

    def body(carry, inp):
        m, l, acc = carry
        j, k_j, v_j = inp
        s = jnp.einsum("bqhd,bchd->bhqc", qg, k_j.astype(jnp.float32))
        kpos = (j * chunk + jnp.arange(chunk))[None, :]
        mask = kpos < Sk
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqc,bchd->bhqd", p, v_j.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        carry = (
            constraint(m_new, ("batch", "act_heads", None)),
            constraint(l_new, ("batch", "act_heads", None)),
            constraint(acc_new, ("batch", "act_heads", None, None)),
        )
        return carry, None

    m0 = jnp.full((B, QH, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, QH, Sq), jnp.float32)
    acc0 = jnp.zeros((B, QH, Sq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (jnp.arange(nk), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 1, 2)  # b h q d -> b q h d
    return out.astype(q.dtype)


def attention(
    params,
    x: jnp.ndarray,  # [B, S, D]
    positions: jnp.ndarray,  # [S] or [B, S]
    a: AttentionConfig,
    causal: bool = True,
    chunk: int = 1024,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill), blockwise."""
    q, k, v = _qkv(params, x, a, positions)
    out = _blockwise_attn(q, k, v, causal=causal, window=a.window, q_offset=0, chunk=chunk)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def cache_shape(a: AttentionConfig, batch: int, seq_len: int) -> tuple[int, ...]:
    eff = min(seq_len, a.window) if a.window else seq_len
    return (batch, eff, a.num_kv_heads, a.head_dim)


def prefill_attention(
    params,
    x: jnp.ndarray,  # [B, S, D]
    positions: jnp.ndarray,
    a: AttentionConfig,
    cache_len: int,
    chunk: int = 1024,
) -> tuple[jnp.ndarray, dict]:
    """Attention + cache construction. Returns (out, {"k","v"} sized cache_len)."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, a, positions)
    out = _blockwise_attn(q, k, v, causal=True, window=a.window, q_offset=0, chunk=chunk)
    eff = min(cache_len, a.window) if a.window else cache_len
    if a.window and S >= eff:
        # rolling cache: keep the last `eff` keys, laid out so slot i holds
        # the key whose global position == i (mod eff)
        last_k, last_v = k[:, S - eff :], v[:, S - eff :]
        roll = (S - eff) % eff
        ck = jnp.roll(last_k, shift=roll, axis=1)
        cv = jnp.roll(last_v, shift=roll, axis=1)
    else:
        pad = eff - S
        assert pad >= 0, f"cache_len {eff} < prefill len {S}"
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ck = constraint(ck, ("batch", "cache_seq", "act_heads", None))
    cv = constraint(cv, ("batch", "cache_seq", "act_heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), {"k": ck, "v": cv}


def decode_attention(
    params,
    x: jnp.ndarray,  # [B, 1, D]
    pos: jnp.ndarray,  # scalar int32 — position of this token
    cache: dict,  # {"k","v"}: [B, C, KH, Dh]
    a: AttentionConfig,
) -> tuple[jnp.ndarray, dict]:
    """Single-token decode against the cache (standard or rolling)."""
    B = x.shape[0]
    C = cache["k"].shape[1]
    q, k_new, v_new = _qkv(params, x, a, jnp.full((B, 1), pos), rope=True)

    slot = pos % C if a.window else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    ck = constraint(ck, ("batch", "cache_seq", "act_heads", None))
    cv = constraint(cv, ("batch", "cache_seq", "act_heads", None))

    KH, Dh = a.num_kv_heads, a.head_dim
    G = a.num_heads // KH
    qg = q.reshape(B, KH, G, Dh).astype(jnp.float32) / (Dh**0.5)
    s = jnp.einsum("bhgd,bchd->bhgc", qg, ck.astype(jnp.float32))

    idx = jnp.arange(C)
    if a.window:
        # slot i holds global position p_i = pos - ((pos - i) mod C); valid if p_i >= 0
        p_i = pos - jnp.mod(pos - idx, C)
        valid = p_i >= 0
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgc,bchd->bhgd", p, cv.astype(jnp.float32))
    out = out.reshape(B, 1, a.num_heads, Dh).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), {"k": ck, "v": cv}


# --- cross-attention (encoder-decoder) --------------------------------------
def cross_attn_specs(a: AttentionConfig, d: int, dtype: str) -> dict:
    return attn_specs(a, d, dtype)


def cross_kv(params, enc_out: jnp.ndarray, a: AttentionConfig) -> dict:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return {"k": k, "v": v}


def cross_attention(params, x: jnp.ndarray, kv: dict, a: AttentionConfig, chunk: int = 1024):
    """Decoder-side cross attention (no mask, no RoPE on cross path)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if a.qkv_bias:
        q = q + params["bq"]
    out = _blockwise_attn(q, kv["k"], kv["v"], causal=False, window=None, q_offset=0, chunk=chunk)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])
