"""Parameter-spec system: abstract shapes + logical axes, no framework deps.

Every model exposes ``param_specs(cfg) -> pytree[ParamSpec]``. From the spec
tree we derive, without ever allocating a full-size model:

- ``abstract(tree)``            ShapeDtypeStructs for the dry-run
- ``shardings(tree, mesh)``     NamedShardings from the logical axes
- ``materialize(key, tree)``    real arrays for smoke tests / real training
- ``count_params(cfg)``         analytic parameter counts (MoE: active subset)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.rules import named_sharding


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    dtype: str = "bfloat16"
    init: str = "normal"  # normal | zeros | ones | const
    scale: float = 1.0  # std for normal, value for const

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def abstract(tree):
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)), tree)


def shardings(tree, mesh, rules=None):
    return tree_map_specs(lambda s: named_sharding(mesh, s.shape, s.axes, rules), tree)


def partition_specs(tree, mesh, rules=None):
    from repro.parallel.rules import partition_spec

    return tree_map_specs(lambda s: partition_spec(s.shape, s.axes, mesh, rules), tree)


def _init_one(key, spec: ParamSpec):
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "const":
        return jnp.full(spec.shape, spec.scale, dt)
    return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dt)


def materialize(key, tree):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten([_init_one(k, s) for k, s in zip(keys, leaves)])


def stack_layer(spec: ParamSpec, n_layers: int) -> ParamSpec:
    """Add the leading stacked-layers dim (scanned over at apply time)."""
    return ParamSpec(
        shape=(n_layers, *spec.shape),
        axes=("layers", *spec.axes),
        dtype=spec.dtype,
        init=spec.init,
        scale=spec.scale,
    )


def spec_bytes(tree) -> int:
    total = 0
    for s in jax.tree.leaves(tree, is_leaf=is_spec):
        total += math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
    return total


def spec_count(tree) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(tree, is_leaf=is_spec))


def count_params(cfg, active_only: bool = False) -> int:
    """Analytic param count from the spec tree. active_only: MoE top-k share."""
    from repro.models.model import param_specs  # lazy to avoid cycle

    tree = param_specs(cfg)
    if not active_only or cfg.moe is None:
        return spec_count(tree)
    total = 0
    for _path, s in jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)[0]:
        n = math.prod(s.shape)
        if "expert" in s.axes:  # routed expert weights: only top_k/E active
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total
