"""Rotary position embeddings."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, Dh]; positions: [B, S] or [S]. Rotate-half convention."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2 :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
