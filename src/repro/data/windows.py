"""Windowing of trajectories into training batches (paper §4: batches of
size S_B forming a [S_B, |Y|+m, k] tensor — we use [S_B, k, |Y|+m] layout).

Two families live here:

- ``make_windows``: host-side (numpy) offline windowing of a whole trajectory,
  used by the one-shot recovery paths.
- ``roll_buffer`` / ``window_views`` / ``buffer_stats``: device-side (jnp)
  streaming analogues used by the online service (core/stream.py) — a slot's
  ring buffer is rolled forward each tick and re-windowed INSIDE the compiled
  tick program, so continuous ingestion costs no host round-trip.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def make_windows(
    ys: np.ndarray,
    us: np.ndarray | None,
    window: int,
    stride: int = 1,
    normalize: bool = True,
) -> tuple[np.ndarray, np.ndarray | None, dict]:
    """Slice [T, n] trajectories into [N_windows, window, n] batches.

    Returns (y_windows, u_windows, norm_stats). Normalization is per-dimension
    affine over the whole trajectory (recorded so recovered coefficients can
    be mapped back to physical units).
    """
    stats = {"mean": np.zeros(ys.shape[-1]), "scale": np.ones(ys.shape[-1])}
    if normalize:
        stats["mean"] = ys.mean(axis=0)
        stats["scale"] = ys.std(axis=0) + 1e-8
        ys = (ys - stats["mean"]) / stats["scale"]
    starts = np.arange(0, ys.shape[0] - window + 1, stride)
    yw = np.stack([ys[s : s + window] for s in starts])
    uw = None
    if us is not None and us.shape[-1] > 0:
        uw = np.stack([us[s : s + window] for s in starts]).astype(np.float32)
    return yw.astype(np.float32), uw, stats


# ---------------------------------------------------------------------------
# device-side streaming helpers (jnp; jit/vmap-safe, static shapes)
# ---------------------------------------------------------------------------
def n_buffer_windows(buf_len: int, window: int, stride: int) -> int:
    """Number of sliding windows a length-``buf_len`` buffer yields."""
    if buf_len < window:
        raise ValueError(f"buffer length {buf_len} shorter than window {window}")
    return (buf_len - window) // stride + 1


def roll_buffer(buf: jnp.ndarray, new: jnp.ndarray) -> jnp.ndarray:
    """Shift a ring buffer left and append ``new`` observations at the end.

    buf: [..., L, n], new: [..., C, n] with C <= L. Oldest C samples drop out;
    static shapes, so this lowers to one fused slice+concat inside jit.
    """
    chunk = new.shape[-2]
    return jnp.concatenate([buf[..., chunk:, :], new], axis=-2)


def window_views(buf: jnp.ndarray, window: int, stride: int) -> jnp.ndarray:
    """Sliding windows over the time axis: [..., L, n] -> [..., N, T, n].

    Gather-based (one advanced-index op), matching make_windows' slicing for
    the same (window, stride) — pinned by tests/test_stream.py.
    """
    n_win = n_buffer_windows(buf.shape[-2], window, stride)
    starts = np.arange(n_win) * stride
    idx = starts[:, None] + np.arange(window)[None, :]
    return buf[..., idx, :]


def buffer_stats(buf: jnp.ndarray, eps: float = 1e-6) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-dimension (mean, scale) over the buffer's time axis.

    The streaming analogue of make_windows' trajectory-wide normalization:
    recomputed every tick from the CURRENT buffer contents, so recovered
    coefficients can always be mapped back to physical units with the stats
    that produced them (library.denormalize_theta). (Near-)constant channels
    — e.g. the zero-padded dims of a heterogeneous stream fleet — keep
    scale 1 so denormalization never divides by ~0.
    """
    mean = buf.mean(axis=-2, keepdims=True)
    std = buf.std(axis=-2, keepdims=True)
    scale = jnp.where(std < eps, 1.0, std)
    return mean, scale
