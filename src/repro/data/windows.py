"""Windowing of trajectories into training batches (paper §4: batches of
size S_B forming a [S_B, |Y|+m, k] tensor — we use [S_B, k, |Y|+m] layout)."""

from __future__ import annotations

import numpy as np


def make_windows(
    ys: np.ndarray,
    us: np.ndarray | None,
    window: int,
    stride: int = 1,
    normalize: bool = True,
) -> tuple[np.ndarray, np.ndarray | None, dict]:
    """Slice [T, n] trajectories into [N_windows, window, n] batches.

    Returns (y_windows, u_windows, norm_stats). Normalization is per-dimension
    affine over the whole trajectory (recorded so recovered coefficients can
    be mapped back to physical units).
    """
    stats = {"mean": np.zeros(ys.shape[-1]), "scale": np.ones(ys.shape[-1])}
    if normalize:
        stats["mean"] = ys.mean(axis=0)
        stats["scale"] = ys.std(axis=0) + 1e-8
        ys = (ys - stats["mean"]) / stats["scale"]
    starts = np.arange(0, ys.shape[0] - window + 1, stride)
    yw = np.stack([ys[s : s + window] for s in starts])
    uw = None
    if us is not None and us.shape[-1] > 0:
        uw = np.stack([us[s : s + window] for s in starts]).astype(np.float32)
    return yw.astype(np.float32), uw, stats
