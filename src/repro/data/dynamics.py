"""Benchmark dynamical systems (paper §6.1 case studies).

Simulation case studies (paper: Matlab + ODE45) are regenerated here with our
RK4 integrator at a fine internal step, then subsampled — numerically
equivalent at the reported tolerances for these smooth systems.

- lorenz:         chaotic Lorenz-63 (sigma, rho, beta)
- f8:             F-8 Crusader aircraft short-period model (cubic, from
                  Kaiser/Kutz/Brunton SINDY-MPC paper, ref [18])
- lotka_volterra: 2-species predator-prey (Hudson Bay lynx/hare regime)
- pathogen:       pathogenic attack / immune response model (ref [18])
- aid:            Bergman minimal model of glucose-insulin dynamics — stands
                  in for the OhioT1D dataset (not redistributable), same
                  dimensionality and 5-min CGM sampling.
- damped_oscillator:  linear 2-state damped harmonic oscillator.
- controlled_pendulum: small-angle pendulum with sinusoidal torque input
                  (SINDYc-style exogenous drive) — pairs with
                  core/engine.recover_many's multi-system batches.

Each system carries its ground-truth sparse coefficient matrix in the
polynomial library basis so recovery error is measured exactly
(MSE(theta_est, theta_true) — paper Table 6 metric).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.library import n_library_terms, term_names
from repro.core.ode import odeint


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    name: str
    state_dim: int
    input_dim: int
    order: int  # minimal library order that contains the true dynamics
    dynamics: Callable  # f(y, u, t, args) -> dy/dt
    y0: tuple
    dt: float
    t_end: float
    input_fn: Callable | None = None  # u(t) exogenous drive
    true_coef: Callable | None = None  # () -> [n_terms, n] ground truth


# --- Lorenz-63 --------------------------------------------------------------
def _lorenz(y, u, t, args):
    sigma, rho, beta = 10.0, 28.0, 8.0 / 3.0
    x, yv, z = y[..., 0], y[..., 1], y[..., 2]
    return jnp.stack([sigma * (yv - x), x * (rho - z) - yv, x * yv - beta * z], axis=-1)


def _lorenz_coef():
    # library over (x, y, z), order 2, graded-lex: [1, x, y, z, x2, xy, xz, y2, yz, z2]
    n_terms = n_library_terms(3, 2)
    c = np.zeros((n_terms, 3))
    names = term_names(3, 2, ["x", "y", "z"])
    ix = {n: i for i, n in enumerate(names)}
    c[ix["x"], 0], c[ix["y"], 0] = -10.0, 10.0
    c[ix["x"], 1], c[ix["y"], 1], c[ix["x*z"], 1] = 28.0, -1.0, -1.0
    c[ix["x*y"], 2], c[ix["z"], 2] = 1.0, -8.0 / 3.0
    return c


# --- F-8 Crusader (cubic short-period model, SINDY-MPC ref [18]) ------------
def _f8(y, u, t, args):
    x1, x2, x3 = y[..., 0], y[..., 1], y[..., 2]
    dx1 = (
        -0.877 * x1
        + x3
        - 0.088 * x1 * x3
        + 0.47 * x1**2
        - 0.019 * x2**2
        - x1**2 * x3
        + 3.846 * x1**3
    )
    dx2 = x3
    dx3 = -4.208 * x1 - 0.396 * x3 - 0.47 * x1**2 - 3.564 * x1**3
    return jnp.stack([dx1, dx2, dx3], axis=-1)


def _f8_coef():
    n_terms = n_library_terms(3, 3)
    c = np.zeros((n_terms, 3))
    names = term_names(3, 3, ["x1", "x2", "x3"])
    ix = {n: i for i, n in enumerate(names)}
    c[ix["x1"], 0], c[ix["x3"], 0] = -0.877, 1.0
    c[ix["x1*x3"], 0], c[ix["x1^2"], 0], c[ix["x2^2"], 0] = -0.088, 0.47, -0.019
    c[ix["x1^2*x3"], 0], c[ix["x1^3"], 0] = -1.0, 3.846
    c[ix["x3"], 1] = 1.0
    c[ix["x1"], 2], c[ix["x3"], 2], c[ix["x1^2"], 2], c[ix["x1^3"], 2] = (
        -4.208,
        -0.396,
        -0.47,
        -3.564,
    )
    return c


# --- Lotka-Volterra (Hudson Bay lynx/hare regime) ---------------------------
_LV = (0.55, 0.028, 0.84, 0.026)  # a, b, c, d (per-year, pelt-count scale)


def _lotka(y, u, t, args):
    a, b, c, d = _LV
    h, l = y[..., 0], y[..., 1]
    return jnp.stack([a * h - b * h * l, -c * l + d * h * l], axis=-1)


def _lotka_coef():
    n_terms = n_library_terms(2, 2)
    c = np.zeros((n_terms, 2))
    names = term_names(2, 2, ["h", "l"])
    ix = {n: i for i, n in enumerate(names)}
    a, b, cc, d = _LV
    c[ix["h"], 0], c[ix["h*l"], 0] = a, -b
    c[ix["l"], 1], c[ix["h*l"], 1] = -cc, d
    return c


# --- Pathogenic attack (innate immune response, ref [18]) -------------------
def _pathogen(y, u, t, args):
    # reduced 2-state pathogen (P) / immune-cell (I) interaction
    p, i = y[..., 0], y[..., 1]
    dp = 1.2 * p - 0.9 * p * i
    di = 0.05 + 0.6 * p * i - 0.8 * i
    return jnp.stack([dp, di], axis=-1)


def _pathogen_coef():
    n_terms = n_library_terms(2, 2)
    c = np.zeros((n_terms, 2))
    names = term_names(2, 2, ["p", "i"])
    ix = {n: i for i, n in enumerate(names)}
    c[ix["p"], 0], c[ix["p*i"], 0] = 1.2, -0.9
    c[ix["1"], 1], c[ix["p*i"], 1], c[ix["i"], 1] = 0.05, 0.6, -0.8
    return c


# --- AID: Bergman minimal model (glucose G, remote insulin X, plasma I) -----
_BERGMAN = dict(p1=0.028, p2=0.025, p3=1.3e-5, n=0.23, gb=4.5, ib=15.0)


def _aid_input(t):
    # insulin bolus schedule + meal disturbance (periodic), per 5-min units
    bolus = 25.0 * (jnp.sin(2 * jnp.pi * t / 60.0) > 0.95)
    return jnp.stack([bolus], axis=-1) if jnp.ndim(t) else jnp.array([bolus])


def _aid(y, u, t, args):
    p = _BERGMAN
    g, x, i = y[..., 0], y[..., 1], y[..., 2]
    u_ins = u[..., 0] if u is not None and u.shape[-1] else 0.0
    dg = -p["p1"] * (g - p["gb"]) - x * g
    dx = -p["p2"] * x + p["p3"] * (i - p["ib"])
    di = -p["n"] * (i - p["ib"]) + u_ins / 12.0
    return jnp.stack([dg, dx, di], axis=-1)


def _aid_coef():
    # library over (g, x, i, u), order 2
    n_terms = n_library_terms(4, 2)
    c = np.zeros((n_terms, 3))
    names = term_names(4, 2, ["g", "x", "i", "u"])
    ix = {n: i for i, n in enumerate(names)}
    p = _BERGMAN
    c[ix["1"], 0], c[ix["g"], 0], c[ix["g*x"], 0] = p["p1"] * p["gb"], -p["p1"], -1.0
    c[ix["x"], 1], c[ix["i"], 1], c[ix["1"], 1] = -p["p2"], p["p3"], -p["p3"] * p["ib"]
    c[ix["i"], 2], c[ix["1"], 2], c[ix["u"], 2] = -p["n"], p["n"] * p["ib"], 1.0 / 12.0
    return c


# --- damped harmonic oscillator (linear 2-state testbed) --------------------
_OSC = (2.0, 0.3)  # omega, damping c


def _damped_osc(y, u, t, args):
    omega, c = _OSC
    x, v = y[..., 0], y[..., 1]
    return jnp.stack([v, -(omega**2) * x - c * v], axis=-1)


def _damped_osc_coef():
    n_terms = n_library_terms(2, 2)
    c = np.zeros((n_terms, 2))
    names = term_names(2, 2, ["x", "v"])
    ix = {n: i for i, n in enumerate(names)}
    omega, cc = _OSC
    c[ix["v"], 0] = 1.0
    c[ix["x"], 1], c[ix["v"], 1] = -(omega**2), -cc
    return c


# --- controlled pendulum (small-angle, sinusoidal torque input) -------------
_PEND = (4.9, 0.35)  # g/l, damping


def _pend_input(t):
    tq = 0.6 * jnp.sin(1.1 * t)
    return jnp.stack([tq], axis=-1) if jnp.ndim(t) else jnp.array([tq])


def _pendulum(y, u, t, args):
    gl, c = _PEND
    th, w = y[..., 0], y[..., 1]
    tq = u[..., 0] if u is not None and u.shape[-1] else 0.0
    return jnp.stack([w, -gl * th - c * w + tq], axis=-1)


def _pendulum_coef():
    # library over (th, w, u), order 2
    n_terms = n_library_terms(3, 2)
    c = np.zeros((n_terms, 2))
    names = term_names(3, 2, ["th", "w", "u"])
    ix = {n: i for i, n in enumerate(names)}
    gl, cc = _PEND
    c[ix["w"], 0] = 1.0
    c[ix["th"], 1], c[ix["w"], 1], c[ix["u"], 1] = -gl, -cc, 1.0
    return c


SYSTEMS: dict[str, SystemSpec] = {
    "lorenz": SystemSpec(
        "lorenz", 3, 0, 2, _lorenz, (-8.0, 7.0, 27.0), 0.01, 10.0, None, _lorenz_coef
    ),
    "f8": SystemSpec("f8", 3, 0, 3, _f8, (0.3, 0.0, 0.2), 0.01, 12.0, None, _f8_coef),
    "lotka_volterra": SystemSpec(
        "lotka_volterra", 2, 0, 2, _lotka, (30.0, 4.0), 0.05, 40.0, None, _lotka_coef
    ),
    "pathogen": SystemSpec(
        "pathogen", 2, 0, 2, _pathogen, (0.5, 0.3), 0.02, 30.0, None, _pathogen_coef
    ),
    "aid": SystemSpec("aid", 3, 1, 2, _aid, (7.0, 0.0, 18.0), 5.0, 1000.0, _aid_input, _aid_coef),
    "damped_oscillator": SystemSpec(
        "damped_oscillator", 2, 0, 2, _damped_osc, (1.2, 0.0), 0.01, 20.0, None, _damped_osc_coef
    ),
    "controlled_pendulum": SystemSpec(
        "controlled_pendulum",
        2,
        1,
        2,
        _pendulum,
        (0.6, 0.0),
        0.01,
        20.0,
        _pend_input,
        _pendulum_coef,
    ),
}


def get_system(name: str) -> SystemSpec:
    if name not in SYSTEMS:
        raise KeyError(f"unknown system {name!r}; available: {', '.join(sorted(SYSTEMS))}")
    return SYSTEMS[name]


def embed_true_coef(spec: SystemSpec, n_state: int, n_input: int, order: int) -> np.ndarray:
    """Embed spec's ground-truth Theta into a larger padded library.

    The streaming service (core/stream.py) zero-pads a heterogeneous fleet to
    common (n_state, n_input, order); recovered coefficients then live in the
    padded library basis. This maps the spec's [n_terms_spec, state_dim]
    truth into [n_terms(n_state+n_input, order), n_state] (zeros elsewhere)
    so recovery error is measured in one consistent basis.
    """
    if spec.true_coef is None:
        raise ValueError(f"system {spec.name!r} has no ground-truth coefficients")
    if order < spec.order or n_state < spec.state_dim or n_input < spec.input_dim:
        raise ValueError(f"padded library smaller than {spec.name!r}'s own library")
    small = np.asarray(spec.true_coef(), float)
    # shared naming scheme: states s0.., inputs i0.. — the spec's variables map
    # to the first state/input positions of the padded layout, so every spec
    # term name appears verbatim in the padded library's term list.
    small_names = term_names(
        spec.state_dim + spec.input_dim,
        spec.order,
        [f"s{i}" for i in range(spec.state_dim)] + [f"i{j}" for j in range(spec.input_dim)],
    )
    big_names = term_names(
        n_state + n_input,
        order,
        [f"s{i}" for i in range(n_state)] + [f"i{j}" for j in range(n_input)],
    )
    ix = {name: k for k, name in enumerate(big_names)}
    big = np.zeros((n_library_terms(n_state + n_input, order), n_state))
    for k, name in enumerate(small_names):
        big[ix[name], : spec.state_dim] = small[k]
    return big


def generate_trajectory(
    name: str,
    n_samples: int | None = None,
    noise_std: float = 0.0,
    seed: int = 0,
    oversample: int = 4,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Integrate a system and return (ts [T], ys [T, n], us [T, m]).

    Integration runs at dt/oversample internally (RK4) and subsamples to the
    spec's dt — the fixed-step stand-in for the paper's ODE45 generation.
    """
    spec = SYSTEMS[name]
    n_samples = n_samples or int(spec.t_end / spec.dt)
    fine = n_samples * oversample
    ts_fine = jnp.linspace(0.0, n_samples * spec.dt, fine + 1)
    if spec.input_fn is not None:
        us_fine = jax.vmap(spec.input_fn)(ts_fine)
    else:
        us_fine = jnp.zeros((fine + 1, 0))
    y0 = jnp.asarray(spec.y0, jnp.float32)
    ys_fine = odeint(spec.dynamics, y0, ts_fine, us=us_fine, method="rk4")
    sl = slice(None, None, oversample)
    ts, ys, us = np.asarray(ts_fine[sl]), np.asarray(ys_fine[sl]), np.asarray(us_fine[sl])
    if noise_std > 0:
        rng = np.random.default_rng(seed)
        ys = ys + noise_std * ys.std(axis=0, keepdims=True) * rng.standard_normal(ys.shape)
    return ts, ys.astype(np.float32), us.astype(np.float32)
