"""Deterministic, step-addressable token pipeline.

Requirements at scale (and honored here):

- step-addressable: ``batch_at(step)`` is a pure function of (seed, step) so
  a restarted / re-meshed job re-reads exactly the batch it crashed on —
  no iterator state needs checkpointing (the Supervisor resumes by step id).
- host-sharded: each host materializes ONLY its slice of the global batch
  (``host_slice``), then ``jax.make_array_from_process_local_data`` assembles
  the global array (single-host here, but the code path is the multi-host
  one).
- reproducible across restarts and host counts (counter-based threefry;
  no sequential RNG state).

Sources:
- ``SyntheticLM``: Zipf-distributed tokens with a Markov structure so CE is
  learnable (loss decreases) — used by examples/train_lm.py and tests.
- ``DocPackLM``: packs documents (synthetic "sentences" with EOS) into fixed
  windows — exercises the real packing path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: int = 1


class SyntheticLM:
    """Zipf marginals + learnable first-order structure.

    token_{t+1} ~ 0.7 * P(next | prev) + 0.3 * Zipf  where the conditional is
    a deterministic permutation chain (prev -> (a*prev + c) mod V) — a model
    can reach substantially-below-unigram CE by learning the chain.
    """

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        p = 1.0 / ranks**cfg.zipf_a
        self.zipf = (p / p.sum()).astype(np.float32)
        self.a, self.c = 6364136223846793005 % V or 1, 1442695040888963407 % V

    def _tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        V = self.cfg.vocab_size
        out = np.empty(n, dtype=np.int32)
        out[0] = rng.choice(V, p=self.zipf)
        chain = rng.random(n) < 0.7
        zipf_draws = rng.choice(V, size=n, p=self.zipf)
        for i in range(1, n):
            out[i] = (self.a * out[i - 1] + self.c) % V if chain[i] else zipf_draws[i]
        return out

    def batch_at(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        """Global batch for ``step`` (this host's rows filled; pure in step)."""
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        rows_per_host = cfg.global_batch // n_hosts
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, host_id]))
        toks = np.stack([self._tokens(rng, cfg.seq_len + 1) for _ in range(rows_per_host)])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class DocPackLM(SyntheticLM):
    """Document packing: EOS-delimited variable-length docs packed greedily."""

    EOS = 0

    def _tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(0, dtype=np.int32)
        while out.size < n:
            doc_len = int(rng.integers(8, 64))
            doc = super()._tokens(rng, doc_len)
            doc[-1] = self.EOS
            out = np.concatenate([out, doc])
        return out[:n]


def device_put_batch(batch: dict, shardings: dict | None):
    """Host numpy batch -> global jax Arrays under the given shardings."""
    if shardings is None:
        return jax.tree.map(jnp.asarray, batch)
    return jax.tree.map(lambda x, s: jax.make_array_from_process_local_data(s, x), batch, shardings)
