from repro.data.dynamics import (  # noqa: F401
    SYSTEMS,
    SystemSpec,
    generate_trajectory,
    get_system,
)
from repro.data.windows import make_windows  # noqa: F401
