"""Hardware-contract rules for the compiled-plan auditor (analysis/audit.py).

MERINDA's recovery speed comes from *structural* properties of the lowered
program — buffers reused in place, state resident on chip, no host
round-trips mid-stream, fixed-point datapaths, no cross-mesh chatter. Each
rule here checks one of those properties statically, against the OPTIMIZED
HLO of a compiled program (what XLA actually emitted, not what the Python
decorators requested):

    R1 donation       every donated input is aliased to an output in the
                      module header — no silent copy fallback
    R2 residency      the tiling.py VMEM model's predicted bytes is within
                      a per-family tolerance band of the parsed fused-stage
                      per-step traffic
    R3 host-transfer  no infeed/outfeed/host-callback ops inside the tick
                      program beyond a declared allowlist
    R4 dtype          the int8/PWL serving path transports gate/head weight
                      matrices as s8 parameters (no f32 widening on entry)
    R5 collectives    the collective set (and wire bytes) of sharded-mesh
                      plans matches the parallel/rules.py prediction

Every rule is a pure function ``(program name, hlo text, prediction) ->
[Finding]`` — no jax, no plan objects — so rules are unit-testable on
synthetic HLO and the auditor stays the only place that knows how to lower
a plan's programs. Rules that match entry parameters by their jax argument
path (R1, R4) emit a vacuity Finding when NOTHING matches: an auditor whose
contract silently stopped binding (metadata naming drift) is itself a
violation, not a pass.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.analysis import hlo as H

#: rule id -> one-line contract (the README table is generated from this)
RULES: dict[str, str] = {
    "R1": "donation: every donated input is aliased to an output (no copy fallback)",
    "R2": "residency: tiling.py VMEM model within the family band of parsed per-step bytes",
    "R3": "host-transfer: no device<->host ops in the tick beyond the allowlist",
    "R4": "dtype: int8 serving path transports gate/head weights as s8 parameters",
    "R5": "collectives: sharded-plan collective set matches parallel/rules.py prediction",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structured contract violation."""

    rule: str  # "R1".."R5"
    program: str  # which compiled program ("tick", "epoch", "fused_step", ...)
    op: str  # HLO op / parameter the finding anchors on ("" = whole module)
    expected: str
    actual: str
    message: str

    def __str__(self) -> str:
        anchor = f" @ {self.op}" if self.op else ""
        return (
            f"[{self.rule}] {self.program}{anchor}: {self.message} "
            f"(expected {self.expected}, got {self.actual})"
        )


def _root(op_name: str) -> str:
    """'state.params.encoder.w' -> 'state' (jax argument-path root)."""
    return op_name.split(".")[0].split("[")[0] if op_name else ""


# -- R1 ----------------------------------------------------------------------
def check_donation(program: str, text: str, donated: Sequence[str]) -> list[Finding]:
    """R1: every surviving parameter of a donated argument must be aliased.

    ``donated`` names the donated Python arguments (e.g. ``("state",)`` for
    the tick, ``("params", "opt_state")`` for the epoch). jit PRUNES unused
    arguments, so only parameters that survived into the entry computation
    are held to the contract; XLA dropping an alias entry it could not honor
    (the silent copy fallback) is exactly what this catches.
    """
    if not donated:
        return []
    aliased = {a.param_number for a in H.parse_io_aliases(text)}
    findings, matched = [], 0
    for p in H.entry_parameters(text):
        if _root(p.op_name) not in donated:
            continue
        matched += 1
        if p.index not in aliased:
            findings.append(
                Finding(
                    rule="R1",
                    program=program,
                    op=f"parameter({p.index})",
                    expected="input_output_alias entry",
                    actual="none (copy fallback)",
                    message=f"donated argument leaf {p.op_name!r} is not aliased to any output",
                )
            )
    if matched == 0:
        findings.append(
            Finding(
                rule="R1",
                program=program,
                op="",
                expected=f"entry parameters named under donated args {list(donated)}",
                actual="no matching parameters",
                message="donation audit bound nothing — op_name metadata drifted; "
                "the rule would be vacuous",
            )
        )
    return findings


# -- R2 ----------------------------------------------------------------------
def check_residency(
    program: str,
    text: str,
    predicted_bytes: int,
    steps: int,
    band: tuple[float, float],
    family: str = "gru",
) -> list[Finding]:
    """R2: parsed per-step traffic of the fused stage vs the VMEM model.

    The compiled stage is a ``lax.scan`` over the window's ``steps`` input
    steps, and the CPU lowering re-streams the (kernel-resident) weights on
    every trip — so the comparable figure is the parsed bytes-accessed
    NORMALIZED per input step, held to ``band`` (a per-family tolerance,
    tiling.residency_tolerance) around the model's predicted residency.
    Catches order-of-magnitude model drift: a resident buffer the model
    misses, a dropped term, a tile that silently stopped applying.
    """
    findings: list[Finding] = []
    if predicted_bytes <= 0:
        findings.append(
            Finding(
                rule="R2",
                program=program,
                op="",
                expected="> 0 predicted residency bytes",
                actual=str(predicted_bytes),
                message="VMEM model predicted nonpositive residency",
            )
        )
        return findings
    per_step = H.analyze_module(text, 1).hbm_bytes / max(steps, 1)
    ratio = per_step / predicted_bytes
    lo, hi = band
    if not (lo <= ratio <= hi):
        findings.append(
            Finding(
                rule="R2",
                program=program,
                op="",
                expected=f"per-step/predicted in [{lo}, {hi}] ({family} band)",
                actual=f"{ratio:.2f} ({per_step:.0f} B/step vs {predicted_bytes} B predicted)",
                message="compiled fused-stage traffic disagrees with the tiling.py VMEM model",
            )
        )
    return findings


# -- R3 ----------------------------------------------------------------------
def check_host_transfers(program: str, text: str, allowlist: Sequence[str] = ()) -> list[Finding]:
    """R3: no device<->host boundary crossings inside the compiled program.

    The tick's contract is that ALL host syncs happen in the service layer
    (RecoveryService counts them); an infeed/outfeed or a python callback
    custom-call INSIDE the compiled program would stall every tick
    uncounted. ``allowlist`` entries are substrings matched against the
    callback target (or opcode) of intentionally-declared crossings.
    """
    findings = []
    for t in H.host_transfer_ops(text):
        label = t.target or t.kind
        if any(a and a in label for a in allowlist):
            continue
        findings.append(
            Finding(
                rule="R3",
                program=program,
                op=t.op,
                expected="no device<->host transfer",
                actual=label,
                message=f"{t.kind} in computation {t.computation!r} crosses the host boundary",
            )
        )
    return findings


# -- R4 ----------------------------------------------------------------------
def check_weight_dtypes(program: str, text: str, weights: Mapping[str, str]) -> list[Finding]:
    """R4: quantized weights enter the serving program at their serving dtype.

    ``weights`` maps jax argument names (or argument-path roots) of the
    gate/head weight matrices to their contracted HLO dtype (``"s8"``). The
    int8 path dequantizes per-channel INSIDE the program (scales ride as
    separate f32 rows), so the transport contract is at the parameter level:
    a weight matrix arriving as f32 means the serving path silently widened
    — quadratically more transport bytes than the fixed-point story claims.
    Every contracted weight must be found; a missing one is a finding, not a
    pass (pruning a weight from its own serving program is itself a bug).
    """
    findings, seen = [], set()
    for p in H.entry_parameters(text):
        want = weights.get(p.op_name) or weights.get(_root(p.op_name))
        if want is None:
            continue
        seen.add(p.op_name if p.op_name in weights else _root(p.op_name))
        if p.dtype != want:
            findings.append(
                Finding(
                    rule="R4",
                    program=program,
                    op=f"parameter({p.index})",
                    expected=want,
                    actual=p.dtype or "?",
                    message=f"serving weight {p.op_name!r} enters the program as "
                    f"{p.dtype or '?'} — f32 widening on the transport path",
                )
            )
    for name in sorted(set(weights) - seen):
        findings.append(
            Finding(
                rule="R4",
                program=program,
                op="",
                expected=f"{weights[name]} parameter {name!r}",
                actual="not found among entry parameters",
                message=f"contracted serving weight {name!r} never entered the program",
            )
        )
    return findings


# -- R5 ----------------------------------------------------------------------
def check_collectives(
    program: str,
    text: str,
    n_devices: int,
    predicted_ops: Mapping[str, int],
    predicted_wire_bytes: float = 0.0,
    wire_tol: float = 0.05,
) -> list[Finding]:
    """R5: the compiled collective census matches the sharding-rule prediction.

    ``predicted_ops`` maps collective kind -> count (parallel/rules.py
    ``predict_tick_collectives``: empty for the slot-sharded tick). Counts
    must match exactly; the wire-byte total is held to ``wire_tol`` relative
    tolerance (only checked when the census agrees — a census mismatch
    already explains any wire delta).
    """
    stats = H.collective_stats(text, n_devices)
    findings = []
    for kind in sorted(set(stats.ops) | set(predicted_ops)):
        got, want = stats.ops.get(kind, 0), predicted_ops.get(kind, 0)
        if got != want:
            findings.append(
                Finding(
                    rule="R5",
                    program=program,
                    op=kind,
                    expected=f"{want} x {kind}",
                    actual=str(got),
                    message="collective census disagrees with the sharding-rule prediction",
                )
            )
    if not findings:
        denom = max(predicted_wire_bytes, 1.0)
        if abs(stats.wire_bytes - predicted_wire_bytes) / denom > wire_tol:
            findings.append(
                Finding(
                    rule="R5",
                    program=program,
                    op="",
                    expected=f"~{predicted_wire_bytes:.0f} collective wire bytes",
                    actual=f"{stats.wire_bytes:.0f}",
                    message="collective wire-byte total off the prediction",
                )
            )
    return findings
