"""Plan auditor: static HLO-contract verification for compiled RecoveryPlans.

``audit_plan`` lowers each of a compiled plan's jitted programs to OPTIMIZED
HLO (``.lower(...).compile().as_text()`` — what XLA actually emitted) and
holds the text to the hardware contracts in ``analysis/rules.py``:

    R1 donation, R2 VMEM-model residency, R3 host-transfer hygiene,
    R4 int8 weight transport, R5 sharded-tick collective census.

The auditor owns the lowering recipe per program (which concrete shapes to
trace with, which arguments are donated, which weights are contracted s8);
the rules stay pure text->Findings functions. ``compile_plan(spec,
audit="warn"|"error")`` runs this at plan-compile time and stamps the
verdict into ``plan.lowering.audit``; violations raise :class:`AuditError`
under ``"error"`` and ``warnings.warn`` under ``"warn"``.

CLI (the CI ``audit-matrix`` job):

    python -m repro.analysis.audit --matrix \\
        --error-rules R1,R3,R4 --warn-rules R2,R5 --json findings.json

compiles the full encoder x fused x quant spec matrix (tiny stream shapes)
including the device-resident control-plane cells, audits every cell, runs
the 2-virtual-device mesh cells in subprocesses (R5 needs >1 device), and
exits nonzero on any error-rule finding.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.analysis import rules as R
from repro.core import encoders, engine
from repro.core import stream as stream_mod
from repro.core.merinda import init_mr
from repro.core.quant import make_sigmoid_table, make_tanh_table, quantize_int8
from repro.kernels.mr_step import ref as mr_ref
from repro.kernels.mr_step import tiling
from repro.optim import adamw_init
from repro.parallel.rules import predict_tick_collectives

DEFAULT_RULES = ("R1", "R2", "R3", "R4", "R5")

#: host-transfer substrings the tick program may legitimately contain: NONE.
#: All host syncs of the service live in RecoveryService.tick_once (counted
#: in sync_log); the compiled tick itself must stay on device.
DEFAULT_TICK_ALLOWLIST: tuple[str, ...] = ()


class AuditError(ValueError):
    """A compiled plan violated its hardware contract (audit="error")."""

    def __init__(self, report: "AuditReport"):
        self.report = report
        lines = "\n".join(f"  {f}" for f in report.findings)
        super().__init__(f"plan audit failed with {len(report.findings)} finding(s):\n{lines}")


@dataclasses.dataclass
class AuditReport:
    """Outcome of one ``audit_plan`` run: findings + what was actually checked."""

    findings: list[R.Finding]
    checked: dict[str, list[str]]  # rule id -> programs it ran over

    @property
    def ok(self) -> bool:
        return not self.findings

    @property
    def verdict(self) -> str:
        """Compact stamp for plan.lowering.audit: "pass:R1,R3" / "fail:R1"."""
        if self.ok:
            return "pass:" + ",".join(sorted(self.checked))
        return "fail:" + ",".join(sorted({f.rule for f in self.findings}))

    def to_json(self) -> dict:
        return {
            "verdict": self.verdict,
            "checked": self.checked,
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }


def _compiled_text(lowered) -> str:
    return lowered.compile().as_text()


def _fused_batch(plan) -> int:
    """The fused-stage batch the plan was tiled for (mirrors plan.py)."""
    if plan.spec.mode == "stream":
        return plan.scfg.n_windows
    return plan.spec.batch_size or 16


def _fused_step_text(plan) -> tuple[str, int]:
    """Lower the plan's fused per-window stage; returns (hlo text, T steps)."""
    from repro.kernels.mr_step import ops as mr_ops

    cfg = plan.cfg
    B = _fused_batch(plan)
    T = plan.scfg.window if plan.spec.mode == "stream" else 32
    params = init_mr(jax.random.key(0), cfg)
    xs = jnp.zeros((B, T, cfg.state_dim + cfg.input_dim), jnp.float32)
    block_b = plan.lowering.block_b
    fn = jax.jit(lambda p, x: mr_ops.mr_step(p, cfg, x, block_b=block_b))
    return _compiled_text(fn.lower(params, xs)), T


def _serving_weight_text(plan) -> tuple[str, dict[str, str]]:
    """Lower the int8 serving stage at KERNEL SIGNATURE level: weights are
    quantized OUTSIDE the program and enter as s8 parameters (the transport
    contract R4 checks). Returns (hlo text, weight name -> dtype contract).

    ``mr_step_int8`` itself quantizes float params on the fly inside the jit
    (a convenience for the reference path); production serving caches the
    int8 tensors and calls the kernel signature — which is what a dtype
    audit must hold to, so that is what gets lowered here.
    """
    cfg = plan.cfg
    family = encoders.get_encoder(cfg.encoder).family
    B = _fused_batch(plan)
    T = plan.scfg.window if plan.spec.mode == "stream" else 32
    params = init_mr(jax.random.key(0), cfg)
    xs = jnp.zeros((B, T, cfg.state_dim + cfg.input_dim), jnp.float32)
    h0 = jnp.zeros((B, cfg.hidden), jnp.float32)
    w1q = quantize_int8(params.head_w1, axis=-1)
    w2q = quantize_int8(params.head_w2, axis=-1)
    sig_t = make_sigmoid_table(16)

    if family == "ltc":
        enc = params.encoder
        w_inq = quantize_int8(enc.w_in, axis=-1)
        w_recq = quantize_int8(enc.w_rec, axis=-1)

        def serve(
            xs,
            h0,
            w_inq,
            w_in_s,
            w_recq,
            w_rec_s,
            bias,
            a,
            inv_tau,
            w1q,
            w1_s,
            b1,
            w2q,
            w2_s,
            b2,
        ):
            args = (xs, h0, w_inq, w_in_s, w_recq, w_rec_s, bias, a, inv_tau)
            head = (w1q, w1_s, b1, w2q, w2_s, b2)
            return mr_ref.mr_step_ltc_int8_reference(
                *args, *head, sig_t, dt=cfg.dt, n_substeps=cfg.ltc_substeps
            )

        lowered = jax.jit(serve).lower(
            xs,
            h0,
            w_inq.values,
            w_inq.scale,
            w_recq.values,
            w_recq.scale,
            enc.bias,
            enc.a,
            enc.inv_tau,
            w1q.values,
            w1q.scale,
            params.head_b1,
            w2q.values,
            w2q.scale,
            params.head_b2,
        )
        weights = {"w_inq": "s8", "w_recq": "s8", "w1q": "s8", "w2q": "s8"}
        return _compiled_text(lowered), weights

    # gru family (the standard cell; flow families are float-serving)
    d_in = cfg.state_dim + cfg.input_dim
    wxq = quantize_int8(params.encoder.w[:d_in], axis=-1)
    whq = quantize_int8(params.encoder.w[d_in:], axis=-1)
    tanh_t = make_tanh_table(16)
    dts = jnp.ones((T,), jnp.float32)

    def serve(xs, h0, wxq, whq, wx_s, wh_s, b, dts, w1q, w1_s, b1, w2q, w2_s, b2):
        gate = (xs, h0, wxq, whq, wx_s, wh_s, b, dts)
        head = (w1q, w1_s, b1, w2q, w2_s, b2)
        return mr_ref.mr_step_int8_reference(*gate, *head, sig_t, tanh_t)

    lowered = jax.jit(serve).lower(
        xs,
        h0,
        wxq.values,
        whq.values,
        wxq.scale,
        whq.scale,
        params.encoder.b,
        dts,
        w1q.values,
        w1q.scale,
        params.head_b1,
        w2q.values,
        w2q.scale,
        params.head_b2,
    )
    weights = {"wxq": "s8", "whq": "s8", "w1q": "s8", "w2q": "s8"}
    return _compiled_text(lowered), weights


def audit_plan(
    plan,
    *,
    rules: tuple[str, ...] = DEFAULT_RULES,
    host_allowlist: tuple[str, ...] = DEFAULT_TICK_ALLOWLIST,
) -> AuditReport:
    """Audit every program of a compiled RecoveryPlan; see module docstring.

    Which rules run depends on the plan: R1/R3 on the mode's donated program
    (tick / epoch; the batch program declares no donation, by design), R2
    only for fused lowerings, R4 only for int8 serving, R5 only on meshed
    stream plans (a 1-device census is vacuously collective-free).
    """
    spec, cfg, scfg = plan.spec, plan.cfg, plan.scfg
    findings: list[R.Finding] = []
    checked: dict[str, list[str]] = {}

    def run(rule: str, program: str, fn, *args, **kw):
        if rule not in rules:
            return
        checked.setdefault(rule, []).append(program)
        findings.extend(fn(program, *args, **kw))

    key = jax.random.key(0)
    if spec.mode == "stream":
        state = stream_mod.init_slots(key, cfg, scfg, spec.n_slots)
        if plan.mesh is not None:
            state = stream_mod.shard_slots(state, plan.mesh)
        new_y = jnp.zeros((spec.n_slots, scfg.chunk, cfg.state_dim), jnp.float32)
        new_u = jnp.zeros((spec.n_slots, scfg.chunk, cfg.input_dim), jnp.float32)
        banked_tick = plan.lowering.tick_kernel == "banked"
        quant_tick = plan.lowering.quant_serving and scfg.steps_per_tick == 0
        if banked_tick:
            lowered = stream_mod.tick_banked.lower(
                state,
                new_y,
                new_u,
                key,
                cfg=cfg,
                scfg=scfg,
                quant=quant_tick,
                slots_per_bank=plan.lowering.tick_slots_per_bank or 1,
            )
        else:
            lowered = stream_mod.tick.lower(state, new_y, new_u, key, cfg=cfg, scfg=scfg)
        text = _compiled_text(lowered)
        run("R1", "tick", R.check_donation, text, ("state",))
        run("R3", "tick", R.check_host_transfers, text, host_allowlist)
        if banked_tick and not scfg.steps_per_tick:
            # K=0 serve tick: the compiled program IS the banked mr_tick
            # serving segment, so its traffic is held to the tick-level VMEM
            # model directly (training ticks bury the kernel inside the scan
            # program, where per-step attribution is the scan's, not the
            # tick kernel's)
            local_slots = spec.n_slots // max(spec.mesh_slots, 1)
            predicted = tiling.tick_vmem_bytes(
                cfg, scfg, slots_per_bank=local_slots, int8=quant_tick
            )
            run(
                "R2",
                "tick_banked",
                R.check_residency,
                text,
                predicted,
                scfg.window,
                tiling.TICK_RESIDENCY_BAND,
                family=encoders.get_encoder(cfg.encoder).family,
            )
        if plan.mesh is not None:
            n_dev = int(plan.mesh.devices.size)
            predicted = predict_tick_collectives(plan.mesh)
            run("R5", "tick", R.check_collectives, text, n_dev, predicted)
        if plan.lowering.control_plane == "device":
            # the device-resident control-plane program (core/control.py):
            # tick + eviction mask + queue refill + warm gather fused into one
            # donated program. R1 holds BOTH trees' donation, R3 pins zero
            # host transfers (the zero-readback claim, statically), and R5
            # holds the sharded control plane to the EMPTY collective census
            # (admission/refill must stay shard-local).
            from repro.core import control as control_mod

            shards = max(spec.mesh_slots, 1)
            control = control_mod.init_control(
                key,
                cfg,
                scfg,
                spec.n_slots,
                shards=shards,
                queue_capacity=plan.lowering.tick_queue_capacity,
                warm_capacity=plan.lowering.warm_capacity,
                snapshot_period=plan.lowering.tick_snapshot_period,
            )
            if plan.mesh is not None:
                control = control_mod.shard_control(control, plan.mesh)
            lowered = control_mod.tick_device.lower(
                state,
                control,
                new_y,
                new_u,
                key,
                cfg=cfg,
                scfg=scfg,
                kernel=plan.lowering.tick_kernel,
                quant=quant_tick,
                slots_per_bank=plan.lowering.tick_slots_per_bank or 1,
                shards=shards,
            )
            text = _compiled_text(lowered)
            run("R1", "tick_device", R.check_donation, text, ("state", "control"))
            run("R3", "tick_device", R.check_host_transfers, text, host_allowlist)
            if plan.mesh is not None:
                n_dev = int(plan.mesh.devices.size)
                predicted = predict_tick_collectives(plan.mesh)
                run("R5", "tick_device", R.check_collectives, text, n_dev, predicted)
    elif spec.mode == "offline":
        params = init_mr(key, cfg)
        opt = adamw_init(params)
        N = max(spec.batch_size or 8, 4)
        ys = jnp.zeros((N, scfg.window, cfg.state_dim), jnp.float32)
        us = jnp.zeros((N, scfg.window, cfg.input_dim), jnp.float32) if cfg.input_dim else None
        lowered = engine.run_epoch.lower(
            params,
            opt,
            ys,
            us,
            key,
            spec.lr,
            None,
            cfg=cfg,
            steps=spec.steps,
            batch_size=spec.batch_size,
        )
        text = _compiled_text(lowered)
        run("R1", "epoch", R.check_donation, text, ("params", "opt_state"))
        run("R3", "epoch", R.check_host_transfers, text, host_allowlist)

    if plan.lowering.fused:
        text, T = _fused_step_text(plan)
        family = encoders.get_encoder(cfg.encoder).family
        if plan.lowering.measured_bytes is not None:
            # a measured-tuned plan carries the per-step traffic the tuner
            # parsed from the chosen candidate's own compiled HLO; the audit
            # re-measures against THAT figure (self-consistency of two parses
            # of the same program) in the much tighter tuned band, not the
            # static residency model
            band = tiling.TUNED_RESIDENCY_BAND
            predicted = plan.lowering.measured_bytes
        else:
            band = tiling.residency_tolerance(family)
            predicted = plan.lowering.vmem_bytes or tiling.config_vmem_bytes(
                cfg, _fused_batch(plan), block_b=plan.lowering.block_b
            )
        run("R2", "fused_step", R.check_residency, text, predicted, T, band, family=family)
        run("R3", "fused_step", R.check_host_transfers, text, host_allowlist)

    if plan.lowering.quant_serving:
        text, weights = _serving_weight_text(plan)
        run("R4", "serving_int8", R.check_weight_dtypes, text, weights)
        run("R3", "serving_int8", R.check_host_transfers, text, host_allowlist)

    return AuditReport(findings=findings, checked=checked)


# ---------------------------------------------------------------------------
# --matrix CLI (the CI audit-matrix job)
# ---------------------------------------------------------------------------

# tiny stream shapes: 2 windows of 8 per tick, 2 slots — enough structure to
# exercise every contract, small enough that the full matrix compiles on a
# CPU CI runner in minutes
_TINY = dict(state_dim=2, order=2, hidden=8, dense_hidden=16, mode="stream", n_slots=2)
_TINY_STREAM = dict(buf_len=16, window=8, stride=8, chunk=8, steps_per_tick=2)


def _matrix_specs():
    """Every encoder x fused x quant cell as a (label, RecoverySpec) pair."""
    from repro.api.spec import RecoverySpec, TickSpec
    from repro.core.stream import StreamConfig

    cells = []
    for name in encoders.encoder_names():
        row = encoders.get_encoder(name)
        for fused in (False, True):
            if fused and not row.fusable:
                continue
            for quant in (False, True) if row.int8 else (False,):
                label = f"{name}:fused={int(fused)}:int8={int(quant)}"
                spec = RecoverySpec(
                    encoder=name,
                    precision="int8_pwl" if quant else "fp32",
                    fused=fused,
                    stream=StreamConfig(**_TINY_STREAM),
                    **_TINY,
                )
                cells.append((label, spec))
    # banked one-kernel tick cells (kernels/mr_step/tick.py): the supporting
    # GRU families with a training tick, the K=0 serve tick — where R2 runs
    # against the tick program's own OPTIMIZED HLO — and its int8 serve twin
    banked = [
        ("gru:tick=banked", "gru", 2, "fp32"),
        ("gru_flow:tick=banked", "gru_flow", 2, "fp32"),
        ("gru:tick=banked:K=0", "gru", 0, "fp32"),
        ("gru:tick=banked:K=0:int8=1", "gru", 0, "int8_pwl"),
    ]
    for label, name, k, precision in banked:
        spec = RecoverySpec(
            encoder=name,
            precision=precision,
            stream=StreamConfig(**{**_TINY_STREAM, "steps_per_tick": k}),
            tick=TickSpec(steps_per_tick=k, tick_kernel="banked"),
            **_TINY,
        )
        cells.append((label, spec))
    # device-resident control-plane cells (core/control.py): the fused
    # tick + eviction + refill + warm-gather program, over both tick bodies
    # (R1 donation on both trees, R3 zero host transfers; the sharded R5
    # census runs in the mesh cells below)
    k = _TINY_STREAM["steps_per_tick"]
    for label, tick_kernel in (
        ("gru:control=device", "composite"),
        ("gru:tick=banked:control=device", "banked"),
    ):
        spec = RecoverySpec(
            encoder="gru",
            stream=StreamConfig(**_TINY_STREAM),
            tick=TickSpec(
                steps_per_tick=k,
                tick_kernel=tick_kernel,
                control="device",
                queue_capacity=2,
                snapshot_period=2,
                warm_capacity=4,
            ),
            **_TINY,
        )
        cells.append((label, spec))
    return cells


def _run_mesh_cell(
    n_devices: int,
    rules: tuple[str, ...],
    tick_kernel: str = "composite",
    control: str = "host",
) -> dict:
    """Audit one slot-sharded plan under ``n_devices`` CPU virtual devices.

    XLA_FLAGS must be set before jax initializes, so the meshed cell runs in
    a subprocess (same pattern as tests/conftest.run_devices).
    ``tick_kernel`` picks the tick structure the sharded cell compiles
    ("banked" runs R1/R3/R5 against the banked tick program's HLO);
    ``control="device"`` audits the device-resident control-plane program
    (R5's empty census then covers the sharded queues/refill/warm gather).
    """
    snippet = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count={n_devices}"
        )
        import json
        from repro.analysis import audit as audit_mod
        from repro.api.plan import compile_plan
        from repro.api.spec import RecoverySpec, TickSpec
        from repro.core.stream import StreamConfig

        spec = RecoverySpec(
            encoder="gru", fused=True, mesh_slots={n_devices},
            stream=StreamConfig(**{_TINY_STREAM!r}),
            tick=TickSpec(
                steps_per_tick={_TINY_STREAM["steps_per_tick"]!r},
                tick_kernel={tick_kernel!r},
                control={control!r},
                queue_capacity=2, snapshot_period=2, warm_capacity=4,
            ),
            **{_TINY!r},
        )
        report = audit_mod.audit_plan(compile_plan(spec), rules={rules!r})
        print("AUDITCELL " + json.dumps(report.to_json()))
        """
    )
    env = dict(os.environ)
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(p for p in (src_root, env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-c", snippet],
        capture_output=True,
        text=True,
        env=env,
        timeout=560,
        check=False,
    )
    for line in proc.stdout.splitlines():
        if line.startswith("AUDITCELL "):
            return json.loads(line.split(" ", 1)[1])
    return {
        "verdict": "infra-error",
        "checked": {},
        "findings": [],
        "stderr": proc.stderr[-2000:],
    }


def _run_restored_cell(survivors: int, rules: tuple[str, ...]) -> dict:
    """Audit the plan the ServiceSupervisor compiles AFTER an elastic re-mesh.

    The chaos-recovery path (runtime/resilience.py) re-plans the slot mesh on
    the surviving devices and recompiles before restoring the snapshot; that
    RESTORED plan must honor the same HLO contracts as the original. The
    subprocess pins ``2 * survivors`` virtual devices, builds the original
    device-control spec at mesh ``2 * survivors``, drops half the devices,
    re-plans via ``replan_spec``, recompiles, and audits the restored plan —
    so R5's collective census still runs against a real multi-device mesh
    (shrinking all the way to 1 device would make it vacuous).
    """
    n_devices = 2 * survivors
    stream_cfg = {**_TINY_STREAM}
    tiny = {**_TINY, "n_slots": n_devices}  # mesh_slots must divide n_slots
    snippet = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count={n_devices}"
        )
        import json
        from repro.analysis import audit as audit_mod
        from repro.api.plan import compile_plan
        from repro.api.spec import RecoverySpec, TickSpec
        from repro.core.stream import StreamConfig
        from repro.runtime import replan_spec

        spec = RecoverySpec(
            encoder="gru", fused=True, mesh_slots={n_devices},
            stream=StreamConfig(**{stream_cfg!r}),
            tick=TickSpec(
                steps_per_tick={stream_cfg["steps_per_tick"]!r},
                control="device",
                queue_capacity=2, snapshot_period=2, warm_capacity=4,
            ),
            **{tiny!r},
        )
        respec = replan_spec(spec, {survivors})
        assert respec.mesh_slots == {survivors}, respec.mesh_slots
        report = audit_mod.audit_plan(compile_plan(respec), rules={rules!r})
        print("AUDITCELL " + json.dumps(report.to_json()))
        """
    )
    env = dict(os.environ)
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(p for p in (src_root, env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-c", snippet],
        capture_output=True,
        text=True,
        env=env,
        timeout=560,
        check=False,
    )
    for line in proc.stdout.splitlines():
        if line.startswith("AUDITCELL "):
            return json.loads(line.split(" ", 1)[1])
    return {
        "verdict": "infra-error",
        "checked": {},
        "findings": [],
        "stderr": proc.stderr[-2000:],
    }


def _parse_rules(arg: str) -> tuple[str, ...]:
    out = tuple(r.strip() for r in arg.split(",") if r.strip())
    unknown = [r for r in out if r not in R.RULES]
    if unknown:
        raise SystemExit(f"unknown rule id(s) {unknown}; known: {sorted(R.RULES)}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Static HLO-contract audit of compiled RecoveryPlans.",
    )
    ap.add_argument(
        "--matrix",
        action="store_true",
        help="audit the full encoder x fused x quant spec matrix",
    )
    ap.add_argument(
        "--error-rules",
        default="R1,R2,R3,R4,R5",
        type=_parse_rules,
        help="comma-separated rules whose findings fail the run (exit 1)",
    )
    ap.add_argument(
        "--warn-rules",
        default="",
        type=_parse_rules,
        help="comma-separated rules whose findings only warn",
    )
    ap.add_argument("--json", default=None, help="write all cells + findings here")
    ap.add_argument(
        "--mesh-devices",
        type=int,
        default=2,
        help="CPU virtual devices for the sharded-mesh cell (0 = skip R5 mesh cell)",
    )
    args = ap.parse_args(argv)
    if not args.matrix:
        ap.error("nothing to do: pass --matrix")
    active = tuple(dict.fromkeys(args.error_rules + args.warn_rules))

    from repro.api.plan import compile_plan

    cells, n_err, n_warn = [], 0, 0
    for label, spec in _matrix_specs():
        report = audit_plan(compile_plan(spec), rules=active)
        cell = {"cell": label, **report.to_json()}
        cells.append(cell)
        for f in report.findings:
            if f.rule in args.error_rules:
                n_err += 1
                print(f"ERROR {label} {f}")
            else:
                n_warn += 1
                print(f"WARN  {label} {f}")
        print(f"{label}: {report.verdict}")

    def ingest_subprocess_cell(label: str, cell: dict) -> None:
        nonlocal n_err, n_warn
        cells.append({"cell": label, **cell})
        if cell["verdict"] == "infra-error":
            # a crashed subprocess is an environment problem, not a
            # contract violation — surface it loudly but do not fail
            # warn-mode CI
            n_warn += 1
            print(f"WARN  {label} mesh cell failed to run:\n{cell.get('stderr', '')}")
            return
        for f in cell["findings"]:
            rule = f["rule"]
            line = f"[{rule}] {f['program']}: {f['message']}"
            if rule in args.error_rules:
                n_err += 1
                print(f"ERROR {label} {line}")
            else:
                n_warn += 1
                print(f"WARN  {label} {line}")
        print(f"{label}: {cell['verdict']}")

    if args.mesh_devices and "R5" in active:
        mesh_cells = [
            (f"gru:fused=1:mesh={args.mesh_devices}", "composite", "host"),
            (f"gru:tick=banked:mesh={args.mesh_devices}", "banked", "host"),
            (
                f"gru:control=device:mesh={args.mesh_devices}",
                "composite",
                "device",
            ),
        ]
        for label, tick_kernel, control in mesh_cells:
            cell = _run_mesh_cell(
                args.mesh_devices, active, tick_kernel=tick_kernel, control=control
            )
            ingest_subprocess_cell(label, cell)
        # restored-plan cell: the plan the supervisor recompiles after an
        # elastic re-mesh (mesh 2N -> N via replan_spec) must pass the same
        # contracts as a first-compile plan — recovery may not relax R1
        # donation, R3 zero host transfers, or the R5 collective census
        label = f"gru:control=device:restored:mesh={2 * args.mesh_devices}->{args.mesh_devices}"
        ingest_subprocess_cell(label, _run_restored_cell(args.mesh_devices, active))

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"rules": R.RULES, "cells": cells}, fh, indent=2)
        print(f"wrote {args.json} ({len(cells)} cells)")
    print(f"audit matrix: {len(cells)} cells, {n_err} error(s), {n_warn} warning(s)")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
