"""Measured-cost autotuner: close the loop from HLO cost to lowering choice.

The static lowering policy (``tiling.auto_block_b`` / ``auto_slots_per_bank``)
trusts the hand-written VMEM residency model. This module makes the decision
EMPIRICAL: given a :class:`~repro.api.spec.RecoverySpec` it

1. enumerates candidate lowerings from the SAME generators the static path
   walks (``tiling.block_b_candidates`` batch tiles, fused-vs-unfused where
   the encoder family supports both, the substep-scan unroll factor of the
   multi-substep families, ``tiling.slots_per_bank_candidates`` bank sizes
   for a banked stream tick);
2. lowers each candidate's per-window stage to OPTIMIZED HLO and scores it
   with the trip-count-aware parse (``analysis/hlo.analyze_module``) —
   per-input-step HBM bytes and FLOPs — cross-checked against XLA's own
   ``Compiled.cost_analysis()`` figures;
3. ranks candidates by the roofline time estimate (bytes/HBM_BW vs
   flops/PEAK_FLOPS, whichever binds), preferring candidates that fit the
   VMEM budget and whose measured traffic lands inside the R2 residency band
   of the static prediction, and optionally refines the top-k with timed
   micro-runs;
4. returns a ranked :class:`TuneReport` with predicted-vs-measured bytes and
   flops per candidate, and persists the decision in an on-disk cache keyed
   by (spec fingerprint, device kind, mesh shape) so a warm
   ``compile_plan(spec, tune="measured")`` pays ZERO search cost.

``compile_plan(spec, tune="off"|"static"|"measured")`` is the integration
point (api/plan.py): the chosen candidate and its cost evidence are stamped
into ``plan.lowering`` (``tuned``, ``tune_cache_key``, ``predicted_bytes``,
``measured_bytes``).

CLI::

    python -m repro.analysis.tuner --what-if --encoder ltc --fused \\
        --batch 48 --vmem-budget 40000          # replay the candidate table
    python -m repro.analysis.tuner --smoke --json TUNE_report.json

``--what-if`` prints the ranked table and explains the decision (why
block_b=16 beat 24 on this device); ``--smoke`` is the CI tune-smoke step:
two specs tuned cold then recompiled warm, asserting the warm pass hits the
cache with zero lowered candidates.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time
import warnings
from pathlib import Path

from repro.analysis import hlo as H
from repro.kernels.mr_step import tiling

TUNER_VERSION = 1  # bump to invalidate every cached decision

TUNE_MODES = ("off", "static", "measured")

#: hard cap on lowered candidates per tune() call: each candidate costs one
#: XLA compile, and the divisor ladder of a large batch is long. Candidates
#: past the cap are dropped FROM THE MEASURED SET ONLY (the static scores
#: still cover them) and the drop is recorded in TuneReport.n_dropped.
MAX_LOWERED = 12


# ---------------------------------------------------------------------------
# candidates
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point in the lowering design space.

    ``stage="step"`` tunes the fused per-window stage (block_b x fused x
    substep_unroll); ``stage="tick"`` tunes the banked service tick's bank
    size (``slots_per_bank``) — the two searches are independent because the
    two programs are.
    """

    block_b: int | None = None
    fused: bool = False
    substep_unroll: int = 1
    stage: str = "step"  # "step" | "tick"
    slots_per_bank: int | None = None

    def label(self) -> str:
        if self.stage == "tick":
            return f"tick:spb={self.slots_per_bank}"
        bits = [f"block_b={self.block_b}", "fused" if self.fused else "unfused"]
        if self.substep_unroll != 1:
            bits.append(f"unroll={self.substep_unroll}")
        return ":".join(bits)


@dataclasses.dataclass
class ScoredCandidate:
    """One candidate with its cost evidence (predicted vs measured)."""

    candidate: Candidate
    predicted_bytes: int  # static VMEM residency model (tiling.py)
    fits_budget: bool
    parsed_bytes: float | None = None  # analyze_module per-input-step HBM traffic
    parsed_flops: float | None = None
    xla_bytes: float | None = None  # Compiled.cost_analysis() cross-check
    xla_flops: float | None = None
    t_step_us: float | None = None  # roofline per-step time estimate
    in_band: bool = True  # parsed/predicted inside the R2 residency band
    measured_us: float | None = None  # timed micro-run (refine_topk only)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["candidate"] = dataclasses.asdict(self.candidate)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ScoredCandidate":
        d = dict(d)
        d["candidate"] = Candidate(**d["candidate"])
        return cls(**d)


@dataclasses.dataclass
class TuneReport:
    """Outcome of one tune() call: the ranked table + the decision."""

    cache_key: str
    spec_fingerprint: str
    device_kind: str
    mesh_shape: tuple[int, ...]
    mode: str  # "static" | "measured"
    candidates: list[ScoredCandidate]  # ranked, best first (step stage)
    chosen: ScoredCandidate
    tick_candidates: list[ScoredCandidate] = dataclasses.field(default_factory=list)
    chosen_tick: ScoredCandidate | None = None
    cache_hit: bool = False
    n_lowered: int = 0  # candidate lowerings performed THIS call (0 on warm)
    n_dropped: int = 0  # candidates past MAX_LOWERED (static scores only)
    budget_bytes: int | None = None
    budget_source: str | None = None

    def to_json(self) -> dict:
        return {
            "version": TUNER_VERSION,
            "cache_key": self.cache_key,
            "spec_fingerprint": self.spec_fingerprint,
            "device_kind": self.device_kind,
            "mesh_shape": list(self.mesh_shape),
            "mode": self.mode,
            "candidates": [s.to_json() for s in self.candidates],
            "chosen": self.chosen.to_json(),
            "tick_candidates": [s.to_json() for s in self.tick_candidates],
            "chosen_tick": self.chosen_tick.to_json() if self.chosen_tick else None,
            "cache_hit": self.cache_hit,
            "n_lowered": self.n_lowered,
            "n_dropped": self.n_dropped,
            "budget_bytes": self.budget_bytes,
            "budget_source": self.budget_source,
        }


# ---------------------------------------------------------------------------
# fingerprint + cache
# ---------------------------------------------------------------------------
def spec_fingerprint(spec) -> str:
    """Deterministic digest of every spec field (nested configs included)."""
    blob = json.dumps(dataclasses.asdict(spec), sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def device_kind() -> str:
    import jax

    devs = jax.local_devices()
    return devs[0].device_kind if devs else "unknown"


def tune_cache_key(spec, kind: str | None = None, mesh_shape: tuple[int, ...] | None = None) -> str:
    """Cache key = (spec fingerprint, device kind, mesh shape, tuner version).

    Any spec field change (hidden_dim bump, new window geometry) changes the
    fingerprint and therefore misses the cache; so does moving the plan to a
    different device kind or mesh.
    """
    kind = device_kind() if kind is None else kind
    if mesh_shape is None:
        mesh_shape = (spec.mesh_slots,) if spec.mode == "stream" else ()
    blob = f"{spec_fingerprint(spec)}|{kind}|{','.join(map(str, mesh_shape))}|v{TUNER_VERSION}"
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def cache_dir() -> Path:
    """On-disk tuning cache root: $REPRO_TUNE_CACHE or ~/.cache/repro/tune."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "tune"


def _cache_load(path: Path, key: str) -> dict | None:
    """A cached decision, or None (missing / corrupted / stale version)."""
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except FileNotFoundError:
        return None
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        warnings.warn(
            f"tuning cache {path} is corrupted ({e}); falling back to a fresh search",
            stacklevel=3,
        )
        return None
    if (
        not isinstance(doc, dict)
        or doc.get("version") != TUNER_VERSION
        or doc.get("cache_key") != key
    ):
        return None
    try:
        # validate the payload shape eagerly so a truncated-but-valid-JSON
        # file degrades to a fresh search, not a crash downstream
        ScoredCandidate.from_json(doc["chosen"])
        [ScoredCandidate.from_json(d) for d in doc["candidates"]]
    except (KeyError, TypeError) as e:
        warnings.warn(
            f"tuning cache {path} has an unreadable payload ({e}); "
            f"falling back to a fresh search",
            stacklevel=3,
        )
        return None
    return doc


def _cache_store(path: Path, doc: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    with open(tmp, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)  # atomic on POSIX: a reader never sees a torn file


# ---------------------------------------------------------------------------
# enumeration
# ---------------------------------------------------------------------------
def _step_batch(spec) -> int | None:
    """The fused-stage batch knowable at compile time (mirrors api/plan.py)."""
    if spec.mode == "stream":
        return spec.stream_config().n_windows
    return spec.batch_size


def _step_window(spec) -> int:
    return spec.stream_config().window if spec.mode == "stream" else 32


def enumerate_candidates(spec) -> list[Candidate]:
    """The step-stage design space for one spec, static-policy point first.

    Axes: batch tile (``tiling.block_b_candidates``; pinned when the spec
    carries an explicit int), fused-vs-unfused (both only when the family is
    fusable and the spec is float — int8 serving and QAT pin the kernel
    path), and the substep-scan unroll factor (multi-substep families only).
    The list is deterministic and deduplicated; the candidate matching the
    spec's own static lowering always leads, so the measured set (capped at
    MAX_LOWERED) can never lose the baseline it must beat.
    """
    from repro.core import encoders

    row = encoders.get_encoder(spec.encoder)
    batch = _step_batch(spec)

    if isinstance(spec.block_b, int):
        tiles: list[int | None] = [spec.block_b]
    elif spec.block_b == "auto" and batch is not None:
        tiles = tiling.block_b_candidates(batch)
    else:
        tiles = [None]  # batch unknown at compile time: only full batch is legal

    if row.fusable and spec.precision == "fp32" and spec.qat is None:
        fused_opts = [spec.fused, not spec.fused]
    else:
        fused_opts = [spec.fused]

    if row.family in ("ltc", "node"):
        unrolls = sorted({1, 2, spec.ltc_substeps})
    else:
        unrolls = [1]
    if spec.substep_unroll not in unrolls:
        unrolls = sorted({spec.substep_unroll, *unrolls})

    out: list[Candidate] = []
    for fused in fused_opts:
        for bb in tiles if fused else [None]:  # block_b tiles the FUSED stage only
            for u in unrolls:
                out.append(Candidate(block_b=bb, fused=fused, substep_unroll=u))
    # the static-policy point leads (see docstring)
    static = static_candidate(spec)
    out = [static] + [c for c in out if c != static]
    return out


def static_candidate(spec, budget: int | None = None) -> Candidate:
    """The candidate the static policy (auto_block_b + the spec) would pick."""
    batch = _step_batch(spec)
    bb: int | None
    if isinstance(spec.block_b, int):
        bb = spec.block_b
    elif spec.block_b == "auto" and spec.fused:
        if budget is None:
            budget = (
                spec.vmem_budget_bytes
                if spec.vmem_budget_bytes is not None
                else tiling.detect_vmem_budget()
            )
        bb = tiling.auto_block_b(spec.to_mr_config(), batch, budget)
    else:
        bb = None
    return Candidate(block_b=bb, fused=spec.fused, substep_unroll=spec.substep_unroll)


def enumerate_tick_candidates(spec) -> list[Candidate]:
    """Bank sizes for the banked stream tick (empty off-stream / unsupported)."""
    if spec.mode != "stream":
        return []
    requested = spec.tick_spec().tick_kernel
    if requested not in ("banked", "auto"):
        return []
    from repro.kernels.mr_step import tick as tick_mod

    cfg = spec.to_mr_config()
    scfg = spec.stream_config()
    quant_tick = spec.precision == "int8_pwl" and scfg.steps_per_tick == 0
    if not tick_mod.tick_supported(cfg, int8=quant_tick):
        return []
    local_slots = spec.n_slots // spec.mesh_slots
    return [
        Candidate(stage="tick", slots_per_bank=spb)
        for spb in tiling.slots_per_bank_candidates(local_slots)
    ]


# ---------------------------------------------------------------------------
# scoring
# ---------------------------------------------------------------------------
def _candidate_cfg(spec, cand: Candidate):
    cfg = spec.to_mr_config(block_b=cand.block_b, substep_unroll=cand.substep_unroll)
    if cfg.fused != cand.fused:
        cfg = dataclasses.replace(cfg, fused=cand.fused)
    return cfg


def _lower_step(spec, cand: Candidate):
    """Compile one step-stage candidate; returns (Compiled, hlo text, T)."""
    import jax
    import jax.numpy as jnp

    from repro.core.merinda import init_mr, mr_forward

    cfg = _candidate_cfg(spec, cand)
    B = _step_batch(spec) or 16
    T = _step_window(spec)
    params = init_mr(jax.random.key(0), cfg)
    ys = jnp.zeros((B, T, cfg.state_dim), jnp.float32)
    us = jnp.zeros((B, T, cfg.input_dim), jnp.float32) if cfg.input_dim else None
    fn = jax.jit(lambda p, y, u: mr_forward(p, cfg, y, u))
    compiled = fn.lower(params, ys, us).compile()
    return compiled, compiled.as_text(), T, (params, ys, us)


def _lower_tick(spec, cand: Candidate):
    """Compile one tick-stage candidate; returns (Compiled, hlo text, T)."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core import stream as stream_mod

    cfg = spec.to_mr_config()
    scfg = spec.stream_config()
    quant_tick = spec.precision == "int8_pwl" and scfg.steps_per_tick == 0
    key = jax.random.key(0)
    state = stream_mod.init_slots(key, cfg, scfg, spec.n_slots)
    new_y = jnp.zeros((spec.n_slots, scfg.chunk, cfg.state_dim), jnp.float32)
    new_u = jnp.zeros((spec.n_slots, scfg.chunk, cfg.input_dim), jnp.float32)
    fn = jax.jit(
        functools.partial(
            stream_mod.tick_banked,
            cfg=cfg,
            scfg=scfg,
            quant=quant_tick,
            slots_per_bank=cand.slots_per_bank or 1,
        )
    )
    compiled = fn.lower(state, new_y, new_u, key).compile()
    return compiled, compiled.as_text(), scfg.window, None


def _xla_costs(compiled) -> tuple[float | None, float | None]:
    """(flops, bytes accessed) from Compiled.cost_analysis(), defensively.

    jax 0.4.x wraps the per-device dict in a list; either spelling (and a
    backend that raises) degrades to (None, None) — the parse-based score
    is the primary signal, this is the cross-check.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return None, None
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    if not isinstance(cost, dict):
        return None, None
    return cost.get("flops"), cost.get("bytes accessed")


def _roofline_us(flops: float, bytes_: float) -> float:
    """Per-step roofline time in microseconds: the binding term wins."""
    return max(flops / H.PEAK_FLOPS, bytes_ / H.HBM_BW) * 1e6


def _predicted_bytes(spec, cand: Candidate) -> int:
    if cand.stage == "tick":
        return tiling.tick_vmem_bytes(
            spec.to_mr_config(),
            spec.stream_config(),
            slots_per_bank=cand.slots_per_bank or 1,
            int8=spec.precision == "int8_pwl" and spec.stream_config().steps_per_tick == 0,
        )
    return tiling.config_vmem_bytes(
        _candidate_cfg(spec, cand), _step_batch(spec) or 16, block_b=cand.block_b
    )


def score_candidate(
    spec, cand: Candidate, budget: int | None, *, lower: bool = True
) -> ScoredCandidate:
    """Static prediction always; parsed + XLA measurement when ``lower``."""
    predicted = _predicted_bytes(spec, cand)
    fits = budget is None or predicted <= budget
    sc = ScoredCandidate(candidate=cand, predicted_bytes=predicted, fits_budget=fits)
    if not lower:
        return sc
    compiled, text, T, _ = (_lower_tick if cand.stage == "tick" else _lower_step)(spec, cand)
    costs = H.analyze_module(text, 1)
    sc.parsed_bytes = costs.hbm_bytes / max(T, 1)
    sc.parsed_flops = costs.flops / max(T, 1)
    xf, xb = _xla_costs(compiled)
    sc.xla_flops = xf / max(T, 1) if xf is not None else None
    sc.xla_bytes = xb / max(T, 1) if xb is not None else None
    sc.t_step_us = _roofline_us(sc.parsed_flops, sc.parsed_bytes)
    if cand.stage == "tick":
        lo, hi = tiling.TICK_RESIDENCY_BAND
    else:
        from repro.core import encoders

        lo, hi = tiling.residency_tolerance(encoders.get_encoder(spec.encoder).family)
    ratio = sc.parsed_bytes / max(predicted, 1)
    sc.in_band = lo <= ratio <= hi
    return sc


def _rank_key(sc: ScoredCandidate):
    """Deterministic ranking: budget-fitting in-band candidates first, then
    the roofline estimate (micro-run time when refined), with a fixed
    structural tie-break so identical scores order identically everywhere."""
    c = sc.candidate
    t = sc.measured_us if sc.measured_us is not None else sc.t_step_us
    return (
        not sc.fits_budget,
        not sc.in_band,
        round(t, 4) if t is not None else float("inf"),
        -(c.block_b or 1 << 30),  # larger tile preferred at equal cost
        c.substep_unroll,  # least unrolling at equal cost
        not c.fused,
        -(c.slots_per_bank or 0),
    )


def _time_compiled(compiled, args, *, repeats: int = 3) -> float:
    """Best-of-N wall time of one compiled call, in microseconds."""
    import jax

    flat = [a for a in args if a is not None] if args else []
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = compiled(*flat)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------
def tune(
    spec,
    mode: str = "measured",
    *,
    cache: bool = True,
    cache_root: Path | str | None = None,
    refine_topk: int = 0,
) -> TuneReport:
    """Pick the best lowering for ``spec``; see the module docstring.

    ``mode="static"`` scores the candidate table with the VMEM model only
    (no lowering, no cache) and chooses exactly what the static policy
    chooses — the table is the what-if evidence. ``mode="measured"`` lowers
    every candidate (up to MAX_LOWERED), scores the optimized HLO, and
    caches the decision; a warm call returns the cached report with
    ``cache_hit=True`` and ``n_lowered=0``. ``refine_topk`` times the top-k
    step candidates with micro-runs and re-ranks (opt-in: wall times are
    machine-dependent, so compile_plan never sets it).
    """
    if mode not in ("static", "measured"):
        raise ValueError(f"tune mode must be 'static' or 'measured', got {mode!r}")
    kind = device_kind()
    mesh_shape = (spec.mesh_slots,) if spec.mode == "stream" else ()
    fingerprint = spec_fingerprint(spec)
    key = tune_cache_key(spec, kind, mesh_shape)
    if spec.vmem_budget_bytes is not None:
        budget, budget_src = spec.vmem_budget_bytes, "explicit"
    else:
        budget, budget_src = tiling.resolve_vmem_budget()

    cands = enumerate_candidates(spec)
    tick_cands = enumerate_tick_candidates(spec)

    if mode == "static":
        scored = [score_candidate(spec, c, budget, lower=False) for c in cands]
        tick_scored = [score_candidate(spec, c, budget, lower=False) for c in tick_cands]
        chosen_c = static_candidate(spec, budget)
        chosen = next(s for s in scored if s.candidate == chosen_c)
        chosen_tick = next((s for s in tick_scored if s.fits_budget), None)
        return TuneReport(
            cache_key=key,
            spec_fingerprint=fingerprint,
            device_kind=kind,
            mesh_shape=mesh_shape,
            mode=mode,
            candidates=scored,
            chosen=chosen,
            tick_candidates=tick_scored,
            chosen_tick=chosen_tick,
            budget_bytes=budget,
            budget_source=budget_src,
        )

    cpath = Path(cache_root) if cache_root is not None else cache_dir()
    cpath = cpath / f"{key}.json"
    if cache:
        doc = _cache_load(cpath, key)
        if doc is not None:
            return TuneReport(
                cache_key=key,
                spec_fingerprint=fingerprint,
                device_kind=kind,
                mesh_shape=mesh_shape,
                mode="measured",
                candidates=[ScoredCandidate.from_json(d) for d in doc["candidates"]],
                chosen=ScoredCandidate.from_json(doc["chosen"]),
                tick_candidates=[ScoredCandidate.from_json(d) for d in doc["tick_candidates"]],
                chosen_tick=ScoredCandidate.from_json(doc["chosen_tick"])
                if doc.get("chosen_tick")
                else None,
                cache_hit=True,
                n_lowered=0,
                n_dropped=doc.get("n_dropped", 0),
                budget_bytes=doc.get("budget_bytes"),
                budget_source=doc.get("budget_source"),
            )

    lowered_set = cands[:MAX_LOWERED]
    dropped = cands[MAX_LOWERED:]
    scored = [score_candidate(spec, c, budget, lower=True) for c in lowered_set]
    scored += [score_candidate(spec, c, budget, lower=False) for c in dropped]
    n_lowered = len(lowered_set)
    if refine_topk > 0:
        for sc in sorted(scored, key=_rank_key)[:refine_topk]:
            if sc.candidate.stage != "step" or sc.t_step_us is None:
                continue
            compiled, _, _, args = _lower_step(spec, sc.candidate)
            sc.measured_us = _time_compiled(compiled, args)
    scored.sort(key=_rank_key)
    chosen = scored[0]

    tick_scored = [score_candidate(spec, c, budget, lower=True) for c in tick_cands]
    n_lowered += len(tick_cands)
    tick_scored.sort(key=_rank_key)
    chosen_tick = tick_scored[0] if tick_scored else None

    report = TuneReport(
        cache_key=key,
        spec_fingerprint=fingerprint,
        device_kind=kind,
        mesh_shape=mesh_shape,
        mode="measured",
        candidates=scored,
        chosen=chosen,
        tick_candidates=tick_scored,
        chosen_tick=chosen_tick,
        cache_hit=False,
        n_lowered=n_lowered,
        n_dropped=len(dropped),
        budget_bytes=budget,
        budget_source=budget_src,
    )
    if cache:
        _cache_store(cpath, report.to_json())
    return report


# ---------------------------------------------------------------------------
# what-if / smoke CLI
# ---------------------------------------------------------------------------
def _fmt_bytes(x: float | None) -> str:
    if x is None:
        return "-"
    return f"{x / 1024:.1f}K" if x >= 1024 else f"{x:.0f}"


def explain(report: TuneReport) -> str:
    """Human-readable replay of the decision (the --what-if body)."""
    lines = [
        f"tune[{report.mode}] key={report.cache_key} device={report.device_kind} "
        f"mesh={report.mesh_shape or '()'} budget={_fmt_bytes(report.budget_bytes)} "
        f"({report.budget_source}) cache_hit={report.cache_hit} "
        f"lowered={report.n_lowered} dropped={report.n_dropped}",
        f"{'rank':<4} {'candidate':<32} {'pred_B':>8} {'meas_B/step':>11} "
        f"{'flops/step':>10} {'xla_B/step':>10} {'t_us':>8} fit band",
    ]
    winners = {report.chosen.candidate}
    if report.chosen_tick is not None:
        winners.add(report.chosen_tick.candidate)
    for i, sc in enumerate(report.candidates + report.tick_candidates):
        mark = "*" if sc.candidate in winners else " "
        t_str = f"{sc.t_step_us:.2f}" if sc.t_step_us is not None else "-"
        lines.append(
            f"{mark}{i:<3} {sc.candidate.label():<32} {_fmt_bytes(sc.predicted_bytes):>8} "
            f"{_fmt_bytes(sc.parsed_bytes):>11} {_fmt_bytes(sc.parsed_flops):>10} "
            f"{_fmt_bytes(sc.xla_bytes):>10} {t_str:>8} "
            f"{'y' if sc.fits_budget else 'N'}   {'y' if sc.in_band else 'N'}"
        )
    ch = report.chosen
    runners = [s for s in report.candidates if s is not ch]
    if runners and ch.t_step_us is not None and runners[0].t_step_us is not None:
        ru = runners[0]
        why = []
        if ch.fits_budget and not ru.fits_budget:
            why.append(f"it fits the budget ({_fmt_bytes(ch.predicted_bytes)} resident)")
        if ch.in_band and not ru.in_band:
            why.append("its measured traffic matches the residency model")
        if ru.t_step_us > (ch.t_step_us or 0):
            why.append(
                f"its roofline step time is {ru.t_step_us / max(ch.t_step_us, 1e-9):.2f}x "
                f"lower ({ch.t_step_us:.2f}us vs {ru.t_step_us:.2f}us)"
            )
        if why:
            lines.append(
                f"chose {ch.candidate.label()} over {ru.candidate.label()}: " + "; ".join(why)
            )
    return "\n".join(lines)


def _spec_from_args(args) -> "object":
    from repro.api.spec import RecoverySpec

    kw = dict(
        state_dim=args.state_dim,
        hidden=args.hidden,
        encoder=args.encoder,
        fused=args.fused,
        block_b="auto",
        mode=args.mode,
    )
    if args.vmem_budget:
        kw["vmem_budget_bytes"] = args.vmem_budget
    if args.mode in ("offline", "batch"):
        kw["batch_size"] = args.batch
    return RecoverySpec(**kw)


def _smoke_specs():
    from repro.api.spec import RecoverySpec

    return [
        (
            "gru_flow:fused:b16",
            RecoverySpec(
                state_dim=2, hidden=8, dense_hidden=16, encoder="gru_flow",
                fused=True, block_b="auto", mode="batch", batch_size=16, steps=4,
            ),
        ),
        (
            "ltc:fused:b12",
            RecoverySpec(
                state_dim=2, hidden=8, dense_hidden=16, encoder="ltc", ltc_substeps=4,
                fused=True, block_b="auto", mode="batch", batch_size=12, steps=4,
            ),
        ),
    ]


def _run_smoke(args) -> int:
    """CI tune-smoke: cold tune two specs, then assert the warm path is free."""
    from repro.api import plan as plan_mod

    reports = {}
    for label, spec in _smoke_specs():
        cold = plan_mod.compile_plan(spec, tune="measured")
        if cold.lowering.tuned not in ("measured", "measured:cached"):
            print(f"FAIL {label}: cold compile not tuned ({cold.lowering.tuned})")
            return 1
        warm = plan_mod.compile_plan(spec, tune="measured")
        if warm.lowering.tuned != "measured:cached":
            print(f"FAIL {label}: warm compile missed the cache ({warm.lowering.tuned})")
            return 1
        warm_report = tune(spec, mode="measured")
        if not warm_report.cache_hit or warm_report.n_lowered != 0:
            print(
                f"FAIL {label}: warm tune lowered {warm_report.n_lowered} candidates "
                f"(cache_hit={warm_report.cache_hit})"
            )
            return 1
        if warm.lowering.block_b != cold.lowering.block_b:
            print(f"FAIL {label}: warm choice diverged from cold")
            return 1
        reports[label] = warm_report.to_json()
        print(f"ok {label}: chosen={warm_report.chosen.candidate.label()} warm n_lowered=0")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(reports, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")
    print("tune-smoke: warm compiles hit the cache with zero lowered candidates")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.tuner",
        description="Measured-cost autotuner: replay / explain lowering decisions.",
    )
    ap.add_argument("--what-if", action="store_true", help="print the ranked candidate table")
    ap.add_argument("--smoke", action="store_true", help="CI tune-smoke (two specs, warm assert)")
    ap.add_argument("--tune", default="measured", choices=("static", "measured"))
    ap.add_argument("--encoder", default="gru_flow")
    ap.add_argument("--state-dim", type=int, default=2)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--mode", default="batch", choices=("offline", "batch", "stream"))
    ap.add_argument("--fused", action="store_true")
    ap.add_argument("--vmem-budget", type=int, default=0, help="explicit VMEM budget in bytes")
    ap.add_argument("--no-cache", action="store_true", help="ignore + don't write the cache")
    ap.add_argument("--measure-topk", type=int, default=0, help="micro-run the top-k candidates")
    ap.add_argument("--cache-dir", default=None, help="override the tuning cache root")
    ap.add_argument("--json", default=None, help="write the TuneReport here")
    args = ap.parse_args(argv)
    if args.cache_dir:
        os.environ["REPRO_TUNE_CACHE"] = args.cache_dir
    if args.smoke:
        return _run_smoke(args)
    if not args.what_if:
        ap.error("nothing to do: pass --what-if or --smoke")
    spec = _spec_from_args(args)
    report = tune(spec, mode=args.tune, cache=not args.no_cache, refine_topk=args.measure_topk)
    print(explain(report))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report.to_json(), fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
