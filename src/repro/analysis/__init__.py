"""Static analysis of compiled programs: HLO cost parsing + plan auditing.

- ``analysis.hlo``: trip-count-aware FLOPs/bytes/collective parse of
  optimized HLO text, plus the contract parses (donation aliases, entry
  parameters, host-transfer ops) the auditor builds on.
- ``analysis.rules``: the hardware-contract rules R1-R5, each a pure
  function from parsed HLO + a prediction to structured Findings.
- ``analysis.audit``: ``audit_plan`` (drives the rules over a compiled
  RecoveryPlan's programs) and the ``python -m repro.analysis.audit
  --matrix`` CLI.
- ``analysis.tuner``: the measured-cost autotuner — candidate lowerings
  scored from their own optimized HLO, cached decisions, and the
  ``python -m repro.analysis.tuner --what-if`` CLI.

``tuner`` is imported lazily (it pulls jax at tune time); the light parse
surface stays importable without an accelerator runtime.
"""

from repro.analysis.hlo import analyze_module, collective_stats, roofline_terms
from repro.analysis.rules import RULES, Finding


def __getattr__(name):
    if name in ("tune", "TuneReport", "Candidate", "tune_cache_key", "spec_fingerprint"):
        from repro.analysis import tuner

        return getattr(tuner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
