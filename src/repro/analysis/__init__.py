"""Static analysis of compiled programs: HLO cost parsing + plan auditing.

- ``analysis.hlo``: trip-count-aware FLOPs/bytes/collective parse of
  optimized HLO text, plus the contract parses (donation aliases, entry
  parameters, host-transfer ops) the auditor builds on.
- ``analysis.rules``: the hardware-contract rules R1-R5, each a pure
  function from parsed HLO + a prediction to structured Findings.
- ``analysis.audit``: ``audit_plan`` (drives the rules over a compiled
  RecoveryPlan's programs) and the ``python -m repro.analysis.audit
  --matrix`` CLI.
"""

from repro.analysis.hlo import analyze_module, collective_stats, roofline_terms
from repro.analysis.rules import RULES, Finding
