"""Post-SPMD HLO analysis: trip-count-aware FLOPs / bytes / collective parse.

Why not just ``compiled.cost_analysis()``: XLA counts every ``while`` body
(lax.scan — our layer stacks, KV-chunk loops, CE chunking) exactly ONCE,
under-reporting FLOPs/bytes/collectives of an L-layer scanned model by ~L x.
This module parses the optimized HLO text into its computation graph,
recovers each while loop's trip count from its condition's comparison
constant, and accumulates costs with the correct multipliers:

  flops        dot/convolution ops: 2 * prod(result_dims) * prod(contracted)
               (dots inside fusions are still counted; >99% of model FLOPs)
  hbm bytes    TPU-fusion simulation: the CPU backend materializes many small
               kLoop fusions that Mosaic/XLA:TPU would fuse through. A value
               is MATERIALIZED iff its producer is a heavy op (dot / conv /
               collective / copy / concat / scatter / DUS / sort / param), it
               has != 1 consumer, or its single consumer needs materialized
               operands (dot/conv lhs+rhs). Traffic = one write per
               materialized value + one read per consuming op.
  collectives  operand bytes per kind, with ring wire-byte factors:
                 all-reduce         2 * B * (n-1)/n
                 all-gather         B_operand * (n-1)
                 reduce-scatter     B_operand * (n-1)/n
                 all-to-all         B * (n-1)/n
                 collective-permute B

bf16 normalization: XLA:CPU float-normalizes bf16 compute to f32 and the
algebraic simplifier then cancels the bf16 round-trips, so activations that
are bf16 on TPU appear as f32 end-to-end in CPU HLO. With f32_as_bf16=True
(set when the model's dtype is bfloat16) every f32 tensor is counted at
2 bytes/element. This slightly under-counts intentionally-f32 buffers
(softmax statistics, CE logsumexp, optimizer moments) — a few GB against
multi-TB totals, uniform across perf variants.

reduce-scatter recognition: the CPU SPMD pipeline lacks the
ReduceScatterCreator pass, so a partial-sum dot feeding a sharded consumer
lowers as all-reduce + dynamic-slice(1/n). The TPU pipeline emits a true
reduce-scatter for the same program, so an all-reduce whose every consumer
slices out <= 1/group of the result is counted as a reduce-scatter (wire
B*(n-1)/n instead of 2B*(n-1)/n, and only the sliced shard materializes).

Hardware model (v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8,
    "f32": 4,
    "bf16": 2,
    "f16": 2,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "s64": 8,
    "u64": 8,
    "s32": 4,
    "u32": 4,
    "s16": 2,
    "u16": 2,
    "s8": 1,
    "u8": 1,
    "pred": 1,
    "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# heavy ops: results always materialize to memory (MXU outputs, data movers,
# collectives); their tensor operands must also be materialized
_HEAVY_OPS = {
    "dot",
    "convolution",
    "copy",
    "concatenate",
    "scatter",
    "gather",
    "dynamic-slice",
    "dynamic-update-slice",
    "sort",
    "reduce-window",
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "custom-call",
    "rng",
    "pad",
    "reverse",
    "cholesky",
    "triangular-solve",
    "fft",
}
# structural ops: no traffic of their own; values flow through
_SKIP_OPS = {
    "parameter",
    "constant",
    "tuple",
    "get-tuple-element",
    "bitcast",
    "after-all",
    "token",
    "while",
    "call",
    "conditional",
    "domain",
    "partition-id",
    "replica-id",
    "bitcast-convert",
    "optimization-barrier",
    "get-dimension-size",
    "rng-get-and-update-state",
    "all-reduce-done",
    "all-gather-done",
    "async-done",
    "async-start",
    "copy-start",
    "copy-done",
    "send",
    "recv",
    "send-done",
    "recv-done",
    "iota",
    "constant",
}


def _shape_info(type_str: str, f32_as_bf16: bool = False) -> tuple[int, list[int]]:
    """'bf16[16,4096,512]' -> (bytes, dims). Tuples: summed bytes, first dims."""
    total, first_dims = 0, None
    for dt, dims_s in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        nbytes = _DTYPE_BYTES[dt]
        if f32_as_bf16 and dt == "f32":
            nbytes = 2  # CPU float-normalization artifact (see module doc)
        total += n * nbytes
        if first_dims is None:
            first_dims = dims
    return total, (first_dims or [])


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_type: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list[_Op]
    is_fusion_interior: bool = False


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(
    # result type is either a tuple "(...)" (no nested parens in HLO types;
    # may contain /*index=k*/ comments) or a plain shape token
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{}.]+)\s+([\w\-]+)\((.*)$"
)


def parse_hlo(text: str) -> tuple[dict[str, _Computation], str]:
    """Returns (computations, entry_name)."""
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    entry = ""
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line == "}":
            cur = None
            continue
        if cur is None and line.endswith("{"):
            hdr = _COMP_HDR.match(line)
            if hdr:
                cur = _Computation(hdr.group(2), [])
                comps[cur.name] = cur
                if hdr.group(1):
                    entry = cur.name
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, result_type, kind, rest = m.groups()
        # operands: % names before the closing paren of the call (attrs after
        # the call may reference computations, handled separately via _called)
        operands = re.findall(r"%([\w.\-]+)", rest)
        cur.ops.append(_Op(name, kind, result_type, operands, line))
    return comps, entry


def _called(op: _Op, attr: str) -> list[str]:
    out = []
    for m in re.finditer(rf"{attr}=%?([\w.\-_]+)", op.line):
        out.append(m.group(1))
    m = re.search(rf"{attr}=\{{([^}}]*)\}}", op.line)
    if m:
        out.extend(re.findall(r"%?([\w.\-_]+)", m.group(1)))
    return out


def _trip_count(op: _Op, comps: dict[str, _Computation]) -> int:
    """Recover a while loop's trip count.

    Primary: XLA's own loop analysis, serialized on the while op as
    backend_config={"known_trip_count":{"n":"8"},...}. Fallback: the largest
    integer constant in the condition computation (lax.scan lowers to
    `compare(i, constant(N)), direction=LT`). Unknown -> 1 (conservative).
    """
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
    if m:
        return int(m.group(1))
    best = 1
    for cname in _called(op, "condition"):
        cond = comps.get(cname)
        if cond is None:
            continue
        consts: dict[str, int] = {}
        for o in cond.ops:
            if o.kind == "constant":
                mm = re.search(r"constant\((-?\d+)\)", o.line)
                if mm:
                    consts[o.name] = int(mm.group(1))
            elif o.kind == "fusion":  # compare may be wrapped in a tiny fusion
                for f in _called(o, "calls"):
                    inner = comps.get(f)
                    if inner:
                        for io in inner.ops:
                            if io.kind == "compare":
                                for opn in o.operands:
                                    if opn in consts and consts[opn] > best:
                                        best = consts[opn]
        for o in cond.ops:
            if o.kind == "compare":
                for opn in o.operands:
                    if opn in consts and consts[opn] > best:
                        best = consts[opn]
    return best


def _dot_lhs_dims(op: _Op, name_type: dict[str, str]) -> list[int]:
    """The lhs operand's dims, preferring the shape spelled on the dot's line.

    Fusion-interior dots name region parameters whose types collide across
    computations in the global ``name_type`` map (every fusion calls its
    region arg ``param_0``); the optimized-HLO printer inlines each operand's
    type right on the dot line (``dot(f32[4,8,32]{...} %param_0, ...)``), so
    that spelling — positionally the first shape after the opening paren — is
    authoritative when present.
    """
    paren = op.line.find("(")
    if paren >= 0:
        m = _SHAPE_RE.search(op.line, paren)
        if m:
            return [int(d) for d in m.group(2).split(",") if d]
    if op.operands:
        return _shape_info(name_type.get(op.operands[0], ""))[1]
    return []


def _dot_flops(op: _Op, name_type: dict[str, str]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims).

    The result shape already carries the batch dims once (a batched dot's
    result is [batch..., lhs_free..., rhs_free...]), so only the CONTRACTING
    dims of the lhs multiply in — any index listed in ``lhs_batch_dims`` is
    excluded even if an HLO spelling repeats it in the contracting list,
    which would double-count the batch extent on banked-tick programs.
    """
    rbytes, rdims = _shape_info(op.result_type)
    n_res = 1
    for d in rdims:
        n_res *= d
    batch_idx: set[int] = set()
    mb = re.search(r"lhs_batch_dims=\{([\d,]*)\}", op.line)
    if mb:
        batch_idx = {int(i) for i in mb.group(1).split(",") if i}
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if m:
        ldims = _dot_lhs_dims(op, name_type)
        for i in m.group(1).split(","):
            if i and int(i) < len(ldims) and int(i) not in batch_idx:
                contract *= ldims[int(i)]
    return 2.0 * n_res * contract


def _conv_flops(op: _Op, name_type: dict[str, str]) -> float:
    rbytes, rdims = _shape_info(op.result_type)
    n_res = 1
    for d in rdims:
        n_res *= d
    # kernel spatial*input-feature product
    k = 1
    if len(op.operands) > 1:
        _, kdims = _shape_info(name_type.get(op.operands[1], ""))
        for d in kdims:
            k *= d
        _, odims = _shape_info(name_type.get(op.operands[1], ""))
    return 2.0 * n_res * max(k, 1) / max(rdims[-1] if rdims else 1, 1)


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_ops: dict = dataclasses.field(default_factory=dict)
    collective_operand_bytes: dict = dataclasses.field(default_factory=dict)
    collective_wire_bytes: float = 0.0

    def add_collective(self, kind: str, count: float, obytes: float, wire: float):
        self.collective_ops[kind] = self.collective_ops.get(kind, 0) + count
        self.collective_operand_bytes[kind] = (
            self.collective_operand_bytes.get(kind, 0.0) + obytes
        )
        self.collective_wire_bytes += wire


def analyze_module(text: str, n_devices: int, f32_as_bf16: bool = False) -> HloCosts:
    """Trip-count-aware cost accumulation over the optimized HLO module."""
    comps, entry = parse_hlo(text)

    # global op-name -> result type (operand shapes for dot flops / op bytes)
    name_type: dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            name_type[op.name] = op.result_type

    if not entry:  # fallback: computation not called by anyone
        called_all: set[str] = set()
        for c in comps.values():
            for op in c.ops:
                for attr in ("calls", "to_apply", "body", "condition",
                             "branch_computations", "true_computation",
                             "false_computation"):
                    called_all.update(_called(op, attr))
        cands = [n for n in comps if n not in called_all]
        entry = cands[-1] if cands else next(iter(comps), "")

    costs = HloCosts()
    visiting: set[str] = set()

    def sb(type_str: str) -> int:
        return _shape_info(type_str, f32_as_bf16)[0]

    def comp_flops_only(name: str, mult: float):
        """Count dot flops inside fusion-interior computations."""
        c = comps.get(name)
        if c is None:
            return
        for op in c.ops:
            if op.kind == "dot":
                costs.flops += mult * _dot_flops(op, name_type)
            elif op.kind == "convolution":
                costs.flops += mult * _conv_flops(op, name_type)

    def walk(name: str, mult: float):
        c = comps.get(name)
        if c is None or name in visiting:
            return
        visiting.add(name)

        # ---- materialization pass (TPU-fusion simulation, see module doc) --
        local = {op.name: op for op in c.ops}
        n_consumers: dict[str, int] = defaultdict(int)
        consumer_kind: dict[str, str] = {}
        consumers: dict[str, list[_Op]] = defaultdict(list)
        for op in c.ops:
            for o in set(op.operands):
                if o in local:
                    n_consumers[o] += 1
                    consumer_kind[o] = op.kind
                    consumers[o].append(op)
        root = c.ops[-1].name if c.ops else ""

        def ar_is_reduce_scatter(op: _Op, n: int) -> bool:
            """AR whose consumers all slice <= 1/n of it == TPU reduce-scatter.

            Tuple all-reduces are followed through their get-tuple-elements
            (each component must itself be fully sliced down by 1/n).
            """

            def sliced_down(src_bytes: int, cons: list[_Op]) -> bool:
                if not cons:
                    return False
                for cop in cons:
                    if cop.kind == "get-tuple-element":
                        if not sliced_down(sb(cop.result_type), consumers.get(cop.name, [])):
                            return False
                        continue
                    if sb(cop.result_type) * max(n, 1) > src_bytes + 1:
                        return False
                    if not ("slice" in cop.kind or "slice" in cop.line or cop.kind == "fusion"):
                        return False
                return True

            return sliced_down(sb(op.result_type), consumers.get(op.name, []))

        def materialized(op: _Op) -> bool:
            if op.kind in _SKIP_OPS:
                return False
            if op.kind in _HEAVY_OPS or op.kind == "while":
                return True
            if op.name == root:
                return True  # computation outputs land in memory
            nc = n_consumers.get(op.name, 0)
            if nc != 1:
                return True  # multi-read (or dead: conservative)
            # single consumer: fused through unless consumer needs real operands
            return consumer_kind.get(op.name) in _HEAVY_OPS

        is_mat = {op.name: materialized(op) for op in c.ops}
        override_bytes: dict[str, int] = {}  # RS-reclassified ARs: 1/n size

        for op in c.ops:
            kind = op.kind
            if kind == "while":
                trips = _trip_count(op, comps)
                for b in _called(op, "body"):
                    walk(b, mult * trips)
                continue
            if kind in ("call", "custom-call"):
                for f in _called(op, "to_apply"):
                    walk(f, mult)
                if kind == "call":
                    continue
            if kind == "conditional":
                for attr in ("branch_computations", "true_computation", "false_computation"):
                    for f in _called(op, attr):
                        walk(f, mult)  # upper bound: all branches
                continue
            if kind == "fusion":
                for f in _called(op, "calls"):
                    comp_flops_only(f, mult)
            if kind == "dot":
                costs.flops += mult * _dot_flops(op, name_type)
            elif kind == "convolution":
                costs.flops += mult * _conv_flops(op, name_type)

            # collectives
            ckind = None
            for cc in _COLLECTIVES:
                if kind in (cc, cc + "-start"):
                    ckind = cc
                    break
            if ckind is not None:
                ob = 0
                for o in op.operands:
                    if o in name_type:
                        ob += sb(name_type[o])
                if ob == 0:
                    ob = sb(op.result_type)
                    if ckind == "all-gather":  # result = operand * n
                        ob = ob // max(_group_size(op.line, n_devices), 1)
                n = _group_size(op.line, n_devices)
                if ckind == "all-reduce" and ar_is_reduce_scatter(op, n):
                    ckind = "reduce-scatter"  # what the TPU pipeline emits
                    override_bytes[op.name] = sb(op.result_type) // max(n, 1)
                if ckind == "all-reduce":
                    wire = 2 * ob * (n - 1) / max(n, 1)
                elif ckind == "all-gather":
                    wire = ob * (n - 1)
                elif ckind in ("reduce-scatter", "all-to-all"):
                    wire = ob * (n - 1) / max(n, 1)
                else:
                    wire = ob
                if n > 1:
                    costs.add_collective(ckind, mult, mult * ob, mult * wire)

            # hbm traffic: write if this value materializes; read each
            # materialized operand once (fused-through operands are free —
            # their producer's reads were already charged)
            if kind in _SKIP_OPS:
                continue
            if is_mat.get(op.name, True):
                rw = override_bytes.get(op.name, sb(op.result_type))
            else:
                rw = 0
            rd = 0
            for o in set(op.operands):
                if o in local and not is_mat.get(o, True):
                    continue  # fused through
                if o in name_type:
                    rd += override_bytes.get(o, sb(name_type[o]))
            costs.hbm_bytes += mult * (rw + rd)
        visiting.discard(name)

    if entry:
        walk(entry, 1.0)
    return costs


# ---------------------------------------------------------------------------
# static contract parses (analysis/audit.py rules R1/R3/R4)
# ---------------------------------------------------------------------------
_PARAM_NUM_RE = re.compile(r"parameter\((\d+)\)")
_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')
# one donation entry: {out_index}: (param_number, {param_tuple_path}, kind)
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{[\d,\s]*\},\s*(may-alias|must-alias)\)"
)

HOST_TRANSFER_KINDS = ("infeed", "outfeed", "send", "recv", "send-done", "recv-done")
# custom_call_target substrings that mark a python host callback
# (xla_python_cpu_callback + its FFI variants, io_callback, debug prints)
_CALLBACK_TARGET_MARKS = ("callback", "host")


@dataclasses.dataclass(frozen=True)
class EntryParam:
    """One ENTRY-computation parameter of a compiled module."""

    index: int
    dtype: str  # HLO dtype token ("f32", "s8", ...)
    dims: tuple[int, ...]
    op_name: str  # jax argument path from metadata ("state.params.encoder.w")


def entry_parameters(text: str) -> list[EntryParam]:
    """The ENTRY computation's parameters, with their jax argument paths.

    Fusion-interior computations also contain ``parameter(...)`` ops (their
    region arguments); only the ENTRY computation's parameters correspond to
    the jitted callable's arguments, so everything else is skipped. Note jit
    PRUNES unused arguments (keep_unused=False), so the surviving parameters
    can be a subset of the Python signature. jax stamps each parameter's
    flattened argument path into ``metadata={op_name=...}``, which is what
    maps an HLO parameter back to a donated Python argument (rules R1/R4).
    """
    comps, entry = parse_hlo(text)
    c = comps.get(entry)
    out = []
    for op in c.ops if c else []:
        if op.kind != "parameter":
            continue
        m = _PARAM_NUM_RE.search(op.line)
        if not m:
            continue
        dm = _SHAPE_RE.search(op.result_type)
        nm = _OP_NAME_RE.search(op.line)
        out.append(
            EntryParam(
                index=int(m.group(1)),
                dtype=dm.group(1) if dm else "",
                dims=tuple(int(d) for d in dm.group(2).split(",") if d) if dm else (),
                op_name=nm.group(1) if nm else "",
            )
        )
    return sorted(out, key=lambda p: p.index)


@dataclasses.dataclass(frozen=True)
class IoAlias:
    """One input->output buffer-reuse entry from the module header."""

    output_index: tuple[int, ...]
    param_number: int
    kind: str  # "may-alias" | "must-alias"


def parse_io_aliases(text: str) -> list[IoAlias]:
    """Donation results from the module header's ``input_output_alias``.

    jax lowers ``donate_argnums`` into may-alias entries; XLA silently DROPS
    any entry it cannot honor and falls back to a copy, so the compiled
    header — not the Python decorator — is the ground truth for which
    donated buffers are actually reused in place (rule R1).
    """
    out = []
    for line in text.splitlines():
        if "input_output_alias=" not in line:
            continue
        blob = line.split("input_output_alias=", 1)[1]
        for m in _ALIAS_ENTRY_RE.finditer(blob):
            out.append(
                IoAlias(
                    output_index=tuple(int(d) for d in m.group(1).split(",") if d.strip()),
                    param_number=int(m.group(2)),
                    kind=m.group(3),
                )
            )
        break  # one header per module
    return out


@dataclasses.dataclass(frozen=True)
class HostTransfer:
    """One op crossing the device<->host boundary."""

    computation: str
    kind: str  # HLO opcode ("custom-call" for callbacks)
    target: str  # custom_call_target ("" for raw transfer opcodes)
    op: str  # HLO op name


def host_transfer_ops(text: str) -> list[HostTransfer]:
    """Every op that crosses the device<->host boundary (rule R3).

    Raw transfer opcodes (infeed/outfeed/send/recv) plus custom-calls whose
    target is a python host callback — the form ``jax.pure_callback`` /
    ``io_callback`` / debug prints lower to on CPU.
    """
    comps, _ = parse_hlo(text)
    out = []
    for c in comps.values():
        for op in c.ops:
            if op.kind in HOST_TRANSFER_KINDS:
                out.append(HostTransfer(c.name, op.kind, "", op.name))
            elif op.kind == "custom-call":
                m = re.search(r'custom_call_target="([^"]*)"', op.line)
                target = m.group(1) if m else ""
                if any(s in target.lower() for s in _CALLBACK_TARGET_MARKS):
                    out.append(HostTransfer(c.name, op.kind, target, op.name))
    return out


# ---------------------------------------------------------------------------
# legacy surface (kept for tests / callers): collective_stats + roofline_terms
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CollectiveStats:
    ops: dict
    operand_bytes: dict
    wire_bytes: float

    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())


def collective_stats(hlo_text: str, n_devices: int) -> CollectiveStats:
    c = analyze_module(hlo_text, n_devices)
    return CollectiveStats(
        ops={k: int(v) for k, v in c.collective_ops.items()},
        operand_bytes=c.collective_operand_bytes,
        wire_bytes=c.collective_wire_bytes,
    )


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_wire_bytes_per_dev: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float = 0.0  # 6*N*D (train) / 2*N*D (serve)
    useful_ratio: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(
    flops_per_dev: float,
    hbm_bytes_per_dev: float,
    coll_wire_bytes_per_dev: float,
    model_flops_global: float = 0.0,
    n_devices: int = 1,
) -> Roofline:
    tc = flops_per_dev / PEAK_FLOPS
    tm = hbm_bytes_per_dev / HBM_BW
    tl = coll_wire_bytes_per_dev / ICI_BW
    terms = {"compute": tc, "memory": tm, "collective": tl}
    bottleneck = max(terms, key=terms.get)
    useful = 0.0
    if model_flops_global and flops_per_dev:
        useful = model_flops_global / (flops_per_dev * n_devices)
    return Roofline(
        flops_per_dev=flops_per_dev,
        hbm_bytes_per_dev=hbm_bytes_per_dev,
        coll_wire_bytes_per_dev=coll_wire_bytes_per_dev,
        t_compute=tc,
        t_memory=tm,
        t_collective=tl,
        bottleneck=bottleneck,
        model_flops=model_flops_global,
        useful_ratio=useful,
    )
