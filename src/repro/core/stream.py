"""Slot-based, continuously-batched online model-recovery service.

The paper's headline property is "one setup, then continuous streaming":
configure the pipeline once, then recovery updates flow with no per-step
launch or synchronization overhead (the FPGA dataflow claim). This module is
the serving-system analogue for a FLEET of dynamical-system streams:

- N slots each hold one stream's ring-buffer window, warm-started MERINDA
  params and optimizer state inside ONE shared pytree (SlotState);
- every tick executes a single donated, jit-cached program (``tick``) that
  rolls new observations into every slot's buffer (data/windows.py),
  re-windows and re-normalizes device-side, runs K scan-jitted recovery
  steps per slot via a vmapped train loop, and reads out per-slot
  coefficient estimates + their tick-over-tick delta;
- slots whose coefficient delta falls below threshold are EVICTED and the
  next queued stream is ADMITTED into the freed slot via
  ``dynamic_update_slice`` — the same admission structure as the LM decode
  service in launch/serve.py, applied to model recovery;
- evicted params are kept in a warm-start registry, so a returning stream
  resumes from its previous model instead of a cold init.

``RecoveryService`` is the host-side orchestrator (queue, eviction policy,
warm-start registry); everything numerical stays inside compiled programs.

With a ``mesh`` (built by ``repro.api.compile_plan`` from
``RecoverySpec.mesh_slots``), every SlotState leaf's slot axis is SHARDED
across a ``("slots",)`` device mesh (``shard_slots``): the fused stage makes
per-slot cost uniform, so the even slot split is a balanced shard map and
one service scales past a single chip's VMEM/HBM. The single-device path is
the trivial mesh (``mesh=None``); numerics are identical either way
(tests/test_api.py pins 2-virtual-device parity).

The per-window recovery stage itself is merinda.mr_forward, so the service
inherits the stage-fused dataflow for free: an ``MRConfig(fused=True)``
routes every tick's encode + norm + head through the single fused
kernels/mr_step stage (one dispatch, VMEM-resident hidden state) — the same
code path the engine's epoch scan and serve_mr --fused use. The int8
readout (``readout_theta(..., quant=True)``) serves converged coefficients
through the fused fixed-point stage (kernels/mr_step int8 + PWL: quantized
gate AND head weights) — the paper's serving configuration end to end.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import enum
import functools
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoders
from repro.core.engine import WARMUP_STEPS
from repro.core.merinda import (
    MRConfig,
    MRParams,
    init_mr,
    mr_forward,
    mr_train_step,
)
from repro.data.windows import buffer_stats, n_buffer_windows, roll_buffer, window_views
from repro.optim import adamw_init
from repro.parallel import named_sharding, use_mesh_rules

# Slot-axis sharding rule table for the parallel/ spec resolver: the leading
# (slot) axis of every SlotState leaf shards over the "slots" mesh axis; the
# divisibility fallback in partition_spec replicates any leaf whose slot
# count doesn't divide the mesh, so an odd configuration degrades safely
# instead of forcing GSPMD padding.
SLOT_RULES: dict[str, list[tuple[str, ...]]] = {"slots": [("slots",)]}


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static service configuration (hashable: usable as a jit static arg)."""

    buf_len: int = 160  # ring-buffer length L (observations per slot)
    window: int = 32  # T: window length fed to the encoder
    stride: int = 8  # window stride over the buffer
    chunk: int = 16  # C: new observations ingested per tick
    steps_per_tick: int = 8  # K: optimizer steps per slot per tick (0 = serve-only)
    lr: float = 3e-3
    batch_size: int | None = None  # windows per step (None = all N windows)
    ema: float = 0.9  # smoothing for the per-tick Theta readout
    delta_tol: float = 0.015  # relative coefficient-delta eviction threshold
    min_steps: int = 128  # no eviction before this many optimizer steps
    max_steps: int = 400  # unconditional eviction budget per stream

    def __post_init__(self):
        if self.window > self.buf_len:
            raise ValueError(f"window {self.window} exceeds buf_len {self.buf_len}")
        if self.chunk > self.buf_len:
            # roll_buffer would silently GROW the buffer past buf_len and
            # every static shape downstream (admit, n_windows) would be wrong
            raise ValueError(f"chunk {self.chunk} exceeds buf_len {self.buf_len}")
        if self.stride < 1 or self.chunk < 1:
            raise ValueError("stride and chunk must be >= 1")
        if self.steps_per_tick < 0:
            # 0 is a pure serve/monitor tick: ingest + readout, no training
            raise ValueError("steps_per_tick must be >= 0")

    @property
    def n_windows(self) -> int:
        return n_buffer_windows(self.buf_len, self.window, self.stride)


class SlotState(NamedTuple):
    """One shared pytree for all S slots (every leaf has leading axis S)."""

    params: Any  # MRParams, leaves [S, ...]
    opt: Any  # AdamWState, leaves [S, ...]
    buf_y: jnp.ndarray  # [S, L, n] raw observations (ring buffer)
    buf_u: jnp.ndarray  # [S, L, m] exogenous inputs (m may be 0)
    theta: jnp.ndarray  # [S, n_terms, n] last readout (normalized coords)
    delta: jnp.ndarray  # [S] relative theta change at the last tick
    loss: jnp.ndarray  # [S] last-step reconstruction MSE
    mean: jnp.ndarray  # [S, n] normalization stats FROZEN at admission
    scale: jnp.ndarray  # [S, n]
    steps: jnp.ndarray  # [S] int32 optimizer steps since admission
    active: jnp.ndarray  # [S] bool
    stream_id: jnp.ndarray  # [S] int32 (-1 = empty slot)


def shard_slots(state: SlotState, mesh) -> SlotState:
    """Shard every SlotState leaf's slot axis across ``mesh`` ("slots" axis).

    The fused stage makes per-slot cost uniform, so an even slot split IS the
    balanced shard map — one service then scales past a single chip's
    VMEM/HBM. Placement goes through the ``parallel/`` rule table
    (``named_sharding`` + SLOT_RULES) so the mesh-shim and divisibility
    safety properties apply; the jitted ``tick``/``admit`` programs see the
    sharded pytree as inputs and XLA's SPMD partitioner keeps every per-slot
    computation on the slot's device.
    """

    def put(leaf):
        axes = ("slots",) + (None,) * (leaf.ndim - 1)
        return jax.device_put(leaf, named_sharding(mesh, leaf.shape, axes, SLOT_RULES))

    return jax.tree.map(put, state)


def cold_start(key: jax.Array, cfg: MRConfig) -> tuple[MRParams, Any]:
    """Fresh (params, opt_state) for one admission."""
    params = init_mr(key, cfg)
    return params, adamw_init(params)


def init_slots(key: jax.Array, cfg: MRConfig, scfg: StreamConfig, n_slots: int) -> SlotState:
    """All-empty service state: per-slot fresh params, inactive slots."""
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_slots))
    params = jax.vmap(lambda k: init_mr(k, cfg))(keys)
    opt = jax.vmap(adamw_init)(params)
    n, m = cfg.state_dim, cfg.input_dim
    return SlotState(
        params=params,
        opt=opt,
        buf_y=jnp.zeros((n_slots, scfg.buf_len, n), jnp.float32),
        buf_u=jnp.zeros((n_slots, scfg.buf_len, m), jnp.float32),
        theta=jnp.zeros((n_slots, cfg.n_terms, n), jnp.float32),
        delta=jnp.full((n_slots,), jnp.inf, jnp.float32),
        loss=jnp.full((n_slots,), jnp.inf, jnp.float32),
        mean=jnp.zeros((n_slots, n), jnp.float32),
        scale=jnp.ones((n_slots, n), jnp.float32),
        steps=jnp.zeros((n_slots,), jnp.int32),
        active=jnp.zeros((n_slots,), bool),
        stream_id=jnp.full((n_slots,), -1, jnp.int32),
    )


def _write_slot(tree: Any, slot: jnp.ndarray, one: Any) -> Any:
    """Write one slot's entry (leading axis) across a whole pytree."""

    def wr(full, new):
        new = jnp.asarray(new, full.dtype)
        return jax.lax.dynamic_update_slice_in_dim(full, new[None], slot, axis=0)

    return jax.tree.map(wr, tree, one)


@functools.partial(jax.jit, donate_argnums=(0,))
def admit(
    state: SlotState,
    slot: jnp.ndarray,  # scalar int32 (traced: one program serves all slots)
    stream_id: jnp.ndarray,
    buf_y: jnp.ndarray,  # [L, n] initial history
    buf_u: jnp.ndarray,  # [L, m]
    params: MRParams,  # cold init or warm-start tree (single slot)
    opt: Any,
) -> SlotState:
    """Admit one stream into ``slot`` (dynamic_update_slice across the pytree).

    Normalization stats are computed from the admission history and FROZEN
    for the stream's lifetime: re-estimating them as the buffer slides would
    wobble the coefficient basis under the optimizer every tick (a moving
    target Theta has to chase) and make the EMA readout mix estimates from
    different coordinate systems.
    """
    n_terms, n = state.theta.shape[1:]
    mean, scale = buffer_stats(buf_y)
    return SlotState(
        params=_write_slot(state.params, slot, params),
        opt=_write_slot(state.opt, slot, opt),
        buf_y=_write_slot(state.buf_y, slot, buf_y),
        buf_u=_write_slot(state.buf_u, slot, buf_u),
        theta=_write_slot(state.theta, slot, jnp.zeros((n_terms, n))),
        delta=_write_slot(state.delta, slot, jnp.inf),
        loss=_write_slot(state.loss, slot, jnp.inf),
        mean=_write_slot(state.mean, slot, mean[0]),
        scale=_write_slot(state.scale, slot, scale[0]),
        steps=_write_slot(state.steps, slot, jnp.zeros((), jnp.int32)),
        active=_write_slot(state.active, slot, jnp.ones((), bool)),
        stream_id=_write_slot(state.stream_id, slot, stream_id),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def deactivate(state: SlotState, slot: jnp.ndarray) -> SlotState:
    """Mark a slot empty (no queued stream to admit)."""
    return state._replace(
        active=_write_slot(state.active, slot, jnp.zeros((), bool)),
        stream_id=_write_slot(state.stream_id, slot, jnp.full((), -1, jnp.int32)),
    )


def _slot_windows(buf_y, buf_u, mean, scale, scfg: StreamConfig):
    """Normalize a buffer (frozen admission stats) and window it."""
    yw = window_views((buf_y - mean) / scale, scfg.window, scfg.stride)
    uw = window_views(buf_u, scfg.window, scfg.stride)
    return yw, uw


def _recover_steps(params, opt, yw, uw, key, steps0, *, cfg: MRConfig, scfg: StreamConfig):
    """K optimizer steps on one slot's windows (scan body; vmapped in tick)."""
    n_win = yw.shape[0]
    bs = scfg.batch_size or n_win
    sample = bs < n_win

    def body(carry, j):
        p, o = carry
        if sample:
            sub = jax.random.fold_in(key, j)
            idx = jax.random.randint(sub, (bs,), 0, n_win)
            yb, ub = jnp.take(yw, idx, axis=0), jnp.take(uw, idx, axis=0)
        else:
            yb, ub = yw, uw
        # linear warmup then inverse-sqrt decay: the decay makes the Theta
        # readout settle so the coefficient-delta eviction signal converges
        # (constant lr keeps the estimate jittering above any useful tol)
        frac = (steps0 + j + 1.0) / WARMUP_STEPS
        lr_t = scfg.lr * jnp.minimum(frac, jax.lax.rsqrt(frac))
        p, o, aux = mr_train_step(p, o, cfg, yb, ub, lr_t, None)
        return (p, o), aux["recon_mse"]

    (params, opt), recon = jax.lax.scan(body, (params, opt), jnp.arange(scfg.steps_per_tick))
    theta, _ = mr_forward(params, cfg, yw, uw)
    return params, opt, theta.mean(axis=0), recon[-1]


def _tick_impl(
    state: SlotState,
    new_y: jnp.ndarray,
    new_u: jnp.ndarray,
    key: jax.Array,
    *,
    cfg: MRConfig,
    scfg: StreamConfig,
) -> SlotState:
    """Composite tick body (un-jitted: ``tick`` wraps it; the device-resident
    control-plane program in core/control.py inlines it ahead of the on-device
    eviction/refill section so both paths trace the identical tick math)."""
    buf_y = roll_buffer(state.buf_y, new_y)
    buf_u = roll_buffer(state.buf_u, new_u)
    yw, uw = jax.vmap(lambda y, u, mu, sd: _slot_windows(y, u, mu, sd, scfg))(
        buf_y, buf_u, state.mean, state.scale
    )

    if scfg.steps_per_tick:
        n_slots = buf_y.shape[0]
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_slots))
        params, opt, theta, recon = jax.vmap(
            lambda p, o, y, u, k, s: _recover_steps(p, o, y, u, k, s, cfg=cfg, scfg=scfg)
        )(state.params, state.opt, yw, uw, keys, state.steps)
        loss = jnp.where(state.active, recon, jnp.inf)
    else:
        # serve/monitor tick: no optimizer steps, readout only
        params, opt, loss = state.params, state.opt, state.loss
        theta = jax.vmap(lambda p, y, u: mr_forward(p, cfg, y, u)[0].mean(axis=0))(params, yw, uw)

    # EMA-smoothed readout: the window set (and its normalization) shifts a
    # little every tick, so the raw per-tick Theta jitters even after the
    # model has converged; the EMA is what the delta threshold watches.
    # The first tick after admission seeds the EMA directly (a fresh slot is
    # at step 0 with its delta still at the admission-time inf).
    seed = (state.steps == 0) & jnp.isinf(state.delta)
    theta = jnp.where(
        seed[:, None, None],
        theta,
        scfg.ema * state.theta + (1.0 - scfg.ema) * theta,
    )
    # relative coefficient delta: |Theta| grows toward its asymptote long
    # after the loss plateaus, so an absolute threshold never fires at a
    # scale-free setting — normalize by the current coefficient magnitude
    change = jnp.max(jnp.abs(theta - state.theta), axis=(1, 2))
    delta = change / (jnp.max(jnp.abs(theta), axis=(1, 2)) + 1e-3)
    delta = jnp.where(state.active, delta, jnp.inf)
    return state._replace(
        params=params,
        opt=opt,
        buf_y=buf_y,
        buf_u=buf_u,
        theta=theta,
        delta=delta,
        loss=loss,
        steps=state.steps + scfg.steps_per_tick,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "scfg"), donate_argnums=(0,))
def tick(
    state: SlotState,
    new_y: jnp.ndarray,  # [S, C, n] fresh observations (zeros for idle slots)
    new_u: jnp.ndarray,  # [S, C, m]
    key: jax.Array,
    *,
    cfg: MRConfig,
    scfg: StreamConfig,
) -> SlotState:
    """One service tick: ingest + K recovery steps + readout, for ALL slots.

    A single compiled program (jit-cached across the whole run): ring-buffer
    roll, per-slot re-normalization and windowing, the vmapped K-step train
    scan and the coefficient readout all execute device-side with zero
    per-slot or per-step dispatch — the service-level analogue of the
    paper's "one setup, continuous streaming" pipeline.
    """
    return _tick_impl(state, new_y, new_u, key, cfg=cfg, scfg=scfg)


def pack_status(state: SlotState) -> jnp.ndarray:
    """Pack the per-slot eviction scalars into ONE [S, 4] array
    (``[delta, loss, steps, active]``) so a whole service status costs a
    single host readback — the banked tick and the device-resident control
    plane both return it instead of individual SlotState leaves."""
    return jnp.stack(
        [
            state.delta,
            state.loss,
            state.steps.astype(jnp.float32),
            state.active.astype(jnp.float32),
        ],
        axis=-1,
    )


def _tick_banked_impl(
    state: SlotState,
    new_y: jnp.ndarray,
    new_u: jnp.ndarray,
    key: jax.Array,
    *,
    cfg: MRConfig,
    scfg: StreamConfig,
    quant: bool = False,
    slots_per_bank: int = 1,
) -> tuple[SlotState, jnp.ndarray]:
    """Banked tick body (un-jitted; see ``_tick_impl`` for why it exists)."""
    from repro.kernels.mr_step.tick import mr_tick

    if scfg.steps_per_tick:
        buf_y = roll_buffer(state.buf_y, new_y)
        buf_u = roll_buffer(state.buf_u, new_u)
        yw, uw = jax.vmap(lambda y, u, mu, sd: _slot_windows(y, u, mu, sd, scfg))(
            buf_y, buf_u, state.mean, state.scale
        )
        n_slots = buf_y.shape[0]
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_slots))
        # the in-scan forward readout is unused here (the banked kernel reads
        # out below, from the post-training params) — XLA dead-code-eliminates
        # it, leaving exactly the composite tick's training program
        params, opt, _, recon = jax.vmap(
            lambda p, o, y, u, k, s: _recover_steps(p, o, y, u, k, s, cfg=cfg, scfg=scfg)
        )(state.params, state.opt, yw, uw, keys, state.steps)
        loss = jnp.where(state.active, recon, jnp.inf)
    else:
        params, opt, loss = state.params, state.opt, state.loss

    seed = (state.steps == 0) & jnp.isinf(state.delta)
    buf_y, buf_u, theta, delta = mr_tick(
        params,
        cfg,
        scfg,
        state.buf_y,
        state.buf_u,
        new_y,
        new_u,
        state.mean,
        state.scale,
        state.theta,
        seed,
        state.active,
        quant=quant,
        slots_per_bank=slots_per_bank,
    )
    delta = jnp.where(state.active, delta, jnp.inf)
    steps = state.steps + scfg.steps_per_tick
    state = state._replace(
        params=params,
        opt=opt,
        buf_y=buf_y,
        buf_u=buf_u,
        theta=theta,
        delta=delta,
        loss=loss,
        steps=steps,
    )
    return state, pack_status(state)


@functools.partial(
    jax.jit, static_argnames=("cfg", "scfg", "quant", "slots_per_bank"), donate_argnums=(0,)
)
def tick_banked(
    state: SlotState,
    new_y: jnp.ndarray,  # [S, C, n]
    new_u: jnp.ndarray,  # [S, C, m]
    key: jax.Array,
    *,
    cfg: MRConfig,
    scfg: StreamConfig,
    quant: bool = False,
    slots_per_bank: int = 1,
) -> tuple[SlotState, jnp.ndarray]:
    """Banked one-kernel tick: same contract as ``tick``, plus packed status.

    The training segment (K > 0) is BITWISE the composite tick's — the same
    vmapped ``_recover_steps`` scan — but the whole serving segment (ring
    ingest, window substeps, head, EMA Theta readout, delta) collapses into
    one slot-banked ``mr_tick`` program (kernels/mr_step/tick.py) instead of
    the composite stage sequence. Returns ``(state, status)`` where status
    packs ``[delta, loss, steps, active]`` per slot into one [S, 4] array so
    ``RecoveryService.tick_once`` needs a single host readback per tick.
    ``quant`` serves the readout through the int8/PWL twin (K = 0 monitor
    ticks: the serving configuration).
    """
    return _tick_banked_impl(
        state, new_y, new_u, key, cfg=cfg, scfg=scfg, quant=quant, slots_per_bank=slots_per_bank
    )


def readout_theta(
    params: MRParams,
    cfg: MRConfig,
    yw: jnp.ndarray,  # [N, T, n] normalized windows
    uw: jnp.ndarray | None = None,
    quant: bool = False,
) -> jnp.ndarray:
    """Serving readout: mean-over-windows Theta (normalized coordinates).

    quant=True serves through the stage-FUSED fixed-point step
    (kernels/mr_step int8: int8 cell + head weights with per-channel scales,
    PWL activations; interpret mode off-TPU) — the paper's serving
    configuration as one kernel. Requires an encoder whose cell has a PWL
    mapping: 'gru' (paper Eq. 12-15) or 'ltc' (sigmoid-only substep).
    """
    if not quant:
        theta, _ = mr_forward(params, cfg, yw, uw)
        return theta.mean(axis=0)
    from repro.kernels.mr_step.ops import mr_step_int8

    xs = yw if uw is None or uw.shape[-1] == 0 else jnp.concatenate([yw, uw], axis=-1)
    theta, _ = mr_step_int8(params, cfg, xs, interpret=True)
    return theta.mean(axis=0)


class StreamResult(NamedTuple):
    """Host-side record for one completed stream."""

    stream_id: int
    theta: np.ndarray  # [n_terms, n] normalized coordinates
    mean: np.ndarray  # [n] buffer stats for denormalization
    scale: np.ndarray  # [n]
    steps: int
    reason: str  # "converged" | "budget"


class SubmitStatus(enum.Enum):
    """Typed admission backpressure signal returned by ``submit``.

    ENQUEUED — the stream is queued (host deque or a device shard ring) and
    will be admitted as capacity frees. OVERFLOW — every device ring was
    full; the stream sits in the bounded host-side overflow queue and drains
    into a ring at the next snapshot/fill with free capacity. REJECTED — the
    overflow queue is also full; the caller must retry later (nothing was
    retained). ``submit`` never raises on pressure.
    """

    ENQUEUED = "enqueued"
    OVERFLOW = "overflow"
    REJECTED = "rejected"


class SubmitResult(NamedTuple):
    """What ``submit`` did with one stream (see :class:`SubmitStatus`)."""

    status: SubmitStatus
    stream_id: int
    shard: int | None = None  # device ring the stream landed in (ENQUEUED)

    @property
    def accepted(self) -> bool:
        return self.status is not SubmitStatus.REJECTED


class RecoveryService:
    """Host orchestrator: admission queue, eviction policy, warm-start registry.

    All numerics run inside the compiled ``tick``/``admit`` programs; this
    class only moves O(slots) scalars across the host boundary per tick.

    Two control planes (``control=`` — a ``control.ControlPlane`` record built
    by the plan — selects the device-resident one):

    - **host** (the reference): admission pops a ``collections.deque``,
      eviction decisions read per-slot scalars back each tick and each
      admission runs the ``admit`` program (plus a reshard on a mesh). Kept
      bitwise-stable — the device path is locked against it.
    - **device**: the queue, the eviction mask, the refill and the warm-start
      lookup all live inside ONE donated tick program
      (``control.tick_device``); the host only enqueues arrivals and drains a
      packed status snapshot + event log every ``snapshot_period`` ticks.
      Between arrivals and snapshots a tick is ZERO host readbacks and zero
      reshards (the slot shard is never re-pinned).
    """

    def __init__(
        self,
        cfg: MRConfig,
        scfg: StreamConfig,
        n_slots: int,
        seed: int = 0,
        quant: bool = False,
        mesh=None,
        tick_program=None,
        control=None,
        warm_capacity: int = 32,
        overflow_capacity: int = 16,
    ):
        encoders.validate_config(cfg)  # fused x fusable fails HERE, not mid-trace
        self.cfg, self.scfg, self.n_slots = cfg, scfg, n_slots
        self.quant = quant
        self.mesh = mesh  # jax Mesh over ("slots",) | None = single device
        # Host-boundary accounting for the mesh-scaling work (phase 2 of the
        # ROADMAP multi-device item): every device->host readback is a sync
        # point the sharded service pays ACROSS the mesh, and every re-pin of
        # the slot shard after admission is a reshard. bench_stream reports
        # these per tick so the per-device-admission redesign has a baseline.
        self.counters = {"host_syncs": 0, "reshards": 0}
        # per-tick host-sync deltas (appended by tick_once): the first tick
        # compiles and the eviction/admission ticks read extra scalars, so
        # per-tick attribution lets consumers report a MEDIAN instead of a
        # mean skewed by those outliers (bench_stream mesh rows)
        self.sync_log: list[int] = []
        # the compiled tick: a RecoveryPlan passes its pre-bound program so
        # the service runs EXACTLY what the plan compiled; standalone
        # construction binds the module-level program with this config
        if tick_program is None and control is None:
            from repro.deprecation import warn_deprecated_once

            warn_deprecated_once(
                "stream.RecoveryService",
                "direct RecoveryService(...) construction (and the service-internal "
                "tick jit path it binds) is deprecated; build a "
                "RecoverySpec(mode='stream') and use api.compile_plan(spec)"
                ".make_service() instead — the plan compiles the tick program "
                "(composite or banked, TickSpec.tick_kernel) alongside the others",
            )
        self._tick = tick_program or functools.partial(tick, cfg=cfg, scfg=scfg)
        self.key = jax.random.key(seed)
        self.state = init_slots(self.key, cfg, scfg, n_slots)
        if mesh is not None:
            self.state = shard_slots(self.state, mesh)
        # host admission queue: (stream_id, buf_y, buf_u, priority) entries;
        # pops take the highest tier first, FIFO within a tier (_queue_pop)
        self.queue: collections.deque = collections.deque()
        # bounded host-side spill for device-plane admissions when every
        # shard ring is full; drains back into the rings as capacity frees
        # (fill_slots / snapshot ticks). Beyond this, submit() REJECTs.
        self.overflow: collections.deque = collections.deque()
        self.overflow_capacity = int(overflow_capacity)
        # bounded LRU warm-start registry (stream_id -> evicted params): a
        # long-running service would otherwise accumulate one params tree per
        # stream it has EVER served; beyond capacity the least-recently-used
        # entry is dropped and a returning stream cold-starts
        self.warm: collections.OrderedDict[int, MRParams] = collections.OrderedDict()
        self.warm_capacity = int(warm_capacity)
        self.results: dict[int, StreamResult] = {}
        self.ticks = 0
        # host-side snapshot of the per-slot status, refreshed wherever the
        # status is already being read (fill_slots / tick_once / snapshots) so
        # polling `done`, `drain()` or `slot_streams()` never forces a fresh
        # device->host readback
        self._active_view = np.zeros((n_slots,), bool)
        self._slot_view = np.full((n_slots,), -1, np.int64)
        self._delta_view = np.full((n_slots,), np.inf, np.float32)
        self._loss_view = np.full((n_slots,), np.inf, np.float32)
        self._steps_view = np.zeros((n_slots,), np.int64)
        self._prio_view = np.zeros((n_slots,), np.int64)  # tier per slot
        self._prio_of: dict[int, int] = {}  # stream_id -> submitted tier
        self._undrained: list[StreamResult] = []
        # -- resilience / latency accounting (runtime/resilience.py) ---------
        # per-tick wall latency (ms) + per-shard heartbeats feeding the
        # straggler detector; serve_mr surfaces p50/p99 and the flags.
        # checkpointer is attached by RecoveryPlan.make_service when the
        # TickSpec requests periodic service snapshots.
        from repro.runtime.heartbeat import HeartbeatRegistry, StragglerDetector

        self.tick_ms: list[float] = []
        self.registry = HeartbeatRegistry()
        self.stragglers = StragglerDetector(self.registry)
        self.straggler_flags: list[str] = []
        self.checkpointer = None
        # -- device-resident control plane (control.py) ----------------------
        self.control_plane = control
        self.control = None
        self._pending: set[int] = set()  # submitted, no result yet
        self._seen_done: set[int] = set()  # completed since last resubmission
        self._inflight: list[set[int]] = []  # per-shard: enqueued, not yet admitted
        self._ticks_since_snapshot = 0
        if control is not None:
            from repro.core import control as control_mod

            self.control = control_mod.init_control(
                self.key,
                cfg,
                scfg,
                n_slots,
                shards=control.shards,
                queue_capacity=control.queue_capacity,
                warm_capacity=control.warm_capacity,
                snapshot_period=control.snapshot_period,
            )
            if mesh is not None:
                self.control = control_mod.shard_control(self.control, mesh)
            self._inflight = [set() for _ in range(control.shards)]

    def _mesh_ctx(self):
        """Activate the slot mesh (jax.set_mesh shim via parallel/) around
        every compiled-program call; a no-op on the trivial mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return use_mesh_rules(self.mesh, SLOT_RULES)

    def _host_read(self, leaf) -> np.ndarray:
        """Counted device->host readback (each is one host-sync point; on a
        sharded service it gathers the slot axis across the whole mesh)."""
        self.counters["host_syncs"] += 1
        return np.asarray(leaf)

    def _reshard(self):
        """Re-pin the slot shard after a host-driven state update."""
        self.counters["reshards"] += 1
        self.state = shard_slots(self.state, self.mesh)

    # -- warm-start registry (bounded LRU) ----------------------------------
    def _warm_put(self, stream_id: int, params: MRParams):
        self.warm[stream_id] = params
        self.warm.move_to_end(stream_id)
        while len(self.warm) > self.warm_capacity:
            self.warm.popitem(last=False)

    def _warm_get(self, stream_id: int) -> MRParams | None:
        params = self.warm.get(stream_id)
        if params is not None:
            self.warm.move_to_end(stream_id)
        return params

    # -- admission ----------------------------------------------------------
    def submit(
        self,
        stream_id: int,
        history_y: np.ndarray,
        history_u: np.ndarray | None = None,
        priority: int = 0,
    ) -> SubmitResult:
        """Enqueue a stream with its initial buf_len-observation history.

        On the device control plane the history (and a cold params tree — the
        on-device warm cache overrides it on a hit) is appended straight into
        the least-loaded shard's on-device admission queue; the slot axis is
        never resharded. Returns a typed :class:`SubmitResult` instead of
        raising on pressure: a full shard ring spills into the bounded host
        overflow queue (OVERFLOW), and a full overflow queue REJECTs.

        ``priority`` is the admission tier (0 = default; higher pops first
        and may preempt a cold lower-tier slot under pressure).
        """
        from repro.core.control import PRIORITY_LIMIT

        L, m = self.scfg.buf_len, self.cfg.input_dim
        if history_y.shape != (L, self.cfg.state_dim):
            raise ValueError(f"history must be [{L}, {self.cfg.state_dim}], got {history_y.shape}")
        if not 0 <= priority < PRIORITY_LIMIT:
            raise ValueError(f"priority must be in [0, {PRIORITY_LIMIT}), got {priority}")
        if history_u is None:
            history_u = np.zeros((L, m), np.float32)
        sid = int(stream_id)
        self._prio_of[sid] = int(priority)
        if self.control_plane is None:
            self.queue.append(
                (sid, np.asarray(history_y), np.asarray(history_u), int(priority))
            )
            return SubmitResult(SubmitStatus.ENQUEUED, sid)
        shard = self._enqueue_device(sid, history_y, history_u, int(priority))
        if shard is not None:
            return SubmitResult(SubmitStatus.ENQUEUED, sid, shard)
        if len(self.overflow) >= self.overflow_capacity:
            self._prio_of.pop(sid, None)
            return SubmitResult(SubmitStatus.REJECTED, sid)
        self.overflow.append(
            (sid, np.asarray(history_y), np.asarray(history_u), int(priority))
        )
        self._pending.add(sid)
        self._seen_done.discard(sid)
        return SubmitResult(SubmitStatus.OVERFLOW, sid)

    def _enqueue_device(self, sid, history_y, history_u, priority) -> int | None:
        """Append one arrival into the least-loaded shard ring; None = all full.

        Host-side occupancy accounting is conservative: ``_inflight`` counts
        ids enqueued-but-not-admitted AND preempted-back-to-queue (snapshot
        reconciliation re-adds victims), so the compiled ``enqueue`` can
        never overflow a device queue.
        """
        cp = self.control_plane
        shard = min(range(cp.shards), key=lambda i: (len(self._inflight[i]), i))
        if len(self._inflight[shard]) >= cp.queue_capacity:
            return None
        params, _ = cold_start(jax.random.fold_in(self.key, 1000 + sid), self.cfg)
        with self._mesh_ctx():
            self.control = cp.enqueue(
                self.control,
                jnp.int32(shard),
                jnp.int32(sid),
                jnp.asarray(history_y, jnp.float32),
                jnp.asarray(history_u, jnp.float32),
                params,
                jnp.int32(priority),
            )
        self._inflight[shard].add(sid)
        self._pending.add(sid)
        self._seen_done.discard(sid)
        return shard

    def _drain_overflow(self) -> int:
        """Move overflowed arrivals into shard rings while capacity lasts."""
        moved = 0
        while self.overflow:
            sid, by, bu, prio = self.overflow[0]
            if self._enqueue_device(sid, by, bu, prio) is None:
                break
            self.overflow.popleft()
            moved += 1
        return moved

    def _queue_pop(self) -> tuple[int, np.ndarray, np.ndarray, int]:
        """Pop the host queue entry with the highest tier (FIFO within a
        tier): the host-plane mirror of the device queue's priority-composed
        sort key. ``max`` keeps the first index on ties, which IS the FIFO
        order — all-default-tier traffic reduces to ``popleft``."""
        best = max(range(len(self.queue)), key=lambda i: self.queue[i][3])
        entry = self.queue[best]
        del self.queue[best]
        return entry

    def _admit_into(self, slot: int):
        if not self.queue:
            with self._mesh_ctx():
                self.state = deactivate(self.state, jnp.int32(slot))
            if self.mesh is not None:
                # same propagation hazard as the admit path below: the
                # update mixes in replicated scalars, so re-pin the shard
                self._reshard()
            self._active_view[slot] = False
            self._slot_view[slot] = -1
            self._prio_view[slot] = 0
            return None
        stream_id, buf_y, buf_u, prio = self._queue_pop()
        warm_params = self._warm_get(stream_id)
        if warm_params is not None:
            params = warm_params
            opt = adamw_init(params)
        else:
            params, opt = cold_start(jax.random.fold_in(self.key, 1000 + stream_id), self.cfg)
        with self._mesh_ctx():
            self.state = admit(
                self.state,
                jnp.int32(slot),
                jnp.int32(stream_id),
                jnp.asarray(buf_y),
                jnp.asarray(buf_u),
                params,
                opt,
            )
        if self.mesh is not None:
            # admission mixes replicated single-slot operands into the update;
            # re-pin the slot shard so every later tick sees the same layout
            self._reshard()
        self._active_view[slot] = True
        self._slot_view[slot] = int(stream_id)
        self._delta_view[slot] = np.inf
        self._loss_view[slot] = np.inf
        self._steps_view[slot] = 0
        self._prio_view[slot] = int(prio)
        return stream_id

    def _preempt_host(self):
        """Host-plane mirror of the device preemption pass: while a waiting
        arrival's tier strictly exceeds the lowest-tier COLD active slot
        (``steps < min_steps``), the victim's params go to the warm registry
        and the victim re-enters the queue with its LIVE buffers at its
        original tier, then the arrival is admitted into the freed slot.
        Warm slots (past min_steps) are never preempted — they are about to
        converge and evict on their own. Terminates: each displacement
        strictly raises the resident tier multiset."""
        while self.queue:
            prio = max(e[3] for e in self.queue)
            cold = [
                s
                for s in range(self.n_slots)
                if self._active_view[s] and self._steps_view[s] < self.scfg.min_steps
            ]
            if not cold:
                return
            victim = min(cold, key=lambda s: (self._prio_view[s], s))
            if prio <= self._prio_view[victim]:
                return
            vid = int(self._slot_view[victim])
            st = self.state
            self._warm_put(vid, jax.tree.map(lambda a: a[victim], st.params))
            self.queue.append(
                (
                    vid,
                    self._host_read(st.buf_y[victim]),
                    self._host_read(st.buf_u[victim]),
                    int(self._prio_view[victim]),
                )
            )
            # _admit_into pops by tier, so it picks the arrival we just
            # compared (the re-queued victim sits strictly below it)
            self._admit_into(victim)

    def fill_slots(self) -> list[int]:
        """Bootstrap: admit queued streams into every empty slot.

        Device control plane: one ``pump`` program drains the on-device rings
        into every idle slot, then a snapshot refreshes the host views.
        """
        if self.control_plane is not None:
            self._drain_overflow()
            before = {int(i) for i in self._slot_view if i >= 0}
            with self._mesh_ctx():
                self.state, self.control, status = self.control_plane.pump(
                    self.state, self.control
                )
            self._snapshot(status)
            return [int(i) for i in self._slot_view if i >= 0 and int(i) not in before]
        admitted = []
        active = self._host_read(self.state.active)
        self._active_view = np.asarray(active, bool).copy()
        for s in range(self.n_slots):
            if not active[s] and self.queue:
                sid = self._admit_into(s)
                if sid is not None:
                    admitted.append(sid)
        return admitted

    # -- the tick loop ------------------------------------------------------
    def slot_streams(self) -> list[int]:
        """stream_id per slot (-1 = empty); the driver feeds chunks by this.

        Host path: a per-call device readback (the reference data router).
        Device path: the cached snapshot view — no readback; between
        snapshots the map is as fresh as the last snapshot tick.
        """
        if self.control_plane is not None:
            return [int(i) for i in self._slot_view]
        return [int(i) for i in self._host_read(self.state.stream_id)]

    def _evict(self, slot: int, reason: str) -> StreamResult:
        st = self.state
        sid = int(self._host_read(st.stream_id[slot]))
        theta = st.theta[slot]
        if self.quant:
            yw, uw = _slot_windows(
                st.buf_y[slot], st.buf_u[slot], st.mean[slot], st.scale[slot], self.scfg
            )
            slot_params = jax.tree.map(lambda a: a[slot], st.params)
            theta = readout_theta(slot_params, self.cfg, yw, uw, quant=True)
        res = StreamResult(
            stream_id=sid,
            theta=self._host_read(theta),
            mean=self._host_read(st.mean[slot]),
            scale=self._host_read(st.scale[slot]),
            steps=int(self._host_read(st.steps[slot])),
            reason=reason,
        )
        self.results[sid] = res
        self._undrained.append(res)
        self._warm_put(sid, jax.tree.map(lambda a: a[slot], st.params))
        return res

    def _snapshot(self, status) -> list[StreamResult]:
        """Device control plane: refresh the host views from the packed
        [S, 5] status and drain the on-device event log into StreamResults.

        The ONLY device->host readbacks on the device path happen here — two
        per snapshot (status + event log), every ``snapshot_period`` ticks.
        """
        from repro.core import control as control_mod

        cp = self.control_plane
        prev_slots = self._slot_view.copy()
        snap = self._host_read(status)
        self._delta_view = snap[:, 0].copy()
        self._loss_view = snap[:, 1].copy()
        self._steps_view = snap[:, 2].astype(np.int64)
        self._active_view = snap[:, 3] > 0
        self._slot_view = snap[:, 4].astype(np.int64)
        for s in range(self.n_slots):
            sid = int(self._slot_view[s])
            self._prio_view[s] = self._prio_of.get(sid, 0) if sid >= 0 else 0
        with self._mesh_ctx():
            self.control, events = cp.drain(self.control)
        new_results = []
        for sid, steps, code, theta, mean, scale in control_mod.decode_events(
            self._host_read(events), self.cfg
        ):
            res = StreamResult(
                stream_id=sid,
                theta=theta,
                mean=mean,
                scale=scale,
                steps=steps,
                reason="converged" if code == 1 else "budget",
            )
            self.results[sid] = res
            self._undrained.append(res)
            self._pending.discard(sid)
            self._seen_done.add(sid)
            new_results.append(res)
        # an enqueued id leaves its shard's in-flight set once the snapshot
        # shows it admitted (slot view) or already completed (event log); an
        # id that WAS resident and is now neither resident nor completed was
        # preempted back into its shard's queue — re-count it in-flight so
        # the host-side occupancy bound stays conservative
        resident = {int(i) for i in self._slot_view if i >= 0}
        slots_per_shard = self.n_slots // cp.shards
        for s in range(self.n_slots):
            sid = int(prev_slots[s])
            if sid >= 0 and sid not in resident and sid not in self._seen_done:
                self._inflight[s // slots_per_shard].add(sid)
        settled = resident | self._seen_done
        for shard_ids in self._inflight:
            shard_ids.difference_update(settled)
        self._ticks_since_snapshot = 0
        self._drain_overflow()
        return new_results

    def tick_once(self, chunks_y: np.ndarray, chunks_u: np.ndarray | None = None) -> dict:
        """Advance the service one tick; returns an info dict of host scalars.

        Device control plane: ONE donated program runs tick + eviction mask +
        queue refill + warm-start gather; the host reads nothing back except
        at snapshot ticks (every ``snapshot_period``), so ``sync_log`` records
        0 for steady-state ticks. Between snapshots the info dict serves the
        cached (snapshot-stale) views.
        """
        t0 = time.perf_counter()
        syncs0 = self.counters["host_syncs"]
        S, C, m = self.n_slots, self.scfg.chunk, self.cfg.input_dim
        if chunks_u is None:
            chunks_u = np.zeros((S, C, m), np.float32)
        if self.control_plane is not None:
            cp = self.control_plane
            with self._mesh_ctx():
                self.state, self.control, status = cp.tick(
                    self.state,
                    self.control,
                    jnp.asarray(chunks_y, jnp.float32),
                    jnp.asarray(chunks_u, jnp.float32),
                    jax.random.fold_in(self.key, self.ticks),
                )
            self.ticks += 1
            self._ticks_since_snapshot += 1
            evicted: list[StreamResult] = []
            if self._ticks_since_snapshot >= cp.snapshot_period:
                evicted = self._snapshot(status)
            info = {
                "tick": self.ticks,
                "evicted": evicted,
                "active": int(self._active_view.sum()),
                "delta": self._delta_view,
                "loss": self._loss_view,
                "steps": self._steps_view,
            }
            # checkpoint before closing the sync window so a snapshot tick's
            # staging readbacks land in THIS tick's sync_log delta (honest
            # per-tick attribution; period=0 keeps steady state untouched)
            if self.checkpointer is not None:
                self.checkpointer.after_tick(self)
            self._finish_tick(t0)
            self.sync_log.append(self.counters["host_syncs"] - syncs0)
            return info
        with self._mesh_ctx():
            out = self._tick(
                self.state,
                jnp.asarray(chunks_y, jnp.float32),
                jnp.asarray(chunks_u, jnp.float32),
                jax.random.fold_in(self.key, self.ticks),
            )
        self.ticks += 1
        # kernel-path-aware sync accounting: the banked tick returns (state,
        # status) with every per-slot scalar packed into ONE array, so the
        # whole eviction scan costs a single host readback; the composite
        # tick reads each SlotState leaf separately (the 5.17-syncs/tick
        # baseline of the ROADMAP device-resident-control-plane item).
        banked = not isinstance(out, SlotState)
        loss = None
        if banked:
            self.state, status = out
            snap = self._host_read(status)
            delta, loss = snap[:, 0], snap[:, 1]
            steps, active = snap[:, 2].astype(np.int64), snap[:, 3] > 0
        else:
            self.state = out
            delta = self._host_read(self.state.delta)
            steps = self._host_read(self.state.steps)
            active = self._host_read(self.state.active)
        self._active_view = np.asarray(active, bool).copy()
        self._delta_view = np.asarray(delta).copy()
        if banked:
            self._loss_view = np.asarray(loss).copy()
        self._steps_view = np.asarray(steps, np.int64)
        evicted = []
        for s in range(S):
            if not active[s]:
                continue
            converged = steps[s] >= self.scfg.min_steps and delta[s] <= self.scfg.delta_tol
            budget = steps[s] >= self.scfg.max_steps
            if converged or budget:
                res = self._evict(s, "converged" if converged else "budget")
                evicted.append(res)
                self._admit_into(s)
        # under pressure a higher-tier waiting arrival may displace a cold
        # lower-tier slot (the host mirror of the device preemption pass)
        self._preempt_host()
        # eviction/admission updated the cached view in place, so the active
        # count never needs a second device readback (the polling-side fix:
        # `done` and `drain()` read the same host-side view)
        if not banked:
            self._loss_view = np.array(self._host_read(self.state.loss))
        info = {
            "tick": self.ticks,
            "evicted": evicted,
            "active": int(self._active_view.sum()),
            "delta": delta,
            "loss": self._loss_view,
            "steps": steps,
        }
        if self.checkpointer is not None:
            self.checkpointer.after_tick(self)
        self._finish_tick(t0)
        self.sync_log.append(self.counters["host_syncs"] - syncs0)
        return info

    def _finish_tick(self, t0: float):
        """Latency accounting: per-tick wall ms, one heartbeat per shard
        (host path beats a single logical worker), straggler re-check."""
        dt = time.perf_counter() - t0
        self.tick_ms.append(dt * 1e3)
        n_workers = len(self._inflight) or 1
        for i in range(n_workers):
            self.registry.beat(f"shard{i}", self.ticks, dt)
        self.straggler_flags = self.stragglers.check()

    def drain(self) -> list[StreamResult]:
        """Completed-stream results accumulated since the last drain.

        Pure host-side bookkeeping (results land here at eviction on the host
        path, at snapshot ticks on the device path) — polling it never costs
        a device readback.
        """
        out, self._undrained = self._undrained, []
        return out

    @property
    def done(self) -> bool:
        """True when no stream is queued, running or awaiting a result.

        Served from the cached status views (host path) or the pending set
        (device path) — polling `done` in a serve loop is readback-free; it
        used to force a `_host_read(state.active)` per call.
        """
        if self.control_plane is not None:
            return not self._pending
        return not self.queue and not bool(self._active_view.any())
