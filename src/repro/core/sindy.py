"""SINDY baseline: sequential thresholded least squares (STLSQ).

The paper compares MERINDA against SINDY (Table 5; refs [12, 18]). Given a
trajectory X[t] (and inputs U[t]) we estimate derivatives, build the monomial
library Theta(X, U), and solve the sparse regression

    dX/dt = Theta(X, U) @ Xi

with ridge-regularized least squares + hard thresholding (Brunton et al.).
Pure JAX: the active-set mask is carried through a fixed number of STLSQ
rounds with masked ridge solves, so the whole fit jits.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.library import polynomial_features


class SindyFit(NamedTuple):
    coef: jnp.ndarray  # [n_terms, n_state]
    mask: jnp.ndarray  # [n_terms, n_state] bool active set
    residual: jnp.ndarray  # scalar: ||dX - Theta @ coef||^2 / N


def finite_difference(x: jnp.ndarray, dt: float) -> jnp.ndarray:
    """2nd-order central differences (one-sided at the ends). x: [T, n]."""
    dxdt = jnp.gradient(x, dt, axis=0)
    return dxdt


def _masked_ridge(
    theta: jnp.ndarray, dx: jnp.ndarray, mask: jnp.ndarray, lam: float
) -> jnp.ndarray:
    """Solve min ||Theta_masked w - dx||^2 + lam ||w||^2 per state dim.

    Masking is done by zeroing columns; the ridge term keeps the normal
    equations well-posed even with zeroed (inactive) columns, whose solution
    coefficients are then re-zeroed by the mask.
    """
    n_terms = theta.shape[1]

    def solve_one(mask_col, dx_col):
        th = theta * mask_col[None, :]  # zero inactive columns
        gram = th.T @ th + lam * jnp.eye(n_terms, dtype=theta.dtype)
        rhs = th.T @ dx_col
        w = jnp.linalg.solve(gram, rhs)
        return w * mask_col

    return jax.vmap(solve_one, in_axes=(1, 1), out_axes=1)(mask, dx)


@partial(jax.jit, static_argnames=("n_iters",))
def stlsq(
    theta: jnp.ndarray,
    dx: jnp.ndarray,
    threshold: float = 0.1,
    lam: float = 1e-5,
    n_iters: int = 10,
) -> SindyFit:
    """STLSQ on precomputed features. theta: [N, n_terms], dx: [N, n_state]."""
    n_terms, n_state = theta.shape[1], dx.shape[1]
    mask0 = jnp.ones((n_terms, n_state), dtype=theta.dtype)

    def body(mask, _):
        coef = _masked_ridge(theta, dx, mask, lam)
        mask = (jnp.abs(coef) >= threshold).astype(theta.dtype)
        return mask, None

    mask, _ = jax.lax.scan(body, mask0, None, length=n_iters)
    coef = _masked_ridge(theta, dx, mask, lam)
    resid = jnp.mean((theta @ coef - dx) ** 2)
    return SindyFit(coef=coef, mask=mask.astype(bool), residual=resid)


def fit_sindy(
    x: jnp.ndarray,
    dt: float,
    order: int = 2,
    u: jnp.ndarray | None = None,
    threshold: float = 0.1,
    lam: float = 1e-5,
    n_iters: int = 10,
) -> SindyFit:
    """End-to-end SINDY: derivatives -> library -> STLSQ.

    x: [T, n_state]; u: optional [T, m] exogenous inputs appended to the
    library variables (SINDYc-style).
    """
    dx = finite_difference(x, dt)
    z = x if u is None else jnp.concatenate([x, u], axis=-1)
    theta = polynomial_features(z, z.shape[-1], order)
    return stlsq(theta, dx, threshold=threshold, lam=lam, n_iters=n_iters)


def sindy_dynamics(order: int):
    """Return f(y, u, t, coef) evaluating the recovered model — for SOLVE()."""

    def f(y, u, t, coef):
        z = y if u is None or u.shape[-1] == 0 else jnp.concatenate([y, u], axis=-1)
        feats = polynomial_features(z, z.shape[-1], order)
        return feats @ coef

    return f
