"""Fixed-point emulation + piecewise-linear activations (LUT analogue).

The paper's FPGA design uses ap_fixed arithmetic (8-16 b activations,
12-16 b weights/accumulators) and single-cycle LUT/ROM tables for sigmoid and
tanh. On TPU we adapt, not port:

- fixed-point Qm.n  ->  symmetric integer fake-quant with a straight-through
  estimator (training) and true int8 weight storage + per-channel scales for
  the serving kernel path (kernels/gru_scan int8 variant);
- LUT activation    ->  piecewise-linear table evaluated as gather + FMA on
  the VPU. ``pwl_table`` precomputes the segment slopes/intercepts exactly the
  way the FPGA ROM would be initialized, and ``pwl_apply`` is branch-free.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# fixed-point fake quantization
# ---------------------------------------------------------------------------
def quantize_fixed(x: jnp.ndarray, int_bits: int, frac_bits: int) -> jnp.ndarray:
    """Round to Q(int_bits).(frac_bits) two's-complement grid (saturating)."""
    scale = jnp.asarray(2.0**frac_bits, x.dtype)
    lo = -(2.0 ** (int_bits + frac_bits - 1))
    hi = 2.0 ** (int_bits + frac_bits - 1) - 1
    q = jnp.clip(jnp.round(x * scale), lo, hi)
    return q / scale


def fake_quant_ste(x: jnp.ndarray, int_bits: int, frac_bits: int) -> jnp.ndarray:
    """Fake-quant with straight-through gradient (for quantization-aware MR)."""
    q = quantize_fixed(x, int_bits, frac_bits)
    return x + jax.lax.stop_gradient(q - x)


class Int8Quantized(NamedTuple):
    values: jnp.ndarray  # int8
    scale: jnp.ndarray  # per-channel (last dim) float scale


def quantize_int8(w: jnp.ndarray, axis: int = -1) -> Int8Quantized:
    """Symmetric per-channel int8 — the weight format of the serving kernel."""
    amax = jnp.max(
        jnp.abs(w), axis=tuple(d for d in range(w.ndim) if d != axis % w.ndim), keepdims=True
    )
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return Int8Quantized(values=q, scale=scale.astype(jnp.float32))


def dequantize_int8(q: Int8Quantized, dtype=jnp.float32) -> jnp.ndarray:
    return q.values.astype(dtype) * q.scale.astype(dtype)


# ---------------------------------------------------------------------------
# piecewise-linear activation tables (the LUT/ROM analogue)
# ---------------------------------------------------------------------------
class PWLTable(NamedTuple):
    x_min: float
    x_max: float
    slopes: jnp.ndarray  # [n_segments]
    intercepts: jnp.ndarray  # [n_segments]
    left: float  # saturation value below x_min
    right: float  # saturation value above x_max


def pwl_table(
    fn: Callable[[np.ndarray], np.ndarray],
    x_min: float,
    x_max: float,
    n_segments: int = 64,
) -> PWLTable:
    """Build the PWL ROM contents for an elementwise function.

    Segments are uniform (address = high bits of the fixed-point input, as in
    the FPGA LUT); slope/intercept per segment interpolate fn exactly at the
    knots, so max error is the second-order remainder within a segment.
    """
    knots = np.linspace(x_min, x_max, n_segments + 1)
    y = fn(knots)
    slopes = (y[1:] - y[:-1]) / (knots[1:] - knots[:-1])
    intercepts = y[:-1] - slopes * knots[:-1]
    return PWLTable(
        x_min=float(x_min),
        x_max=float(x_max),
        slopes=jnp.asarray(slopes, jnp.float32),
        intercepts=jnp.asarray(intercepts, jnp.float32),
        left=float(y[0]),
        right=float(y[-1]),
    )


def pwl_apply(table: PWLTable, x: jnp.ndarray) -> jnp.ndarray:
    """Branch-free PWL evaluation: segment gather + one FMA (VPU-friendly)."""
    n = table.slopes.shape[0]
    width = (table.x_max - table.x_min) / n
    idx = jnp.clip(((x - table.x_min) / width).astype(jnp.int32), 0, n - 1)
    y = table.slopes[idx] * x + table.intercepts[idx]
    y = jnp.where(x < table.x_min, table.left, y)
    y = jnp.where(x > table.x_max, table.right, y)
    return y.astype(x.dtype)


def _np_sigmoid(x: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-x))


def make_sigmoid_table(n_segments: int = 64) -> PWLTable:
    return pwl_table(_np_sigmoid, -8.0, 8.0, n_segments)


def make_tanh_table(n_segments: int = 64) -> PWLTable:
    return pwl_table(np.tanh, -4.0, 4.0, n_segments)


def pwl_max_error(
    table: PWLTable, fn: Callable[[np.ndarray], np.ndarray], n_probe: int = 20001
) -> float:
    xs = np.linspace(table.x_min, table.x_max, n_probe)
    approx = np.asarray(pwl_apply(table, jnp.asarray(xs, jnp.float32)))
    return float(np.max(np.abs(approx - fn(xs))))


class QuantConfig(NamedTuple):
    """Accuracy-budgeted widths (paper: 8-16b act, 12-16b weight/accum)."""

    act_int_bits: int = 3
    act_frac_bits: int = 13  # 16-bit activations
    weight_int_bits: int = 2
    weight_frac_bits: int = 12  # 14-bit weights
    pwl_segments: int = 64

    @property
    def act_bits(self) -> int:
        return self.act_int_bits + self.act_frac_bits

    @property
    def weight_bits(self) -> int:
        return self.weight_int_bits + self.weight_frac_bits


def qat_weight(w: jnp.ndarray, quant: QuantConfig | None) -> jnp.ndarray:
    """The one QAT weight treatment (shared by merinda, encoders, mr_step)."""
    if quant is None:
        return w
    return fake_quant_ste(w, quant.weight_int_bits, quant.weight_frac_bits)


def qat_act(x: jnp.ndarray, quant: QuantConfig | None) -> jnp.ndarray:
    """The one QAT activation treatment (see qat_weight)."""
    if quant is None:
        return x
    return fake_quant_ste(x, quant.act_int_bits, quant.act_frac_bits)
