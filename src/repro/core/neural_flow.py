"""GRU neural-flow cell — the paper's high-level substitution (Fig. 1 right).

Following neural-flow theory (Bilos et al. [11]) the NODE layer's solution
operator F(t, u) is approximated by a *single* gated update per time step,
subject to the flow conditions (paper Eq. 4):

    F(0, u) = Z(0, u)    (identity at t=0)  and  F invertible.

We implement two cells:

1. ``gru_cell``      — the standard GRU used by the hardware pipeline
                       (paper Eqs. 12-15); this is what the Pallas kernel
                       (kernels/gru_scan) accelerates.
2. ``gru_flow_cell`` — the flow-corrected variant: the update is scaled by a
                       time gate phi(dt) with phi(0) = 0 (so F(0) = identity)
                       and contracted by alpha < 1/2 (Lipschitz < 1 =>
                       h + alpha*g(h) is invertible, Bilos Prop. 2). The dense
                       layer that approximates F^{-1} lives in merinda.py.

Both share one parameter layout so kernels and reference paths interchange.
The three gate affines are stored *fused* ([D+H, 3H]) — the TPU analogue of
the paper's banked-BRAM layout: one wide GEMM per step feeds all MAC lanes.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INV_LIPSCHITZ_ALPHA = 0.4  # 2/5, Bilos et al. — keeps the flow invertible


class GRUParams(NamedTuple):
    # fused gate weights: columns ordered [reset | update | candidate]
    w: jnp.ndarray  # [d_in + hidden, 3*hidden]
    b: jnp.ndarray  # [3*hidden]
    time_scale: jnp.ndarray  # [hidden] log-scale of the time gate phi

    @property
    def hidden(self) -> int:
        return self.w.shape[1] // 3

    @property
    def d_in(self) -> int:
        return self.w.shape[0] - self.hidden


def init_gru(key: jax.Array, d_in: int, hidden: int, dtype=jnp.float32) -> GRUParams:
    k1, k2 = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(d_in + hidden)
    w = (jax.random.normal(k1, (d_in + hidden, 3 * hidden)) * scale).astype(dtype)
    return GRUParams(
        w=w,
        b=jnp.zeros((3 * hidden,), dtype),
        time_scale=jnp.zeros((hidden,), dtype),  # phi(dt) = tanh(softplus(ts)*dt)
    )


def _gates(params: GRUParams, x: jnp.ndarray, h: jnp.ndarray):
    """Fused gate computation: one wide GEMM + one candidate GEMM.

    Returns (r, z, c). The candidate requires r (x) h, so the fused weight
    matrix is consumed in two MXU passes: [x,h]@W[:, :2H] then [x, r*h]@W[:, 2H:].
    """
    hidden = params.hidden
    xh = jnp.concatenate([x, h], axis=-1)
    rz = xh @ params.w[:, : 2 * hidden] + params.b[: 2 * hidden]
    r = jax.nn.sigmoid(rz[..., :hidden])
    z = jax.nn.sigmoid(rz[..., hidden:])
    xrh = jnp.concatenate([x, r * h], axis=-1)
    c = jnp.tanh(xrh @ params.w[:, 2 * hidden :] + params.b[2 * hidden :])
    return r, z, c


def gru_cell(params: GRUParams, x: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Standard GRU step (paper Eq. 15): h' = (1-z) (x) c + z (x) h."""
    _, z, c = _gates(params, x, h)
    return (1.0 - z) * c + z * h


def gru_flow_cell(
    params: GRUParams, x: jnp.ndarray, h: jnp.ndarray, dt: jnp.ndarray | float
) -> jnp.ndarray:
    """Flow step: h' = h + phi(dt) * alpha * (1-z) (x) (c - h).

    phi(dt) = tanh(softplus(time_scale) * dt) satisfies phi(0)=0 elementwise,
    so F(0) = identity; |phi*alpha*(1-z)| < 1/2 keeps the residual map a
    contraction => invertible flow (initial condition + invertibility, Eq. 4).
    This is exactly paper Eq. 11 rearranged, with the time gate inserted.
    """
    _, z, c = _gates(params, x, h)
    dt = jnp.asarray(dt, dtype=h.dtype)
    phi = jnp.tanh(jax.nn.softplus(params.time_scale) * dt)
    return h + phi * INV_LIPSCHITZ_ALPHA * (1.0 - z) * (c - h)


def gru_scan_ref(
    params: GRUParams,
    xs: jnp.ndarray,
    h0: jnp.ndarray,
    dts: jnp.ndarray | None = None,
    flow: bool = True,
    unroll: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference sequence scan (pure lax.scan). xs: [B, T, D] -> (h_T, hs [B,T,H]).

    This is the oracle the Pallas kernel (kernels/gru_scan) is tested
    against. ``unroll`` is the window-scan unroll factor handed to lax.scan —
    a pure lowering knob the measured-cost autotuner searches over (the GRU
    families have no substep loop, so the window scan is their only one).
    """
    T = xs.shape[1]
    if dts is None:
        dts = jnp.ones((T,), dtype=xs.dtype)

    def body(h, inp):
        x_t, dt_t = inp
        h = gru_flow_cell(params, x_t, h, dt_t) if flow else gru_cell(params, x_t, h)
        return h, h

    h_final, hs = jax.lax.scan(body, h0, (jnp.swapaxes(xs, 0, 1), dts), unroll=unroll)
    return h_final, jnp.swapaxes(hs, 0, 1)


def gru_op_counts(d_in: int, hidden: int, batch: int = 1) -> dict:
    """Per-time-step op counts — compare with ltc.ltc_op_counts: no sub-steps."""
    macs = batch * (d_in + hidden) * 3 * hidden
    elementwise = batch * hidden * 10
    return {"macs": macs, "elementwise": elementwise, "sequential_depth": 1}
