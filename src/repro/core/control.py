"""Device-resident control plane: zero-readback service ticks.

The host-queue ``RecoveryService`` (core/stream.py) pays the paper's exact
anti-pattern on every tick: admission/eviction decisions round-trip the host
(5 readbacks/tick on the composite path) and every admission re-pins the slot
shard (a full reshard on a mesh). This module moves the whole control plane
into the compiled program, so a steady-state service tick is ONE donated,
collective-free program with ZERO host readbacks:

- **per-shard admission queues** — a fixed-capacity compact queue of pending
  stream histories + cold-start params held in the :class:`ControlState`
  pytree (leading axis = shard, sharded over the same ``("slots",)`` mesh
  axis as SlotState). ``enqueue`` appends one arrival with
  ``dynamic_update_slice``; the slot axis is never resharded. Each entry
  carries a PRIORITY TIER: admission pops highest tier first (stable FIFO
  within a tier), and an arrival still waiting after every idle slot fills
  may preempt a cold (``steps < min_steps``) strictly-lower-tier slot — the
  victim re-enqueues at the tail with its live buffers and params, so
  pressure reorders work but never drops a stream.
- **on-device eviction** — ``tick_device`` runs the (composite or banked)
  tick body, derives the eviction mask from the post-tick
  ``[delta, loss, steps, active]`` scalars inside the program, and appends
  one fixed-width event record per evicted stream to an on-device log.
- **in-program refill** — freed slots pop the shard-local ring in slot order
  (a cumsum prefix-rank turns multi-pop/multi-push into one vectorized
  scatter/gather; no per-slot program launches).
- **device-side warm start** — evicted params are pushed into a bounded
  on-device ring cache keyed by stream id; admission gathers from it and
  falls back to the enqueued cold-start tree on a miss. The host dict never
  sits on the hot path.
- **periodic snapshot** — the host drains the packed status + event log every
  ``snapshot_period`` ticks (``drain_events``); between arrivals and
  snapshots ``RecoveryService.sync_log`` records 0.

Everything per-shard is shard-LOCAL: the [S] slot axis reshapes to
[shards, slots_per_shard], the control step vmaps over the shard axis, and no
operation contracts or permutes across shards — the predicted collective
census of the sharded control plane stays EMPTY
(``parallel.rules.predict_tick_collectives``; audit rule R5 enforces it on
the compiled HLO, R3 pins zero host transfers).

Parity with the host path (pinned by tests/test_tick.py): at mesh 1 the
single shard queue IS the host deque (slot-order pops), admission stats /
cold params / opt reinit reproduce ``stream.admit`` + ``adamw_init``
exactly, and eviction uses the same converged/budget predicate — randomized
traffic through both planes yields identical slot occupancy and Θ. The one
documented divergence: within a tick the device plane publishes ALL warm
evictions before ANY admission (the host interleaves per slot), visible only
if a stream is simultaneously running and queued — which admission dedup
upstream never produces.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.merinda import MRConfig, init_mr
from repro.core.stream import (
    SLOT_RULES,
    SlotState,
    StreamConfig,
    _tick_banked_impl,
    _tick_impl,
    pack_status,
)
from repro.data.windows import buffer_stats
from repro.parallel import named_sharding
from repro.parallel.rules import constraint


#: exclusive upper bound on admission priorities (int32 sort keys compose
#: priority with queue position / slot index, so the tier space is bounded)
PRIORITY_LIMIT = 1 << 16


class ControlState(NamedTuple):
    """On-device control plane for all shards (every leaf leads with M).

    M = shards, Q = queue capacity, W = warm-cache capacity, E = event-log
    capacity (slots_per_shard * (snapshot_period + 1): at most one eviction
    per slot per tick, drained every snapshot_period ticks, so the log can
    never overflow between drains).

    The queue is COMPACT, not a ring: pending entries always occupy indices
    ``[0, q_len)`` (enqueue appends at ``q_len``, the control step re-packs
    survivors to the front after popping). A head cursor can't express
    priority-ordered pops — the popped set is an arbitrary subset of the
    pending window — so compaction replaces it.
    """

    q_ids: jnp.ndarray  # [M, Q] int32 pending stream ids (-1 = empty)
    q_buf_y: jnp.ndarray  # [M, Q, L, n] pending admission histories
    q_buf_u: jnp.ndarray  # [M, Q, L, m]
    q_params: Any  # MRParams, leaves [M, Q, ...] (cold-start fallback)
    q_prio: jnp.ndarray  # [M, Q] int32 admission priority tier (0 = default)
    q_len: jnp.ndarray  # [M] int32 pending count
    w_ids: jnp.ndarray  # [M, W] int32 warm-cache keys (-1 = empty)
    w_params: Any  # MRParams, leaves [M, W, ...] evicted params
    w_pos: jnp.ndarray  # [M] int32 warm-ring cursor
    ev_log: jnp.ndarray  # [M, E, R] f32 eviction events (id < 0 = empty)
    ev_len: jnp.ndarray  # [M] int32 events since the last drain
    s_prio: jnp.ndarray  # [M, P] int32 priority of the stream in each slot


def event_record_width(cfg: MRConfig) -> int:
    """Event record: [stream_id, steps, reason, theta.flat, mean, scale].

    All packed as f32 — stream ids and step counts stay < 2^24, exactly
    representable — so one [E, R] array carries every per-eviction result
    a host StreamResult needs and the snapshot drains them in ONE readback.
    """
    n = cfg.state_dim
    return 3 + cfg.n_terms * n + 2 * n


def init_control(
    key: jax.Array,
    cfg: MRConfig,
    scfg: StreamConfig,
    n_slots: int,
    *,
    shards: int,
    queue_capacity: int,
    warm_capacity: int,
    snapshot_period: int,
) -> ControlState:
    """All-empty control state (ring cursors at 0, ids at -1)."""
    if n_slots % shards:
        raise ValueError(f"n_slots ({n_slots}) must divide over {shards} shard(s)")
    M, Q, W = shards, queue_capacity, warm_capacity
    E = (n_slots // shards) * (snapshot_period + 1)
    n, m, L = cfg.state_dim, cfg.input_dim, scfg.buf_len
    template = init_mr(key, cfg)

    def zeros_like_tree(prefix):
        return jax.tree.map(lambda leaf: jnp.zeros(prefix + leaf.shape, leaf.dtype), template)

    return ControlState(
        q_ids=jnp.full((M, Q), -1, jnp.int32),
        q_buf_y=jnp.zeros((M, Q, L, n), jnp.float32),
        q_buf_u=jnp.zeros((M, Q, L, m), jnp.float32),
        q_params=zeros_like_tree((M, Q)),
        q_prio=jnp.zeros((M, Q), jnp.int32),
        q_len=jnp.zeros((M,), jnp.int32),
        w_ids=jnp.full((M, W), -1, jnp.int32),
        w_params=zeros_like_tree((M, W)),
        w_pos=jnp.zeros((M,), jnp.int32),
        ev_log=jnp.full((M, E, event_record_width(cfg)), -1.0, jnp.float32),
        ev_len=jnp.zeros((M,), jnp.int32),
        s_prio=jnp.zeros((M, n_slots // shards), jnp.int32),
    )


def shard_control(control: ControlState, mesh) -> ControlState:
    """Pin every ControlState leaf's shard axis over the ``("slots",)`` mesh.

    One shard row per device (M == mesh size), co-located with that device's
    slot shard — enqueue/refill/warm-lookup are then device-local forever.
    """

    def put(leaf):
        axes = ("slots",) + (None,) * (leaf.ndim - 1)
        return jax.device_put(leaf, named_sharding(mesh, leaf.shape, axes, SLOT_RULES))

    return jax.tree.map(put, control)


def _pin(tree):
    """Re-assert the shard-axis sharding on every leaf of a program OUTPUT
    (``parallel.constraint``: a no-op without an active mesh), so donation +
    in-place scatters can never drift a leaf toward replication — the
    reshard-free invariant the device path is gated on."""

    def one(leaf):
        return constraint(leaf, ("slots",) + (None,) * (leaf.ndim - 1))

    return jax.tree.map(one, tree)


@functools.partial(jax.jit, donate_argnums=(0,))
def enqueue(
    control: ControlState,
    shard: jnp.ndarray,  # scalar int32 (traced: one program serves all shards)
    stream_id: jnp.ndarray,  # scalar int32
    buf_y: jnp.ndarray,  # [L, n] admission history
    buf_u: jnp.ndarray,  # [L, m]
    params: Any,  # single cold-start MRParams tree
    priority: jnp.ndarray,  # scalar int32 tier (higher pops first)
) -> ControlState:
    """Append one arrival to ``shard``'s compact admission queue (donated).

    This is the ONLY host->device write of the device control plane; it
    touches one queue row via ``dynamic_update_slice`` and never re-shards
    the slot axis. The host guards queue capacity (``RecoveryService.submit``
    tracks per-shard in-flight depth and spills to its bounded overflow
    queue), so overflow cannot occur here.
    """
    tail = control.q_len[shard]

    def write(full, new):
        new = jnp.asarray(new, full.dtype)
        start = (shard, tail) + (jnp.int32(0),) * (full.ndim - 2)
        return jax.lax.dynamic_update_slice(full, new[None, None], start)

    return _pin(
        control._replace(
            q_ids=control.q_ids.at[shard, tail].set(stream_id),
            q_buf_y=write(control.q_buf_y, buf_y),
            q_buf_u=write(control.q_buf_u, buf_u),
            q_params=jax.tree.map(write, control.q_params, params),
            q_prio=control.q_prio.at[shard, tail].set(priority),
            q_len=control.q_len.at[shard].add(1),
        )
    )


def _broadcast(mask: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    return mask.reshape(mask.shape + (1,) * (like.ndim - mask.ndim))


def _shard_control_step(
    st: SlotState,  # one shard's slot slice (leaves [P, ...])
    ctl: ControlState,  # one shard's control slice (no leading M)
    evict: jnp.ndarray,  # [P] bool eviction mask (from the post-tick status)
    reason: jnp.ndarray,  # [P] f32 (1 = converged, 2 = budget)
    *,
    min_steps: int,  # preemption cold threshold (0 disables preemption)
) -> tuple[SlotState, ControlState]:
    """One shard's eviction + refill + warm lookup (vmapped over shards).

    Everything is shard-local and vectorized: a cumsum prefix-rank assigns
    each evicting/idle slot its event-log / queue position, scatters use
    ``mode="drop"`` with an out-of-bounds index for masked-out slots, and
    gathers blend per-leaf with ``jnp.where`` — no per-slot control flow, no
    cross-shard communication.

    Admission pops the compact queue in PRIORITY order (stable: FIFO within
    a tier, so an all-default-priority service reduces bitwise to the old
    FIFO plane). Arrivals still waiting after every idle slot is filled may
    PREEMPT: the highest-priority remaining arrival displaces the
    lowest-priority COLD slot (``steps < min_steps``) whose tier is strictly
    lower — the victim's current params go to the warm ring and the victim
    is re-enqueued at the queue tail with its live buffers, so no stream is
    lost and net queue occupancy is unchanged (one pop per re-enqueue).
    """
    P = evict.shape[0]
    Q = ctl.q_ids.shape[0]
    W = ctl.w_ids.shape[0]
    E = ctl.ev_log.shape[0]
    f32 = jnp.float32
    i32 = jnp.int32

    # -- eviction: append event records, push params into the warm ring -----
    erank = jnp.cumsum(evict.astype(i32)) - 1
    n_evict = jnp.sum(evict.astype(i32))
    record = jnp.concatenate(
        [
            st.stream_id.astype(f32)[:, None],
            st.steps.astype(f32)[:, None],
            reason[:, None],
            st.theta.reshape(P, -1),
            st.mean,
            st.scale,
        ],
        axis=-1,
    )
    # E is sized so the log never wraps between drains (see ControlState)
    ev_pos = jnp.where(evict, ctl.ev_len + erank, E)  # E = OOB -> dropped
    ev_log = ctl.ev_log.at[ev_pos].set(record, mode="drop")
    ev_len = ctl.ev_len + n_evict
    w_write = jnp.where(evict, (ctl.w_pos + erank) % W, W)
    w_ids = ctl.w_ids.at[w_write].set(st.stream_id, mode="drop")
    w_params = jax.tree.map(
        lambda full, lv: full.at[w_write].set(lv, mode="drop"), ctl.w_params, st.params
    )
    w_pos = (ctl.w_pos + n_evict) % W
    active = st.active & ~evict
    stream_id = jnp.where(evict, -1, st.stream_id)

    # -- pop order: priority-descending, FIFO within a tier -----------------
    # compact queue: entries live at [0, q_len). The int32 sort key composes
    # (PRIORITY_LIMIT - prio) with the queue index, so argsort yields higher
    # tiers first and exact insertion order inside a tier; empty entries key
    # strictly above every filled one.
    qidx = jnp.arange(Q, dtype=i32)
    filled = qidx < ctl.q_len
    key_q = jnp.where(
        filled,
        (PRIORITY_LIMIT - 1 - ctl.q_prio) * Q + qidx,
        PRIORITY_LIMIT * Q + qidx,
    )
    order = jnp.argsort(key_q)  # [Q] queue positions in pop order
    qinv = jnp.argsort(order)  # pop rank of each queue position

    # -- phase 1: pop arrivals into idle slots, in slot order ---------------
    idle = ~active
    arank = jnp.cumsum(idle.astype(i32)) - 1
    take = idle & (arank < ctl.q_len)
    n_take = jnp.sum(take.astype(i32))

    # -- phase 2: preemption of cold lower-tier slots by waiting arrivals ---
    # rank-r remaining arrival (pop rank n_take + r) pairs with the rank-r
    # eligible victim (lowest tier first, slot order within a tier); the pair
    # preempts iff the arrival's tier is strictly higher. Both sequences are
    # sorted toward each other, so pair validity is prefix-monotone and the
    # preempted set is exactly the first n_pre pairs.
    vict_elig = active & (st.steps < min_steps)
    n_elig = jnp.sum(vict_elig.astype(i32))
    sidx = jnp.arange(P, dtype=i32)
    vkey = jnp.where(vict_elig, ctl.s_prio * P + sidx, PRIORITY_LIMIT * P + sidx)
    vorder = jnp.argsort(vkey)  # slot indices, lowest-tier victims first
    vinv = jnp.argsort(vorder)  # victim rank of each slot
    pair_rank = n_take + sidx  # pop rank of the r-th pairing's arrival
    a_pos = order[jnp.clip(pair_rank, 0, Q - 1)]
    pair_ok = (
        (pair_rank < ctl.q_len)
        & (sidx < n_elig)
        & (ctl.q_prio[a_pos] > ctl.s_prio[vorder])
    )
    n_pre = jnp.sum(pair_ok.astype(i32))
    pre = vict_elig & (vinv < n_pre)  # [P] preempted-slot mask

    # -- combined admission gather ------------------------------------------
    adm = take | pre
    pop_rank = jnp.where(take, arank, n_take + vinv)
    q_pos = order[jnp.clip(pop_rank, 0, Q - 1)]
    pop_id = jnp.where(adm, ctl.q_ids[q_pos], -1)
    pop_prio = jnp.where(adm, ctl.q_prio[q_pos], 0)
    pop_by = ctl.q_buf_y[q_pos]  # [P, L, n]
    pop_bu = ctl.q_buf_u[q_pos]
    cold = jax.tree.map(lambda leaf: leaf[q_pos], ctl.q_params)

    # preempted victims: current params into the warm ring (after the
    # eviction pushes), so a later return warm-starts from where it stopped
    prank = jnp.cumsum(pre.astype(i32)) - 1
    w_write2 = jnp.where(pre, (w_pos + prank) % W, W)
    w_ids = w_ids.at[w_write2].set(stream_id, mode="drop")
    w_params = jax.tree.map(
        lambda full, lv: full.at[w_write2].set(lv, mode="drop"), w_params, st.params
    )
    w_pos = (w_pos + n_pre) % W

    # warm-start lookup: gather over the (post-push) bounded warm ring; a
    # miss falls back to the cold tree that rode in on the queue
    hit_mat = (pop_id[:, None] == w_ids[None, :]) & (pop_id[:, None] >= 0)
    hit = hit_mat.any(axis=1)
    w_idx = jnp.argmax(hit_mat, axis=1)
    warm = jax.tree.map(lambda leaf: leaf[w_idx], w_params)
    params_new = jax.tree.map(
        lambda w, c: jnp.where(_broadcast(hit, w), w, c), warm, cold
    )

    # identical admission math to stream.admit: stats frozen from the
    # enqueued history, theta/delta/loss reset, opt re-init (adamw_init is
    # step=0 + zero moments, i.e. zeros_like)
    mean_new, scale_new = buffer_stats(pop_by)
    mean_new, scale_new = mean_new[:, 0], scale_new[:, 0]
    n_terms, n = st.theta.shape[1:]

    def blend(new, old):
        return jnp.where(_broadcast(adm, old), new.astype(old.dtype), old)

    st_new = SlotState(
        params=jax.tree.map(blend, params_new, st.params),
        opt=jax.tree.map(lambda old: blend(jnp.zeros_like(old), old), st.opt),
        buf_y=blend(pop_by, st.buf_y),
        buf_u=blend(pop_bu, st.buf_u),
        theta=blend(jnp.zeros((P, n_terms, n), f32), st.theta),
        delta=jnp.where(adm, jnp.inf, st.delta),
        loss=jnp.where(adm, jnp.inf, st.loss),
        mean=blend(mean_new, st.mean),
        scale=blend(scale_new, st.scale),
        steps=jnp.where(adm, 0, st.steps).astype(i32),
        active=active | adm,
        stream_id=jnp.where(adm, pop_id, stream_id).astype(i32),
    )

    # -- queue compaction + victim re-enqueue -------------------------------
    # survivors (pop rank >= n_take + n_pre) pack to the front in pop-rank
    # order; preempted victims append behind them with their live buffers,
    # current params and original tier. One pop per re-enqueue, so q_len
    # never grows past its pre-step value.
    n_pop = n_take + n_pre
    keep = filled & (qinv >= n_pop)
    dest = jnp.where(keep, qinv - n_pop, Q)  # survivor's compacted position
    q_ids_c = jnp.full_like(ctl.q_ids, -1).at[dest].set(ctl.q_ids, mode="drop")
    q_prio_c = jnp.zeros_like(ctl.q_prio).at[dest].set(ctl.q_prio, mode="drop")
    q_by_c = jnp.zeros_like(ctl.q_buf_y).at[dest].set(ctl.q_buf_y, mode="drop")
    q_bu_c = jnp.zeros_like(ctl.q_buf_u).at[dest].set(ctl.q_buf_u, mode="drop")
    q_params_c = jax.tree.map(
        lambda full: jnp.zeros_like(full).at[dest].set(full, mode="drop"), ctl.q_params
    )
    rem = ctl.q_len - n_pop
    vdest = jnp.where(pre, rem + prank, Q)
    q_ids_c = q_ids_c.at[vdest].set(stream_id, mode="drop")
    q_prio_c = q_prio_c.at[vdest].set(ctl.s_prio, mode="drop")
    q_by_c = q_by_c.at[vdest].set(st.buf_y, mode="drop")
    q_bu_c = q_bu_c.at[vdest].set(st.buf_u, mode="drop")
    q_params_c = jax.tree.map(
        lambda full, lv: full.at[vdest].set(lv, mode="drop"), q_params_c, st.params
    )

    s_prio = jnp.where(evict, 0, ctl.s_prio)
    ctl_new = ctl._replace(
        q_ids=q_ids_c,
        q_buf_y=q_by_c,
        q_buf_u=q_bu_c,
        q_params=q_params_c,
        q_prio=q_prio_c,
        q_len=rem + n_pre,
        w_ids=w_ids,
        w_params=w_params,
        w_pos=w_pos,
        ev_log=ev_log,
        ev_len=ev_len,
        s_prio=jnp.where(adm, pop_prio, s_prio).astype(i32),
    )
    return st_new, ctl_new


def _control_apply(
    state: SlotState,
    control: ControlState,
    evict: jnp.ndarray,
    reason: jnp.ndarray,
    *,
    shards: int,
    min_steps: int = 0,
) -> tuple[SlotState, ControlState]:
    """Reshape [S] -> [shards, P], vmap the shard-local control step, fold
    back. The reshape splits the already-sharded leading axis on shard
    boundaries, so SPMD keeps every shard's control step on its own device."""
    S = state.active.shape[0]
    P = S // shards

    def split(leaf):
        return leaf.reshape((shards, P) + leaf.shape[1:])

    step = functools.partial(_shard_control_step, min_steps=min_steps)
    st_sh, ctl_sh = jax.vmap(step)(
        jax.tree.map(split, state), control, split(evict), split(reason)
    )
    return jax.tree.map(lambda leaf: leaf.reshape((S,) + leaf.shape[2:]), st_sh), ctl_sh


def _status5(state: SlotState) -> jnp.ndarray:
    """[S, 5] packed post-control status: [delta, loss, steps, active, id]."""
    return jnp.concatenate(
        [pack_status(state), state.stream_id.astype(jnp.float32)[:, None]], axis=-1
    )


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "scfg", "kernel", "quant", "slots_per_bank", "shards"),
    donate_argnums=(0, 1),
)
def tick_device(
    state: SlotState,
    control: ControlState,
    new_y: jnp.ndarray,  # [S, C, n]
    new_u: jnp.ndarray,  # [S, C, m]
    key: jax.Array,
    *,
    cfg: MRConfig,
    scfg: StreamConfig,
    kernel: str = "composite",
    quant: bool = False,
    slots_per_bank: int = 1,
    shards: int = 1,
) -> tuple[SlotState, ControlState, jnp.ndarray]:
    """One zero-readback service tick: tick body + eviction + refill fused.

    Runs the (bitwise-reference) composite or banked tick body, computes the
    converged/budget eviction mask from the post-tick scalars IN-PROGRAM,
    logs evictions, refills freed slots from the shard-local queues with the
    on-device warm-start gather, and returns the next (state, control) plus
    the packed [S, 5] status. The host touches none of it except at snapshot
    ticks — both state trees are donated, so steady state is one program
    launch with zero transfers in either direction.
    """
    if kernel == "banked":
        state, _ = _tick_banked_impl(
            state, new_y, new_u, key, cfg=cfg, scfg=scfg, quant=quant, slots_per_bank=slots_per_bank
        )
    else:
        state = _tick_impl(state, new_y, new_u, key, cfg=cfg, scfg=scfg)
    converged = (state.steps >= scfg.min_steps) & (state.delta <= scfg.delta_tol)
    budget = state.steps >= scfg.max_steps
    evict = state.active & (converged | budget)
    reason = jnp.where(converged, 1.0, jnp.where(budget, 2.0, 0.0)).astype(jnp.float32)
    state, control = _control_apply(
        state, control, evict, reason, shards=shards, min_steps=scfg.min_steps
    )
    state, control = _pin(state), _pin(control)
    return state, control, _status5(state)


@functools.partial(jax.jit, static_argnames=("shards",), donate_argnums=(0, 1))
def pump(
    state: SlotState, control: ControlState, *, shards: int = 1
) -> tuple[SlotState, ControlState, jnp.ndarray]:
    """Admission-only control step (bootstrap / between-tick refill): pop the
    shard queues into every idle slot without running a tick. A fresh slot
    can never satisfy the eviction predicate (delta = inf, steps = 0), so the
    all-False eviction mask is exact. No preemption here (min_steps=0 marks
    no slot cold): a bootstrap pump only fills idle capacity."""
    S = state.active.shape[0]
    evict = jnp.zeros((S,), bool)
    reason = jnp.zeros((S,), jnp.float32)
    state, control = _control_apply(state, control, evict, reason, shards=shards)
    state, control = _pin(state), _pin(control)
    return state, control, _status5(state)


@jax.jit
def drain_events(control: ControlState) -> tuple[ControlState, jnp.ndarray]:
    """Snapshot drain: return the event log and reset it on device.

    Not donated: the returned log aliases the input buffer, so XLA copies
    exactly the [M, E, R] log — the queues and warm cache stay resident.
    """
    cleared = control._replace(
        ev_log=jnp.full_like(control.ev_log, -1.0),
        ev_len=jnp.zeros_like(control.ev_len),
    )
    return _pin(cleared), control.ev_log


def decode_events(events: np.ndarray, cfg: MRConfig) -> list[tuple]:
    """Host-side parse of one drained [M, E, R] event log.

    Yields ``(stream_id, steps, reason_code, theta, mean, scale)`` per
    eviction, in shard-major order; empty rows (id < 0) are skipped.
    """
    n_terms, n = cfg.n_terms, cfg.state_dim
    k = n_terms * n
    out = []
    for shard_rows in np.asarray(events):
        for rec in shard_rows:
            sid = int(rec[0])
            if sid < 0:
                continue
            out.append(
                (
                    sid,
                    int(rec[1]),
                    int(rec[2]),
                    rec[3 : 3 + k].reshape(n_terms, n).copy(),
                    rec[3 + k : 3 + k + n].copy(),
                    rec[3 + k + n : 3 + k + 2 * n].copy(),
                )
            )
    return out


@dataclasses.dataclass(frozen=True)
class ControlPlane:
    """The compiled device control plane a RecoveryPlan hands the service:
    the four programs plus the capacities baked into the ControlState shapes
    (all recorded in ``plan.lowering``)."""

    queue_capacity: int  # Q: pending admissions per shard
    snapshot_period: int  # host drains status + events every N ticks
    warm_capacity: int  # W: on-device warm-cache entries per shard
    shards: int  # M: mesh size (1 = trivial mesh)
    tick: Callable  # tick_device with statics bound
    enqueue: Callable  # enqueue (no statics)
    pump: Callable  # pump with shards bound
    drain: Callable  # drain_events
