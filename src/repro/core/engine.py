"""Scan-jitted streaming recovery engine (the paper's dataflow claim, host side).

MERINDA's FPGA win comes from setting the pipeline up ONCE and streaming —
no per-step launches (paper §4). The original ``train_mr`` host loop was the
exact anti-pattern: a Python ``for`` over optimizer steps, re-entering jit,
sampling minibatch indices and gathering windows with separate dispatches
every iteration. This module is the host-side analogue of the kernel fix:

- ``run_epoch`` compiles the WHOLE training run into one donated
  ``jax.lax.scan`` program — minibatch sampling (counter-derived keys via
  ``jax.random.fold_in``), LR warmup, the value_and_grad/clip/AdamW update
  and metric accumulation all execute device-side with zero per-step Python
  dispatch.
- ``recover_many`` vmaps the same epoch program over a BATCH of distinct
  dynamical systems: S models are initialized, trained and read out in one
  compiled call (the "many concurrent model recoveries" serving scenario).

The scan body calls ``merinda.mr_train_step`` directly (jit inlines under
the scan), so per-step math is the old loop's by construction — only the
dispatch structure differs.

Since the plan/compile/run redesign (``repro.api``), this module owns the
PRIMITIVES — ``run_epoch``, ``recover_one``, ``_recover_many_jit``,
``system_keys``, ``stack_systems`` — while the public entry points
(``train_mr_scan``, ``recover_many``) are deprecated wrappers that build a
``RecoverySpec`` and run through ``api.compile_plan``. Encoder names and the
``fused`` flag are validated eagerly at compile time there (a typo or a
non-fusable ``fused=True`` fails with the registered names, not a mid-trace
error), and ``cfg.fused=True`` routes every forward through the stage-fused
per-window kernel (kernels/mr_step) — the epoch scan, the streaming tick
(core/stream.py) and serve_mr then share one fused code path.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.merinda import (
    MRConfig,
    MRParams,
    init_mr,
    mr_train_step,
    recover_coefficients,
)
from repro.optim import adamw_init

WARMUP_STEPS = 50  # matches the original train_mr warmup


def make_phys(cfg: MRConfig, norm: dict | None):
    """(T^T, out_scale) for physical-unit sparsity penalties, or None.

    norm is the stats dict from data/windows.make_windows; see mr_loss.
    """
    if norm is None:
        return None
    from repro.core.library import normalization_transform

    n_vars = cfg.state_dim + cfg.input_dim
    mean = np.concatenate([np.asarray(norm["mean"]), np.zeros(cfg.input_dim)])
    scale = np.concatenate([np.asarray(norm["scale"]), np.ones(cfg.input_dim)])
    T = normalization_transform(mean, scale, n_vars, cfg.order)
    return (
        jnp.asarray(T.T, jnp.float32),
        jnp.asarray(scale[: cfg.state_dim], jnp.float32),
    )


def _epoch(
    params: MRParams,
    opt_state,
    ys: jnp.ndarray,  # [N, T, n]
    us: jnp.ndarray | None,  # [N, T, m] | None
    key: jax.Array,
    lr: jnp.ndarray | float,
    phys: tuple | None,
    *,
    cfg: MRConfig,
    steps: int,
    batch_size: int | None,
):
    """One compiled training run: lax.scan over optimizer steps.

    Returns (params, opt_state, metrics) with metrics a dict of [steps]
    arrays (loss, recon_mse, sparsity_l1, grad_norm, lr). Pure function of
    its inputs — vmappable across systems (see recover_many).
    """
    n = ys.shape[0]
    bs = batch_size or n
    sample = bs < n

    def step_fn(carry, step):
        params, opt_state = carry
        if sample:
            sub = jax.random.fold_in(key, step)
            idx = jax.random.randint(sub, (bs,), 0, n)
            yb = jnp.take(ys, idx, axis=0)
            ub = None if us is None else jnp.take(us, idx, axis=0)
        else:
            yb, ub = ys, us
        lr_t = lr * jnp.minimum(1.0, (step + 1.0) / WARMUP_STEPS)
        params, opt_state, aux = mr_train_step(params, opt_state, cfg, yb, ub, lr_t, phys)
        return (params, opt_state), dict(aux, lr=lr_t)

    (params, opt_state), metrics = jax.lax.scan(step_fn, (params, opt_state), jnp.arange(steps))
    return params, opt_state, metrics


# Donated entry point: params/opt_state buffers are reused in place across the
# scan — the single-program structure XLA needs to elide per-step copies.
run_epoch = functools.partial(
    jax.jit,
    static_argnames=("cfg", "steps", "batch_size"),
    donate_argnums=(0, 1),
)(_epoch)


def train_mr_scan(
    cfg: MRConfig,
    ys: jnp.ndarray,
    us: jnp.ndarray | None = None,
    steps: int = 500,
    lr: float = 3e-3,
    seed: int = 0,
    batch_size: int | None = None,
    norm: dict | None = None,
) -> tuple[MRParams, dict]:
    """Deprecated wrapper: builds a RecoverySpec and runs the compiled plan.

    Prefer ``repro.api``::

        plan = api.compile_plan(api.RecoverySpec(..., mode="offline"))
        params, metrics = plan.run_offline(ys, us, norm=norm)

    Returns (params, metrics) where metrics holds [steps]-shaped arrays.
    ``merinda.train_mr`` wraps this and re-serializes metrics into the old
    history-of-dicts format.
    """
    from repro import api
    from repro.deprecation import warn_deprecated_once

    warn_deprecated_once(
        "engine.train_mr_scan",
        "engine.train_mr_scan is deprecated; build a RecoverySpec(mode='offline') "
        "and run api.compile_plan(spec).run_offline(...) instead",
    )
    spec = api.RecoverySpec.from_mr_config(
        cfg, mode="offline", steps=steps, lr=lr, seed=seed, batch_size=batch_size
    )
    return api.compile_plan(spec).run_offline(ys, us, norm=norm)


def history_from_metrics(metrics: dict, log_every: int) -> list[dict]:
    """The old train_mr history format: one dict per logged step."""
    if not log_every:
        return []
    host = {k: np.asarray(v) for k, v in metrics.items()}
    steps = next(iter(host.values())).shape[0]
    return [
        {k: float(v[s]) for k, v in host.items()} | {"step": s}
        for s in range(0, steps, log_every)
    ]


# ---------------------------------------------------------------------------
# multi-system recovery: one vmapped program recovers a fleet of models
# ---------------------------------------------------------------------------
def system_keys(seed: int, n_systems: int) -> jax.Array:
    """Per-system PRNG keys; the sequential path derives the same ones so
    vmapped and one-at-a-time recovery are comparable bit-for-bit."""
    base = jax.random.key(seed)
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(n_systems))


def recover_one(
    cfg: MRConfig,
    ys: jnp.ndarray,  # [N, T, n]
    us: jnp.ndarray | None,
    key: jax.Array,
    steps: int = 500,
    lr: float = 3e-3,
    batch_size: int | None = None,
    n_active: int | None = None,
) -> jnp.ndarray:
    """Init -> train -> aggregate Theta for ONE system. Pure in (key, data),
    so jax.vmap over the leading axis is the multi-system engine."""
    params = init_mr(key, cfg)
    opt_state = adamw_init(params)
    params, _, _ = _epoch(
        params,
        opt_state,
        ys,
        us,
        key,
        lr,
        None,
        cfg=cfg,
        steps=steps,
        batch_size=batch_size,
    )
    return recover_coefficients(params, cfg, ys, us, n_active=n_active)


def recover_many(
    cfg: MRConfig,
    ys_batch: jnp.ndarray,  # [S, N, T, n]
    us_batch: jnp.ndarray | None = None,  # [S, N, T, m] | None
    steps: int = 500,
    lr: float = 3e-3,
    seed: int = 0,
    batch_size: int | None = None,
    n_active: int | None = None,
) -> jnp.ndarray:
    """Deprecated wrapper: builds a RecoverySpec and runs the compiled plan.

    Prefer ``repro.api``::

        plan = api.compile_plan(api.RecoverySpec(..., mode="batch"))
        theta_batch = plan.run_batch(ys_batch, us_batch)

    Returns theta_batch [S, n_terms, n_state] (normalized coords). All
    systems must share (state_dim, input_dim, order) — use
    ``stack_systems`` to zero-pad a heterogeneous set to common dims.
    """
    from repro import api
    from repro.deprecation import warn_deprecated_once

    warn_deprecated_once(
        "engine.recover_many",
        "engine.recover_many is deprecated; build a RecoverySpec(mode='batch') "
        "and run api.compile_plan(spec).run_batch(...) instead",
    )
    spec = api.RecoverySpec.from_mr_config(
        cfg,
        mode="batch",
        steps=steps,
        lr=lr,
        seed=seed,
        batch_size=batch_size,
        n_active=n_active,
    )
    return api.compile_plan(spec).run_batch(ys_batch, us_batch)


# module-level jit so repeat calls with the same static config hit the
# compile cache (a per-call jit(lambda ...) would retrace every invocation)
@functools.partial(jax.jit, static_argnames=("cfg", "steps", "batch_size", "n_active"))
def _recover_many_jit(ys_batch, us_batch, keys, lr, *, cfg, steps, batch_size, n_active):
    def one(ys, us, key):
        return recover_one(
            cfg,
            ys,
            us,
            key,
            steps=steps,
            lr=lr,
            batch_size=batch_size,
            n_active=n_active,
        )

    if us_batch is None:
        return jax.vmap(lambda ys, k: one(ys, None, k))(ys_batch, keys)
    return jax.vmap(one)(ys_batch, us_batch, keys)


def stack_systems(
    names: Sequence[str],
    window: int = 32,
    stride: int = 4,
    n_samples: int = 600,
) -> tuple[jnp.ndarray, jnp.ndarray | None, list[dict], MRConfig]:
    """Generate + window + zero-pad a heterogeneous system set for recover_many.

    State/input dims are zero-padded up to the set's maxima (a padded state
    channel is identically zero, so its library terms vanish and the L1
    penalty zeroes its coefficients). Returns (ys [S,N,T,n_max],
    us [S,N,T,m_max] or None, per-system norm stats, a ready MRConfig).
    """
    from repro.data.dynamics import generate_trajectory, get_system
    from repro.data.windows import make_windows

    specs = [get_system(n) for n in names]
    dts = {s.dt for s in specs}
    if len(dts) > 1:
        # cfg.dt is shared across the vmapped batch; integrating a system's
        # windows at the wrong sampling interval recovers garbage silently
        raise ValueError(
            f"stack_systems requires a common sampling dt, got {sorted(dts)} "
            f"for {list(names)} — stack only systems generated on one grid"
        )
    n_max = max(s.state_dim for s in specs)
    m_max = max(s.input_dim for s in specs)
    order = max(s.order for s in specs)
    yws, uws, norms = [], [], []
    for spec in specs:
        _, ys, us = generate_trajectory(spec.name, n_samples=n_samples)
        yw, uw, norm = make_windows(ys, us, window=window, stride=stride)
        N, T = yw.shape[:2]
        yw = np.pad(yw, ((0, 0), (0, 0), (0, n_max - spec.state_dim)))
        if m_max:
            uw = (
                np.zeros((N, T, m_max), np.float32)
                if uw is None
                else np.pad(uw, ((0, 0), (0, 0), (0, m_max - uw.shape[-1])))
            )
            uws.append(uw)
        yws.append(yw)
        norms.append(norm)
    ys_batch = jnp.asarray(np.stack(yws))
    us_batch = jnp.asarray(np.stack(uws)) if m_max else None
    cfg = MRConfig(
        state_dim=n_max,
        input_dim=m_max,
        order=order,
        hidden=32,
        dense_hidden=64,
        dt=dts.pop(),
    )
    return ys_batch, us_batch, norms, cfg
