"""Fixed-step ODE solvers as jax.lax control flow.

These are the iterative solvers whose cost the paper eliminates (high-level
optimization) and also the SOLVE() used inside the MERINDA loss (Fig. 4):
``Y_est = SOLVE(Y(0), theta_est, U)``.

All solvers integrate ``dy/dt = f(y, u, t, args)`` over a uniform grid and are
differentiable (pure lax.scan, no custom VJP needed at these sizes).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

Dynamics = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray, Any], jnp.ndarray]
# f(y, u, t, args) -> dy/dt


def _euler_step(f: Dynamics, y, u, t, dt, args):
    return y + dt * f(y, u, t, args)


def _heun_step(f: Dynamics, y, u, t, dt, args):
    k1 = f(y, u, t, args)
    k2 = f(y + dt * k1, u, t + dt, args)
    return y + 0.5 * dt * (k1 + k2)


def _rk4_step(f: Dynamics, y, u, t, dt, args):
    k1 = f(y, u, t, args)
    k2 = f(y + 0.5 * dt * k1, u, t + 0.5 * dt, args)
    k3 = f(y + 0.5 * dt * k2, u, t + 0.5 * dt, args)
    k4 = f(y + dt * k3, u, t + dt, args)
    return y + (dt / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4)


_STEPPERS = {"euler": _euler_step, "heun": _heun_step, "rk4": _rk4_step}


def odeint(
    f: Dynamics,
    y0: jnp.ndarray,
    ts: jnp.ndarray,
    us: jnp.ndarray | None = None,
    args: Any = None,
    method: str = "rk4",
) -> jnp.ndarray:
    """Integrate f over the time grid ``ts`` (shape [T]).

    us: optional exogenous inputs sampled on the same grid, shape [T, m]
        (zero-order hold within a step).
    Returns the trajectory, shape [T, *y0.shape]; trajectory[0] == y0.
    """
    step = _STEPPERS[method]
    if us is None:
        us = jnp.zeros((ts.shape[0], 0), dtype=y0.dtype)

    def body(y, inp):
        t, dt, u = inp
        y_next = step(f, y, u, t, dt, args)
        return y_next, y_next

    dts = jnp.diff(ts)
    _, ys = jax.lax.scan(body, y0, (ts[:-1], dts, us[:-1]))
    return jnp.concatenate([y0[None], ys], axis=0)


def solve_ivp_fixed(
    f: Dynamics,
    y0: jnp.ndarray,
    t0: float,
    t1: float,
    n_steps: int,
    us: jnp.ndarray | None = None,
    args: Any = None,
    method: str = "rk4",
) -> jnp.ndarray:
    """Uniform-grid convenience wrapper; returns [n_steps+1, ...] trajectory."""
    ts = jnp.linspace(t0, t1, n_steps + 1)
    return odeint(f, y0, ts, us=us, args=args, method=method)


@partial(jax.jit, static_argnames=("f", "method", "n_substeps", "unroll"))
def multi_step_solver_cell(
    f: Dynamics,
    y: jnp.ndarray,
    u: jnp.ndarray,
    dt: jnp.ndarray,
    args: Any = None,
    method: str = "euler",
    n_substeps: int = 6,
    unroll: int = 1,
) -> jnp.ndarray:
    """One *NODE-style cell forward pass*: N sequential solver sub-steps.

    This is the primitive whose cost the paper profiles (Table 1: 87.7% of
    forward latency; 6 sub-steps) and then removes. Each sub-step depends on
    the previous -> inherently sequential (lax.scan, cannot parallelize;
    ``unroll`` only changes the lowering of the substep loop, not the math).
    """
    step = _STEPPERS[method]
    sub_dt = dt / n_substeps

    def body(y, i):
        y = step(f, y, u, i.astype(y.dtype) * sub_dt, sub_dt, args)
        return y, None

    y, _ = jax.lax.scan(body, y, jnp.arange(n_substeps), unroll=unroll)
    return y
