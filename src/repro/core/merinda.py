"""MERINDA: GRU-NN based Model Recovery (paper Fig. 4) + baselines.

Pipeline (per batch of trajectory windows):

    [Y, U] --encoder--> V hidden states --dense head--> (Theta_est, shifts)
    Y_est = SOLVE(Y(0), Theta_est, U)          (RK4, core/ode.py)
    loss  = MSE(Y, Y_est) + lambda * ||Theta||_1  (+ optional coef supervision)

The encoder is pluggable through the registry in ``core/encoders.py`` (one
row per family + backend), so the paper's comparison set is one code path:

    "gru_flow" — MERINDA (GRU neural flow, single gated update/step)
    "gru"      — plain GRU (hardware pipeline target, paper Eq. 12-15)
    "ltc"      — Liquid Time-Constant baseline (iterative fused solver)
    "node"     — ODE-RNN / NODE-style baseline (EMILY/PiNODE family)
    "*_kernel" — the GRU families routed through the Pallas gru_scan kernel

The dense head maps the final hidden state to C(M+n, n) x n coefficient
estimates plus q input-shift values; sparsity is induced by an L1 penalty and
(at recovery time) magnitude pruning to |Theta| active terms — the paper's
"pruned dense layer" exploiting the model's inherent sparsity.

``MRConfig.fused=True`` replaces the encode -> head stage sequence with the
stage-FUSED per-window kernel family (kernels/mr_step): scan + RMS-norm +
dense head execute as one ``pallas_call`` with the hidden state resident in
VMEM (the paper's BRAM-tiling dataflow). Every registry encoder has a fused
lowering — the GRU(-flow) single-update kernels and the multi-substep
LTC/NODE fused-solver variants (K solver substeps per input step, unrolled
in-kernel). The fused and unfused paths share identical math; off-TPU the
fused op resolves to the same reference program
(kernels/runtime.resolve_dispatch).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import encoders, ode
from repro.core.library import n_library_terms, polynomial_features
from repro.core.quant import QuantConfig, fake_quant_ste, qat_act, qat_weight
from repro.optim import adamw_update, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class MRConfig:
    state_dim: int  # n = |Y|
    input_dim: int = 0  # m = |U|
    order: int = 2  # M (library polynomial order)
    hidden: int = 64  # V (encoder nodes)
    dense_hidden: int = 128
    encoder: str = "gru_flow"  # any name registered in core/encoders.py
    n_shifts: int = 0  # q input-shift values
    dt: float = 0.05
    solver: str = "rk4"
    ltc_substeps: int = 6
    lambda_sparse: float = 1e-3
    recon_weight: float = 1.0
    quant: QuantConfig | None = None  # fixed-point QAT when set
    fused: bool = False  # stage-fused per-window step (kernels/mr_step)
    block_b: int | None = None  # fused-stage batch tile (None = full batch)
    # scan-unroll factor for the sequential loops of the reference/XLA
    # lowering (LTC/NODE substep scans; the GRU window scan). A pure lowering
    # knob — identical math at any value — resolved by the measured-cost
    # autotuner (analysis/tuner.py); the Pallas kernels already unroll their
    # substep loops in-kernel and ignore it.
    substep_unroll: int = 1

    @property
    def n_terms(self) -> int:
        # library over [Y, U] jointly (SINDYc-style) so inputs can enter terms
        return n_library_terms(self.state_dim + self.input_dim, self.order)

    @property
    def n_coef(self) -> int:
        return self.n_terms * self.state_dim


class MRParams(NamedTuple):
    encoder: Any  # GRUParams | LTCParams | dict (node)
    head_w1: jnp.ndarray
    head_b1: jnp.ndarray
    head_w2: jnp.ndarray
    head_b2: jnp.ndarray


def init_mr(key: jax.Array, cfg: MRConfig, dtype=jnp.float32) -> MRParams:
    k_enc, k1, k2 = jax.random.split(key, 3)
    d_in = cfg.state_dim + cfg.input_dim
    enc = encoders.get_encoder(cfg.encoder).init(k_enc, d_in, cfg.hidden, dtype)
    out_dim = cfg.n_coef + cfg.n_shifts
    s1 = 1.0 / jnp.sqrt(cfg.hidden)
    s2 = 1.0 / jnp.sqrt(cfg.dense_hidden)
    return MRParams(
        encoder=enc,
        head_w1=(jax.random.normal(k1, (cfg.hidden, cfg.dense_hidden)) * s1).astype(dtype),
        head_b1=jnp.zeros((cfg.dense_hidden,), dtype),
        head_w2=(jax.random.normal(k2, (cfg.dense_hidden, out_dim)) * s2 * 0.1).astype(dtype),
        head_b2=jnp.zeros((out_dim,), dtype),
    )


RMS_EPS = 1e-6  # head RMS-normalization epsilon (shared with kernels/mr_step)


def head_math(
    h: jnp.ndarray,  # [B, V] encoder summary state
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    act_bits: tuple[int, int] | None = None,  # (int_bits, frac_bits) QAT
) -> jnp.ndarray:
    """Raw dense-head math: RMS-norm -> optional act fake-quant -> relu MLP.

    SINGLE source of truth for the head stage — consumed by
    ``head_from_hidden`` (unfused path) and by the fused-stage oracle
    (kernels/mr_step/ref.py); the Pallas kernel body re-implements only the
    ``dot_general`` spellings and is parity-tested against this.

    RMS-normalizing the summary state keeps the initial Theta scale O(0.1)
    for every encoder family (the iterative NODE/LTC encoders otherwise
    hand the head O(50) activations and the RK4 reconstruction diverges).
    """
    h = h * jax.lax.rsqrt(jnp.mean(jnp.square(h), axis=-1, keepdims=True) + RMS_EPS)
    if act_bits is not None:
        h = fake_quant_ste(h, *act_bits)
    z = jax.nn.relu(h @ w1 + b1)
    return z @ w2 + b2


def _encode(params: MRParams, cfg: MRConfig, xs: jnp.ndarray) -> jnp.ndarray:
    """xs: [B, T, n+m] -> final hidden state [B, V] (registry-dispatched)."""
    return encoders.get_encoder(cfg.encoder).encode(params.encoder, cfg, xs)


def head_from_hidden(params: MRParams, cfg: MRConfig, h: jnp.ndarray):
    """Dense head: encoder summary state [B, V] -> (theta, shifts).

    Split out of mr_forward so serving paths that swap the encoder (e.g. the
    int8/PWL kernel in core/stream.py) reuse the exact head math.
    """
    q = cfg.quant
    out = head_math(
        h,
        qat_weight(params.head_w1, q),
        params.head_b1,
        qat_weight(params.head_w2, q),
        params.head_b2,
        act_bits=(q.act_int_bits, q.act_frac_bits) if q is not None else None,
    )
    theta = out[..., : cfg.n_coef].reshape(h.shape[0], cfg.n_terms, cfg.state_dim)
    shifts = out[..., cfg.n_coef :]
    return theta, shifts


def mr_forward(params: MRParams, cfg: MRConfig, ys: jnp.ndarray, us: jnp.ndarray | None):
    """Returns (theta [B, n_terms, n_state], shifts [B, q]).

    ``cfg.fused=True`` runs encode + RMS-norm + dense head as ONE fused
    per-window stage (kernels/mr_step) instead of separate ops — identical
    math, single dispatch, hidden state never leaves VMEM on TPU.
    """
    xs = ys if us is None or us.shape[-1] == 0 else jnp.concatenate([ys, us], axis=-1)
    xs = qat_act(xs, cfg.quant)
    if cfg.fused:
        from repro.kernels.mr_step.ops import mr_step

        return mr_step(params, cfg, xs, block_b=cfg.block_b)
    h = _encode(params, cfg, xs)
    return head_from_hidden(params, cfg, h)


def _recovered_dynamics(cfg: MRConfig):
    """f(y, u, t, theta): dy/dt = library([y,u]) @ theta  (per window)."""

    def f(y, u, t, theta):
        z = y if cfg.input_dim == 0 else jnp.concatenate([y, u], axis=-1)
        feats = polynomial_features(z, cfg.state_dim + cfg.input_dim, cfg.order)
        # bounded derivative: windows are normalized to O(1), so |dy/dt| >> 100
        # only occurs for transient bad Theta early in training — clipping
        # keeps RK4 finite without affecting converged solutions.
        return jnp.clip(feats @ theta, -100.0, 100.0)

    return f


def reconstruct(params: MRParams, cfg: MRConfig, ys: jnp.ndarray, us: jnp.ndarray | None):
    """SOLVE(Y(0), Theta_est, U) per window. ys: [B, T, n] -> Y_est [B, T, n]."""
    theta, _ = mr_forward(params, cfg, ys, us)
    T = ys.shape[1]
    ts = jnp.arange(T, dtype=ys.dtype) * cfg.dt
    f = _recovered_dynamics(cfg)

    def solve_one(y0, u_seq, th):
        return ode.odeint(f, y0, ts, us=u_seq, args=th, method=cfg.solver)

    u_seq = us if us is not None and cfg.input_dim else jnp.zeros((ys.shape[0], T, 0), ys.dtype)
    y_est = jax.vmap(solve_one)(ys[:, 0], u_seq, theta)
    return y_est, theta


def mr_loss(
    params: MRParams,
    cfg: MRConfig,
    ys: jnp.ndarray,
    us: jnp.ndarray | None,
    phys: tuple | None = None,
):
    """phys=(T_transpose, out_scale): when windows are z-scored, penalize
    sparsity of the PHYSICAL-unit coefficients (T^T theta) * scale — the
    basis change otherwise lets spurious constant/low-order terms hide in
    normalized coordinates (library.denormalize_theta)."""
    y_est, theta = reconstruct(params, cfg, ys, us)
    recon = jnp.mean((y_est - ys) ** 2)
    if phys is not None:
        Tt, out_scale = phys
        theta_phys = jnp.einsum("kt,btn->bkn", Tt, theta) * out_scale
        sparse = jnp.mean(jnp.abs(theta_phys))
    else:
        sparse = jnp.mean(jnp.abs(theta))
    loss = cfg.recon_weight * recon + cfg.lambda_sparse * sparse
    return loss, {"recon_mse": recon, "sparsity_l1": sparse}


@partial(jax.jit, static_argnames=("cfg",))
def mr_train_step(params: MRParams, opt_state, cfg: MRConfig, ys, us, lr, phys=None):
    (loss, aux), grads = jax.value_and_grad(mr_loss, has_aux=True)(params, cfg, ys, us, phys)
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    params, opt_state = adamw_update(grads, opt_state, params, lr=lr, weight_decay=1e-4)
    aux = dict(aux, loss=loss, grad_norm=gnorm)
    return params, opt_state, aux


def train_mr(
    cfg: MRConfig,
    ys: jnp.ndarray,
    us: jnp.ndarray | None,
    steps: int = 500,
    lr: float = 3e-3,
    seed: int = 0,
    batch_size: int | None = None,
    log_every: int = 0,
    callback: Callable[[int, dict], None] | None = None,
    norm: dict | None = None,
):
    """Full training run. ys: [N_windows, T, n]. Returns (params, history).

    The whole run executes as ONE compiled lax.scan program (core/engine.py):
    minibatch sampling, LR warmup and metric accumulation are all device-side
    — no per-step jit re-entry. ``callback`` therefore fires after the run
    completes (one call per logged step), not interleaved with training.

    norm: the stats dict from data/windows.make_windows — when given, the L1
    sparsity penalty is applied to physical-unit coefficients (see mr_loss).
    """
    from repro.core import engine

    params, metrics = engine.train_mr_scan(
        cfg,
        ys,
        us,
        steps=steps,
        lr=lr,
        seed=seed,
        batch_size=batch_size,
        norm=norm,
    )
    history = engine.history_from_metrics(metrics, log_every)
    if callback:
        for h in history:
            callback(h["step"], h)
    return params, history


def recover_coefficients(
    params: MRParams,
    cfg: MRConfig,
    ys: jnp.ndarray,
    us: jnp.ndarray | None,
    n_active: int | None = None,
) -> jnp.ndarray:
    """Aggregate per-window Theta estimates and magnitude-prune to n_active."""
    theta, _ = mr_forward(params, cfg, ys, us)
    theta = jnp.mean(theta, axis=0)  # [n_terms, n_state]
    if n_active is not None:
        flat = jnp.abs(theta).ravel()
        k = min(n_active, flat.shape[0])
        thresh = jnp.sort(flat)[-k]
        theta = jnp.where(jnp.abs(theta) >= thresh, theta, 0.0)
    return theta


def prune_theta(theta, n_active: int):
    """Magnitude-prune a HOST-side theta to its ``n_active`` largest terms.

    The single numpy spelling, shared by ``recover_physical_coefficients``
    and ``api.RecoveryPlan.readout``; ``recover_coefficients`` keeps the jnp
    twin above because it runs inside jit/vmap (device-side).
    """
    import numpy as np

    flat = np.abs(theta).ravel()
    k = min(n_active, flat.size)
    thresh = np.sort(flat)[-k]
    return np.where(np.abs(theta) >= thresh, theta, 0.0)


def recover_physical_coefficients(
    params: MRParams,
    cfg: MRConfig,
    ys: jnp.ndarray,
    us: jnp.ndarray | None,
    norm: dict,
    n_active: int | None = None,
):
    """Recovered Theta mapped back to PHYSICAL units.

    Training runs on z-scored windows (data/windows.py records mean/scale);
    the learned dynamics dz/dt = Theta_z phi(z) transform exactly back to
    dy/dt = Theta_y phi(y) through the binomial basis change
    (core/library.denormalize_theta). Pruning applies in physical units.
    """
    import numpy as np

    from repro.core.library import denormalize_theta

    theta_z = np.asarray(recover_coefficients(params, cfg, ys, us, n_active=None))
    theta_y = denormalize_theta(
        theta_z,
        norm["mean"],
        norm["scale"],
        n_vars=cfg.state_dim + cfg.input_dim,
        order=cfg.order,
        n_state=cfg.state_dim,
    )
    if n_active is not None:
        theta_y = prune_theta(theta_y, n_active)
    return theta_y
