"""Polynomial candidate-function library for sparse model recovery.

An n-dimensional model with M-th order nonlinearity draws from
C(M+n, n) monomial terms (paper §3.1 "Sparsity"). The library maps a state
(optionally augmented with exogenous inputs) to the monomial feature vector;
sparse regression then selects p << C(M+n, n) of them.

The exponent table is built *statically* (Python ints) so the jnp evaluation
is a single vectorized power/product — no data-dependent control flow, which
keeps it fuseable and TPU-friendly.
"""

from __future__ import annotations

import itertools
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def n_library_terms(n_vars: int, order: int) -> int:
    """C(M+n, n): number of monomials of total degree <= order in n_vars."""
    return math.comb(order + n_vars, n_vars)


def exponent_table(n_vars: int, order: int) -> np.ndarray:
    """[n_terms, n_vars] integer exponents, graded-lex order (constant first)."""
    rows = []
    for total in range(order + 1):
        # all exponent tuples with sum == total, lexicographic
        for combo in itertools.combinations_with_replacement(range(n_vars), total):
            e = [0] * n_vars
            for idx in combo:
                e[idx] += 1
            rows.append(e)
    table = np.asarray(rows, dtype=np.int32)
    assert table.shape[0] == n_library_terms(n_vars, order)
    return table


def term_names(n_vars: int, order: int, var_names: list[str] | None = None) -> list[str]:
    names = var_names or [f"x{i}" for i in range(n_vars)]
    out = []
    for row in exponent_table(n_vars, order):
        if not row.any():
            out.append("1")
            continue
        parts = []
        for name, e in zip(names, row):
            if e == 1:
                parts.append(name)
            elif e > 1:
                parts.append(f"{name}^{e}")
        out.append("*".join(parts))
    return out


@partial(jax.jit, static_argnames=("n_vars", "order"))
def polynomial_features(x: jnp.ndarray, n_vars: int, order: int) -> jnp.ndarray:
    """Evaluate the monomial library.

    x: [..., n_vars] -> [..., n_terms]. Computed as prod(x**e) over the static
    exponent table; exact for integer exponents (no log/exp tricks).
    """
    table = jnp.asarray(exponent_table(n_vars, order)).astype(x.dtype)  # [n_terms, n_vars]
    xb = x[..., None, :]
    # grad-safe x**e: d/dx x**0 = 0 * x**-1 is NaN at x == 0, and jnp.where
    # alone doesn't block NaN cotangents — the standard double-where guard
    is_zero = table == 0
    x_safe = jnp.where(is_zero, jnp.ones_like(xb), xb)
    powered = jnp.where(is_zero, jnp.ones_like(xb), x_safe**table)
    return jnp.prod(powered, axis=-1)


def normalization_transform(
    mean: np.ndarray, scale: np.ndarray, n_vars: int, order: int
) -> np.ndarray:
    """Basis-change matrix T for z-scored coordinates: phi(z) = T @ phi(y).

    z_j = (y_j - mean_j) / scale_j. Each normalized monomial expands
    binomially into raw monomials of equal-or-lower degree, so a model
    recovered on normalized windows maps EXACTLY back to physical units:

        dz/dt = Theta_z . phi(z)
        dy_i/dt = scale_i * (T^T Theta_z)[., i]     (see denormalize_theta)

    Returns T [n_terms, n_terms] with phi_k(z) = sum_m T[k, m] phi_m(y).
    """
    table = exponent_table(n_vars, order)
    index = {tuple(row): i for i, row in enumerate(table)}
    n_terms = table.shape[0]
    T = np.zeros((n_terms, n_terms))
    for k, row in enumerate(table):
        # expand prod_j ((y_j - mu_j)/s_j)^e_j term by term
        acc: dict[tuple, float] = {tuple([0] * n_vars): 1.0}
        for j, e in enumerate(row):
            if e == 0:
                continue
            # ((y_j - mu)/s)^e = s^-e * sum_r C(e,r) y^r (-mu)^(e-r)
            expand = {
                r: math.comb(e, r) * ((-mean[j]) ** (e - r)) / (scale[j] ** e)
                for r in range(e + 1)
            }
            new_acc: dict[tuple, float] = {}
            for exps, c in acc.items():
                for r, cr in expand.items():
                    e2 = list(exps)
                    e2[j] += r
                    key = tuple(e2)
                    new_acc[key] = new_acc.get(key, 0.0) + c * cr
            acc = new_acc
        for exps, c in acc.items():
            T[k, index[exps]] += c
    return T


def denormalize_theta(
    theta_z: np.ndarray,  # [n_terms, n_state] coefficients in z coordinates
    mean: np.ndarray,
    scale: np.ndarray,
    n_vars: int,
    order: int,
    n_state: int | None = None,
) -> np.ndarray:
    """Map coefficients recovered on normalized windows to physical units.

    n_vars covers state (+ any unnormalized inputs appended: pass mean=0,
    scale=1 entries for those dims). Only the first n_state outputs are
    state derivatives (scaled by their own scale_i).
    """
    n_state = n_state if n_state is not None else theta_z.shape[1]
    mean = np.asarray(mean, float)
    scale = np.asarray(scale, float)
    if mean.shape[0] < n_vars:  # inputs appended unnormalized
        mean = np.concatenate([mean, np.zeros(n_vars - mean.shape[0])])
        scale = np.concatenate([scale, np.ones(n_vars - scale.shape[0])])
    T = normalization_transform(mean, scale, n_vars, order)
    theta_y = T.T @ np.asarray(theta_z, float)  # [n_terms, n_state]
    return theta_y * scale[None, :n_state]


class PolynomialLibrary:
    """Stateful convenience wrapper (static metadata + jitted evaluation)."""

    def __init__(self, n_vars: int, order: int, var_names: list[str] | None = None):
        self.n_vars = n_vars
        self.order = order
        self.n_terms = n_library_terms(n_vars, order)
        self.names = term_names(n_vars, order, var_names)
        self.exponents = exponent_table(n_vars, order)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return polynomial_features(x, self.n_vars, self.order)

    def __repr__(self) -> str:  # pragma: no cover
        return f"PolynomialLibrary(n={self.n_vars}, M={self.order}, terms={self.n_terms})"
