"""PINN + Sparse Regression baseline (Chen et al., Nature Comm. 2021 — ref [20]).

A tanh-MLP x_hat(t) fits the measurements; automatic differentiation provides
dx_hat/dt at collocation points; the physics residual ties the derivative to a
sparse combination of library terms:

    L = ||x_hat(t_i) - x_i||^2
      + w_phys * ||dx_hat/dt - Theta(x_hat, u) @ Xi||^2
      + w_l1 * ||Xi||_1

with periodic hard thresholding of Xi (the "SR" alternation). This is the
GPU-friendly dense-autodiff workload the paper contrasts with MERINDA.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.library import n_library_terms, polynomial_features
from repro.optim import adamw_init, adamw_update, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class PinnSRConfig:
    state_dim: int
    input_dim: int = 0
    order: int = 2
    width: int = 64
    depth: int = 3
    fourier_k: int = 16  # sin/cos(k t_hat) input features (spectral-bias fix)
    w_phys: float = 1.0
    w_l1: float = 1e-3
    threshold: float = 0.05
    threshold_every: int = 200

    @property
    def n_terms(self) -> int:
        return n_library_terms(self.state_dim + self.input_dim, self.order)


class PinnSRParams(NamedTuple):
    mlp: list  # [(w, b), ...]
    xi: jnp.ndarray  # [n_terms, n_state]
    xi_mask: jnp.ndarray  # [n_terms, n_state]


def init_pinn_sr(key: jax.Array, cfg: PinnSRConfig, dtype=jnp.float32) -> PinnSRParams:
    keys = jax.random.split(key, cfg.depth + 1)
    d_in = 1 + 2 * cfg.fourier_k
    dims = [d_in] + [cfg.width] * (cfg.depth - 1) + [cfg.state_dim]
    mlp = []
    for k, (di, do) in zip(keys, zip(dims[:-1], dims[1:])):
        w = (jax.random.normal(k, (di, do)) / jnp.sqrt(di)).astype(dtype)
        mlp.append((w, jnp.zeros((do,), dtype)))
    xi = jnp.zeros((cfg.n_terms, cfg.state_dim), dtype)
    return PinnSRParams(mlp=mlp, xi=xi, xi_mask=jnp.ones_like(xi))


def mlp_x(params: PinnSRParams, t: jnp.ndarray) -> jnp.ndarray:
    """t: [...,] -> x_hat [..., n_state]. Fourier-featurized input."""
    d_in = params.mlp[0][0].shape[0]
    K = (d_in - 1) // 2
    feats = [t[..., None]]
    if K:
        k = jnp.arange(1, K + 1, dtype=t.dtype)
        ang = t[..., None] * k  # t is trainer-normalized to ~N(0,1)
        feats += [jnp.sin(ang), jnp.cos(ang)]
    h = jnp.concatenate(feats, axis=-1)
    for i, (w, b) in enumerate(params.mlp):
        h = h @ w + b
        if i < len(params.mlp) - 1:
            h = jnp.tanh(h)
    return h


def pinn_sr_loss(params: PinnSRParams, cfg: PinnSRConfig, ts, xs, us=None):
    """ts: [N], xs: [N, n]. Physics residual via jvp-based time derivative."""
    x_hat = mlp_x(params, ts)
    data = jnp.mean((x_hat - xs) ** 2)

    # dx_hat/dt at collocation points (forward-mode through the scalar input)
    def x_of_t(t):
        return mlp_x(params, t)

    _, dx_dt = jax.jvp(x_of_t, (ts,), (jnp.ones_like(ts),))

    z = x_hat if us is None or cfg.input_dim == 0 else jnp.concatenate([x_hat, us], axis=-1)
    feats = polynomial_features(z, cfg.state_dim + cfg.input_dim, cfg.order)
    xi = params.xi * params.xi_mask
    phys = jnp.mean((dx_dt - feats @ xi) ** 2)
    l1 = jnp.mean(jnp.abs(xi))
    loss = data + cfg.w_phys * phys + cfg.w_l1 * l1
    return loss, {"data_mse": data, "phys_mse": phys, "l1": l1}


@partial(jax.jit, static_argnames=("cfg",))
def _pinn_step(params, opt_state, cfg: PinnSRConfig, ts, xs, us, lr):
    (loss, aux), grads = jax.value_and_grad(pinn_sr_loss, has_aux=True)(params, cfg, ts, xs, us)
    grads, _ = clip_by_global_norm(grads, 5.0)
    params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
    return params, opt_state, dict(aux, loss=loss)


def train_pinn_sr(
    cfg: PinnSRConfig,
    ts: jnp.ndarray,
    xs: jnp.ndarray,
    us: jnp.ndarray | None = None,
    steps: int = 2000,
    lr: float = 1e-2,
    seed: int = 0,
):
    # normalize the time input to O(1) — raw t saturates the tanh MLP and the
    # recovered xi is reported in normalized-time units (d/dt_hat)
    t_mu, t_sd = jnp.mean(ts), jnp.std(ts) + 1e-8
    ts = (ts - t_mu) / t_sd
    params = init_pinn_sr(jax.random.key(seed), cfg)
    opt_state = adamw_init(params)
    history = []
    for step in range(steps):
        params, opt_state, aux = _pinn_step(params, opt_state, cfg, ts, xs, us, lr)
        if step and step % cfg.threshold_every == 0:  # SR alternation
            mask = (jnp.abs(params.xi) >= cfg.threshold).astype(params.xi.dtype)
            params = params._replace(xi_mask=mask)
        if step % 100 == 0:
            history.append({k: float(v) for k, v in aux.items()} | {"step": step})
    return params, history


def recovered_xi(params: PinnSRParams) -> jnp.ndarray:
    return params.xi * params.xi_mask
