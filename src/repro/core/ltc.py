"""Liquid Time-Constant (LTC) cell — the paper's primary baseline.

LTC networks (Hasani et al.) modulate an input-driven nonlinear dynamical
system:

    dh/dt = -[1/tau + f(x, h)] * h + f(x, h) * A,     f = sigma(W x + U h + b)

and require an *iterative* solver per time step. Following the LTC reference
implementation the paper builds on ([5]), we use the fused semi-implicit
Euler update, N sub-steps per input sample:

    h_{k+1} = (h_k + dt * f * A) / (1 + dt * (1/tau + f))

Each sub-step contains exactly the profiled hotspots of paper Table 2:
recurrent sigmoid (f), sum operations, and the (fused) Euler update — and each
depends on the previous sub-step, which is the sequential bottleneck MERINDA
removes.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LTCParams(NamedTuple):
    w_in: jnp.ndarray  # [d_in, hidden]
    w_rec: jnp.ndarray  # [hidden, hidden]
    bias: jnp.ndarray  # [hidden]
    a: jnp.ndarray  # [hidden]   equilibrium target A
    inv_tau: jnp.ndarray  # [hidden]   1/tau (positive via softplus at init)


def init_ltc(key: jax.Array, d_in: int, hidden: int, dtype=jnp.float32) -> LTCParams:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / jnp.sqrt(d_in)
    scale_rec = 1.0 / jnp.sqrt(hidden)
    return LTCParams(
        w_in=(jax.random.normal(k1, (d_in, hidden)) * scale_in).astype(dtype),
        w_rec=(jax.random.normal(k2, (hidden, hidden)) * scale_rec).astype(dtype),
        bias=jnp.zeros((hidden,), dtype),
        a=(jax.random.normal(k3, (hidden,)) * 0.5).astype(dtype),
        inv_tau=jnp.ones((hidden,), dtype) * 0.5,
    )


def ltc_cell(
    params: LTCParams,
    x: jnp.ndarray,
    h: jnp.ndarray,
    dt: float | jnp.ndarray = 1.0,
    n_substeps: int = 6,
    unroll: int = 1,
) -> jnp.ndarray:
    """One LTC time step = n_substeps fused-solver iterations (sequential).

    x: [B, d_in], h: [B, hidden] -> new h [B, hidden]. ``unroll`` is the
    substep-loop unroll factor handed to lax.scan — a pure lowering knob
    (identical math at any value) the measured-cost autotuner searches over.
    """
    sub_dt = dt / n_substeps
    drive = x @ params.w_in + params.bias  # input part is loop-invariant

    def substep(h, _):
        f = jax.nn.sigmoid(drive + h @ params.w_rec)  # recurrent sigmoid (46.7%)
        num = h + sub_dt * f * params.a  # sum ops (34.4%)
        den = 1.0 + sub_dt * (params.inv_tau + f)  # fused Euler update (14.0%)
        return num / den, None

    h, _ = jax.lax.scan(substep, h, None, length=n_substeps, unroll=unroll)
    return h


def ltc_scan(
    params: LTCParams,
    xs: jnp.ndarray,
    h0: jnp.ndarray,
    dt: float = 1.0,
    n_substeps: int = 6,
    unroll: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run the LTC over a sequence. xs: [B, T, d_in] -> (h_T, hs [B, T, H])."""

    def body(h, x_t):
        h = ltc_cell(params, x_t, h, dt=dt, n_substeps=n_substeps, unroll=unroll)
        return h, h

    h_final, hs = jax.lax.scan(body, h0, jnp.swapaxes(xs, 0, 1))
    return h_final, jnp.swapaxes(hs, 0, 1)


def ltc_op_counts(d_in: int, hidden: int, n_substeps: int, batch: int = 1) -> dict:
    """Analytic per-time-step op counts (for the cycles/roofline benchmarks)."""
    mac_in = batch * d_in * hidden  # once per step
    mac_rec = batch * hidden * hidden * n_substeps  # every sub-step
    elementwise = batch * hidden * (6 * n_substeps)  # sigmoid/sum/div per sub-step
    return {
        "macs": mac_in + mac_rec,
        "elementwise": elementwise,
        "sequential_depth": n_substeps,
    }
