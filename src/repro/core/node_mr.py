"""NODE-based MR encoder (EMILY / PiNODE family baseline).

An ODE-RNN: between observations the hidden state evolves under a learned
vector field f_theta (MLP) integrated with N sequential solver sub-steps —
exactly the cost profile of paper Table 1 (ODE solver ~88% of forward pass,
6 sub-steps) — and at each observation the input is injected linearly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ode import multi_step_solver_cell


class NodeEncoderParams(NamedTuple):
    w_f1: jnp.ndarray  # [hidden, hidden]  vector-field MLP
    b_f1: jnp.ndarray
    w_f2: jnp.ndarray  # [hidden, hidden]
    b_f2: jnp.ndarray
    w_in: jnp.ndarray  # [d_in, hidden]   observation injection
    b_in: jnp.ndarray


def init_node_encoder(
    key: jax.Array, d_in: int, hidden: int, dtype=jnp.float32
) -> NodeEncoderParams:
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / jnp.sqrt(hidden)
    return NodeEncoderParams(
        w_f1=(jax.random.normal(k1, (hidden, hidden)) * s).astype(dtype),
        b_f1=jnp.zeros((hidden,), dtype),
        w_f2=(jax.random.normal(k2, (hidden, hidden)) * s * 0.1).astype(dtype),
        b_f2=jnp.zeros((hidden,), dtype),
        w_in=(jax.random.normal(k3, (d_in, hidden)) / jnp.sqrt(d_in)).astype(dtype),
        b_in=jnp.zeros((hidden,), dtype),
    )


def node_scan(
    params: NodeEncoderParams,
    xs: jnp.ndarray,
    h0: jnp.ndarray,
    dt: float | jnp.ndarray = 1.0,
    n_substeps: int = 6,
    unroll: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """ODE-RNN over a sequence. xs: [B, T, d_in] -> (h_T, hs [B, T, H]).

    Single source of truth for the NODE step math — ``node_encode`` (the
    registry row) and the fused mr_step oracle (kernels/mr_step/ref.py)
    both delegate here, mirroring ``ltc.ltc_scan``.
    """

    def field(h, u, t, args):
        z = jnp.tanh(h @ params.w_f1 + params.b_f1)
        return z @ params.w_f2 + params.b_f2

    def step(h, x_t):
        h = multi_step_solver_cell(
            field,
            h,
            x_t,
            jnp.asarray(dt, h.dtype),
            method="euler",
            n_substeps=n_substeps,
            unroll=unroll,
        )
        h = h + x_t @ params.w_in + params.b_in
        return h, h

    h_T, hs = jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))
    return h_T, jnp.swapaxes(hs, 0, 1)


def node_encode(params: NodeEncoderParams, xs: jnp.ndarray, cfg) -> jnp.ndarray:
    """xs: [B, T, d_in] -> h_T [B, hidden]. cfg provides dt and ltc_substeps."""
    B = xs.shape[0]
    h0 = jnp.zeros((B, params.w_f1.shape[0]), xs.dtype)
    h_T, _ = node_scan(
        params, xs, h0, dt=cfg.dt, n_substeps=cfg.ltc_substeps, unroll=cfg.substep_unroll
    )
    return h_T
