"""MR core: the paper's contribution.

- ode:          fixed-step ODE solvers (Euler/Heun/RK4) as lax.scan loops
- library:      polynomial candidate-function library for sparse regression
- sindy:        STLSQ (sequential thresholded least squares) SINDY baseline
- ltc:          Liquid Time-Constant cell with iterative fused ODE solver (paper baseline)
- neural_flow:  GRU-based neural flow cell (the paper's high-level substitution)
- merinda:      full MERINDA MR model (GRU -> dense sparse head -> ODE loss)
- node_mr:      NODE-based MR (EMILY/PiNODE-style baseline)
- pinn_sr:      PINN + sparse regression baseline
- quant:        fixed-point emulation + piecewise-linear (LUT-analogue) activations
"""
