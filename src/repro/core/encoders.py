"""Encoder registry: one table maps encoder names to init/encode backends.

Before this module, ``core/merinda.py`` carried two duplicated ``if
cfg.encoder == ...`` dispatch chains (one in ``init_mr``, one in the scan
path) plus a ``use_kernel`` boolean that silently rerouted only the GRU
families.  The registry collapses all of that into data: every encoder the
paper compares (and every backend it runs on) is ONE row here, and the
stage-pipeline refactor (kernels/mr_step) reads the same rows to decide
whether a config can take the fused per-window kernel.

Registered encoders:

    "gru_flow"         MERINDA GRU neural flow (lax.scan reference)
    "gru"              standard GRU, paper Eq. 12-15 (lax.scan reference)
    "ltc"              Liquid Time-Constant baseline (iterative fused solver)
    "node"             ODE-RNN / NODE baseline (EMILY/PiNODE family)
    "gru_flow_kernel"  gru_flow through the Pallas gru_scan kernel
    "gru_kernel"       gru through the Pallas gru_scan kernel

The ``*_kernel`` rows resolve their actual backend through
``kernels/runtime.resolve_dispatch`` (compiled kernel on TPU, lax.scan
reference on CPU/GPU), so a registry name is a *capability request*, not a
hard backend pin — the same config runs everywhere.

An ``EncoderSpec`` is a frozen record:

    init(key, d_in, hidden, dtype) -> encoder params pytree
    encode(enc_params, cfg, xs)    -> final hidden state [B, hidden]
    flow      time-gated flow update (None for non-GRU families)
    fusable   the fused mr_step kernel family implements this encoder
    kernel    encode routes through a Pallas kernel family
    int8      the fixed-point fused serving stage (int8 weights + PWL
              activations) implements this family — the standard GRU
              (paper Eq. 12-15) and the LTC substep cell (whose only
              nonlinearity is the recurrent sigmoid); the flow gate's
              softplus/tanh-of-dt chain has no PWL mapping, so the flow
              families stay float-serving

``encode`` owns the per-family quantization-aware weight treatment (the QAT
fake-quant previously inlined in merinda._encode), so callers never touch
family internals.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ltc import init_ltc, ltc_scan
from repro.core.neural_flow import GRUParams, gru_scan_ref, init_gru
from repro.core.quant import qat_weight


class EncoderSpec(NamedTuple):
    """One registry row; see module docstring for field semantics."""

    name: str
    init: Callable[..., Any]  # (key, d_in, hidden, dtype) -> params
    encode: Callable[..., jnp.ndarray]  # (params, cfg, xs) -> h_T [B, H]
    flow: bool | None  # GRU families: time-gated flow update?
    fusable: bool  # kernels/mr_step implements this encoder
    kernel: bool  # encode routes through a Pallas kernel
    int8: bool = False  # fixed-point fused serving stage exists
    # which mr_step kernel family (and VMEM residency model) a fusable row
    # lowers to: "gru" (single gated update), "ltc" (semi-implicit solver
    # substeps) or "node" (Euler substeps). Custom fusable rows default to
    # "gru" and must match its GRUParams layout.
    family: str = "gru"


_REGISTRY: dict[str, EncoderSpec] = {}


def register_encoder(spec: EncoderSpec) -> EncoderSpec:
    """Add (or replace) a registry row; returns the spec for chaining."""
    _REGISTRY[spec.name] = spec
    return spec


def get_encoder(name: str) -> EncoderSpec:
    if name not in _REGISTRY:
        raise ValueError(f"unknown encoder {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def encoder_names() -> list[str]:
    return sorted(_REGISTRY)


def fusable_names() -> list[str]:
    return [n for n in encoder_names() if _REGISTRY[n].fusable]


def int8_names() -> list[str]:
    """Encoders with a fixed-point (int8 + PWL) fused serving stage."""
    return [n for n in encoder_names() if _REGISTRY[n].int8]


def validate_config(cfg) -> EncoderSpec:
    """Eager (compile-time) validation of an MRConfig's encoder request.

    Raises ValueError for an unregistered encoder name AND for
    ``fused=True`` with a non-fusable encoder (a custom registry row
    without an mr_step lowering — every built-in family, including the
    multi-substep ``ltc``/``node`` cells, now has one) — the entry points
    (engine, streaming service, ``repro.api.compile_plan``) call this so a
    bad combination fails before any tracing, not as an opaque error deep
    inside a jitted scan (and never silently falls back to the unfused
    stage sequence).
    """
    spec = get_encoder(cfg.encoder)
    if getattr(cfg, "fused", False) and not spec.fusable:
        raise ValueError(
            f"MRConfig(fused=True) requires a fusable encoder, got {cfg.encoder!r} "
            f"(no fused mr_step stage exists for this family; fusable: {fusable_names()})"
        )
    return spec


def quantized_gru_params(params: GRUParams, cfg) -> GRUParams:
    """QAT weight treatment shared by every GRU-family encode path."""
    if cfg.quant is None:
        return params
    return params._replace(w=qat_weight(params.w, cfg.quant))


def _encode_gru_ref(params: GRUParams, cfg, xs: jnp.ndarray, *, flow: bool) -> jnp.ndarray:
    params = quantized_gru_params(params, cfg)
    h0 = jnp.zeros((xs.shape[0], cfg.hidden), xs.dtype)
    h_T, _ = gru_scan_ref(params, xs, h0, flow=flow)
    return h_T


def _encode_gru_kernel(params: GRUParams, cfg, xs: jnp.ndarray, *, flow: bool) -> jnp.ndarray:
    from repro.kernels.gru_scan.ops import gru_scan

    params = quantized_gru_params(params, cfg)
    h0 = jnp.zeros((xs.shape[0], cfg.hidden), xs.dtype)
    h_T, _ = gru_scan(params, xs, h0, flow=flow)
    return h_T


def _encode_ltc(params, cfg, xs: jnp.ndarray) -> jnp.ndarray:
    h0 = jnp.zeros((xs.shape[0], cfg.hidden), xs.dtype)
    h_T, _ = ltc_scan(
        params, xs, h0, dt=cfg.dt, n_substeps=cfg.ltc_substeps, unroll=cfg.substep_unroll
    )
    return h_T


def _init_node(key: jax.Array, d_in: int, hidden: int, dtype=jnp.float32):
    from repro.core.node_mr import init_node_encoder

    return init_node_encoder(key, d_in, hidden, dtype)


def _encode_node(params, cfg, xs: jnp.ndarray) -> jnp.ndarray:
    from repro.core.node_mr import node_encode

    return node_encode(params, xs, cfg)


def _gru_row(name: str, *, flow: bool, kernel: bool) -> EncoderSpec:
    encode = _encode_gru_kernel if kernel else _encode_gru_ref
    return EncoderSpec(
        name=name,
        init=init_gru,
        encode=lambda p, cfg, xs, _e=encode, _f=flow: _e(p, cfg, xs, flow=_f),
        flow=flow,
        fusable=True,
        kernel=kernel,
        int8=not flow,  # the int8 stage implements the standard cell only
    )


register_encoder(_gru_row("gru_flow", flow=True, kernel=False))
register_encoder(_gru_row("gru", flow=False, kernel=False))
register_encoder(_gru_row("gru_flow_kernel", flow=True, kernel=True))
register_encoder(_gru_row("gru_kernel", flow=False, kernel=True))
register_encoder(
    EncoderSpec(
        name="ltc",
        init=init_ltc,
        encode=_encode_ltc,
        flow=None,
        fusable=True,  # multi-substep fused-solver mr_step variant
        kernel=False,
        int8=True,  # substep nonlinearity is one sigmoid -> PWL-able
        family="ltc",
    )
)
register_encoder(
    EncoderSpec(
        name="node",
        init=_init_node,
        encode=_encode_node,
        flow=None,
        fusable=True,  # multi-substep Euler mr_step variant
        kernel=False,
        family="node",
    )
)
