"""Once-per-process DeprecationWarnings for the legacy entry points.

The deprecated wrappers (``engine.train_mr_scan``, ``engine.recover_many``,
direct ``RecoveryService(...)`` construction) sit on hot paths — a streaming
service tick loop or a benchmark sweep calls them hundreds of times — so a
plain ``warnings.warn`` floods the logs with identical lines (Python's
default ``__main__`` filter dedupes per call SITE and module, which resets
under pytest and still repeats across differing stacklevels). This registry
dedupes by KEY: the first call per process warns, every later one is free.
"""

from __future__ import annotations

import warnings

_WARNED: set[str] = set()


def warn_deprecated_once(key: str, message: str, *, stacklevel: int = 3) -> None:
    """Emit ``DeprecationWarning(message)`` once per process for ``key``."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def reset_warned() -> None:
    """Clear the registry (tests use this to re-arm the warnings)."""
    _WARNED.clear()
