from repro.runtime.heartbeat import HeartbeatRegistry, StragglerDetector
from repro.runtime.elastic import plan_mesh, plan_mesh_slots, shrink_plan
from repro.runtime.supervisor import Supervisor, SimulatedFailure
from repro.runtime.resilience import (
    ServiceCheckpointer,
    ServiceSupervisor,
    kill_shard_once,
    replan_spec,
)

__all__ = [
    "HeartbeatRegistry",
    "StragglerDetector",
    "plan_mesh",
    "plan_mesh_slots",
    "shrink_plan",
    "Supervisor",
    "SimulatedFailure",
    "ServiceCheckpointer",
    "ServiceSupervisor",
    "kill_shard_once",
    "replan_spec",
]
