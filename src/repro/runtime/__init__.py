from repro.runtime.heartbeat import HeartbeatRegistry, StragglerDetector
from repro.runtime.elastic import plan_mesh, shrink_plan
from repro.runtime.supervisor import Supervisor, SimulatedFailure

__all__ = [
    "HeartbeatRegistry",
    "StragglerDetector",
    "plan_mesh",
    "shrink_plan",
    "Supervisor",
    "SimulatedFailure",
]
