"""Multi-slice training: pod-local XLA steps + compressed cross-pod sync.

At real scale, cross-pod traffic rides DCN (not ICI) and is driven by the
host runtime (multi-slice MaxText / Pathways do exactly this): each slice
computes gradients on its own ICI-connected mesh, the host exchanges them
across slices, and the optimizer applies the synchronized gradient.

This module implements that pattern with int8 + per-tensor-scale + error-
feedback compression on the exchange (optim/compression.py math), which is
where compression belongs — DCN bandwidth is the scarce resource, and the
ICI-side collectives inside each slice stay full-precision.

On this host "slices" are simulated as S sequential pod-local jit calls over
the same devices; the exchange code path (quantize -> sum -> dequantize ->
error feedback) is identical to what a DCN transport would carry.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def _quantize(g: np.ndarray) -> tuple[np.ndarray, float]:
    amax = float(np.max(np.abs(g))) if g.size else 0.0
    scale = max(amax, 1e-30) / 127.0
    q = np.clip(np.rint(g / scale), -127, 127).astype(np.int8)
    return q, scale


def compressed_cross_slice_mean(
    per_slice_grads: list[Any],
    errors: list[Any] | None,
) -> tuple[Any, list[Any]]:
    """int8(+EF) all-reduce-mean across slices, host side.

    per_slice_grads: list (len S) of grad pytrees (same structure).
    errors: per-slice error-feedback pytrees (or None to init zeros).
    Returns (mean_grads pytree, new per-slice errors).
    """
    S = len(per_slice_grads)
    leaves = [jax.tree.leaves(g) for g in per_slice_grads]
    treedef = jax.tree.structure(per_slice_grads[0])
    if errors is None:
        err_leaves = [[np.zeros(np.shape(x), np.float32) for x in leaves[0]] for _ in range(S)]
    else:
        err_leaves = [list(map(np.asarray, jax.tree.leaves(e))) for e in errors]

    n_leaves = len(leaves[0])
    mean_leaves = []
    for i in range(n_leaves):
        acc = None
        for s in range(S):
            g = np.asarray(leaves[s][i], np.float32) + err_leaves[s][i]
            q, scale = _quantize(g)  # <- the DCN payload: int8 + one scale
            deq = q.astype(np.float32) * scale
            err_leaves[s][i] = g - deq  # error feedback
            acc = deq if acc is None else acc + deq
        mean_leaves.append(acc / S)
    mean = jax.tree.unflatten(treedef, mean_leaves)
    new_errors = [jax.tree.unflatten(treedef, e) for e in err_leaves]
    return mean, new_errors


class MultiSliceTrainer:
    """S simulated slices: grad per slice -> compressed exchange -> update.

    grad_fn(params, batch) -> (loss, grads)   pod-local jitted program
    update_fn(params, opt_state, grads) -> (params, opt_state)
    """

    def __init__(self, grad_fn: Callable, update_fn: Callable, n_slices: int = 2,
                 compress: bool = True):
        self.grad_fn = grad_fn
        self.update_fn = update_fn
        self.n_slices = n_slices
        self.compress = compress
        self._errors: list[Any] | None = None

    def step(self, params, opt_state, slice_batches: list[Any]):
        assert len(slice_batches) == self.n_slices
        losses, grads = [], []
        for b in slice_batches:  # one jit call per slice (DCN boundary)
            l, g = self.grad_fn(params, b)
            losses.append(float(l))
            grads.append(g)
        if self.compress:
            mean, self._errors = compressed_cross_slice_mean(grads, self._errors)
            mean = jax.tree.map(jnp.asarray, mean)
        else:
            mean = jax.tree.map(lambda *xs: sum(xs) / len(xs), *grads)
        params, opt_state = self.update_fn(params, opt_state, mean)
        return params, opt_state, float(np.mean(losses))
