"""Serving resilience: periodic service snapshots + supervised restart.

The training side already has the full stack — atomic async checkpoints
(checkpoint/checkpoint.py), elastic mesh re-planning (runtime/elastic.py),
heartbeats/stragglers (runtime/heartbeat.py) and a supervised restart loop
(runtime/supervisor.py). This module is the SERVING analogue for the
slot-streaming recovery service (core/stream.py):

- :class:`ServiceCheckpointer` — every ``period`` ticks, stage the whole
  service image (SlotState, the device-resident ControlState, the warm-start
  LRU, the tick counter and any supervisor extras) and hand it to
  ``CheckpointManager`` for an async, atomic, CRC-checked write. Restore
  ``device_put``s every slot/control leaf with the CURRENT plan's shardings,
  so a snapshot written on a ("slots",)-mesh of 2 restores onto the shrunken
  1-device plan — reshard-on-restore for the serving state.
- :class:`ServiceSupervisor` — owns the serve loop. On a shard failure
  (:class:`~repro.runtime.supervisor.SimulatedFailure` from a chaos hook) it
  waits out the in-flight snapshot write, drops the lost devices, re-plans
  the slot mesh on the survivors (``plan_mesh_slots``), recompiles the plan
  (``api.compile_plan``), restores the latest snapshot onto the new mesh and
  re-submits every stream the restored image does not already hold — no
  stream is lost, at worst a stream replays the ticks since the snapshot.

Restore rewinds ``service.ticks`` to the snapshot tick, and every tick's
randomness is ``fold_in(key, ticks)``, so a same-mesh restore replays the
exact pre-failure trajectory (tests pin bitwise SlotState/ControlState
round-trip parity). The ControlState is restored only when the shard count
survives unchanged (its leaves are shaped [shards, ...]); on a re-mesh the
queues restart empty and the supervisor re-submits the queued streams.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import pathlib
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.checkpoint.checkpoint import _flatten, _logical_view, restore_checkpoint
from repro.runtime.elastic import plan_mesh_slots
from repro.runtime.supervisor import SimulatedFailure

log = logging.getLogger("repro.resilience")


def _slot_shardings(tree, mesh):
    """Per-leaf NamedSharding over the ("slots",) axis — the same placement
    rule ``shard_slots``/``shard_control`` pin, applied at restore time."""
    from repro.core.stream import SLOT_RULES
    from repro.parallel import named_sharding

    def one(leaf):
        axes = ("slots",) + (None,) * (leaf.ndim - 1)
        return named_sharding(mesh, leaf.shape, axes, SLOT_RULES)

    return jax.tree.map(one, tree)


class ServiceCheckpointer:
    """Periodic async snapshots of a RecoveryService; restore with resharding.

    Attached by ``RecoveryPlan.make_service`` when the TickSpec carries
    ``checkpoint_period``/``checkpoint_dir``; ``RecoveryService.tick_once``
    calls :meth:`after_tick` every tick (a no-op off the period).

    ``extra`` is a host-side dict of arrays snapshotted alongside the
    service image — the supervisor keeps its stream cursors there so a
    restart resumes feeding each stream where the snapshot left off.
    """

    def __init__(self, root: str, period: int, keep: int = 3):
        self.period = int(period)
        self.manager = CheckpointManager(root, keep=keep, save_every=self.period)
        self.extra: dict[str, np.ndarray] = {}

    # -- save ---------------------------------------------------------------
    def _stage(self, service) -> dict:
        tree: dict[str, Any] = {"slots": service.state, "ticks": np.int64(service.ticks)}
        if service.control is not None:
            tree["control"] = service.control
        # warm-start LRU: one params subtree per entry + the LRU order, so a
        # restored service serves the same warm hits the failed one would
        tree["warm"] = {str(sid): params for sid, params in service.warm.items()}
        tree["warm_order"] = np.asarray(list(service.warm.keys()), np.int64)
        for k, v in self.extra.items():
            tree[f"extra/{k}"] = np.asarray(v)
        return tree

    def after_tick(self, service):
        """Snapshot when the tick counter crosses the period (else no-op —
        a steady-state tick pays nothing, keeping the zero-readback gate)."""
        if self.period <= 0 or service.ticks % self.period:
            return
        self.save(service)

    def save(self, service):
        """Stage device->host now (one counted sync), write async."""
        tree = self._stage(service)
        service.counters["host_syncs"] += 1
        self.manager.maybe_save(service.ticks, tree, mesh=service.mesh, force=True)

    def wait(self):
        self.manager.wait()

    # -- restore ------------------------------------------------------------
    def restore_into(self, service) -> dict | None:
        """Restore the latest snapshot into a FRESH service, resharding every
        slot/control leaf onto the service's current mesh.

        Returns ``{"step", "resident", "queued", "extra"}`` (None when no
        snapshot exists). The ControlState is taken only when its shard count
        matches the restoring plan; otherwise the queues restart empty and
        ``queued`` is what the caller must re-submit.
        """
        self.manager.wait()
        step = self.manager.latest()
        if step is None:
            return None
        d = pathlib.Path(self.manager.root) / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = manifest["leaves"]

        like: dict[str, Any] = {"slots": service.state}
        shardings: dict[str, Any] | None = None
        if service.mesh is not None:
            shardings = {"slots": _slot_shardings(service.state, service.mesh)}
        take_control = False
        if service.control is not None:
            ctl_flat = _flatten(service.control)
            take_control = all(
                f"control/{k}" in leaves
                and leaves[f"control/{k}"]["shape"] == list(v.shape)
                for k, v in ctl_flat
            )
            if take_control:
                like["control"] = service.control
                if shardings is not None:
                    shardings["control"] = _slot_shardings(service.control, service.mesh)

        expect_axes = ("slots",) if service.mesh is not None else None
        restored, _ = restore_checkpoint(
            self.manager.root, step, like, shardings, expect_axes=expect_axes
        )
        service.state = restored["slots"]
        if take_control:
            service.control = restored["control"]
        service.ticks = int(np.load(d / leaves["ticks"]["file"]))

        self._restore_warm(service, d, leaves)
        extra = {
            k[len("extra/") :]: np.load(d / meta["file"])
            for k, meta in leaves.items()
            if k.startswith("extra/")
        }
        resident, queued = self._rebuild_views(service, take_control)
        log.info(
            "restored service snapshot step=%d (%d resident, %d queued, control=%s)",
            step,
            len(resident),
            len(queued),
            "restored" if take_control else "reset",
        )
        return {"step": step, "resident": resident, "queued": queued, "extra": extra}

    def _restore_warm(self, service, d: pathlib.Path, leaves: dict):
        from repro.core.stream import cold_start

        order_meta = leaves.get("warm_order")
        if order_meta is None:
            return
        warm_order = [int(s) for s in np.load(d / order_meta["file"])]
        if not warm_order:
            return
        template, _ = cold_start(jax.random.fold_in(service.key, 0), service.cfg)
        tpaths = _flatten(template)
        treedef = jax.tree_util.tree_structure(template)
        for sid in warm_order:
            vals = []
            for pkey, _leaf in tpaths:
                meta = leaves.get(f"warm/{sid}/{pkey}")
                if meta is None:
                    vals = None
                    break
                vals.append(
                    jax.numpy.asarray(_logical_view(np.load(d / meta["file"]), meta["dtype"]))
                )
            if vals is not None:
                service.warm[sid] = treedef.unflatten(vals)
        while len(service.warm) > service.warm_capacity:
            service.warm.popitem(last=False)

    @staticmethod
    def _rebuild_views(service, take_control: bool) -> tuple[set[int], set[int]]:
        """Refresh the host-side caches from the restored image (restore-time
        readbacks — the running service never repeats them)."""
        sid_view = np.asarray(service.state.stream_id)
        service._active_view = np.asarray(service.state.active, bool).copy()
        service._slot_view = sid_view.astype(np.int64)
        service._delta_view = np.asarray(service.state.delta, np.float32).copy()
        service._loss_view = np.asarray(service.state.loss, np.float32).copy()
        service._steps_view = np.asarray(service.state.steps).astype(np.int64)
        resident = {int(i) for i in sid_view if i >= 0}
        queued: set[int] = set()
        if service.control_plane is not None:
            service._inflight = [set() for _ in range(service.control_plane.shards)]
            if take_control:
                for row, ids in enumerate(np.asarray(service.control.q_ids)):
                    for sid in ids:
                        if sid >= 0:
                            service._inflight[row].add(int(sid))
                            queued.add(int(sid))
            service._pending = resident | queued
            service._seen_done = set()
            service._ticks_since_snapshot = 0
        return resident, queued


def replan_spec(spec, n_available: int):
    """Shrink a stream RecoverySpec's slot mesh onto ``n_available`` devices
    (largest divisor of n_slots that fits — ``plan_mesh_slots``)."""
    plan = plan_mesh_slots(n_available, spec.n_slots)
    return dataclasses.replace(spec, mesh_slots=plan.shape[0])


def kill_shard_once(at_tick: int, n_lost: int = 1) -> Callable[[int], None]:
    """Chaos hook: lose ``n_lost`` device(s) at the first tick >= at_tick
    (fires exactly once; the supervisor's restart must absorb it)."""
    state = {"fired": False}

    def chaos(tick: int):
        if not state["fired"] and tick >= at_tick:
            state["fired"] = True
            raise SimulatedFailure(n_lost)

    return chaos


class ServiceSupervisor:
    """Drives a streaming RecoverySpec through shard failures.

    Owns the serve loop (the chunk-routing pattern of
    ``launch/serve_mr.run_service``) plus the restart path: on a
    :class:`SimulatedFailure` it re-plans the slot mesh on the surviving
    devices, recompiles the plan, restores the latest service snapshot with
    resharding and re-submits any stream the restored image dropped.
    ``chaos(tick)`` may raise SimulatedFailure (tests / chaos configs).
    """

    def __init__(
        self,
        spec,
        ckpt_dir: str,
        checkpoint_period: int = 4,
        max_restarts: int = 4,
        chaos: Callable[[int], None] | None = None,
        devices: list | None = None,
        keep: int = 3,
    ):
        if spec.mode != "stream":
            raise ValueError(f"ServiceSupervisor serves stream plans, got mode={spec.mode!r}")
        self.base_spec = spec
        self.ckpt_dir = str(ckpt_dir)
        self.checkpoint_period = int(checkpoint_period)
        self.max_restarts = int(max_restarts)
        self.chaos = chaos
        self.devices = list(devices if devices is not None else jax.devices())
        self.keep = keep
        self.restarts = 0
        self.history: list[dict] = []  # per-incarnation stats
        self.spec = self.plan = self.service = None
        self._compile(len(self.devices))

    def _compile(self, n_available: int):
        from repro.api.plan import compile_plan

        spec = replan_spec(self.base_spec, n_available)
        tspec = dataclasses.replace(
            spec.tick_spec(),
            checkpoint_period=self.checkpoint_period,
            checkpoint_dir=self.ckpt_dir,
        )
        self.spec = spec = dataclasses.replace(spec, tick=tspec)
        self.plan = compile_plan(spec)
        self.service = self.plan.make_service()
        return self.service

    def _incarnation_stats(self) -> dict:
        svc = self.service
        return {
            "ticks": svc.ticks,
            "tick_ms": list(svc.tick_ms),
            "counters": dict(svc.counters),
            "sync_log": list(svc.sync_log),
            "mesh_shape": tuple(self.plan.lowering.mesh_shape),
        }

    def serve(self, ys: np.ndarray, us: np.ndarray | None = None, max_ticks: int = 400) -> dict:
        """Feed every stream through the service until all recover (or the
        tick budget runs out), absorbing injected shard failures.

        ys [R, T_total, n] / us [R, T_total, m]; cursors wrap modulo T_total
        (a slow or replayed stream never starves). Returns the summary dict
        (results, recovered_streams_fraction, restarts, tick latencies).
        """
        svc = self.service
        n_streams, t_total = ys.shape[:2]
        if us is None:
            us = np.zeros(ys.shape[:2] + (svc.cfg.input_dim,), np.float32)
        L = svc.scfg.buf_len
        results: dict[int, Any] = {}
        cursors = {i: L for i in range(n_streams)}
        for i in range(n_streams):
            svc.submit(i, ys[i, :L], us[i, :L])
        svc.fill_slots()
        total_ticks = 0
        while len(results) < n_streams and total_ticks < max_ticks:
            try:
                if self.chaos is not None:
                    self.chaos(total_ticks)
                svc = self.service
                slots, chunk = svc.n_slots, svc.scfg.chunk
                chunks_y = np.zeros((slots, chunk, svc.cfg.state_dim), np.float32)
                chunks_u = np.zeros((slots, chunk, svc.cfg.input_dim), np.float32)
                for s, sid in enumerate(svc.slot_streams()):
                    if sid < 0:
                        continue
                    idx = (cursors[sid] + np.arange(chunk)) % t_total
                    chunks_y[s] = ys[sid, idx]
                    chunks_u[s] = us[sid, idx]
                    cursors[sid] += chunk
                if svc.checkpointer is not None:
                    # stamp cursors BEFORE the tick: a snapshot taken inside
                    # tick_once then restores a consistent (state, cursor) pair
                    svc.checkpointer.extra["cursors"] = np.asarray(
                        [cursors[i] for i in range(n_streams)], np.int64
                    )
                svc.tick_once(chunks_y, chunks_u)
                total_ticks += 1
                results.update(svc.results)
            except SimulatedFailure as e:
                results.update(self.service.results)
                self._recover(e, ys, us, cursors, results, t_total)
        self.history.append(self._incarnation_stats())
        results.update(self.service.results)
        all_ms = [t for h in self.history for t in h["tick_ms"]]
        return {
            "results": results,
            "ticks": total_ticks,
            "restarts": self.restarts,
            "recovered_streams_fraction": len(results) / max(n_streams, 1),
            "p50_tick_ms": float(np.percentile(all_ms, 50)) if all_ms else 0.0,
            "p99_tick_ms": float(np.percentile(all_ms, 99)) if all_ms else 0.0,
            "straggler_flags": list(self.service.straggler_flags),
            "final_mesh": tuple(self.plan.lowering.mesh_shape),
            "counters": {
                k: sum(h["counters"][k] for h in self.history)
                for k in ("host_syncs", "reshards")
            },
        }

    def _recover(self, e: SimulatedFailure, ys, us, cursors, results, t_total: int):
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError("restart budget exhausted") from e
        if e.n_lost >= len(self.devices):
            raise RuntimeError("no surviving devices") from e
        old = self.service
        if old.checkpointer is not None:
            old.checkpointer.wait()  # never restore a torn in-flight write
        self.history.append(self._incarnation_stats())
        log.warning("shard failure (%s); re-meshing on survivors", e)
        # surviving devices: drop from the tail (the lost shard's chips)
        self.devices = self.devices[: len(self.devices) - e.n_lost]
        svc = self._compile(len(self.devices))
        info = svc.checkpointer.restore_into(svc) if svc.checkpointer is not None else None
        safe: set[int] = set()
        if info is not None:
            safe = info["resident"] | info["queued"]
            saved = info["extra"].get("cursors")
            if saved is not None:
                for i in range(min(len(cursors), len(saved))):
                    cursors[i] = int(saved[i])
        else:
            # failed before the first snapshot: every stream restarts from
            # its initial history
            for i in cursors:
                cursors[i] = svc.scfg.buf_len
        L = svc.scfg.buf_len
        for sid in sorted(cursors):
            if sid in results or sid in svc.results or sid in safe:
                continue
            idx = (cursors[sid] - L + np.arange(L)) % t_total
            svc.submit(sid, ys[sid, idx], us[sid, idx])
        svc.fill_slots()
