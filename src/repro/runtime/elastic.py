"""Elastic mesh planning: largest healthy mesh after failures.

Policy (documented in DESIGN.md §5): shrink the DATA axis first — model/TP
degree is dictated by per-layer weight shapes and changing it reshapes every
compiled program, while data-parallel width only rescales throughput. Pods
drop next (a whole pod lost); the model axis is preserved unless fewer than
``model`` devices survive.

``plan_mesh`` is pure (unit-testable); ``build_mesh`` materializes it.
"""

from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def plan_mesh(
    n_available: int,
    model: int = 16,
    max_data: int = 16,
    pods: int = 1,
) -> MeshPlan:
    """Largest (pod, data, model) mesh fitting n_available devices.

    data is kept a power of two (keeps global batch divisible and collectives
    ring-friendly); model is preserved if at all possible.
    """
    if n_available < 1:
        raise ValueError("no devices")
    model_eff = model
    while model_eff > n_available:
        model_eff //= 2
    per_pod_target = max_data * model_eff
    pods_eff = max(1, min(pods, n_available // per_pod_target))
    data = _pow2_floor(max(1, n_available // (pods_eff * model_eff)))
    data = min(data, max_data)
    if pods_eff > 1:
        return MeshPlan((pods_eff, data, model_eff), ("pod", "data", "model"))
    return MeshPlan((data, model_eff), ("data", "model"))


def plan_mesh_slots(n_available: int, n_slots: int) -> MeshPlan:
    """Largest 1-D ``("slots",)`` mesh fitting n_available devices.

    The serving mesh shards the slot axis, so the device count must divide
    ``n_slots`` (shard_slots requires equal per-shard slot counts). Picks the
    largest divisor of n_slots that fits — after a shard failure the service
    restores onto this plan (runtime/resilience.py).
    """
    if n_available < 1:
        raise ValueError("no devices")
    if n_slots < 1:
        raise ValueError("no slots")
    d = min(n_available, n_slots)
    while n_slots % d:
        d -= 1
    return MeshPlan((d,), ("slots",))


def shrink_plan(current: MeshPlan, n_failed: int) -> MeshPlan:
    """Re-plan after n_failed devices drop out of the current mesh."""
    return plan_mesh(
        current.n_devices - n_failed,
        model=current.shape[-1],
        max_data=current.shape[-2],
        pods=current.shape[0] if len(current.shape) == 3 else 1,
    )


def build_mesh(plan: MeshPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    n = plan.n_devices
    if n > len(devices):
        raise ValueError(f"plan needs {n} devices, have {len(devices)}")
    import numpy as np

    arr = np.asarray(devices[:n]).reshape(plan.shape)
    return jax.sharding.Mesh(arr, plan.axes)
