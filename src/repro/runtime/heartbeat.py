"""Worker heartbeats + straggler detection.

At real multi-pod scale every host runs a heartbeat thread that reports
(host_id, step, step_time) to this registry (backed by the coordination
service / jax.distributed KV store); here it is an in-process registry with
identical semantics, exercised by the supervisor and tests.

Straggler rule (robust, scale-free): a worker is a straggler when its recent
mean step time exceeds ``median + k * MAD`` across workers (k=5 by default)
for at least ``patience`` consecutive checks. MAD-based thresholds don't
false-positive when the whole fleet slows together (e.g. checkpoint write).

Dead-worker rule: no heartbeat for ``timeout`` seconds.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict, deque


@dataclasses.dataclass
class WorkerStat:
    last_seen: float
    step: int
    times: deque  # recent step durations


class HeartbeatRegistry:
    def __init__(self, window: int = 16, timeout: float = 60.0):
        self.window = window
        self.timeout = timeout
        self._lock = threading.Lock()
        self._workers: dict[str, WorkerStat] = {}

    def beat(self, worker: str, step: int, step_time: float, now: float | None = None):
        now = time.time() if now is None else now
        with self._lock:
            st = self._workers.get(worker)
            if st is None:
                st = self._workers[worker] = WorkerStat(now, step, deque(maxlen=self.window))
            st.last_seen = now
            st.step = step
            st.times.append(step_time)

    def workers(self) -> list[str]:
        with self._lock:
            return sorted(self._workers)

    def dead(self, now: float | None = None) -> list[str]:
        now = time.time() if now is None else now
        with self._lock:
            return sorted(w for w, st in self._workers.items() if now - st.last_seen > self.timeout)

    def mean_times(self) -> dict[str, float]:
        with self._lock:
            return {
                w: (sum(st.times) / len(st.times))
                for w, st in self._workers.items()
                if st.times
            }

    def remove(self, worker: str):
        with self._lock:
            self._workers.pop(worker, None)


def _median(xs: list[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


class StragglerDetector:
    """median + k*MAD rule with a consecutive-hits requirement."""

    def __init__(self, registry: HeartbeatRegistry, k: float = 5.0, patience: int = 3):
        self.registry = registry
        self.k = k
        self.patience = patience
        self._hits: dict[str, int] = defaultdict(int)

    def check(self) -> list[str]:
        """Returns workers currently flagged as stragglers."""
        means = self.registry.mean_times()
        if len(means) < 3:
            return []
        vals = list(means.values())
        med = _median(vals)
        mad = _median([abs(v - med) for v in vals]) or 1e-9
        thresh = med + self.k * mad
        flagged = []
        for w, v in means.items():
            if v > thresh:
                self._hits[w] += 1
                if self._hits[w] >= self.patience:
                    flagged.append(w)
            else:
                self._hits[w] = 0
        return sorted(flagged)
