"""Training supervisor: checkpoint/restart + elastic re-mesh on failure.

The supervisor owns the train loop. Each step it:
  1. runs the jitted step on the current mesh,
  2. beats the heartbeat registry and polls the straggler detector,
  3. periodically checkpoints (async, atomic - checkpoint/checkpoint.py).

On failure (a real XlaRuntimeError from a lost device, or a
``SimulatedFailure`` injected by tests/chaos config):
  a. waits for any in-flight checkpoint write, then
  b. re-plans the mesh on the surviving device set (runtime/elastic.py,
     data axis shrinks first),
  c. rebuilds + recompiles the step function for the new mesh,
  d. restores the latest checkpoint WITH resharding (device_put under the
     new mesh's shardings),
  e. resumes from the restored step.

This is the standard supervised-restart pattern (MaxText/Pathways-style);
everything here is mesh-size agnostic, so the same code path drives 4 hosts
or 1000.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax

from repro.checkpoint import CheckpointManager
from repro.runtime.elastic import MeshPlan, build_mesh, plan_mesh
from repro.runtime.heartbeat import HeartbeatRegistry, StragglerDetector

log = logging.getLogger("repro.supervisor")


class SimulatedFailure(Exception):
    """Raised by chaos hooks to emulate a device/host loss."""

    def __init__(self, n_lost: int = 1):
        self.n_lost = n_lost
        super().__init__(f"simulated loss of {n_lost} device(s)")


@dataclasses.dataclass
class SupervisorConfig:
    max_steps: int = 1000
    save_every: int = 50
    keep: int = 3
    max_restarts: int = 8


class Supervisor:
    """Drives (build_step, init_state) through failures.

    build_step(mesh) -> (step_fn, state_shardings, init_state_fn)
        step_fn(state, batch) -> (state, metrics); compiled per mesh.
    next_batch(step, mesh) -> batch pytree (data pipeline is step-addressable
        so restarts re-read the right batch — data/pipeline.py).
    chaos(step) -> None or raises SimulatedFailure (tests).
    """

    def __init__(
        self,
        build_step: Callable,
        next_batch: Callable,
        ckpt_dir: str,
        cfg: SupervisorConfig | None = None,
        chaos: Callable[[int], None] | None = None,
        devices: list | None = None,
    ):
        self.build_step = build_step
        self.next_batch = next_batch
        self.cfg = cfg = cfg if cfg is not None else SupervisorConfig()
        self.chaos = chaos
        self.devices = list(devices if devices is not None else jax.devices())
        self.ckpt = CheckpointManager(ckpt_dir, keep=cfg.keep, save_every=cfg.save_every)
        self.registry = HeartbeatRegistry()
        self.stragglers = StragglerDetector(self.registry)
        self.restarts = 0
        self.history: list[dict] = []

    def _make(self, plan: MeshPlan):
        mesh = build_mesh(plan, self.devices)
        step_fn, shardings, init_state = self.build_step(mesh)
        return mesh, step_fn, shardings, init_state

    def run(self, initial_plan: MeshPlan | None = None) -> dict:
        plan = initial_plan or plan_mesh(len(self.devices))
        mesh, step_fn, shardings, init_state = self._make(plan)
        state = init_state()
        step = 0

        # resume if a checkpoint exists (restart-from-scratch case)
        restored, manifest = self.ckpt.restore_latest(state, shardings)
        if restored is not None:
            state, step = restored, manifest["step"] + 1
            log.info("resumed from step %d", manifest["step"])

        while step < self.cfg.max_steps:
            try:
                if self.chaos is not None:
                    self.chaos(step)
                t0 = time.time()
                batch = self.next_batch(step, mesh)
                state, metrics = step_fn(state, batch)
                dt = time.time() - t0
                self.registry.beat("host0", step, dt)
                flagged = self.stragglers.check()
                if flagged:
                    log.warning("stragglers at step %d: %s", step, flagged)
                self.ckpt.maybe_save(step, state, mesh)
                self.history.append(
                    {"step": step, "mesh": plan.shape, "t": dt,
                     "loss": float(metrics.get("loss", float("nan")))}
                )
                step += 1
            except SimulatedFailure as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                log.warning("failure at step %d (%s); re-meshing", step, e)
                self.ckpt.wait()
                # surviving devices: drop from the tail (a lost host's chips)
                self.devices = self.devices[: len(self.devices) - e.n_lost]
                plan = plan_mesh(
                    len(self.devices),
                    model=plan.shape[-1],
                    max_data=plan.shape[-2] if len(plan.shape) >= 2 else 1,
                    pods=plan.shape[0] if len(plan.shape) == 3 else 1,
                )
                mesh, step_fn, shardings, init_state = self._make(plan)
                state = init_state()
                restored, manifest = self.ckpt.restore_latest(state, shardings)
                if restored is not None:
                    state, step = restored, manifest["step"] + 1
                else:  # failed before the first checkpoint
                    state, step = init_state(), 0

        self.ckpt.wait()
        return {
            "final_step": step,
            "restarts": self.restarts,
            "final_mesh": plan.shape,
            "history": self.history,
        }
