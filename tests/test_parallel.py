"""Distribution layer: sharded steps, pipeline parallelism, compression.

Multi-device behaviour runs in subprocesses (conftest.run_devices) so the
main pytest process keeps the real single-device backend.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from conftest import run_devices


def test_sharded_train_step_matches_single_device():
    """Same batch + params: loss on a (2,2) mesh == loss on 1 device."""
    run_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config, ShapeConfig
        from repro.models import model as M
        from repro.parallel import rules as rules_mod
        from repro.parallel.steps import make_train_step, train_state_specs, TrainState
        from repro.models.params import materialize

        cfg = get_config("qwen2.5-3b", smoke=True)
        shape = ShapeConfig("t", 32, 4, "train")
        key = jax.random.key(0)
        params = materialize(key, train_state_specs(cfg).params)
        batch = {
            "tokens": jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size, jnp.int32),
            "labels": jax.random.randint(jax.random.key(2), (4, 32), 0, cfg.vocab_size, jnp.int32),
        }
        # single-device reference
        loss_ref, _ = jax.jit(lambda p, b: M.train_loss(p, b, cfg))(params, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = rules_mod.DEFAULT_RULES
        with rules_mod.use_mesh_rules(mesh, rules):
            jitted, state_sh, batch_sh, _ = make_train_step(cfg, shape, mesh, rules, donate=False)
            zeros = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
            state = TrainState(params=params, m=zeros, v=jax.tree.map(jnp.copy, zeros),
                               step=jnp.zeros((), jnp.int32))
            state = jax.device_put(state, state_sh)
            b = jax.device_put(batch, batch_sh)
            new_state, metrics = jitted(state, b)
        assert abs(float(metrics["loss"]) - float(loss_ref)) < 0.05, \
            (float(metrics["loss"]), float(loss_ref))
        # params actually updated
        delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b2.astype(jnp.float32))))
                    for a, b2 in zip(jax.tree.leaves(params), jax.tree.leaves(new_state.params)))
        assert delta > 0
        print("PASS")
        """,
        n_devices=4,
    )


def test_pipeline_matches_sequential():
    run_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_spmd, make_pp_mesh, bubble_fraction
        L, D, M, mb = 8, 16, 6, 4
        key = jax.random.key(0)
        ws = jax.random.normal(key, (L, D, D)) * (1.0 / D**0.5)
        layer_fn = lambda lp, x: jnp.tanh(x @ lp)
        x = jax.random.normal(key, (M, mb, D))
        mesh = make_pp_mesh(4, 1)
        y_pp = pipeline_spmd(layer_fn, ws, x, mesh)
        def seq(w, xm):
            return jax.lax.scan(lambda c, wi: (jnp.tanh(c @ wi), None), xm, w)[0]
        y_ref = jax.vmap(lambda xm: seq(ws, xm))(x)
        np.testing.assert_allclose(np.asarray(y_pp), np.asarray(y_ref), atol=1e-6)
        g_pp = jax.grad(lambda w: jnp.sum(pipeline_spmd(layer_fn, w, x, mesh)**2))(ws)
        g_ref = jax.grad(lambda w: jnp.sum(jax.vmap(lambda xm: seq(w, xm))(x)**2))(ws)
        np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_ref), atol=1e-5)
        assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
        print("PASS")
        """,
        n_devices=4,
    )


def test_grad_compression_int8_error_feedback():
    """Compressed psum with error feedback: bias vanishes across steps."""
    run_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compress_reduce_grads, init_error_buffers
        from repro.parallel.pipeline import shard_map  # check_rep/check_vma compat
        mesh = jax.make_mesh((4,), ("pod",))
        g_global = jax.random.normal(jax.random.key(0), (4, 64, 8))  # per-pod grads
        mean_ref = jnp.mean(g_global, axis=0)

        def body(g, e):
            out, e2 = compress_reduce_grads({"w": g[0]}, {"w": e[0]}, "pod")
            return out["w"], e2["w"]

        fn = shard_map(body, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P(), P("pod")), check_replication=False)
        # one step: quantization error bounded
        e0 = jnp.zeros_like(g_global)
        red1, e1 = fn(g_global, e0)
        amax = float(jnp.max(jnp.abs(g_global)))
        assert float(jnp.max(jnp.abs(red1 - mean_ref))) < amax / 127.0 + 1e-5
        # error feedback: same grads re-sent -> accumulated mean converges
        acc = jnp.zeros_like(mean_ref); e = e0
        for i in range(8):
            r, e = fn(g_global, e)
            acc = acc + r
        drift = float(jnp.max(jnp.abs(acc / 8 - mean_ref)))
        assert drift < amax / 127.0 / 2, drift
        print("PASS")
        """,
        n_devices=4,
    )


def test_multislice_compressed_training_matches_uncompressed():
    """Host-driven cross-slice int8+EF exchange: training stays on track.

    Two simulated slices train a small MR head; the compressed run must track
    the uncompressed run's loss closely (error feedback removes the bias).
    """
    from repro.runtime.multislice import MultiSliceTrainer

    key = jax.random.key(0)
    W = jax.random.normal(key, (8, 4)) * 0.5  # ground-truth linear map

    def make_batch(seed):
        k = jax.random.key(seed)
        x = jax.random.normal(k, (32, 8))
        return x, x @ W + 0.01 * jax.random.normal(k, (32, 4))

    def loss_fn(params, batch):
        x, y = batch
        return jnp.mean((x @ params - y) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    def update_fn(params, opt_state, grads):
        return params - 0.1 * grads, opt_state

    results = {}
    for compress in (False, True):
        params = jnp.zeros((8, 4))
        tr = MultiSliceTrainer(grad_fn, update_fn, n_slices=2, compress=compress)
        losses = []
        for step in range(40):
            batches = [make_batch(step * 2), make_batch(step * 2 + 1)]
            params, _, loss = tr.step(params, None, batches)
            losses.append(loss)
        results[compress] = (losses, params)
    l_u, p_u = results[False]
    l_c, p_c = results[True]
    assert l_c[-1] < 0.05 * l_c[0], l_c[-1]  # converges
    assert abs(l_c[-1] - l_u[-1]) < 0.02, (l_c[-1], l_u[-1])  # tracks full-precision
    assert float(jnp.max(jnp.abs(p_c - p_u))) < 0.05


def test_multipod_train_step_compiles():
    """(pod, data, model) mesh train step lowers + compiles (pure GSPMD)."""
    run_devices(
        """
        import jax, jax.numpy as jnp
        from repro.configs.base import get_config, ShapeConfig
        from repro.parallel import rules as rules_mod
        from repro.parallel.steps import make_train_step
        cfg = get_config("qwen2.5-3b", smoke=True)
        shape = ShapeConfig("t", 32, 8, "train")
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = rules_mod.DEFAULT_RULES
        with rules_mod.use_mesh_rules(mesh, rules):
            jitted, state_sh, batch_sh, abstract_args = make_train_step(
                cfg, shape, mesh, rules, donate=False)
            compiled = jitted.lower(*abstract_args).compile()
        txt = compiled.as_text()
        assert "all-reduce" in txt or "reduce-scatter" in txt
        print("PASS")
        """,
        n_devices=8,
        timeout=560,
    )
