"""Assigned-architecture configs must match the published dims exactly."""

from __future__ import annotations

import pytest

from repro.configs.base import get_config

# (arch, layers, d_model, heads, kv_heads, d_ff, vocab) from the assignment
PUBLISHED = {
    "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
}


@pytest.mark.parametrize("arch", sorted(PUBLISHED))
def test_published_dims(arch):
    L, d, H, KV, ff, V = PUBLISHED[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    if cfg.attn is not None:
        assert cfg.attn.num_heads == H
        assert cfg.attn.num_kv_heads == KV


def test_mamba2_130m_dims():
    cfg = get_config("mamba2-130m")
    assert cfg.num_layers == 24
    assert cfg.d_model == 768
    assert cfg.vocab_size == 50280
    assert cfg.attn is None  # attention-free
    assert cfg.ssm.state_dim == 128


def test_moe_structure():
    mix = get_config("mixtral-8x22b")
    assert mix.moe.num_experts == 8 and mix.moe.top_k == 2
    assert mix.attn.window is not None  # SWA -> long_500k runnable
    moon = get_config("moonshot-v1-16b-a3b")
    assert moon.moe.num_experts == 64 and moon.moe.top_k == 6


def test_hybrid_and_ssm_extras():
    z = get_config("zamba2-1.2b")
    assert z.family == "hybrid" and z.ssm.state_dim == 64 and z.attn_period > 0
    s = get_config("seamless-m4t-medium")
    assert s.family == "audio" and s.encoder_layers == 12
    p = get_config("phi-3-vision-4.2b")
    assert p.family == "vlm" and p.num_patches > 0


@pytest.mark.parametrize("arch", sorted(PUBLISHED) + ["mamba2-130m", "merinda-gru"])
def test_smoke_config_is_same_family_but_small(arch):
    full, smoke = get_config(arch), get_config(arch, smoke=True)
    assert smoke.family == full.family
    assert smoke.n_params() < full.n_params() / 50
    if full.moe is not None:
        assert smoke.moe is not None and smoke.moe.top_k <= full.moe.top_k
