"""End-to-end behaviour: training drivers, serving driver, dry-run machinery."""

from __future__ import annotations

import json
import pathlib

import numpy as np
import pytest

from conftest import REPO, SRC, run_devices


def test_lm_training_reduces_loss():
    """examples-grade run: reduced qwen on synthetic LM data, loss must fall."""
    run_devices(
        """
        import sys, tempfile
        sys.argv = ["train", "--arch", "qwen2.5-3b", "--steps", "25",
                    "--batch", "8", "--seq", "64", "--data", "2", "--model", "2",
                    "--save-every", "0", "--ckpt-dir", tempfile.mkdtemp()]
        from repro.launch.train import main
        assert main() == 0
        print("PASS")
        """,
        n_devices=4,
        timeout=560,
    )


def test_serving_driver_completes_all_requests():
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2.5-3b"]
    cmd += ["--requests", "6", "--slots", "2", "--prompt-len", "8"]
    cmd += ["--max-new", "6", "--cache-len", "32"]
    p = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=560, cwd=REPO)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "requests=6" in p.stdout


def test_dryrun_machinery_small_mesh():
    """The dry-run entry point end-to-end on a 16-device toy mesh."""
    run_devices(
        """
        import json, pathlib, tempfile, jax
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = lambda multi_pod=False: (
            jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
            else jax.make_mesh((2, 2), ("data", "model")))
        import repro.configs.base as B
        # smoke dims + tiny shape so the cell compiles in seconds
        B.SHAPES["train_4k"] = B.ShapeConfig("train_4k", 64, 8, "train")
        real_get = B.get_config
        B.get_config = lambda name, smoke=False: real_get(name, smoke=True)
        import repro.launch.dryrun as DR
        DR.get_config = B.get_config  # run_cell imports inside the function
        out = pathlib.Path(tempfile.mkdtemp())
        rec = DR.run_cell("qwen2.5-3b", "train_4k", "single", out_dir=out)
        assert rec["status"] == "ok", rec.get("error")
        assert rec["roofline"]["flops_per_dev"] > 0
        assert rec["memory"]["peak_bytes_per_device"] > 0
        rec2 = DR.run_cell("qwen2.5-3b", "train_4k", "multi", out_dir=out)
        assert rec2["status"] == "ok", rec2.get("error")
        print("PASS")
        """,
        n_devices=16,
        timeout=560,
    )


def test_dryrun_artifacts_complete():
    """The committed 80-cell dry-run results: every cell ok or justified skip."""
    art = pathlib.Path(REPO) / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    from repro.configs.base import ARCH_IDS, SHAPES, shape_applicable

    archs = [a for a in ARCH_IDS if a != "merinda-gru"]
    missing, bad = [], []
    for arch in archs:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                p = art / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    missing.append(p.name)
                    continue
                r = json.loads(p.read_text())
                ok, _ = shape_applicable(arch, shape)
                want = ("ok",) if ok else ("skipped",)
                if r["status"] not in want:
                    bad.append((p.name, r["status"], r.get("error", "")[:100]))
    assert not missing, missing[:5]
    assert not bad, bad[:5]


def test_mr_end_to_end_quickstart():
    """The quickstart path: generate -> train MERINDA -> recover -> prune."""
    import jax.numpy as jnp

    from repro.core.merinda import MRConfig, recover_coefficients, train_mr
    from repro.data.dynamics import generate_trajectory
    from repro.data.windows import make_windows

    ts, ys, us = generate_trajectory("lotka_volterra")
    yw, uw, norm = make_windows(ys, us, window=32, stride=4)
    cfg = MRConfig(state_dim=2, order=2, hidden=32, dense_hidden=64, dt=0.05)
    params, hist = train_mr(cfg, jnp.asarray(yw), None, steps=120, lr=3e-3,
                            batch_size=64, log_every=119)
    assert hist[-1]["recon_mse"] < 0.1, hist
    theta = recover_coefficients(params, cfg, jnp.asarray(yw), None, n_active=4)
    assert int((np.abs(np.asarray(theta)) > 0).sum()) <= 4
