"""ssd_scan (Mamba2 SSD) kernel vs oracles: recurrent + chunked + decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ref as R
from repro.kernels.ssd_scan.ops import ssd_scan


def _inputs(key, B, S, H, P, N, G=1):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    D = jax.random.normal(ks[5], (H,))
    return x, dt, A, bm, cm, D


SHAPES = [
    (1, 64, 1, 8, 4, 1),
    (2, 128, 2, 16, 8, 1),
    (2, 96, 4, 32, 16, 2),  # grouped B/C, S not a chunk multiple
]


@pytest.mark.parametrize("B,S,H,P,N,G", SHAPES)
@pytest.mark.parametrize("chunk", [32, 64])
def test_chunked_matches_recurrent(B, S, H, P, N, G, chunk):
    """The chunked (kernel-algorithm) oracle equals the step-by-step scan."""
    args = _inputs(jax.random.key(S + chunk), B, S, H, P, N, G)
    y_seq, s_seq = R.ssd_recurrent(*args)
    y_c, s_c = ssd_scan(*args, chunk=chunk, force_reference=True)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_seq), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_seq), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,P,N,G", SHAPES)
def test_kernel_matches_chunked(B, S, H, P, N, G):
    args = _inputs(jax.random.key(S), B, S, H, P, N, G)
    y_k, s_k = ssd_scan(*args, chunk=32, interpret=True)
    y_r, s_r = ssd_scan(*args, chunk=32, force_reference=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=5e-5, rtol=5e-5)


def test_decode_step_consistent_with_scan():
    """T decode steps == one full scan (state handoff exactness)."""
    B, S, H, P, N = 2, 24, 2, 8, 4
    x, dt, A, bm, cm, D = _inputs(jax.random.key(0), B, S, H, P, N)
    y_full, s_full = R.ssd_recurrent(x, dt, A, bm, cm, D)
    S0 = jnp.zeros((B, H, N, P))
    ys = []
    s = S0
    for t in range(S):
        y_t, s = R.ssd_decode_step(x[:, t], dt[:, t], A, bm[:, t], cm[:, t], D, s)
        ys.append(y_t)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_full), atol=2e-4, rtol=2e-4)


def test_initial_state_carry():
    """scan(x[:64]) then scan(x[64:], init_state) == scan(x) — chunked serving."""
    B, S, H, P, N = 1, 128, 2, 8, 8
    x, dt, A, bm, cm, D = _inputs(jax.random.key(2), B, S, H, P, N)
    y_full, s_full = ssd_scan(x, dt, A, bm, cm, D, chunk=32, force_reference=True)
    y1, s1 = ssd_scan(
        x[:, :64], dt[:, :64], A, bm[:, :64], cm[:, :64], D, chunk=32, force_reference=True
    )
    y2, s2 = ssd_scan(
        x[:, 64:],
        dt[:, 64:],
        A,
        bm[:, 64:],
        cm[:, 64:],
        D,
        chunk=32,
        initial_state=s1,
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 64:]), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=2e-4, rtol=2e-4)


def test_kernel_grads_match_reference():
    B, S, H, P, N = 2, 64, 2, 8, 4
    x, dt, A, bm, cm, D = _inputs(jax.random.key(4), B, S, H, P, N)

    def lk(x, bm):
        return jnp.sum(ssd_scan(x, dt, A, bm, cm, D, chunk=32, interpret=True)[0] ** 2)

    def lr(x, bm):
        return jnp.sum(ssd_scan(x, dt, A, bm, cm, D, chunk=32, force_reference=True)[0] ** 2)

    gk = jax.grad(lk, argnums=(0, 1))(x, bm)
    gr = jax.grad(lr, argnums=(0, 1))(x, bm)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4)
