"""Fault-tolerance runtime: heartbeats, stragglers, elastic plans, supervisor."""

from __future__ import annotations

from repro.runtime.elastic import plan_mesh, shrink_plan
from repro.runtime.heartbeat import HeartbeatRegistry, StragglerDetector


def test_heartbeat_dead_detection():
    reg = HeartbeatRegistry(timeout=10.0)
    reg.beat("h0", 1, 0.1, now=100.0)
    reg.beat("h1", 1, 0.1, now=100.0)
    reg.beat("h0", 2, 0.1, now=105.0)
    assert reg.dead(now=112.0) == ["h1"]
    assert reg.dead(now=106.0) == []


def test_straggler_detector_flags_slow_worker():
    reg = HeartbeatRegistry()
    det = StragglerDetector(reg, k=5.0, patience=2)
    for step in range(6):
        for w in ("h0", "h1", "h2", "h3"):
            reg.beat(w, step, 0.10 + 0.001 * step)
        reg.beat("h4", step, 0.50)  # 5x slower
    flags = [det.check() for _ in range(3)]
    assert flags[-1] == ["h4"]


def test_straggler_no_false_positive_on_global_slowdown():
    reg = HeartbeatRegistry()
    det = StragglerDetector(reg, patience=1)
    for step in range(6):
        slow = 5.0 if step >= 3 else 0.1  # everyone slows together
        for w in ("h0", "h1", "h2", "h3"):
            reg.beat(w, step, slow)
    assert det.check() == []


def test_shrink_plan_drops_data_axis_first():
    p = plan_mesh(512, model=16, max_data=16, pods=2)
    assert p.shape == (2, 16, 16)
    p2 = shrink_plan(p, n_failed=16)  # lost one host row
    assert p2.shape[-1] == 16  # TP degree preserved
    assert p2.n_devices <= 512 - 16


def test_plan_mesh_degenerate():
    assert plan_mesh(1).shape == (1, 1)
    assert plan_mesh(3, model=16).shape == (1, 2)  # model shrinks as last resort


def test_supervisor_failure_restart_subprocess():
    """Full drill: train, inject failure, re-mesh, restore, finish, loss falls."""
    from conftest import run_devices

    run_devices(
        """
        import numpy as np, tempfile, jax
        import sys
        sys.argv = ["train",
            "--arch", "qwen2.5-3b", "--steps", "24", "--batch", "8",
            "--seq", "32", "--data", "4", "--model", "2",
            "--save-every", "8", "--chaos-step", "13",
            "--ckpt-dir", tempfile.mkdtemp()]
        from repro.launch.train import main
        rc = main()
        assert rc == 0
        print("PASS")
        """,
        n_devices=8,
        timeout=560,
    )
