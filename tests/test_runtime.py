"""Fault-tolerance runtime: heartbeats, stragglers, elastic plans, supervisor."""

from __future__ import annotations

import pytest

from repro.runtime.elastic import plan_mesh, plan_mesh_slots, shrink_plan
from repro.runtime.heartbeat import HeartbeatRegistry, StragglerDetector


def test_heartbeat_dead_detection():
    reg = HeartbeatRegistry(timeout=10.0)
    reg.beat("h0", 1, 0.1, now=100.0)
    reg.beat("h1", 1, 0.1, now=100.0)
    reg.beat("h0", 2, 0.1, now=105.0)
    assert reg.dead(now=112.0) == ["h1"]
    assert reg.dead(now=106.0) == []


def test_straggler_detector_flags_slow_worker():
    reg = HeartbeatRegistry()
    det = StragglerDetector(reg, k=5.0, patience=2)
    for step in range(6):
        for w in ("h0", "h1", "h2", "h3"):
            reg.beat(w, step, 0.10 + 0.001 * step)
        reg.beat("h4", step, 0.50)  # 5x slower
    flags = [det.check() for _ in range(3)]
    assert flags[-1] == ["h4"]


def test_straggler_no_false_positive_on_global_slowdown():
    reg = HeartbeatRegistry()
    det = StragglerDetector(reg, patience=1)
    for step in range(6):
        slow = 5.0 if step >= 3 else 0.1  # everyone slows together
        for w in ("h0", "h1", "h2", "h3"):
            reg.beat(w, step, slow)
    assert det.check() == []


def test_shrink_plan_drops_data_axis_first():
    p = plan_mesh(512, model=16, max_data=16, pods=2)
    assert p.shape == (2, 16, 16)
    p2 = shrink_plan(p, n_failed=16)  # lost one host row
    assert p2.shape[-1] == 16  # TP degree preserved
    assert p2.n_devices <= 512 - 16


def test_plan_mesh_degenerate():
    assert plan_mesh(1).shape == (1, 1)
    assert plan_mesh(3, model=16).shape == (1, 2)  # model shrinks as last resort


def test_plan_mesh_slots_largest_divisor():
    assert plan_mesh_slots(2, 4) == plan_mesh_slots(2, 4)
    assert plan_mesh_slots(2, 4).shape == (2,)
    assert plan_mesh_slots(1, 4).shape == (1,)
    assert plan_mesh_slots(3, 4).shape == (2,)  # 3 doesn't divide 4 -> 2
    assert plan_mesh_slots(8, 6).shape == (6,)  # capped at n_slots
    assert plan_mesh_slots(5, 7).shape == (1,)  # prime slots, too few devices
    assert plan_mesh_slots(4, 4).axes == ("slots",)
    with pytest.raises(ValueError):
        plan_mesh_slots(0, 4)


def test_service_checkpoint_roundtrip_bitwise(tmp_path):
    """A restored service replays the failed one's trajectory exactly: every
    SlotState AND ControlState leaf round-trips bitwise, the tick counter
    rewinds to the snapshot, and continuation ticks produce identical
    results on both services (fold_in(key, ticks) replays the randomness)."""
    import jax
    import numpy as np

    from repro import api
    from repro.api import RecoverySpec, TickSpec
    from repro.core.stream import StreamConfig
    from repro.data.dynamics import generate_trajectory

    scfg = StreamConfig(
        buf_len=32,
        window=8,
        stride=8,
        chunk=8,
        steps_per_tick=8,
        min_steps=16,
        max_steps=32,
        delta_tol=0.0,
    )
    spec = RecoverySpec(
        state_dim=3,
        input_dim=0,
        order=2,
        hidden=8,
        dense_hidden=16,
        dt=0.01,
        mode="stream",
        n_slots=2,
        stream=scfg,
        seed=0,
        tick=TickSpec(
            steps_per_tick=8,
            control="device",
            queue_capacity=8,
            snapshot_period=1,
            warm_capacity=8,
            checkpoint_period=2,
            checkpoint_dir=str(tmp_path),
        ),
    )
    _, ys, _ = generate_trajectory("lorenz", n_samples=400, noise_std=0.01, seed=0)
    ys = ys.astype(np.float32)
    svc = api.compile_plan(spec).make_service()
    for sid in range(4):
        svc.submit(sid, ys[sid : sid + 32])
    svc.fill_slots()
    chunk = np.repeat(ys[32:40][None], 2, axis=0)
    for _ in range(2):
        svc.tick_once(chunk)
    svc.checkpointer.wait()
    assert svc.checkpointer.manager.latest() == 2
    svc.checkpointer.period = 0  # one writer from here on (svc2 owns the dir)

    svc2 = api.compile_plan(spec).make_service()
    info = svc2.checkpointer.restore_into(svc2)
    assert info["step"] == 2
    assert info["resident"] == {0, 1} and info["queued"] == {2, 3}
    assert svc2.ticks == svc.ticks == 2
    for a, b in zip(jax.tree.leaves(svc.state), jax.tree.leaves(svc2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(svc.control), jax.tree.leaves(svc2.control)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # deterministic replay: the two services stay in lockstep to completion
    for _ in range(8):
        i1, i2 = svc.tick_once(chunk), svc2.tick_once(chunk)
        np.testing.assert_array_equal(i1["steps"], i2["steps"])
        if svc.done and svc2.done:
            break
    assert svc.results.keys() == svc2.results.keys() == {0, 1, 2, 3}
    for sid in svc.results:
        np.testing.assert_array_equal(svc.results[sid].theta, svc2.results[sid].theta)


def test_service_supervisor_chaos_remesh_subprocess():
    """The serving chaos drill: a 2-shard device-control service loses one
    shard mid-stream; the supervisor restores the latest snapshot onto the
    surviving 1-device plan and every stream still completes."""
    from conftest import run_devices

    run_devices(
        """
        import tempfile
        import numpy as np
        from repro.api import RecoverySpec, TickSpec
        from repro.core.stream import StreamConfig
        from repro.data.dynamics import generate_trajectory
        from repro.runtime import ServiceSupervisor, kill_shard_once

        scfg = StreamConfig(buf_len=32, window=8, stride=8, chunk=8,
                            steps_per_tick=8, min_steps=16, max_steps=32,
                            delta_tol=0.0)
        spec = RecoverySpec(
            state_dim=3, input_dim=0, order=2, hidden=8, dense_hidden=16,
            dt=0.01, mode="stream", n_slots=4, stream=scfg, seed=0,
            mesh_slots=2,
            tick=TickSpec(steps_per_tick=8, control="device",
                          queue_capacity=8, snapshot_period=1,
                          warm_capacity=8))
        n_streams = 6
        ys = np.stack([
            generate_trajectory("lorenz", n_samples=400, noise_std=0.01,
                                seed=i)[1]
            for i in range(n_streams)
        ]).astype(np.float32)
        sup = ServiceSupervisor(spec, tempfile.mkdtemp(),
                                checkpoint_period=2,
                                chaos=kill_shard_once(3, n_lost=1))
        out = sup.serve(ys, max_ticks=60)
        assert out["restarts"] == 1, out
        assert out["final_mesh"] == (1,), out
        assert out["recovered_streams_fraction"] == 1.0, out
        assert set(out["results"]) == set(range(n_streams))
        print("PASS")
        """,
        n_devices=2,
        timeout=560,
    )


def test_supervisor_failure_restart_subprocess():
    """Full drill: train, inject failure, re-mesh, restore, finish, loss falls."""
    from conftest import run_devices

    run_devices(
        """
        import numpy as np, tempfile, jax
        import sys
        sys.argv = ["train",
            "--arch", "qwen2.5-3b", "--steps", "24", "--batch", "8",
            "--seq", "32", "--data", "4", "--model", "2",
            "--save-every", "8", "--chaos-step", "13",
            "--ckpt-dir", tempfile.mkdtemp()]
        from repro.launch.train import main
        rc = main()
        assert rc == 0
        print("PASS")
        """,
        n_devices=8,
        timeout=560,
    )
