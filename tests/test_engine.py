"""core/engine: scan-jitted recovery engine + vmapped multi-system recovery.

The engine must (a) train identically well to the old per-step loop — the
convergence thresholds here mirror test_mr — and (b) recover a batch of
distinct dynamical systems in ONE vmapped call with per-system results
matching the sequential path.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.merinda import MRConfig, train_mr
from repro.data.dynamics import generate_trajectory
from repro.data.windows import make_windows

SYSTEM_SET = ["lorenz", "damped_oscillator", "controlled_pendulum"]


@pytest.fixture(scope="module")
def lorenz_windows():
    _, ys, us = generate_trajectory("lorenz")
    yw, _, norm = make_windows(ys, us, window=32, stride=4)
    return jnp.asarray(yw), norm


def test_train_mr_scan_converges(lorenz_windows):
    yw, _ = lorenz_windows
    cfg = MRConfig(state_dim=3, order=2, hidden=32, dense_hidden=64, dt=0.01)
    params, metrics = engine.train_mr_scan(cfg, yw, steps=150, lr=3e-3, batch_size=64)
    loss = np.asarray(metrics["recon_mse"])
    assert loss.shape == (150,)
    assert np.isfinite(loss).all()
    assert loss[-1] < 0.1 * loss[0]


def test_metrics_history_roundtrip(lorenz_windows):
    """train_mr (the wrapper) must preserve the old history-of-dicts format."""
    yw, _ = lorenz_windows
    cfg = MRConfig(state_dim=3, order=2, hidden=16, dense_hidden=32, dt=0.01)
    params, hist = train_mr(cfg, yw, None, steps=20, batch_size=64, log_every=10)
    assert [h["step"] for h in hist] == [0, 10]
    assert {"loss", "recon_mse", "sparsity_l1", "grad_norm", "step"} <= set(hist[0])


def test_epoch_warmup_lr_schedule(lorenz_windows):
    yw, _ = lorenz_windows
    cfg = MRConfig(state_dim=3, order=2, hidden=16, dense_hidden=32, dt=0.01)
    _, metrics = engine.train_mr_scan(cfg, yw, steps=60, lr=1e-3, batch_size=64)
    lrs = np.asarray(metrics["lr"])
    np.testing.assert_allclose(lrs[0], 1e-3 / engine.WARMUP_STEPS, rtol=1e-5)
    np.testing.assert_allclose(lrs[engine.WARMUP_STEPS :], 1e-3, rtol=1e-5)
    assert (np.diff(lrs[: engine.WARMUP_STEPS]) > 0).all()


def test_stack_systems_pads_to_common_dims():
    ys_b, us_b, norms, cfg = engine.stack_systems(SYSTEM_SET, n_samples=300)
    S = len(SYSTEM_SET)
    assert ys_b.shape[0] == S and ys_b.shape[-1] == 3  # lorenz sets n_max
    assert us_b is not None and us_b.shape[-1] == 1  # pendulum sets m_max
    assert len(norms) == S
    assert (cfg.state_dim, cfg.input_dim) == (3, 1)
    # padded channels are identically zero
    osc = SYSTEM_SET.index("damped_oscillator")
    assert float(jnp.abs(ys_b[osc, ..., 2]).max()) == 0.0
    assert float(jnp.abs(us_b[osc]).max()) == 0.0


def test_recover_many_matches_sequential():
    """One vmapped call over >=3 distinct systems == per-system sequential."""
    ys_b, us_b, norms, cfg = engine.stack_systems(SYSTEM_SET, n_samples=400)
    steps, bs = 60, 64
    thetas = engine.recover_many(cfg, ys_b, us_b, steps=steps, batch_size=bs, seed=0)
    assert thetas.shape == (len(SYSTEM_SET), cfg.n_terms, cfg.state_dim)
    assert bool(jnp.isfinite(thetas).all())

    keys = engine.system_keys(0, len(SYSTEM_SET))
    for i, name in enumerate(SYSTEM_SET):
        th_seq = engine.recover_one(
            cfg,
            ys_b[i],
            None if us_b is None else us_b[i],
            keys[i],
            steps=steps,
            batch_size=bs,
        )
        # identical key streams + identical program; vmap may reassociate
        # reductions, and 60 optimizer steps amplify ulp-level noise, so the
        # bound is loose-ish but far below any coefficient scale of interest
        np.testing.assert_allclose(
            np.asarray(thetas[i]),
            np.asarray(th_seq),
            atol=2e-2,
            rtol=0.0,
            err_msg=name,
        )


def test_recover_many_learns_each_system():
    """The vmapped recovery must actually fit each system, not just run:
    re-simulated windows from the recovered Theta must track the data."""
    from repro.core.merinda import init_mr, mr_loss

    ys_b, us_b, norms, cfg = engine.stack_systems(SYSTEM_SET, n_samples=400)
    keys = engine.system_keys(7, len(SYSTEM_SET))
    for i, name in enumerate(SYSTEM_SET):
        us_i = None if us_b is None else us_b[i]
        params = init_mr(keys[i], cfg)
        from repro.optim import adamw_init

        loss0, _ = mr_loss(params, cfg, ys_b[i], us_i)
        params2, _, metrics = engine.run_epoch(
            params,
            adamw_init(params),
            ys_b[i],
            us_i,
            keys[i],
            3e-3,
            None,
            cfg=cfg,
            steps=120,
            batch_size=64,
        )
        final = float(np.asarray(metrics["recon_mse"])[-1])
        assert final < 0.5 * float(loss0), (name, final, float(loss0))
