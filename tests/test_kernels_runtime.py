"""kernels/runtime compat+dispatch layer: shims pinned against both API
spellings, dispatch policy, and interpret-vs-reference parity for all three
kernel families routed through pallas_call_compat."""

from __future__ import annotations

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import runtime as rt


# --- CompilerParams spelling shim -------------------------------------------
class _ParamsNew:
    def __init__(self, **kw):
        self.kw = kw


class _ParamsOld:
    def __init__(self, **kw):
        self.kw = kw


def test_compiler_params_resolves_new_spelling():
    ns = types.SimpleNamespace(CompilerParams=_ParamsNew)
    assert rt.resolve_compiler_params_cls(ns) is _ParamsNew


def test_compiler_params_resolves_old_spelling():
    ns = types.SimpleNamespace(TPUCompilerParams=_ParamsOld)
    assert rt.resolve_compiler_params_cls(ns) is _ParamsOld


def test_compiler_params_prefers_new_when_both_exist():
    ns = types.SimpleNamespace(CompilerParams=_ParamsNew, TPUCompilerParams=_ParamsOld)
    assert rt.resolve_compiler_params_cls(ns) is _ParamsNew


def test_compiler_params_unknown_namespace_raises():
    with pytest.raises(AttributeError, match="runtime.py"):
        rt.resolve_compiler_params_cls(types.SimpleNamespace())


def test_compiler_params_builds_on_installed_jax():
    p = rt.compiler_params(dimension_semantics=(rt.PARALLEL, rt.ARBITRARY))
    assert tuple(p.dimension_semantics) == (rt.PARALLEL, rt.ARBITRARY)


# --- BlockSpec argument-order shim ------------------------------------------
class _SpecBlockShapeFirst:
    def __init__(self, block_shape=None, index_map=None):
        self.block_shape, self.index_map = block_shape, index_map


class _SpecIndexMapFirst:
    def __init__(self, index_map=None, block_shape=None):
        self.block_shape, self.index_map = block_shape, index_map


def test_blockspec_order_detection_both_orders():
    assert rt.blockspec_block_shape_first(_SpecBlockShapeFirst)
    assert not rt.blockspec_block_shape_first(_SpecIndexMapFirst)


def test_block_spec_builds_on_installed_jax():
    spec = rt.block_spec((8, 128), lambda i: (i, 0))
    assert tuple(spec.block_shape) == (8, 128)


# --- dispatch policy ---------------------------------------------------------
def test_dispatch_force_reference_wins_everywhere():
    for backend in ("cpu", "tpu", "gpu"):
        for interp in (None, False, True):
            assert rt.resolve_dispatch(True, interp, backend=backend) is rt.Dispatch.REFERENCE


def test_dispatch_tpu_runs_kernel():
    assert rt.resolve_dispatch(False, None, backend="tpu") is rt.Dispatch.KERNEL
    assert rt.resolve_dispatch(False, True, backend="tpu") is rt.Dispatch.KERNEL


def test_dispatch_cpu_interpret_vs_reference():
    assert rt.resolve_dispatch(False, True, backend="cpu") is rt.Dispatch.INTERPRET
    assert rt.resolve_dispatch(False, None, backend="cpu") is rt.Dispatch.REFERENCE
    assert rt.resolve_dispatch(False, False, backend="cpu") is rt.Dispatch.REFERENCE


# --- interpret-vs-reference parity through the compat layer ------------------
def test_gru_interpret_matches_reference():
    from repro.core.neural_flow import gru_scan_ref, init_gru
    from repro.kernels.gru_scan.ops import gru_scan

    key = jax.random.key(0)
    p = init_gru(key, 4, 16)
    xs = jax.random.normal(key, (2, 9, 4), jnp.float32)
    h0 = jax.random.normal(jax.random.key(1), (2, 16), jnp.float32) * 0.1
    _, hs_r = gru_scan_ref(p, xs, h0, flow=True)
    _, hs_k = gru_scan(p, xs, h0, flow=True, interpret=True)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_r), atol=2e-5, rtol=2e-5)


def test_flash_interpret_matches_reference():
    from repro.kernels.flash_attention.ops import flash_attention

    key = jax.random.key(2)
    q = jax.random.normal(key, (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(jax.random.key(3), (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(jax.random.key(4), (1, 64, 2, 32), jnp.float32)
    out_k = flash_attention(q, k, v, causal=True, interpret=True, block_q=32, block_k=32)
    out_r = flash_attention(q, k, v, causal=True, force_reference=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5, rtol=2e-5)


def test_ssd_interpret_matches_reference():
    from repro.kernels.ssd_scan.ops import ssd_scan

    key = jax.random.key(5)
    B, T, H, P, G, N = 1, 64, 2, 8, 1, 4
    x = jax.random.normal(key, (B, T, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(6), (B, T, H))) * 0.1
    A = -jax.nn.softplus(jax.random.normal(jax.random.key(7), (H,)))
    bm = jax.random.normal(jax.random.key(8), (B, T, G, N), jnp.float32)
    cm = jax.random.normal(jax.random.key(9), (B, T, G, N), jnp.float32)
    D = jax.random.normal(jax.random.key(10), (H,), jnp.float32)
    y_k, s_k = ssd_scan(x, dt, A, bm, cm, D, chunk=32, interpret=True)
    y_r, s_r = ssd_scan(x, dt, A, bm, cm, D, chunk=32, force_reference=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=2e-4, rtol=2e-4)
