"""core/quant.py edge cases guarding the fused kernel's fixed-point path.

The mr_step int8 kernel consumes these primitives directly (PWL tables,
per-channel int8 scales) and the QAT path consumes quantize_fixed through
fake_quant_ste — saturation, clipping bounds and roundtrip behavior must be
exact or the fused and unfused paths silently diverge.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import (
    dequantize_int8,
    fake_quant_ste,
    make_sigmoid_table,
    make_tanh_table,
    pwl_apply,
    pwl_max_error,
    quantize_fixed,
    quantize_int8,
)


# ---------------------------------------------------------------------------
# PWL tables: saturation beyond the table range
# ---------------------------------------------------------------------------
def test_pwl_saturates_exactly_beyond_range():
    """x < x_min / x > x_max must return the exact saturation constants —
    the FPGA ROM has no entries there; any interpolation would extrapolate."""
    sig = make_sigmoid_table(16)
    xs = jnp.asarray([-1e6, sig.x_min - 1e-3, sig.x_max + 1e-3, 1e6], jnp.float32)
    ys = np.asarray(pwl_apply(sig, xs))
    # saturation constants are stored as f64 floats; the apply path is f32
    np.testing.assert_allclose(ys[:2], sig.left, rtol=1e-6)
    np.testing.assert_allclose(ys[2:], sig.right, rtol=1e-6)

    tnh = make_tanh_table(16)
    ys = np.asarray(pwl_apply(tnh, jnp.asarray([-50.0, 50.0], jnp.float32)))
    np.testing.assert_allclose(ys, [tnh.left, tnh.right], rtol=1e-6)


def test_pwl_exact_at_knots_and_boundary():
    """Segment interpolation is exact at every knot, including x_min/x_max."""
    tab = make_tanh_table(32)
    knots = np.linspace(tab.x_min, tab.x_max, 33)
    approx = np.asarray(pwl_apply(tab, jnp.asarray(knots, jnp.float32)))
    np.testing.assert_allclose(approx, np.tanh(knots), atol=1e-6)


def test_pwl_max_error_helper_matches_direct_probe():
    tab = make_sigmoid_table(64)
    err = pwl_max_error(tab, lambda x: 1.0 / (1.0 + np.exp(-x)))
    assert 0.0 < err < 1e-3


# ---------------------------------------------------------------------------
# Q-format fixed point: clipping bounds
# ---------------------------------------------------------------------------
def test_quantize_fixed_clipping_bounds():
    """Two's-complement Q(i).(f): range is [-2^(i+f-1), 2^(i+f-1)-1] / 2^f —
    asymmetric, like the hardware ap_fixed."""
    i, f = 2, 2  # grid step 0.25, codes in [-8, 7] -> values in [-2.0, 1.75]
    x = jnp.asarray([-100.0, -2.0, 1.75, 100.0], jnp.float32)
    q = np.asarray(quantize_fixed(x, i, f))
    np.testing.assert_array_equal(q, [-2.0, -2.0, 1.75, 1.75])


def test_quantize_fixed_rounds_to_grid():
    q = np.asarray(quantize_fixed(jnp.asarray([0.3, -0.3, 0.125]), 2, 2))
    # 0.3*4=1.2 -> 1 -> 0.25; -0.3 -> -0.25; 0.125*4=0.5 rounds-to-even -> 0.0
    np.testing.assert_array_equal(q, [0.25, -0.25, 0.0])
    # idempotent: grid points are fixed points of the quantizer
    np.testing.assert_array_equal(np.asarray(quantize_fixed(jnp.asarray(q), 2, 2)), q)


def test_fake_quant_ste_gradient_is_identity():
    """Straight-through estimator: d(fake_quant)/dx == 1 even at clip."""
    g = jax.grad(lambda x: jnp.sum(fake_quant_ste(x, 2, 2)))(jnp.asarray([0.3, -5.0, 100.0]))
    np.testing.assert_array_equal(np.asarray(g), 1.0)


# ---------------------------------------------------------------------------
# int8 per-channel scales: roundtrip
# ---------------------------------------------------------------------------
def test_int8_per_channel_roundtrip():
    key = jax.random.key(0)
    # per-channel dynamic ranges spanning 3 orders of magnitude
    w = jax.random.normal(key, (16, 8)) * jnp.asarray([1e-2, 0.1, 1.0, 10.0] * 2)
    q = quantize_int8(w, axis=-1)
    assert q.values.dtype == jnp.int8
    assert q.scale.shape == (1, 8)  # one scale per output channel
    assert int(jnp.max(jnp.abs(q.values.astype(jnp.int32)))) <= 127
    back = np.asarray(dequantize_int8(q))
    # roundtrip error bounded by half an LSB of each channel's scale
    err = np.abs(back - np.asarray(w))
    bound = 0.5 * np.asarray(q.scale) + 1e-9
    assert (err <= bound).all(), (err.max(axis=0), bound)


def test_int8_zero_channel_is_safe():
    """An all-zero channel must not produce NaN/inf scales or values."""
    w = jnp.zeros((4, 3)).at[:, 1].set(jnp.asarray([1.0, -2.0, 0.5, 0.0]))
    q = quantize_int8(w, axis=-1)
    assert np.isfinite(np.asarray(q.scale)).all()
    np.testing.assert_array_equal(np.asarray(q.values[:, 0]), 0)
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q)[:, 0]), 0.0)
