"""Checkpoint subsystem: roundtrip, atomicity, retention, integrity, async."""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _state(key=0):
    k = jax.random.key(key)
    return {
        "params": {
            "w": jax.random.normal(k, (8, 16)).astype(jnp.bfloat16),
            "b": jnp.arange(16, dtype=jnp.float32),
        },
        "opt": {"m": jnp.zeros((8, 16)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_including_bf16(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 10, s)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    r, manifest = restore_checkpoint(tmp_path, 10, like)
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_ignores_torn_tmp(tmp_path):
    save_checkpoint(tmp_path, 5, _state())
    (tmp_path / "step_00000009.tmp").mkdir()  # simulated crash mid-write
    (tmp_path / "step_00000009.tmp" / "x.npy").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 5


def test_retention_keeps_newest(tmp_path):
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, step, _state(), keep=2)
    steps = sorted(int(p.name[5:]) for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert steps == [4, 5]


def test_corruption_detected(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 3, s)
    d = tmp_path / "step_00000003"
    manifest = json.loads((d / "manifest.json").read_text())
    fn = manifest["leaves"]["params/w"]["file"]
    raw = bytearray((d / fn).read_bytes())
    raw[-1] ^= 0xFF
    (d / fn).write_bytes(bytes(raw))
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(tmp_path, 3, like)


def test_shape_mismatch_rejected(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 1, s)
    bad = jax.tree.map(lambda a: jax.ShapeDtypeStruct((1,) + a.shape, a.dtype), s)
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(tmp_path, 1, bad)


def test_mesh_axes_mismatch_rejected(tmp_path):
    """A checkpoint written on one set of mesh axes refuses to restore into a
    plan sharding over DIFFERENT axes — up front, with a clear error, not a
    shape mismatch deep inside device_put. Matching (or absent) axes pass."""
    s = _state()
    mesh = jax.make_mesh((1,), ("data",))
    save_checkpoint(tmp_path, 2, s, mesh=mesh)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    with pytest.raises(ValueError, match="mesh axes .* shards over"):
        restore_checkpoint(tmp_path, 2, like, expect_axes=("slots",))
    r, _ = restore_checkpoint(tmp_path, 2, like, expect_axes=("data",))
    assert r is not None
    # an unsharded save carries no axes and is compatible with anything
    save_checkpoint(tmp_path, 3, s)
    r, _ = restore_checkpoint(tmp_path, 3, like, expect_axes=("slots",))
    assert r is not None


def test_async_manager(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, save_every=2)
    s = _state()
    for step in range(6):
        mgr.maybe_save(step, s)
    mgr.wait()
    assert mgr.latest() == 4
    r, manifest = mgr.restore_latest(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    )
    assert manifest["step"] == 4


def test_reshard_on_restore_across_meshes(run_devices_fixture=None):
    """Save under (4,2) mesh, restore under (2,2) — shards re-placed."""
    from conftest import run_devices

    run_devices(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        d = tempfile.mkdtemp()
        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh8, P("data", "model")))
        save_checkpoint(d, 1, {"x": xs}, mesh=mesh8)
        mesh4 = jax.make_mesh((2, 2), ("data", "model"))
        sh = {"x": NamedSharding(mesh4, P("model", "data"))}
        like = {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        r, man = restore_checkpoint(d, 1, like, sh)
        assert man["mesh"]["shape"] == [4, 2]
        np.testing.assert_array_equal(np.asarray(r["x"]), np.asarray(x))
        assert r["x"].sharding.spec == P("model", "data")
        print("PASS")
        """,
        n_devices=8,
    )
