"""Model zoo: per-arch smoke (reduced configs) + prefill/decode parity.

The decisive correctness test is teacher-forcing parity: running prefill on a
prompt then decoding token-by-token must reproduce the logits of one full
forward pass — this exercises caches, RoPE offsets, rolling SWA windows, SSD
state handoff and the hybrid shared-attention cache in one property.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.models import model as M

ARCHS = [a for a in list_archs()]


def _batch(cfg, B, S, with_labels=True):
    out = {}
    key = jax.random.key(0)
    if cfg.family == "vlm":
        T = S - cfg.num_patches
        out["tokens"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size, jnp.int32)
        if with_labels:
            out["labels"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size, jnp.int32)
        out["patches"] = jnp.zeros((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "audio":
        out["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
        if with_labels:
            out["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
        out["frames"] = jax.random.normal(key, (B, M.AUDIO_SRC_LEN, M.AUDIO_FEAT), jnp.float32)
    else:
        out["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
        if with_labels:
            out["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, B=2, S=32)
    loss, metrics = jax.jit(lambda p, b: M.train_loss(p, b, cfg))(params, batch)
    assert jnp.isfinite(loss), (arch, float(loss))
    assert 2.0 < float(loss) < 20.0, f"{arch}: implausible CE {float(loss)}"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_param_specs_match_materialized(arch):
    cfg = get_config(arch, smoke=True)
    specs = M.param_specs(cfg)
    params = M.init_params(jax.random.key(0), cfg)
    from repro.models.params import is_spec

    flat_s = jax.tree.leaves(specs, is_leaf=is_spec)
    flat_p = jax.tree.leaves(params)
    assert len(flat_s) == len(flat_p)
    for s, p in zip(flat_s, flat_p):
        assert tuple(s.shape) == tuple(p.shape)
        assert jnp.dtype(s.dtype) == p.dtype


@pytest.mark.parametrize(
    "arch", ["qwen2.5-3b", "mixtral-8x22b", "mamba2-130m", "zamba2-1.2b", "merinda-gru"]
)
def test_prefill_decode_parity(arch):
    """prefill(prompt) + N decode steps == full forward logits (greedy path)."""
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.key(0), cfg)
    B, S_p, N_dec = 2, 16, 4
    S = S_p + N_dec
    full = _batch(cfg, B, S, with_labels=False)
    toks = full["tokens"]

    # reference: full-sequence prefill gives logits at every position via
    # prefilling successively longer prompts (cache-free ground truth)
    ref_logits = []
    for t in range(S_p, S):
        b_t = dict(full, tokens=toks[:, :t])
        lg, _ = M.prefill(params, b_t, cfg, cache_len=S)
        ref_logits.append(lg)

    # cached path: one prefill + decode steps
    b0 = dict(full, tokens=toks[:, :S_p])
    lg, cache = M.prefill(params, b0, cfg, cache_len=S)
    got = [lg]
    for t in range(S_p, S - 1):
        lg, cache = M.decode_step(params, cache, toks[:, t : t + 1], jnp.asarray(t), cfg)
        got.append(lg)

    for a, b in zip(got, ref_logits):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=0.12, rtol=0.12,  # bf16 params; logits O(10)
        )


def test_swa_rolling_cache_matches_full_window():
    """Mixtral-family SWA: rolling cache decode == windowed full attention."""
    cfg = get_config("mixtral-8x22b", smoke=True)
    assert cfg.attn.window is not None and cfg.attn.window < 64
    params = M.init_params(jax.random.key(1), cfg)
    B, S_p, N_dec = 1, 40, 6  # prompt longer than window (32)
    S = S_p + N_dec
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size, jnp.int32)

    ref = []
    for t in range(S_p, S):
        lg, _ = M.prefill(params, {"tokens": toks[:, :t]}, cfg, cache_len=S)
        ref.append(lg)
    lg, cache = M.prefill(params, {"tokens": toks[:, :S_p]}, cfg, cache_len=S)
    got = [lg]
    for t in range(S_p, S - 1):
        lg, cache = M.decode_step(params, cache, toks[:, t : t + 1], jnp.asarray(t), cfg)
        got.append(lg)
    # bf16 params: the two paths sum in different orders, so individual logits
    # can differ by a few bf16 ulps of the O(10) activations. The rolling-cache
    # MATH is exact (f32 unit check in the attention module); here we require
    # near-total agreement at a bf16-realistic tolerance.
    for a, b in zip(got, ref):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        frac_close = np.mean(np.abs(a - b) < 0.12)
        assert frac_close > 0.94, frac_close
        np.testing.assert_allclose(a, b, atol=0.35, rtol=0.1)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_model_inputs(arch):
    """input_specs must be sufficient to call the right step for each shape."""
    from repro.configs.base import SHAPES, shape_applicable
    from repro.models.params import abstract

    cfg = get_config(arch, smoke=True)
    for shape in SHAPES.values():
        ok, _ = shape_applicable(arch, shape.name)
        if not ok:
            continue
        specs = M.input_specs(cfg, shape)
        tree = abstract(specs)
        assert all(x is not None for x in jax.tree.leaves(tree))


def test_vocab_padding_rounds_to_256():
    for arch in ARCHS:
        cfg = get_config(arch)
        assert cfg.vocab_padded % 256 == 0
        assert 0 <= cfg.vocab_padded - cfg.vocab_size < 256
