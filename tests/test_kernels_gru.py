"""gru_scan Pallas kernel vs lax.scan oracle: shape/dtype sweeps + grads."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.neural_flow import gru_scan_ref, init_gru
from repro.core.quant import make_sigmoid_table, make_tanh_table, pwl_apply
from repro.kernels.gru_scan.ops import gru_scan, gru_scan_int8

SHAPES = [
    (1, 4, 2, 8),
    (2, 16, 8, 32),
    (4, 33, 16, 64),   # odd T
    (8, 7, 3, 128),    # hardware-aligned H
    (2, 64, 128, 16),  # D > H
]


@pytest.mark.parametrize("B,T,D,H", SHAPES)
@pytest.mark.parametrize("flow", [True, False])
def test_gru_scan_matches_reference(B, T, D, H, flow):
    key = jax.random.key(B * 1000 + T)
    p = init_gru(key, D, H)
    xs = jax.random.normal(key, (B, T, D), jnp.float32)
    h0 = jax.random.normal(jax.random.key(1), (B, H), jnp.float32) * 0.1
    hT_r, hs_r = gru_scan_ref(p, xs, h0, flow=flow)
    hT_k, hs_k = gru_scan(p, xs, h0, flow=flow, interpret=True)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_r), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(hT_k), np.asarray(hT_r), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gru_scan_dtypes(dtype):
    key = jax.random.key(7)
    p = init_gru(key, 8, 32, jnp.float32)
    xs = jax.random.normal(key, (2, 12, 8)).astype(dtype)
    h0 = jnp.zeros((2, 32), dtype)
    _, hs_k = gru_scan(p, xs, h0, interpret=True)
    _, hs_r = gru_scan_ref(p, xs.astype(jnp.float32), h0.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(hs_k, np.float32), np.asarray(hs_r), atol=tol, rtol=tol)


def test_gru_scan_variable_dt():
    """Flow gate: dt=0 steps must leave the state unchanged (F(0)=id)."""
    key = jax.random.key(3)
    p = init_gru(key, 4, 16)
    xs = jax.random.normal(key, (2, 10, 4))
    h0 = jax.random.normal(key, (2, 16)) * 0.3
    dts = jnp.zeros((10,))
    hT, hs = gru_scan(p, xs, h0, dts=dts, flow=True, interpret=True)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h0), atol=1e-6)


def test_gru_kernel_grads_match_reference():
    key = jax.random.key(11)
    p = init_gru(key, 6, 24)
    xs = jax.random.normal(key, (3, 9, 6))
    h0 = jnp.zeros((3, 24))

    def loss_k(w):
        return jnp.sum(gru_scan(p._replace(w=w), xs, h0, interpret=True)[1] ** 2)

    def loss_r(w):
        return jnp.sum(gru_scan_ref(p._replace(w=w), xs, h0)[1] ** 2)

    gk, gr = jax.grad(loss_k)(p.w), jax.grad(loss_r)(p.w)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-4, rtol=1e-4)


def test_gru_batch_blocking_invariance():
    """block_b tiling must not change results (BRAM-banking analogue)."""
    key = jax.random.key(5)
    p = init_gru(key, 8, 32)
    xs = jax.random.normal(key, (8, 12, 8))
    h0 = jnp.zeros((8, 32))
    _, hs_full = gru_scan(p, xs, h0, interpret=True)
    _, hs_tiled = gru_scan(p, xs, h0, block_b=2, interpret=True)
    np.testing.assert_allclose(np.asarray(hs_full), np.asarray(hs_tiled), atol=1e-6)


def test_gru_int8_kernel_matches_int8_reference():
    key = jax.random.key(9)
    p = init_gru(key, 8, 32)
    xs = jax.random.normal(key, (4, 20, 8))
    h0 = jnp.zeros((4, 32))
    _, hs_k = gru_scan_int8(p, xs, h0, interpret=True)
    _, hs_r = gru_scan_int8(p, xs, h0, force_reference=True)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_r), atol=1e-6)


def test_gru_int8_accuracy_budget():
    """Paper's fixed-point claim: quantized path stays close to float."""
    key = jax.random.key(13)
    p = init_gru(key, 8, 32)
    xs = jax.random.normal(key, (4, 30, 8))
    h0 = jnp.zeros((4, 32))
    _, hs_f = gru_scan_ref(p, xs, h0, flow=False)
    _, hs_q = gru_scan_int8(p, xs, h0, force_reference=True)
    err = float(jnp.max(jnp.abs(hs_f - hs_q)))
    assert err < 0.15, f"int8+PWL drifted too far from float: {err}"


def test_pwl_tables_error_bound():
    """Error shrinks ~quadratically with segment count (PWL convergence)."""
    xs = jnp.linspace(-10, 10, 4001)
    errs = {}
    for n in (16, 32, 64):
        sig = pwl_apply(make_sigmoid_table(n), xs)
        tnh = pwl_apply(make_tanh_table(n), xs)
        errs[n] = (
            float(jnp.max(jnp.abs(sig - jax.nn.sigmoid(xs)))),
            float(jnp.max(jnp.abs(tnh - jnp.tanh(xs)))),
        )
    assert errs[16][0] < 2e-2 and errs[16][1] < 3e-2
    assert errs[64][0] < 1e-3 and errs[64][1] < 2e-3
    assert errs[64][0] < errs[16][0] / 8  # ~O(1/n^2)
    assert errs[64][1] < errs[16][1] / 8
