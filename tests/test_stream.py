"""core/stream: slot-based streaming recovery service.

Pins the serving path end to end: device-side windowing helpers, slot
admission/eviction round-trips through the shared pytree, warm-start
re-admission (fewer steps / lower loss than cold start on the same data),
and int8-encoder readout parity with the f32 path.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stream
from repro.core.merinda import MRConfig
from repro.core.stream import RecoveryService, StreamConfig
from repro.data.dynamics import generate_trajectory
from repro.data.windows import make_windows, n_buffer_windows, roll_buffer, window_views

CFG = MRConfig(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder="gru")
SCFG = StreamConfig(
    buf_len=48, window=12, stride=6, chunk=8, steps_per_tick=8, min_steps=16, max_steps=64
)


@pytest.fixture(scope="module")
def lorenz():
    _, ys, _ = generate_trajectory("lorenz", n_samples=400)
    return ys


def _chunks(ys, start, n_slots):
    idx = (start + np.arange(SCFG.chunk)) % len(ys)
    return np.repeat(ys[idx][None], n_slots, axis=0)


# ---------------------------------------------------------------------------
# device-side windowing helpers
# ---------------------------------------------------------------------------
def test_window_views_matches_make_windows(lorenz):
    buf = lorenz[: SCFG.buf_len]
    yw_np, _, _ = make_windows(buf, None, window=SCFG.window, stride=SCFG.stride, normalize=False)
    yw_dev = window_views(jnp.asarray(buf), SCFG.window, SCFG.stride)
    assert yw_dev.shape[0] == n_buffer_windows(SCFG.buf_len, SCFG.window, SCFG.stride)
    np.testing.assert_allclose(np.asarray(yw_dev), yw_np, atol=1e-7)


def test_window_views_batched(lorenz):
    bufs = jnp.asarray(np.stack([lorenz[:48], lorenz[8:56]]))
    yw = window_views(bufs, SCFG.window, SCFG.stride)
    assert yw.shape == (2, n_buffer_windows(48, 12, 6), 12, 3)
    np.testing.assert_allclose(
        np.asarray(yw[1]), np.asarray(window_views(bufs[1], SCFG.window, SCFG.stride)), atol=0
    )


def test_roll_buffer_drops_oldest():
    buf = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)[None]
    new = jnp.full((1, 2, 2), 99.0)
    out = roll_buffer(buf, new)
    assert out.shape == buf.shape
    np.testing.assert_allclose(np.asarray(out[0, :4]), np.asarray(buf[0, 2:]))
    np.testing.assert_allclose(np.asarray(out[0, 4:]), 99.0)


# ---------------------------------------------------------------------------
# admission / eviction round-trip
# ---------------------------------------------------------------------------
def test_admission_eviction_roundtrip(lorenz):
    svc = RecoveryService(CFG, SCFG, n_slots=2, seed=0)
    for sid in range(3):
        svc.submit(sid, lorenz[sid : sid + SCFG.buf_len])
    assert svc.fill_slots() == [0, 1]
    assert svc.slot_streams() == [0, 1]
    assert np.asarray(svc.state.active).all()

    # admission wrote each stream's history into ITS slot only
    np.testing.assert_allclose(np.asarray(svc.state.buf_y[0]), lorenz[:48], atol=1e-6)
    np.testing.assert_allclose(np.asarray(svc.state.buf_y[1]), lorenz[1:49], atol=1e-6)

    p1_before = np.asarray(svc.state.params.head_w1[1])
    cursor = 0
    while not ({0, 1} <= set(svc.results)) and svc.ticks < 20:
        svc.tick_once(_chunks(lorenz, SCFG.buf_len + cursor, 2))
        cursor += SCFG.chunk
        if svc.ticks == 1:
            # ticking trains BOTH slots (params moved) and keeps ids stable
            assert svc.slot_streams() == [0, 1]
            assert not np.allclose(np.asarray(svc.state.params.head_w1[1]), p1_before)
    # max_steps=64 at K=8 forces eviction by tick 8; stream 2 takes a freed
    # slot immediately, the other freed slot deactivates (queue drained)
    assert {0, 1} <= set(svc.results)
    assert 2 in svc.slot_streams()
    assert sorted(svc.slot_streams()) == [-1, 2]
    # evicted streams land in the warm-start registry with a recorded result
    assert {0, 1} <= set(svc.warm)
    for sid in (0, 1):
        res = svc.results[sid]
        assert res.theta.shape == (CFG.n_terms, CFG.state_dim)
        assert np.isfinite(res.theta).all()
        assert res.steps >= SCFG.min_steps
        assert res.reason in ("converged", "budget")
    # draining the queue: once all streams finish, slots deactivate
    while not svc.done and svc.ticks < 40:
        svc.tick_once(_chunks(lorenz, SCFG.buf_len + cursor, 2))
        cursor += SCFG.chunk
    assert svc.done
    assert svc.slot_streams() == [-1, -1]
    assert len(svc.results) == 3


def test_admission_preserves_other_slots(lorenz):
    svc = RecoveryService(CFG, SCFG, n_slots=2, seed=1)
    svc.submit(0, lorenz[:48])
    svc.submit(1, lorenz[5:53])
    svc.fill_slots()
    buf1 = np.asarray(svc.state.buf_y[1])
    w1 = np.asarray(svc.state.params.head_w1[1])
    # admit a new stream into slot 0 only
    svc.submit(9, lorenz[10:58])
    svc._admit_into(0)
    assert svc.slot_streams() == [9, 1]
    np.testing.assert_allclose(np.asarray(svc.state.buf_y[1]), buf1, atol=0)
    np.testing.assert_allclose(np.asarray(svc.state.params.head_w1[1]), w1, atol=0)
    # the admitted slot was fully reset
    assert float(np.asarray(svc.state.delta[0])) == np.inf
    assert int(np.asarray(svc.state.steps[0])) == 0


# ---------------------------------------------------------------------------
# warm start
# ---------------------------------------------------------------------------
def test_warm_start_beats_cold_start(lorenz):
    """A re-admitted stream resumes from its evicted params: after the same
    few ticks on the same data it must sit at a lower loss than cold start."""

    def run_ticks(svc, n):
        losses = []
        cursor = SCFG.buf_len
        for _ in range(n):
            info = svc.tick_once(_chunks(lorenz, cursor, 1))
            cursor += SCFG.chunk
            losses.append(float(info["loss"][0]))
        return losses

    scfg = SCFG  # max_steps=64 -> evicts after 8 ticks
    cold = RecoveryService(CFG, scfg, n_slots=1, seed=3)
    cold.submit(7, lorenz[:48])
    cold.fill_slots()
    cold_losses = run_ticks(cold, 8)
    assert 7 in cold.results  # budget eviction happened; params in registry

    # same service, same stream id re-submitted -> warm start from registry
    cold.submit(7, lorenz[:48])
    cold.fill_slots()
    warm_losses = run_ticks(cold, 2)

    # fresh service, same data, cold init observed over the same 2 ticks
    fresh = RecoveryService(CFG, scfg, n_slots=1, seed=3)
    fresh.submit(7, lorenz[:48])
    fresh.fill_slots()
    fresh_losses = run_ticks(fresh, 2)

    assert warm_losses[-1] < fresh_losses[-1], (warm_losses, fresh_losses)
    # warm start resumes near the evicted loss level, far below loss at init
    assert warm_losses[0] < cold_losses[0], (warm_losses, cold_losses)


# ---------------------------------------------------------------------------
# int8 serving readout
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def trained(lorenz):
    from repro.core import engine

    yw, _, _ = make_windows(lorenz, None, window=SCFG.window, stride=SCFG.stride)
    params, _ = engine.train_mr_scan(CFG, jnp.asarray(yw), steps=100, lr=3e-3)
    return params, jnp.asarray(yw)


def test_int8_readout_parity(trained):
    """The int8/PWL kernel readout must track the f32 encoder within
    quantization tolerance — and must actually quantize (nonzero gap)."""
    params, yw = trained
    th_f32 = np.asarray(stream.readout_theta(params, CFG, yw))
    th_int8 = np.asarray(stream.readout_theta(params, CFG, yw, quant=True))
    assert np.isfinite(th_int8).all()
    rel = np.linalg.norm(th_int8 - th_f32) / (np.linalg.norm(th_f32) + 1e-9)
    assert rel < 0.05, rel
    assert np.abs(th_int8 - th_f32).max() < 0.1
    assert np.abs(th_int8 - th_f32).max() > 1e-7  # not silently running f32


def test_int8_readout_requires_gru(trained):
    params, yw = trained
    cfg_flow = MRConfig(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01)
    with pytest.raises(ValueError, match="encoder='gru'"):
        stream.readout_theta(params, cfg_flow, yw, quant=True)


def test_quant_service_eviction_readout(lorenz):
    """--quant service: evicted results flow through the int8 kernel path."""
    svc = RecoveryService(CFG, SCFG, n_slots=1, seed=0, quant=True)
    svc.submit(0, lorenz[:48])
    svc.fill_slots()
    cursor = SCFG.buf_len
    while not svc.done and svc.ticks < 12:
        svc.tick_once(_chunks(lorenz, cursor, 1))
        cursor += SCFG.chunk
    res = svc.results[0]
    assert np.isfinite(res.theta).all()
    assert res.theta.shape == (CFG.n_terms, CFG.state_dim)
