"""mr_step fused-stage kernels vs references: CPU interpret-mode parity sweep.

Mirrors test_kernels_gru.py for the 4th kernel family — all four encoder
variants (GRU-flow, GRU, and the multi-substep LTC/NODE fused-solver
kernels). Tolerances (acceptance criteria for the stage-fused refactor):

  fp32  fused kernel (interpret) vs unfused reference path:  <= 1e-4
        (observed ~3e-8 — one extra f32 rounding at the stage handoff)
  int8  fused kernel (interpret) vs int8-dequant oracle:      <= 1e-6
        int8+PWL vs the float path:                           <= 0.1
        (quantization error budget, same bound as the service
        readout-parity test in test_stream.py)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoders
from repro.core.merinda import MRConfig, head_from_hidden, init_mr, mr_forward
from repro.core.neural_flow import gru_scan_ref
from repro.kernels.mr_step.ops import mr_step, mr_step_int8

SHAPES = [
    # (B, T, n_state, hidden, dense_hidden)
    (1, 4, 2, 8, 16),
    (2, 16, 3, 32, 64),
    (4, 33, 3, 16, 32),  # odd T
    (8, 7, 2, 64, 128),  # hardware-aligned H
]


def _setup(B, T, n, H, Dh, encoder="gru_flow", seed=0, **kw):
    cfg = MRConfig(state_dim=n, order=2, hidden=H, dense_hidden=Dh, dt=0.01, encoder=encoder, **kw)
    params = init_mr(jax.random.key(seed), cfg)
    xs = jax.random.normal(jax.random.key(seed + 1), (B, T, n), jnp.float32)
    return cfg, params, xs


@pytest.mark.parametrize("B,T,n,H,Dh", SHAPES)
@pytest.mark.parametrize("encoder", ["gru_flow", "gru"])
def test_mr_step_interpret_matches_unfused(B, T, n, H, Dh, encoder):
    """Fused kernel body (interpreter) vs the unfused encode->head stages."""
    cfg, params, xs = _setup(B, T, n, H, Dh, encoder)
    th_u, sh_u = mr_forward(params, cfg, xs, None)
    th_k, sh_k = mr_step(params, cfg, xs, interpret=True)
    np.testing.assert_allclose(np.asarray(th_k), np.asarray(th_u), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sh_k), np.asarray(sh_u), atol=1e-4, rtol=1e-4)


def test_mr_step_reference_dispatch_is_exact():
    """force_reference must bit-match the unfused path (same program)."""
    cfg, params, xs = _setup(4, 12, 3, 16, 32)
    th_u, _ = mr_forward(params, cfg, xs, None)
    th_r, _ = mr_step(params, cfg, xs, force_reference=True)
    np.testing.assert_array_equal(np.asarray(th_r), np.asarray(th_u))


def test_mr_step_head_consumes_final_hidden_state():
    """The fused head must see exactly h_T (not an intermediate step)."""
    cfg, params, xs = _setup(3, 9, 3, 16, 32)
    h_T, _ = gru_scan_ref(params.encoder, xs, jnp.zeros((3, cfg.hidden)), flow=True)
    th_head, _ = head_from_hidden(params, cfg, h_T)
    th_k, _ = mr_step(params, cfg, xs, interpret=True)
    np.testing.assert_allclose(np.asarray(th_k), np.asarray(th_head), atol=1e-5, rtol=1e-5)


def test_mr_step_batch_blocking_invariance():
    """block_b tiling must not change results (BRAM-banking analogue)."""
    cfg, params, xs = _setup(8, 10, 3, 16, 32)
    th_full, _ = mr_step(params, cfg, xs, interpret=True)
    th_tiled, _ = mr_step(params, cfg, xs, block_b=2, interpret=True)
    np.testing.assert_allclose(np.asarray(th_full), np.asarray(th_tiled), atol=1e-6)


def test_mr_step_grads_match_unfused():
    """Training through the fused stage == training through the unfused one.

    The interpret=True leg takes the custom_vjp kernel dispatch (the same
    path TPU training uses), so ops._mr_bwd's 11-gradient contract is
    exercised on CPU — off-TPU default dispatch alone would quietly compare
    reference vs reference.
    """
    cfg, params, xs = _setup(4, 8, 3, 16, 32)
    cfg_f = MRConfig(state_dim=3, order=2, hidden=16, dense_hidden=32, dt=0.01,
                     encoder="gru_flow", fused=True)

    def loss(p, c):
        th, _ = mr_forward(p, c, xs, None)
        return jnp.sum(th**2)

    def loss_cvjp(p):
        th, _ = mr_step(p, cfg, xs, interpret=True)
        return jnp.sum(th**2)

    gu = jax.grad(loss)(params, cfg)
    gf = jax.grad(loss)(params, cfg_f)
    gk = jax.grad(loss_cvjp)(params)
    for other in (gf, gk):
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5
            ),
            gu,
            other,
        )


def test_mr_step_qat_parity():
    """cfg.quant (fixed-point QAT) through the fused kernel == unfused."""
    from repro.core.quant import QuantConfig, fake_quant_ste

    q = QuantConfig(act_int_bits=4, act_frac_bits=10, weight_int_bits=2, weight_frac_bits=12)
    cfg, params, xs = _setup(4, 10, 3, 16, 32, quant=q)
    th_u, _ = mr_forward(params, cfg, xs, None)
    # mr_forward pre-quantizes the window activations before the fused stage
    xs_q = fake_quant_ste(xs, q.act_int_bits, q.act_frac_bits)
    th_k, _ = mr_step(params, cfg, xs_q, interpret=True)
    np.testing.assert_allclose(np.asarray(th_k), np.asarray(th_u), atol=1e-4, rtol=1e-4)


def test_mr_step_rejects_non_fusable_encoders():
    """Every built-in family is fusable now; a custom row without an
    mr_step lowering must still fail eagerly with the registered names."""
    spec = encoders.EncoderSpec(
        name="mean_pool_nofuse",
        init=lambda key, d_in, hidden, dtype=jnp.float32: {"w": jnp.ones((d_in, hidden), dtype)},
        encode=lambda p, cfg, xs: jnp.mean(xs, axis=1) @ p["w"],
        flow=None,
        fusable=False,
        kernel=False,
    )
    encoders.register_encoder(spec)
    try:
        cfg, params, xs = _setup(2, 6, 3, 8, 16, encoder="mean_pool_nofuse")
        with pytest.raises(ValueError, match="fusable"):
            mr_step(params, cfg, xs)
    finally:
        encoders._REGISTRY.pop("mean_pool_nofuse", None)


# ---------------------------------------------------------------------------
# multi-substep variants: LTC (fused-solver) and NODE (Euler substeps)
# ---------------------------------------------------------------------------
SUBSTEP_SHAPES = [
    # (B, T, n_state, hidden, dense_hidden)
    (1, 4, 2, 8, 16),
    (2, 12, 3, 32, 64),
    (4, 9, 3, 16, 32),  # odd T
]


@pytest.mark.parametrize("B,T,n,H,Dh", SUBSTEP_SHAPES)
@pytest.mark.parametrize("encoder", ["ltc", "node"])
def test_mr_step_substep_interpret_matches_unfused(B, T, n, H, Dh, encoder):
    """Fused multi-substep kernel body (interpreter) vs the unfused
    encode -> head stage sequence (core/ltc.py / core/node_mr.py)."""
    cfg, params, xs = _setup(B, T, n, H, Dh, encoder)
    th_u, sh_u = mr_forward(params, cfg, xs, None)
    th_k, sh_k = mr_step(params, cfg, xs, interpret=True)
    np.testing.assert_allclose(np.asarray(th_k), np.asarray(th_u), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sh_k), np.asarray(sh_u), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("encoder", ["ltc", "node"])
def test_mr_step_substep_reference_dispatch_is_exact(encoder):
    """force_reference delegates to ltc_scan/node_scan — bit-identical to
    the unfused stage sequence."""
    cfg, params, xs = _setup(4, 8, 3, 16, 32, encoder)
    th_u, _ = mr_forward(params, cfg, xs, None)
    th_r, _ = mr_step(params, cfg, xs, force_reference=True)
    np.testing.assert_array_equal(np.asarray(th_r), np.asarray(th_u))


@pytest.mark.parametrize("encoder", ["ltc", "node"])
def test_mr_step_substep_count_changes_result(encoder):
    """The kernels must actually run cfg.ltc_substeps solver substeps."""
    import dataclasses

    cfg, params, xs = _setup(2, 6, 3, 16, 32, encoder)
    cfg2 = dataclasses.replace(cfg, ltc_substeps=2)
    th6, _ = mr_step(params, cfg, xs, interpret=True)
    th2, _ = mr_step(params, cfg2, xs, interpret=True)
    assert float(jnp.max(jnp.abs(th6 - th2))) > 0.0


@pytest.mark.parametrize("encoder", ["ltc", "node"])
def test_mr_step_substep_batch_blocking_invariance(encoder):
    cfg, params, xs = _setup(8, 7, 3, 16, 32, encoder)
    th_full, _ = mr_step(params, cfg, xs, interpret=True)
    th_tiled, _ = mr_step(params, cfg, xs, block_b=2, interpret=True)
    np.testing.assert_allclose(np.asarray(th_full), np.asarray(th_tiled), atol=1e-6)


@pytest.mark.parametrize("encoder", ["ltc", "node"])
def test_mr_step_substep_grads_match_unfused(encoder):
    """Training through the fused substep stage == the unfused one (the
    interpret=True leg exercises the custom_vjp reference backward)."""
    cfg, params, xs = _setup(4, 6, 3, 16, 32, encoder)
    cfg_f = MRConfig(
        state_dim=3, order=2, hidden=16, dense_hidden=32, dt=0.01, encoder=encoder, fused=True
    )

    def loss(p, c):
        th, _ = mr_forward(p, c, xs, None)
        return jnp.sum(th**2)

    def loss_cvjp(p):
        th, _ = mr_step(p, cfg, xs, interpret=True)
        return jnp.sum(th**2)

    gu = jax.grad(loss)(params, cfg)
    gf = jax.grad(loss)(params, cfg_f)
    gk = jax.grad(loss_cvjp)(params)
    for other in (gf, gk):
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5
            ),
            gu,
            other,
        )


# ---------------------------------------------------------------------------
# int8 + PWL variants
# ---------------------------------------------------------------------------
def test_mr_step_int8_interpret_matches_int8_reference():
    cfg, params, xs = _setup(4, 20, 3, 32, 64, encoder="gru")
    th_k, sh_k = mr_step_int8(params, cfg, xs, interpret=True)
    th_r, sh_r = mr_step_int8(params, cfg, xs, force_reference=True)
    np.testing.assert_allclose(np.asarray(th_k), np.asarray(th_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(sh_k), np.asarray(sh_r), atol=1e-6)


def test_mr_step_int8_accuracy_budget():
    """Documented int8 tolerance: fused fixed-point stage (int8 gate + head
    weights, PWL activations) within 0.1 of float — and actually quantized."""
    cfg, params, xs = _setup(4, 30, 3, 32, 64, encoder="gru")
    th_f, _ = mr_forward(params, cfg, xs, None)
    th_q, _ = mr_step_int8(params, cfg, xs, force_reference=True)
    err = float(jnp.max(jnp.abs(th_f - th_q)))
    assert err < 0.1, f"int8+PWL fused stage drifted too far from float: {err}"
    assert err > 1e-7, "int8 path silently ran float math"


def test_mr_step_int8_rejects_flow_and_node():
    """int8 exists where the cell nonlinearities PWL-map (gru, ltc) — the
    flow gate and the NODE tanh-MLP field have no fixed-point stage."""
    for encoder in ("gru_flow", "node"):
        cfg, params, xs = _setup(2, 6, 3, 8, 16, encoder=encoder)
        with pytest.raises(ValueError, match="int8-capable"):
            mr_step_int8(params, cfg, xs)


def test_mr_step_ltc_int8_interpret_matches_int8_reference():
    cfg, params, xs = _setup(4, 12, 3, 32, 64, encoder="ltc")
    th_k, sh_k = mr_step_int8(params, cfg, xs, interpret=True)
    th_r, sh_r = mr_step_int8(params, cfg, xs, force_reference=True)
    np.testing.assert_allclose(np.asarray(th_k), np.asarray(th_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(sh_k), np.asarray(sh_r), atol=1e-6)


def test_mr_step_ltc_int8_accuracy_budget():
    """Fixed-point fused LTC (int8 substep + head weights, PWL sigmoid)
    within the documented 0.1 budget of float — and actually quantized."""
    cfg, params, xs = _setup(4, 20, 3, 32, 64, encoder="ltc")
    th_f, _ = mr_forward(params, cfg, xs, None)
    th_q, _ = mr_step_int8(params, cfg, xs, force_reference=True)
    err = float(jnp.max(jnp.abs(th_f - th_q)))
    assert err < 0.1, f"int8+PWL fused LTC stage drifted too far from float: {err}"
    assert err > 1e-7, "int8 LTC path silently ran float math"
