"""flash_attention kernel vs jnp oracle: masks, GQA, windows, grads."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention

CASES = [
    # B, S, QH, KH, Dh, causal, window
    (1, 128, 1, 1, 32, True, None),
    (2, 256, 4, 2, 64, True, None),
    (2, 256, 8, 1, 64, True, None),     # MQA
    (1, 256, 4, 4, 128, False, None),   # bidirectional (encoder)
    (2, 256, 4, 2, 64, True, 128),      # sliding window
    (1, 384, 2, 2, 64, True, 64),       # window smaller than block
]


@pytest.mark.parametrize("B,S,QH,KH,Dh,causal,window", CASES)
def test_flash_matches_reference(B, S, QH, KH, Dh, causal, window):
    key = jax.random.key(S + QH)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, QH, Dh))
    k = jax.random.normal(kk, (B, S, KH, Dh))
    v = jax.random.normal(kv, (B, S, KH, Dh))
    o_k = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    o_r = flash_attention(q, k, v, causal=causal, window=window, force_reference=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5, rtol=2e-5)


def test_flash_q_offset_decode_tail():
    """q_offset: a 1-token suffix query equals the tail of the full result."""
    key = jax.random.key(9)
    B, S, H, Dh = 1, 256, 2, 64
    q = jax.random.normal(key, (B, S, H, Dh))
    k = jax.random.normal(jax.random.key(1), (B, S, H, Dh))
    v = jax.random.normal(jax.random.key(2), (B, S, H, Dh))
    full = flash_attention(q, k, v, causal=True, interpret=True)
    tail = flash_attention(q[:, -128:], k, v, causal=True, q_offset=S - 128, interpret=True)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, -128:]), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 256)])
def test_flash_block_shape_invariance(block_q, block_k):
    """BlockSpec tiling must not change results (VMEM-tiling analogue)."""
    key = jax.random.key(4)
    q = jax.random.normal(key, (1, 256, 2, 64))
    k = jax.random.normal(jax.random.key(5), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.key(6), (1, 256, 2, 64))
    a = flash_attention(q, k, v, block_q=block_q, block_k=block_k, interpret=True)
    b = flash_attention(q, k, v, force_reference=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_flash_grads_match_reference():
    key = jax.random.key(8)
    q = jax.random.normal(key, (1, 128, 2, 32))
    k = jax.random.normal(jax.random.key(1), (1, 128, 1, 32))
    v = jax.random.normal(jax.random.key(2), (1, 128, 1, 32))

    gk = jax.grad(
        lambda q, k, v: jnp.sum(flash_attention(q, k, v, interpret=True) ** 2), (0, 1, 2)
    )(q, k, v)
    gr = jax.grad(
        lambda q, k, v: jnp.sum(flash_attention(q, k, v, force_reference=True) ** 2), (0, 1, 2)
    )(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4)


def test_flash_bf16():
    key = jax.random.key(3)
    q = jax.random.normal(key, (1, 128, 2, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (1, 128, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (1, 128, 2, 64)).astype(jnp.bfloat16)
    o_k = flash_attention(q, k, v, interpret=True)
    o_r = flash_attention(q, k, v, force_reference=True)
    np.testing.assert_allclose(
        np.asarray(o_k, np.float32), np.asarray(o_r, np.float32), atol=3e-2, rtol=3e-2
    )
