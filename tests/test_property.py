"""Hypothesis property tests on system invariants.

hypothesis is a dev-only dependency (requirements-dev.txt); environments
without it (e.g. the minimal CPU container) skip this module instead of
aborting collection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.library import exponent_table, n_library_terms, polynomial_features, term_names
from repro.core.ode import odeint
from repro.core.quant import fake_quant_ste, quantize_fixed, quantize_int8, dequantize_int8
from repro.parallel.rules import DEFAULT_RULES, partition_spec
from repro.runtime.elastic import plan_mesh

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# --- polynomial library ------------------------------------------------------
@given(st.integers(1, 5), st.integers(1, 4))
def test_library_term_count(n_vars, order):
    tbl = exponent_table(n_vars, order)
    assert tbl.shape[0] == n_library_terms(n_vars, order)
    assert len(term_names(n_vars, order)) == tbl.shape[0]
    assert (tbl.sum(axis=1) <= order).all()
    # rows unique
    assert len({tuple(r) for r in tbl}) == tbl.shape[0]


@given(
    st.integers(1, 4),
    st.integers(1, 3),
    st.lists(st.floats(-3, 3, allow_nan=False), min_size=4, max_size=4),
)
def test_library_features_match_exponents(n_vars, order, vals):
    x = jnp.asarray(vals[:n_vars])
    feats = polynomial_features(x, n_vars, order)
    tbl = exponent_table(n_vars, order)
    expect = np.array([np.prod(np.asarray(x) ** row) for row in tbl])
    np.testing.assert_allclose(np.asarray(feats), expect, atol=1e-4, rtol=1e-4)


@given(
    st.integers(1, 3),
    st.integers(1, 3),
    st.lists(st.floats(-2, 2, allow_nan=False), min_size=3, max_size=3),
    st.lists(st.floats(0.3, 3, allow_nan=False), min_size=3, max_size=3),
)
def test_normalization_transform_identity(n_vars, order, means, scales):
    """phi(z(y)) == T @ phi(y) for the recorded affine normalization."""
    from repro.core.library import normalization_transform

    mean = np.asarray(means[:n_vars])
    scale = np.asarray(scales[:n_vars])
    T = normalization_transform(mean, scale, n_vars, order)
    rng = np.random.default_rng(42)
    y = rng.normal(size=n_vars)
    z = (y - mean) / scale
    phi_z = np.asarray(polynomial_features(jnp.asarray(z), n_vars, order))
    phi_y = np.asarray(polynomial_features(jnp.asarray(y), n_vars, order))
    np.testing.assert_allclose(phi_z, T @ phi_y, atol=1e-4, rtol=1e-4)


def test_denormalize_theta_roundtrip():
    from repro.core.library import denormalize_theta, normalization_transform

    n, M = 3, 2
    mean = np.array([1.5, -2.0, 0.3])
    scale = np.array([2.0, 0.5, 1.7])
    rng = np.random.default_rng(0)
    theta_y_true = rng.normal(size=(n_library_terms(n, M), n))
    T = normalization_transform(mean, scale, n, M)
    theta_z = np.linalg.inv(T).T @ theta_y_true / scale[None, :]
    rec = denormalize_theta(theta_z, mean, scale, n, M)
    np.testing.assert_allclose(rec, theta_y_true, atol=1e-6)


# --- ODE solver ---------------------------------------------------------------
@given(st.floats(-2.0, -0.1), st.floats(0.2, 2.0))
def test_rk4_exponential_decay(lam, y0):
    """RK4 on dy/dt = lam*y matches the closed form to O(dt^4)."""
    ts = jnp.linspace(0.0, 1.0, 51)
    f = lambda y, u, t, a: lam * y
    ys = odeint(f, jnp.asarray([y0]), ts, method="rk4")
    exact = y0 * np.exp(lam * np.asarray(ts))
    np.testing.assert_allclose(np.asarray(ys[:, 0]), exact, rtol=1e-5, atol=1e-6)


def test_solver_order_ranking():
    """|err_euler| > |err_heun| > |err_rk4| at fixed step size."""
    ts = jnp.linspace(0.0, 2.0, 21)
    f = lambda y, u, t, a: -y
    exact = np.exp(-np.asarray(ts))
    errs = {}
    for m in ("euler", "heun", "rk4"):
        ys = odeint(f, jnp.asarray([1.0]), ts, method=m)
        errs[m] = np.abs(np.asarray(ys[:, 0]) - exact).max()
    assert errs["euler"] > errs["heun"] > errs["rk4"]


# --- quantization ---------------------------------------------------------------
@given(st.lists(st.floats(-4, 4, allow_nan=False, width=32), min_size=1, max_size=32),
       st.integers(2, 6), st.integers(4, 12))
def test_fixed_point_quantization_error_bound(vals, int_bits, frac_bits):
    x = jnp.asarray(vals, jnp.float32)
    q = quantize_fixed(x, int_bits, frac_bits)
    in_range = np.abs(np.asarray(x)) < 2.0 ** (int_bits - 1) - 2.0**-frac_bits
    err = np.abs(np.asarray(q - x))
    assert (err[in_range] <= 2.0 ** (-frac_bits - 1) + 1e-7).all()


def test_fake_quant_ste_gradient_is_identity():
    """STE: d/dx f(q(x)) == f'(q(x)) — the quantizer passes gradients through."""
    x = jnp.asarray([0.3, -1.7])
    q = fake_quant_ste(x, 4, 8)
    g = jax.grad(lambda x: jnp.sum(fake_quant_ste(x, 4, 8) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(q), atol=1e-6)


@given(st.lists(st.floats(-10, 10, allow_nan=False, width=32), min_size=2, max_size=64))
def test_int8_roundtrip_error(vals):
    w = jnp.asarray(vals, jnp.float32).reshape(1, -1)
    q = quantize_int8(w)
    back = dequantize_int8(q)
    amax = float(jnp.max(jnp.abs(w)))
    if amax > 1e-6:
        assert float(jnp.max(jnp.abs(back - w))) <= amax / 127.0 + 1e-6


# --- sharding rules --------------------------------------------------------------
class _FakeMesh:
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as _np

        class _D:  # minimal .devices with .shape
            pass

        self.devices = _np.empty(tuple(sizes.values()), dtype=object)


MESHES = [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
    {"data": 4, "model": 2},
]

AXIS_NAMES = [
    None,
    "batch",
    "seq",
    "embed",
    "heads",
    "kv_heads",
    "mlp",
    "vocab",
    "expert",
    "cache_seq",
    "seq_sharded",
]


@given(
    st.sampled_from(MESHES),
    st.lists(
        st.tuples(
            st.sampled_from(AXIS_NAMES),
            st.sampled_from([1, 2, 3, 8, 16, 32, 64, 256, 4096]),
        ),
        min_size=1,
        max_size=4,
    ),
)
def test_partition_spec_invariants(mesh_sizes, dims):
    """(1) no mesh axis used twice; (2) every assignment divides its dim."""
    mesh = _FakeMesh(mesh_sizes)
    axes = tuple(a for a, _ in dims)
    shape = tuple(s for _, s in dims)
    spec = partition_spec(shape, axes, mesh, DEFAULT_RULES)
    used = []
    import math

    for i, entry in enumerate(spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        for nm in names:
            assert nm not in used, f"axis {nm} assigned twice: {spec}"
            used.append(nm)
        prod = math.prod(mesh_sizes[nm] for nm in names)
        assert shape[i] % prod == 0, f"dim {shape[i]} not divisible by {prod}"


def test_partition_spec_decode_vs_long_context():
    """The documented conflict-resolution example (DESIGN.md §5)."""
    mesh = _FakeMesh({"data": 16, "model": 16})
    # decode_32k: batch=128 claims data; cache claims model
    spec = partition_spec((128, 32768, 8, 128), ("batch", "cache_seq", "kv_heads", None), mesh)
    assert spec[0] == "data" and spec[1] == "model"
    # long_500k: batch=1 fails divisibility; cache claims data
    spec = partition_spec((1, 524288, 8, 128), ("batch", "cache_seq", "kv_heads", None), mesh)
    assert spec[0] is None and spec[1] == "data"


# --- elastic mesh planning --------------------------------------------------------
@given(st.integers(1, 600), st.sampled_from([2, 4, 8, 16]), st.sampled_from([2, 4, 8, 16]))
def test_plan_mesh_feasible(n, model, max_data):
    plan = plan_mesh(n, model=model, max_data=max_data, pods=2)
    assert plan.n_devices <= n
    assert plan.shape[-1] <= model
    # model axis preserved whenever enough devices exist
    if n >= model:
        assert plan.shape[-1] == model
    # data axis is a power of two
    d = plan.shape[-2]
    assert d & (d - 1) == 0


# --- data pipeline -----------------------------------------------------------------
@given(st.integers(0, 1000), st.integers(0, 1000))
def test_pipeline_step_addressable(step_a, step_b):
    from repro.data.pipeline import PipelineConfig, SyntheticLM

    pipe = SyntheticLM(PipelineConfig(vocab_size=128, seq_len=16, global_batch=2))
    a1 = pipe.batch_at(step_a)
    a2 = pipe.batch_at(step_a)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])  # deterministic
    if step_a != step_b:
        b = pipe.batch_at(step_b)
        assert not np.array_equal(a1["tokens"], b["tokens"])  # distinct steps
