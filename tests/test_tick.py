"""Banked one-kernel service tick (kernels/mr_step/tick.py + TickSpec).

Pins the mr_tick kernel family against the ref.py oracle (fp32 + int8/PWL,
sweep over encoder x input_dim x slots_per_bank), the plan-level
banked-vs-composite service parity (params bitwise, theta/delta <= 1e-5),
the packed-status host-sync drop, TickSpec validation and "auto" kernel
resolution through the tick-level VMEM residency model, and the tick-level
R2 audit cell.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import RecoverySpec, TickSpec
from repro.core import stream
from repro.core.merinda import MRConfig, init_mr
from repro.core.stream import StreamConfig
from repro.data.dynamics import generate_trajectory
from repro.kernels.mr_step import tiling
from repro.kernels.mr_step.tick import mr_tick, tick_supported

# serve-only geometry: 3 windows per buffer, no optimizer steps in the tick
TCFG = StreamConfig(
    buf_len=16, window=8, stride=4, chunk=4, steps_per_tick=0, min_steps=10**9, max_steps=10**9
)
BASE = dict(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01)


def _mr_cfg(encoder="gru", m=0):
    return MRConfig(input_dim=m, encoder=encoder, **BASE)


def _tick_inputs(cfg, scfg, S, seed=0):
    """Random slot-stacked operands for a direct mr_tick call."""
    key = jax.random.key(seed)
    keys = jax.random.split(key, S + 7)
    params = jax.vmap(lambda k: init_mr(k, cfg))(keys[:S])
    n, m, L, C = cfg.state_dim, cfg.input_dim, scfg.buf_len, scfg.chunk
    buf_y = jax.random.normal(keys[S], (S, L, n))
    buf_u = jax.random.normal(keys[S + 1], (S, L, m))
    new_y = jax.random.normal(keys[S + 2], (S, C, n))
    new_u = jax.random.normal(keys[S + 3], (S, C, m))
    mean = jax.random.normal(keys[S + 4], (S, n)) * 0.1
    scale = jax.random.uniform(keys[S + 5], (S, n), minval=0.5, maxval=1.5)
    theta_prev = jax.random.normal(keys[S + 6], (S, cfg.n_terms, n)) * 0.3
    seed_flags = jnp.asarray([True, False] * (S // 2))
    active = jnp.asarray([True] * (S - 1) + [False])
    return params, buf_y, buf_u, new_y, new_u, mean, scale, theta_prev, seed_flags, active


def _run_tick(cfg, scfg, S, *, quant=False, slots_per_bank=1, **dispatch):
    ops = _tick_inputs(cfg, scfg, S)
    return mr_tick(
        ops[0], cfg, scfg, *ops[1:], quant=quant, slots_per_bank=slots_per_bank, **dispatch
    )


# ---------------------------------------------------------------------------
# kernel vs reference oracle: fp32 sweep over encoder x input_dim x bank size
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "encoder,m,spb",
    [
        ("gru", 0, 1),
        ("gru", 2, 2),
        ("gru", 0, 4),
        ("gru_flow", 0, 2),
        ("gru_flow", 2, 1),
    ],
)
def test_mr_tick_interpret_matches_reference(encoder, m, spb):
    cfg = _mr_cfg(encoder, m)
    ref = _run_tick(cfg, TCFG, 4, slots_per_bank=spb, force_reference=True)
    ker = _run_tick(cfg, TCFG, 4, slots_per_bank=spb, interpret=True)
    for r, k, name in zip(ref, ker, ("buf_y", "buf_u", "theta", "delta")):
        np.testing.assert_allclose(np.asarray(k), np.asarray(r), atol=1e-5, err_msg=name)


def test_mr_tick_inactive_slot_reports_inf_delta():
    cfg = _mr_cfg()
    *_, delta = _run_tick(cfg, TCFG, 4, interpret=True)
    assert np.isinf(np.asarray(delta)[-1])  # _tick_inputs deactivates the last slot
    assert np.isfinite(np.asarray(delta)[:-1]).all()


def test_mr_tick_rolls_buffers():
    cfg = _mr_cfg(m=2)
    ops = _tick_inputs(cfg, TCFG, 4)
    buf_y2, buf_u2, _, _ = mr_tick(ops[0], cfg, TCFG, *ops[1:], interpret=True)
    C = TCFG.chunk
    np.testing.assert_allclose(np.asarray(buf_y2[:, :-C]), np.asarray(ops[1][:, C:]), atol=0)
    np.testing.assert_allclose(np.asarray(buf_y2[:, -C:]), np.asarray(ops[3]), atol=0)
    np.testing.assert_allclose(np.asarray(buf_u2[:, -C:]), np.asarray(ops[4]), atol=0)


# ---------------------------------------------------------------------------
# int8/PWL serving twin
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,spb", [(0, 1), (2, 2)])
def test_mr_tick_int8_interpret_matches_reference(m, spb):
    cfg = _mr_cfg("gru", m)
    ref = _run_tick(cfg, TCFG, 4, quant=True, slots_per_bank=spb, force_reference=True)
    ker = _run_tick(cfg, TCFG, 4, quant=True, slots_per_bank=spb, interpret=True)
    for r, k, name in zip(ref, ker, ("buf_y", "buf_u", "theta", "delta")):
        np.testing.assert_allclose(np.asarray(k), np.asarray(r), atol=1e-5, err_msg=name)


def test_mr_tick_int8_tracks_fp32():
    cfg = _mr_cfg("gru")
    theta_f = np.asarray(_run_tick(cfg, TCFG, 4, interpret=True)[2])
    theta_q = np.asarray(_run_tick(cfg, TCFG, 4, quant=True, interpret=True)[2])
    assert np.max(np.abs(theta_q - theta_f)) < 0.25  # int8+PWL vs fp32 readout


def test_mr_tick_rejects_unsupported_family():
    assert not tick_supported(MRConfig(encoder="ltc", **BASE))
    assert tick_supported(_mr_cfg("gru_flow"))
    assert not tick_supported(_mr_cfg("gru_flow"), int8=True)  # PWL = standard gru only
    with pytest.raises(ValueError, match="GRU"):
        _run_tick(MRConfig(encoder="ltc", **BASE), TCFG, 4, force_reference=True)


# ---------------------------------------------------------------------------
# plan-level parity: banked vs composite service, lockstep ticks
# ---------------------------------------------------------------------------
SCFG = StreamConfig(
    buf_len=32, window=8, stride=8, chunk=8, steps_per_tick=0, min_steps=10**9, max_steps=10**9
)


def _spec(**overrides):
    base = dict(mode="stream", n_slots=2, stream=SCFG, encoder="gru", seed=0, **BASE)
    base.update(overrides)
    return RecoverySpec(**base)


def _tick_for(scfg):
    return lambda kernel: TickSpec(
        steps_per_tick=scfg.steps_per_tick, ema_decay=scfg.ema, tick_kernel=kernel
    )


@pytest.fixture(scope="module")
def lorenz():
    _, ys, _ = generate_trajectory("lorenz", n_samples=200)
    return ys


@pytest.mark.parametrize("k", [0, 2])
def test_banked_matches_composite_service(lorenz, k):
    """Same spec, same data: the banked tick's params stay bitwise the
    composite tick's (K > 0 reuses its training scan verbatim) and the
    one-kernel serving segment reproduces theta/delta to 1e-5."""
    scfg = dataclasses.replace(SCFG, steps_per_tick=k)
    services = {}
    for kernel in ("banked", "composite"):
        spec = _spec(stream=scfg, tick=_tick_for(scfg)(kernel))
        svc = api.compile_plan(spec).make_service()
        for sid in range(2):
            svc.submit(sid, lorenz[sid : sid + scfg.buf_len])
        svc.fill_slots()
        services[kernel] = svc
    for t in range(3):
        idx = scfg.buf_len + t * scfg.chunk + np.arange(scfg.chunk)
        chunk = np.repeat(lorenz[idx][None], 2, axis=0)
        info_b = services["banked"].tick_once(chunk)
        info_c = services["composite"].tick_once(chunk)
        np.testing.assert_allclose(info_b["delta"], info_c["delta"], atol=1e-5)
    sb, sc = services["banked"].state, services["composite"].state
    for lb, lc in zip(jax.tree.leaves(sb.params), jax.tree.leaves(sc.params)):
        np.testing.assert_array_equal(np.asarray(lb), np.asarray(lc))
    np.testing.assert_allclose(np.asarray(sb.theta), np.asarray(sc.theta), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sb.steps), np.asarray(sc.steps))


def test_banked_tick_single_host_sync(lorenz):
    """The packed [delta, loss, steps, active] status makes a steady-state
    banked tick ONE host readback; the composite tick reads each SlotState
    leaf separately (the 5.17-syncs/tick ROADMAP baseline)."""
    logs = {}
    for kernel in ("banked", "composite"):
        spec = _spec(tick=_tick_for(SCFG)(kernel))
        svc = api.compile_plan(spec).make_service()
        for sid in range(2):
            svc.submit(sid, lorenz[sid : sid + SCFG.buf_len])
        svc.fill_slots()
        for t in range(4):
            idx = SCFG.buf_len + t * SCFG.chunk + np.arange(SCFG.chunk)
            svc.tick_once(np.repeat(lorenz[idx][None], 2, axis=0))
        logs[kernel] = svc.sync_log[1:]  # tick 0 compiles; steady state after
    assert max(logs["banked"]) <= 2, logs
    assert min(logs["composite"]) >= 4, logs
    assert max(logs["banked"]) < min(logs["composite"])


# ---------------------------------------------------------------------------
# TickSpec validation + "auto" resolution through the VMEM residency model
# ---------------------------------------------------------------------------
def test_tick_spec_validates_literals():
    with pytest.raises(ValueError, match="tick_kernel"):
        TickSpec(tick_kernel="bankd")
    with pytest.raises(ValueError, match="steps_per_tick"):
        TickSpec(steps_per_tick=-1)
    with pytest.raises(ValueError, match="ema_decay"):
        TickSpec(ema_decay=1.0)
    TickSpec(steps_per_tick=0)  # pure serve tick is a valid request


def test_tick_spec_requires_stream_mode():
    with pytest.raises(ValueError, match="tick= requires mode='stream'"):
        RecoverySpec(mode="batch", batch_size=8, tick=TickSpec(), **BASE, encoder="gru")


def test_tick_spec_conflict_with_stream_config():
    with pytest.raises(ValueError, match="tick conflict"):
        _spec(tick=TickSpec(steps_per_tick=SCFG.steps_per_tick + 1))


def test_plan_records_tick_lowering():
    plan = api.compile_plan(_spec())  # tick=None -> composite default
    assert plan.lowering.tick_kernel == "composite"
    assert plan.lowering.tick_slots_per_bank is None

    plan = api.compile_plan(_spec(tick=_tick_for(SCFG)("banked")))
    assert plan.lowering.tick_kernel == "banked"
    assert plan.lowering.tick_slots_per_bank >= 1
    assert 2 % plan.lowering.tick_slots_per_bank == 0


def test_auto_resolves_banked_for_gru_composite_for_ltc():
    plan = api.compile_plan(_spec(tick=_tick_for(SCFG)("auto")))
    assert plan.lowering.tick_kernel == "banked"  # gru fits the tiny shapes

    plan = api.compile_plan(_spec(encoder="ltc", tick=_tick_for(SCFG)("auto")))
    assert plan.lowering.tick_kernel == "composite"
    assert plan.lowering.tick_slots_per_bank is None


def test_explicit_banked_on_ltc_raises():
    with pytest.raises(ValueError, match="GRU-family"):
        api.compile_plan(_spec(encoder="ltc", tick=_tick_for(SCFG)("banked")))


def test_tiny_budget_auto_falls_back_explicit_runs_at_bank_one():
    tiny = dict(block_b="auto", vmem_budget_bytes=1024)
    plan = api.compile_plan(_spec(tick=_tick_for(SCFG)("auto"), **tiny))
    assert plan.lowering.tick_kernel == "composite"  # nothing fits: heuristic declines

    plan = api.compile_plan(_spec(tick=_tick_for(SCFG)("banked"), **tiny))
    assert plan.lowering.tick_kernel == "banked"  # explicit request overrides
    assert plan.lowering.tick_slots_per_bank == 1


def test_plan_tick_program_property():
    plan = api.compile_plan(_spec(tick=_tick_for(SCFG)("banked")))
    assert callable(plan.tick)
    offline = api.compile_plan(RecoverySpec(encoder="gru", **BASE))
    with pytest.raises(ValueError):
        _ = offline.tick


# ---------------------------------------------------------------------------
# tick-level VMEM residency model
# ---------------------------------------------------------------------------
def test_tick_vmem_bytes_monotonic_in_bank_size():
    cfg = _mr_cfg()
    sizes = [tiling.tick_vmem_bytes(cfg, TCFG, slots_per_bank=s) for s in (1, 2, 4)]
    assert sizes[0] < sizes[1] < sizes[2]
    q = tiling.tick_vmem_bytes(cfg, TCFG, slots_per_bank=2, int8=True)
    assert q < sizes[1]  # int8 weights shrink the resident bank


def test_auto_slots_per_bank_policy():
    cfg = _mr_cfg()
    assert tiling.auto_slots_per_bank(cfg, TCFG, 8, None) == 8  # no budget: whole shard
    spb = tiling.auto_slots_per_bank(cfg, TCFG, 8, 10**9)
    assert spb >= 1 and 8 % spb == 0
    assert tiling.auto_slots_per_bank(cfg, TCFG, 8, 64) == 0  # nothing fits


# ---------------------------------------------------------------------------
# audit: the banked K=0 tick program carries a tick-level R2 residency cell
# ---------------------------------------------------------------------------
def test_banked_plan_passes_audit_with_tick_residency_cell():
    spec = _spec(tick=_tick_for(SCFG)("banked"))
    plan = api.compile_plan(spec, audit="error")  # any finding raises
    assert plan.lowering.audit.startswith("pass")
    assert "R2" in plan.lowering.audit


# ---------------------------------------------------------------------------
# device-resident control plane (core/control.py): host-queue parity + syncs
# ---------------------------------------------------------------------------
# budget-only eviction (delta_tol=0): the two planes run the SAME tick math
# but as differently-fused XLA programs, so float-identical convergence
# deltas are not guaranteed near a tolerance — the lockstep comparison pins
# occupancy/steps/reason exactly and theta to 1e-5 instead.
CCFG = StreamConfig(
    buf_len=32,
    window=8,
    stride=8,
    chunk=8,
    steps_per_tick=8,
    min_steps=16,
    max_steps=16,
    delta_tol=0.0,
)


def _control_spec(control, scfg=CCFG, **overrides):
    base = dict(
        mode="stream",
        n_slots=2,
        stream=scfg,
        encoder="gru",
        seed=0,
        tick=TickSpec(
            steps_per_tick=scfg.steps_per_tick,
            control=control,
            queue_capacity=8,
            snapshot_period=1,
            warm_capacity=8,
        ),
        **BASE,
    )
    base.update(overrides)
    return RecoverySpec(**base)


def test_tick_spec_validates_control_plane_fields():
    with pytest.raises(ValueError, match="control"):
        TickSpec(control="fpga")
    with pytest.raises(ValueError, match="queue_capacity"):
        TickSpec(queue_capacity=0)
    with pytest.raises(ValueError, match="snapshot_period"):
        TickSpec(snapshot_period=0)
    with pytest.raises(ValueError, match="warm_capacity"):
        TickSpec(warm_capacity=0)


def test_plan_records_control_plane_lowering():
    low_d = api.compile_plan(_control_spec("device")).lowering
    assert low_d.control_plane == "device"
    assert low_d.tick_queue_capacity == 8
    assert low_d.tick_snapshot_period == 1
    assert low_d.warm_capacity == 8
    low_h = api.compile_plan(_control_spec("host")).lowering
    assert low_h.control_plane == "host"
    assert low_h.tick_queue_capacity is None
    assert low_h.tick_snapshot_period is None


def test_device_control_matches_host_queue_lockstep(lorenz):
    """Randomized admission/eviction traffic through both control planes in
    lockstep: same slot occupancy, same eviction (tick, id, steps, reason),
    per-stream theta to 1e-5 — including a warm-start resubmission wave."""
    rng = np.random.default_rng(7)
    n_streams, slots = 6, 2
    data = np.stack(
        [
            np.roll(lorenz, -int(rng.integers(0, 64)), axis=0)
            + rng.normal(0.0, 0.01, lorenz.shape)
            for _ in range(n_streams)
        ]
    ).astype(np.float32)
    arrivals = {0: [0, 1, 2], 2: [3], 3: [4], 5: [5]}  # rng-drawn, then frozen
    t_total = data.shape[1]

    def run_traffic(svc, resubmit=()):
        cursors = dict.fromkeys(range(n_streams), CCFG.buf_len)
        slot_maps, evictions = [], []
        for sid in resubmit:
            svc.submit(sid, data[sid, : CCFG.buf_len])
        svc.fill_slots()
        t = 0
        while (not svc.done or t in arrivals) and t < 40:
            if not resubmit:
                for sid in arrivals.get(t, ()):
                    svc.submit(sid, data[sid, : CCFG.buf_len])
                    svc.fill_slots()
            chunk = np.zeros((slots, CCFG.chunk, 3), np.float32)
            for s, sid in enumerate(svc.slot_streams()):
                if sid < 0:
                    continue
                idx = (cursors[sid] + np.arange(CCFG.chunk)) % t_total
                chunk[s] = data[sid, idx]
                cursors[sid] += CCFG.chunk
            info = svc.tick_once(chunk)
            slot_maps.append(tuple(int(s) for s in svc.slot_streams()))
            evictions.extend((t, r.stream_id, r.steps, r.reason) for r in info["evicted"])
            t += 1
        return slot_maps, evictions

    services, traces = {}, {}
    for control in ("host", "device"):
        svc = api.compile_plan(_control_spec(control)).make_service()
        traces[control] = run_traffic(svc)
        services[control] = svc
    assert traces["device"] == traces["host"]
    assert services["device"].done and services["host"].done
    res_h, res_d = services["host"].results, services["device"].results
    assert set(res_d) == set(res_h) == set(range(n_streams))
    for sid in range(n_streams):
        assert (res_d[sid].steps, res_d[sid].reason) == (res_h[sid].steps, res_h[sid].reason)
        np.testing.assert_allclose(res_d[sid].theta, res_h[sid].theta, atol=1e-5)
        np.testing.assert_allclose(res_d[sid].mean, res_h[sid].mean, atol=1e-6)
    # warm-start resubmission (below LRU/warm-cache capacity): both planes
    # must serve the cached evicted params, not a cold restart
    for control in ("host", "device"):
        traces[control] = run_traffic(services[control], resubmit=(0, 1))
    assert traces["device"] == traces["host"]
    for sid in (0, 1):
        np.testing.assert_allclose(
            services["device"].results[sid].theta,
            services["host"].results[sid].theta,
            atol=1e-5,
        )


def test_device_queue_backpressure_typed(lorenz):
    """Pressure never raises: a full shard ring spills to the bounded host
    overflow queue (OVERFLOW), a full overflow REJECTs, and overflowed
    arrivals drain back into the ring (and complete) as capacity frees."""
    svc = api.compile_plan(
        _control_spec(
            "device",
            tick=TickSpec(
                steps_per_tick=8, control="device", queue_capacity=2, overflow_capacity=1
            ),
        )
    ).make_service()
    hist = lorenz[: CCFG.buf_len]
    assert svc.submit(0, hist).status is stream.SubmitStatus.ENQUEUED
    assert svc.submit(1, hist).status is stream.SubmitStatus.ENQUEUED
    r2 = svc.submit(2, hist)
    assert r2.status is stream.SubmitStatus.OVERFLOW and r2.accepted
    r3 = svc.submit(3, hist)
    assert r3.status is stream.SubmitStatus.REJECTED and not r3.accepted
    assert 3 not in svc._pending  # nothing retained for a rejected stream
    chunk = np.repeat(lorenz[CCFG.buf_len : CCFG.buf_len + CCFG.chunk][None], 2, axis=0)
    svc.fill_slots()
    for _ in range(12):
        if svc.done:
            break
        svc.tick_once(chunk)
    assert set(svc.results) == {0, 1, 2}  # the overflowed stream completed too


@pytest.mark.parametrize("control", ["host", "device"])
def test_priority_preempts_cold_slot(lorenz, control):
    """A higher-tier arrival displaces the lowest-tier COLD slot (steps <
    min_steps) on both control planes: the victim re-enters the queue with
    its live buffers and still completes, so no stream is lost."""
    svc = api.compile_plan(_control_spec(control)).make_service()
    hist = lorenz[: CCFG.buf_len]
    for sid in (0, 1):
        svc.submit(sid, hist)
    svc.fill_slots()
    assert sorted(svc.slot_streams()) == [0, 1]
    assert svc.submit(2, hist, priority=3).accepted
    chunk = np.repeat(lorenz[CCFG.buf_len : CCFG.buf_len + CCFG.chunk][None], 2, axis=0)
    svc.tick_once(chunk)
    # one tick in, both residents are cold (8 < min_steps=16): victim policy
    # picks the lowest (tier, slot) — slot 0 — and the tier-3 arrival lands
    assert svc.slot_streams() == [2, 1]
    for _ in range(12):
        if svc.done:
            break
        svc.tick_once(chunk)
    assert set(svc.results) == {0, 1, 2}
    assert all(r.reason == "budget" for r in svc.results.values())


def test_device_queue_ring_wraps(lorenz):
    """Capacity-2 ring admits two waves of two: the second wave's writes wrap
    the ring head and still admit/complete the right streams."""
    svc = api.compile_plan(
        _control_spec("device", tick=TickSpec(steps_per_tick=8, control="device", queue_capacity=2))
    ).make_service()
    for sid in (0, 1):
        svc.submit(sid, lorenz[: CCFG.buf_len])
    svc.fill_slots()  # snapshot reconciles: ring is empty again
    for sid in (2, 3):
        svc.submit(sid, lorenz[sid : sid + CCFG.buf_len])
    chunk = np.repeat(lorenz[CCFG.buf_len : CCFG.buf_len + CCFG.chunk][None], 2, axis=0)
    for _ in range(8):
        if svc.done:
            break
        svc.tick_once(chunk)
    assert set(svc.results) == {0, 1, 2, 3}
    assert all(r.steps == CCFG.max_steps for r in svc.results.values())


def test_host_warm_registry_bounded(lorenz):
    """Satellite: the host-path warm-start registry is a bounded LRU sized by
    TickSpec.warm_capacity, not an unbounded dict."""
    svc = api.compile_plan(
        _control_spec(
            "host",
            n_slots=1,
            tick=TickSpec(steps_per_tick=8, control="host", warm_capacity=2),
        )
    ).make_service()
    assert svc.warm_capacity == 2
    for sid in range(3):
        svc.submit(sid, lorenz[sid : sid + CCFG.buf_len])
    svc.fill_slots()
    chunk = lorenz[CCFG.buf_len : CCFG.buf_len + CCFG.chunk][None]
    for _ in range(8):
        if svc.done:
            break
        svc.tick_once(chunk)
    assert set(svc.results) == {0, 1, 2}
    assert list(svc.warm) == [1, 2]  # LRU: stream 0's entry was evicted


def test_device_snapshot_period_steady_state_zero_syncs(lorenz):
    """With snapshot_period=4 and no evictions, only every 4th tick reads
    anything back (status + event drain); the median steady-state tick is
    ZERO host syncs and the service stays queryable from cached views."""
    scfg = dataclasses.replace(CCFG, min_steps=10**9, max_steps=10**9)
    svc = api.compile_plan(
        _control_spec(
            "device",
            scfg=scfg,
            tick=TickSpec(steps_per_tick=8, control="device", snapshot_period=4),
        )
    ).make_service()
    for sid in (0, 1):
        svc.submit(sid, lorenz[: scfg.buf_len])
    svc.fill_slots()
    chunk = np.repeat(lorenz[scfg.buf_len : scfg.buf_len + scfg.chunk][None], 2, axis=0)
    for _ in range(8):
        svc.tick_once(chunk)
    syncs0 = svc.counters["host_syncs"]
    assert list(svc.slot_streams()) == [0, 1]  # served from the snapshot view
    assert svc.done is False  # no eager active-mask readback (satellite fix)
    assert svc.counters["host_syncs"] == syncs0
    assert svc.counters["reshards"] == 0
    log = svc.sync_log[1:]  # tick 0 pays compile-adjacent snapshot timing
    assert float(np.median(log)) == 0.0
    assert all(s == 0 for i, s in enumerate(log, start=2) if i % 4 != 0), log
