"""repro.api: the declarative RecoverySpec -> compile_plan -> RecoveryPlan surface.

Pins the redesign's contract: spec validation fails at compile time (never
mid-trace), each execution mode reproduces its legacy entry point exactly
(train_mr / recover_many / RecoveryService, fp32 and int8), the lowering
record resolves block_b against a VMEM budget, and a 2-virtual-device mesh
shards SlotState without changing the numerics.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import engine, stream
from repro.core.merinda import MRConfig, train_mr
from repro.core.stream import RecoveryService, StreamConfig
from repro.data.dynamics import generate_trajectory
from repro.data.windows import make_windows
from tests.conftest import run_devices

SCFG = StreamConfig(
    buf_len=48, window=12, stride=6, chunk=8, steps_per_tick=8, min_steps=16, max_steps=64
)


def small_spec(**overrides) -> api.RecoverySpec:
    base = dict(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder="gru")
    base.update(overrides)
    return api.RecoverySpec(**base)


@pytest.fixture(scope="module")
def lorenz_windows():
    _, ys, _ = generate_trajectory("lorenz", n_samples=300)
    yw, _, norm = make_windows(ys, None, window=12, stride=6)
    return jnp.asarray(yw), norm


@pytest.fixture(scope="module")
def lorenz_raw():
    _, ys, _ = generate_trajectory("lorenz", n_samples=400)
    return ys


# ---------------------------------------------------------------------------
# spec validation: bad requests fail at construction / compile time
# ---------------------------------------------------------------------------
def test_spec_literal_validation():
    with pytest.raises(ValueError, match="mode"):
        small_spec(mode="streaming")
    with pytest.raises(ValueError, match="precision"):
        small_spec(precision="fp16")
    with pytest.raises(ValueError, match="block_b"):
        small_spec(block_b="automatic")
    with pytest.raises(ValueError, match="vmem_budget_bytes"):
        small_spec(block_b=32, vmem_budget_bytes=1 << 20)
    with pytest.raises(ValueError, match="divide"):
        small_spec(mode="stream", n_slots=3, mesh_slots=2)
    with pytest.raises(ValueError, match="mesh_slots"):
        small_spec(mode="offline", mesh_slots=2)


def test_compile_validation_unknown_encoder():
    with pytest.raises(ValueError, match="unknown encoder"):
        api.compile_plan(small_spec(encoder="gru_typo"))


@pytest.mark.parametrize("encoder", ["ltc", "node"])
def test_compile_validation_fused_substep_families_lower(encoder):
    """fused=True is legal for every registry encoder now: the multi-substep
    families lower to their fused-solver mr_step variants with no new call
    sites (Lowering.dispatch routes through the kernel family)."""
    plan = api.compile_plan(small_spec(encoder=encoder, fused=True))
    assert plan.lowering.fused
    assert plan.lowering.dispatch in ("pallas", "reference")
    assert plan.cfg.fused


def test_compile_validation_fused_requires_fusable():
    """A custom registry row without an mr_step lowering still fails
    eagerly at compile time with the actionable fusable list."""
    from repro.core import encoders

    row = encoders.EncoderSpec(
        name="mean_pool_nofuse_api",
        init=lambda key, d_in, hidden, dtype=None: {},
        encode=lambda p, cfg, xs: xs.mean(axis=1),
        flow=None,
        fusable=False,
        kernel=False,
    )
    encoders.register_encoder(row)
    try:
        with pytest.raises(ValueError, match="fusable"):
            api.compile_plan(small_spec(encoder="mean_pool_nofuse_api", fused=True))
    finally:
        encoders._REGISTRY.pop("mean_pool_nofuse_api", None)


@pytest.mark.parametrize("encoder", ["gru_flow", "node"])
def test_compile_validation_int8_requires_pwl_mappable_cell(encoder):
    """int8 + flow encoder (and int8 + node) is a genuinely unsupported
    combo: no PWL mapping exists, so it still raises the actionable list."""
    with pytest.raises(ValueError, match="int8_pwl"):
        api.compile_plan(small_spec(encoder=encoder, precision="int8_pwl"))


def test_compile_int8_ltc_serving_lowers():
    """The LTC substep cell is sigmoid-only, so its fixed-point fused stage
    exists and int8_pwl serving compiles."""
    plan = api.compile_plan(small_spec(encoder="ltc", precision="int8_pwl"))
    assert plan.lowering.quant_serving


def test_compile_validation_mesh_exceeds_devices():
    # the test process holds exactly one CPU device (see conftest)
    with pytest.raises(ValueError, match="device"):
        api.compile_plan(small_spec(mode="stream", n_slots=4, mesh_slots=4))


def test_mode_mismatch_raises(lorenz_windows):
    yw, _ = lorenz_windows
    plan = api.compile_plan(small_spec(mode="offline", steps=2))
    with pytest.raises(ValueError, match="mode"):
        plan.run_batch(yw[None])
    with pytest.raises(ValueError, match="mode"):
        plan.make_service()


def test_legacy_entry_points_validate_eagerly(lorenz_windows):
    """The deprecated wrappers + service fail BEFORE tracing on a fused
    request with a non-fusable encoder (no silent unfused fallback)."""
    from repro.core import encoders

    yw, _ = lorenz_windows
    row = encoders.EncoderSpec(
        name="mean_pool_nofuse_legacy",
        init=lambda key, d_in, hidden, dtype=None: {},
        encode=lambda p, cfg, xs: xs.mean(axis=1),
        flow=None,
        fusable=False,
        kernel=False,
    )
    encoders.register_encoder(row)
    try:
        cfg = MRConfig(
            state_dim=3,
            order=2,
            hidden=8,
            dense_hidden=16,
            dt=0.01,
            encoder="mean_pool_nofuse_legacy",
            fused=True,
        )
        with pytest.raises(ValueError, match="fusable"):
            engine.train_mr_scan(cfg, yw, steps=1)
        with pytest.raises(ValueError, match="fusable"):
            engine.recover_many(cfg, yw[None], steps=1)
        with pytest.raises(ValueError, match="fusable"):
            RecoveryService(cfg, SCFG, n_slots=1)
    finally:
        encoders._REGISTRY.pop("mean_pool_nofuse_legacy", None)


def test_legacy_entry_points_warn_deprecated_once(lorenz_windows):
    """The deprecated wrappers warn ONCE per process, not per call — the
    service-tick/benchmark loops call them hundreds of times."""
    import warnings

    from repro.deprecation import reset_warned

    yw, _ = lorenz_windows
    cfg = MRConfig(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder="gru")
    reset_warned()
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            for _ in range(3):
                engine.train_mr_scan(cfg, yw, steps=1)
                RecoveryService(cfg, SCFG, n_slots=1)
        dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 2, [str(w.message) for w in dep]  # one per entry point
    finally:
        reset_warned()


# ---------------------------------------------------------------------------
# block_b lowering
# ---------------------------------------------------------------------------
def test_block_b_auto_resolves_against_budget():
    spec = small_spec(
        mode="batch", batch_size=32, fused=True, block_b="auto", vmem_budget_bytes=6000
    )
    plan = api.compile_plan(spec)
    bb = plan.lowering.block_b
    assert bb is not None and 32 % bb == 0 and bb < 32
    assert plan.lowering.vmem_bytes is not None
    assert plan.lowering.vmem_bytes <= 6000
    assert plan.cfg.block_b == bb  # the tile reaches the fused kernel config


def test_block_b_auto_without_budget_detects_device_budget():
    """No explicit vmem_budget_bytes: the budget is auto-detected from the
    device (platform table; CPU resolves the v4/v5 default) and recorded in
    the lowering. The tiny config fits, so the tile stays full-batch."""
    from repro.kernels.mr_step import tiling

    plan = api.compile_plan(small_spec(mode="batch", batch_size=32, fused=True, block_b="auto"))
    assert plan.lowering.block_b is None  # full batch fits the detected budget
    assert plan.lowering.vmem_budget_bytes == tiling.detect_vmem_budget()
    assert plan.lowering.vmem_bytes <= plan.lowering.vmem_budget_bytes


def test_block_b_auto_explicit_budget_overrides_detection():
    spec = small_spec(
        mode="batch", batch_size=32, fused=True, block_b="auto", vmem_budget_bytes=6000
    )
    plan = api.compile_plan(spec)
    assert plan.lowering.vmem_budget_bytes == 6000  # override wins, recorded


def test_detect_vmem_budget_platform_table():
    from repro.kernels.mr_step import tiling

    class FakeDev:
        device_kind = "TPU v6e"

        def memory_stats(self):
            return {}

    assert tiling.detect_vmem_budget(FakeDev()) == int(32 * 1024 * 1024 * 0.5)

    class StatsDev:
        device_kind = "weird"

        def memory_stats(self):
            return {"vmem_size_bytes": 4 * 1024 * 1024}

    assert tiling.detect_vmem_budget(StatsDev()) == int(4 * 1024 * 1024 * 0.5)


@pytest.mark.parametrize("encoder", ["ltc", "node"])
def test_substep_vmem_model_and_auto_tile(encoder):
    """config_vmem_bytes dispatches to the substep-cell residency models and
    the auto tile budgets against them (block_b="auto" stays correct)."""
    from repro.kernels.mr_step import tiling

    cfg = small_spec(encoder=encoder, fused=True, hidden=64, dense_hidden=128).to_mr_config()
    full = tiling.config_vmem_bytes(cfg, 32)
    tiled = tiling.config_vmem_bytes(cfg, 32, block_b=8)
    assert tiled < full  # activation rows tile; weights stay resident
    # residency is substep-count-invariant: the kernels reuse one working set
    import dataclasses

    cfg12 = dataclasses.replace(cfg, ltc_substeps=12)
    assert tiling.config_vmem_bytes(cfg12, 32) == full
    budget = tiled
    bb = tiling.auto_block_b(cfg, 32, budget)
    assert bb is not None and 32 % bb == 0
    assert tiling.config_vmem_bytes(cfg, 32, block_b=bb) <= budget
    plan = api.compile_plan(
        small_spec(
            encoder=encoder,
            fused=True,
            hidden=64,
            dense_hidden=128,
            mode="batch",
            batch_size=32,
            block_b="auto",
            vmem_budget_bytes=budget,
        )
    )
    assert plan.lowering.block_b == bb


def test_block_b_must_divide_compile_time_batch():
    scfg = StreamConfig(buf_len=32, window=8, stride=8, chunk=8)  # n_windows = 4
    with pytest.raises(ValueError, match="divide"):
        api.compile_plan(
            small_spec(mode="stream", n_slots=2, stream=scfg, fused=True, block_b=3)
        )


def test_stream_lr_conflict_rejected():
    # the StreamConfig copies govern the tick; a diverging spec value would
    # be silently dropped, so the spec refuses to construct
    with pytest.raises(ValueError, match="lr"):
        small_spec(mode="stream", stream=SCFG, lr=1e-2)
    # no stream= given: the spec's lr/batch_size flow into the StreamConfig
    scfg = small_spec(mode="stream", lr=1e-2, batch_size=4).stream_config()
    assert scfg.lr == 1e-2 and scfg.batch_size == 4


def test_auto_block_b_walks_divisors_not_halvings():
    from repro.kernels.mr_step import tiling

    cfg = small_spec(fused=True).to_mr_config()
    # batch=50: halving from 25 hits non-divisor 12; the divisor walk must
    # still find 10 when the budget fits a 10-row tile but not a 25-row one
    budget = tiling.config_vmem_bytes(cfg, 50, block_b=10)
    assert tiling.config_vmem_bytes(cfg, 50, block_b=25) > budget
    assert tiling.auto_block_b(cfg, 50, budget) == 10


def test_auto_block_b_prefers_largest_fitting_divisor():
    from repro.kernels.mr_step import tiling

    cfg = small_spec(fused=True).to_mr_config()
    # batch=48 ladder: None, 24, 16, 12, 8. Budget fits a 16-row tile but
    # not 24 — the walk must stop at 16, never settle for a smaller divisor
    budget = tiling.config_vmem_bytes(cfg, 48, block_b=16)
    assert tiling.config_vmem_bytes(cfg, 48, block_b=24) > budget
    assert tiling.auto_block_b(cfg, 48, budget) == 16


def test_auto_block_b_non_power_of_two_batch_reaches_small_divisors():
    from repro.kernels.mr_step import tiling

    cfg = small_spec(fused=True).to_mr_config()
    # batch=12 has NO divisor in [min_block=8, 12): the old walk enumerated
    # an empty ladder and returned None (= full batch) even with the budget
    # blown; the shared block_b_candidates ladder now carries the degraded
    # sub-min_block tail, so a 6-row tile that fits is found
    assert tiling.block_b_candidates(12) == [None, 6, 4, 3, 2, 1]
    budget = tiling.config_vmem_bytes(cfg, 12, block_b=6)
    assert tiling.config_vmem_bytes(cfg, 12) > budget
    assert tiling.auto_block_b(cfg, 12, budget) == 6


def test_vmem_model_matches_bench_stagemap():
    from benchmarks.bench_stagemap import _vmem_bytes
    from repro.kernels.mr_step import tiling

    kw = dict(int8=False, n_seg=0, block_b=64)
    assert _vmem_bytes(256, 8, 64, 128, 32, **kw) == tiling.vmem_bytes(256, 8, 64, 128, 32, **kw)


# ---------------------------------------------------------------------------
# parity with the legacy entry points
# ---------------------------------------------------------------------------
def test_offline_parity_with_train_mr(lorenz_windows):
    yw, norm = lorenz_windows
    spec = small_spec(mode="offline", steps=20, batch_size=16, lr=3e-3, seed=0)
    plan = api.compile_plan(spec)
    params, metrics = plan.run_offline(yw, norm=norm)
    params_l, hist = train_mr(
        plan.cfg,
        yw,
        None,
        steps=20,
        lr=3e-3,
        seed=0,
        batch_size=16,
        log_every=10,
        norm=norm,
    )
    np.testing.assert_array_equal(np.asarray(params.head_w2), np.asarray(params_l.head_w2))
    assert float(metrics["recon_mse"][10]) == pytest.approx(hist[1]["recon_mse"])


def test_batch_parity_with_recover_many(lorenz_windows):
    yw, _ = lorenz_windows
    spec = small_spec(mode="batch", steps=12, batch_size=16, seed=3, n_active=8)
    plan = api.compile_plan(spec)
    theta = plan.run_batch(yw[None])
    theta_l = engine.recover_many(plan.cfg, yw[None], steps=12, batch_size=16, seed=3, n_active=8)
    np.testing.assert_array_equal(np.asarray(theta), np.asarray(theta_l))
    assert theta.shape == (1, plan.cfg.n_terms, 3)


def test_int8_readout_parity(lorenz_windows):
    yw, _ = lorenz_windows
    spec = small_spec(mode="offline", steps=30, batch_size=16, precision="int8_pwl")
    plan = api.compile_plan(spec)
    assert plan.lowering.quant_serving and plan.lowering.dispatch == "reference"
    params, _ = plan.run_offline(yw)
    theta = plan.readout(params, yw)
    theta_l = np.asarray(stream.readout_theta(params, plan.cfg, yw, quant=True))
    np.testing.assert_array_equal(theta, theta_l)


@pytest.mark.parametrize("encoder", ["gru", "ltc", "node"])
def test_fused_plan_runs_and_matches_unfused(lorenz_windows, encoder):
    yw, _ = lorenz_windows
    fused = api.compile_plan(
        small_spec(mode="offline", steps=15, batch_size=16, encoder=encoder, fused=True)
    )
    unfused = api.compile_plan(small_spec(mode="offline", steps=15, batch_size=16, encoder=encoder))
    assert fused.lowering.fused and fused.lowering.dispatch == "reference"
    pf, mf = fused.run_offline(yw)
    pu, mu = unfused.run_offline(yw)
    # fused reference math == unfused stage sequence (same program structure)
    np.testing.assert_allclose(np.asarray(mf["recon_mse"]), np.asarray(mu["recon_mse"]), atol=1e-5)


def test_stream_plan_matches_legacy_service(lorenz_raw):
    ys = lorenz_raw
    spec = small_spec(mode="stream", n_slots=2, stream=SCFG, seed=0)
    plan = api.compile_plan(spec)
    svc_p = plan.make_service()
    cfg = MRConfig(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder="gru")
    svc_l = RecoveryService(cfg, SCFG, n_slots=2, seed=0)
    for svc in (svc_p, svc_l):
        for sid in range(2):
            svc.submit(sid, ys[sid : sid + SCFG.buf_len])
        svc.fill_slots()
    for t in range(3):
        idx = SCFG.buf_len + t * SCFG.chunk + np.arange(SCFG.chunk)
        chunk = np.repeat(ys[idx][None], 2, axis=0)
        info_p = svc_p.tick_once(chunk)
        info_l = svc_l.tick_once(chunk)
    np.testing.assert_array_equal(np.asarray(svc_p.state.theta), np.asarray(svc_l.state.theta))
    np.testing.assert_array_equal(info_p["delta"], info_l["delta"])


# ---------------------------------------------------------------------------
# sharded SlotState: 2 virtual devices, parity with the trivial mesh
# ---------------------------------------------------------------------------
def test_sharded_slots_parity_two_devices():
    run_devices(
        """
        import numpy as np
        from repro import api
        from repro.core.stream import StreamConfig
        from repro.data.dynamics import generate_trajectory

        _, ys, _ = generate_trajectory("lorenz", n_samples=200)
        scfg = StreamConfig(buf_len=32, window=8, stride=8, chunk=8,
                            steps_per_tick=4, min_steps=10**9, max_steps=10**9)

        def run(mesh_slots):
            spec = api.RecoverySpec(
                state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01,
                encoder="gru", mode="stream", n_slots=2, stream=scfg,
                mesh_slots=mesh_slots,
            )
            plan = api.compile_plan(spec)
            svc = plan.make_service()
            for i in range(2):
                svc.submit(i, ys[i : i + 32])
            svc.fill_slots()
            for t in range(3):
                idx = 32 + t * 8 + np.arange(8)
                svc.tick_once(np.repeat(ys[idx][None], 2, axis=0))
            return svc

        svc1, svc2 = run(1), run(2)
        sh = str(svc2.state.theta.sharding)
        assert "slots" in sh, sh  # actually sharded over the mesh axis
        d = np.abs(np.asarray(svc2.state.theta) - np.asarray(svc1.state.theta)).max()
        assert d < 1e-5, d
        assert np.isfinite(np.asarray(svc2.state.loss)).all()
        print("PASS")
        """,
        n_devices=2,
    )
