"""Plan auditor: HLO-contract rules R1-R5, injected violations, audit modes."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import audit as audit_mod
from repro.analysis import rules as R
from repro.analysis.hlo import entry_parameters, host_transfer_ops, parse_io_aliases
from repro.api.plan import compile_plan
from repro.api.spec import RecoverySpec
from repro.core import engine
from repro.core.stream import StreamConfig
from repro.kernels.mr_step import tiling

TINY_STREAM = StreamConfig(buf_len=16, window=8, stride=8, chunk=8, steps_per_tick=2)


def _tiny_spec(**kw):
    base = dict(
        state_dim=2,
        hidden=8,
        dense_hidden=16,
        mode="stream",
        n_slots=2,
        stream=TINY_STREAM,
    )
    base.update(kw)
    return RecoverySpec(**base)


# ---------------------------------------------------------------------------
# contract parsers (analysis/hlo.py additions)
# ---------------------------------------------------------------------------


def test_entry_params_and_alias_parse():
    @functools.partial(jax.jit, donate_argnums=(0,))
    def f(x, y):
        return x + y

    text = f.lower(jnp.zeros(4), jnp.zeros(4)).compile().as_text()
    params = entry_parameters(text)
    assert [p.index for p in params] == [0, 1]
    assert all(p.dtype == "f32" for p in params)
    assert {p.op_name for p in params} == {"x", "y"}
    aliased = {a.param_number for a in parse_io_aliases(text)}
    assert 0 in aliased and 1 not in aliased


def test_host_transfer_ops_detects_callback():
    def f(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2.0, jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )
        return y + 1.0

    text = jax.jit(f).lower(jnp.zeros(4)).compile().as_text()
    hits = host_transfer_ops(text)
    assert hits, "pure_callback custom-call not detected as a host transfer"


# ---------------------------------------------------------------------------
# the acceptance matrix: every encoder x fused x quant cell audits clean
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "label,spec", audit_mod._matrix_specs(), ids=[c[0] for c in audit_mod._matrix_specs()]
)
def test_matrix_cell_audits_clean(label, spec):
    plan = compile_plan(spec, audit="error")  # raises AuditError on violation
    assert plan.lowering.audit.startswith("pass:"), plan.lowering.audit


def test_audit_mode_validation_and_stamp():
    with pytest.raises(ValueError, match="audit"):
        compile_plan(_tiny_spec(), audit="loud")
    plan_off = compile_plan(_tiny_spec())
    assert plan_off.lowering.audit is None  # off = no stamp
    plan = compile_plan(_tiny_spec(), audit="warn")
    assert plan.lowering.audit is not None and plan.lowering.audit.startswith("pass:")


# ---------------------------------------------------------------------------
# injected violations: each rule must actually fire
# ---------------------------------------------------------------------------


def test_r1_detects_missing_donation():
    """The epoch program compiled WITHOUT donate_argnums must fail R1."""
    cfg = _tiny_spec(mode="offline").to_mr_config()
    from repro.core.merinda import init_mr
    from repro.optim import adamw_init

    params = init_mr(jax.random.key(0), cfg)
    opt = adamw_init(params)
    ys = jnp.zeros((4, 8, cfg.state_dim), jnp.float32)
    key = jax.random.key(0)
    undonated = jax.jit(engine._epoch, static_argnames=("cfg", "steps", "batch_size"))
    lowered = undonated.lower(
        params, opt, ys, None, key, 3e-3, None, cfg=cfg, steps=4, batch_size=None
    )
    findings = R.check_donation("epoch", lowered.compile().as_text(), ("params", "opt_state"))
    assert findings and all(f.rule == "R1" for f in findings)
    # and the donated build of the same program passes
    donated = engine.run_epoch.lower(
        params, opt, ys, None, key, 3e-3, None, cfg=cfg, steps=4, batch_size=None
    )
    assert R.check_donation("epoch", donated.compile().as_text(), ("params", "opt_state")) == []


def test_r1_vacuous_binding_is_a_finding():
    """Metadata drift (no parameter matches the donated names) must not pass."""
    text = jax.jit(lambda x: x + 1).lower(jnp.zeros(4)).compile().as_text()
    findings = R.check_donation("tick", text, ("state",))
    assert len(findings) == 1 and "vacuous" in findings[0].message


def test_r2_detects_model_drift():
    """An inflated VMEM-model prediction must push the ratio out of band."""
    plan = compile_plan(_tiny_spec(encoder="gru", fused=True))
    text, T = audit_mod._fused_step_text(plan)
    band = tiling.residency_tolerance("gru")
    real = tiling.config_vmem_bytes(plan.cfg, audit_mod._fused_batch(plan))
    assert R.check_residency("fused_step", text, real, T, band) == []
    findings = R.check_residency("fused_step", text, real * 1000, T, band)
    assert findings and findings[0].rule == "R2"
    assert R.check_residency("fused_step", text, 0, T, band)  # nonpositive


def test_r3_detects_host_callback_and_allowlist():
    def f(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2.0, jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )
        return y + 1.0

    text = jax.jit(f).lower(jnp.zeros(4)).compile().as_text()
    findings = R.check_host_transfers("tick", text, ())
    assert findings and all(f.rule == "R3" for f in findings)
    allowed = R.check_host_transfers("tick", text, ("callback",))
    assert allowed == []


def test_r4_detects_f32_widening_and_missing_weight():
    def serve(xs, wxq):
        return xs @ wxq

    xs = jnp.zeros((4, 8), jnp.float32)
    w_f32 = jnp.zeros((8, 8), jnp.float32)  # widened: should have been s8
    text = jax.jit(serve).lower(xs, w_f32).compile().as_text()
    findings = R.check_weight_dtypes("serving_int8", text, {"wxq": "s8"})
    assert len(findings) == 1 and findings[0].actual == "f32"
    missing = R.check_weight_dtypes("serving_int8", text, {"whq": "s8"})
    assert len(missing) == 1 and "never entered" in missing[0].message


_SYN_AR = """
HloModule syn

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
}
"""


def test_r5_detects_unpredicted_collective():
    findings = R.check_collectives("tick", _SYN_AR, 2, {})
    assert findings and findings[0].rule == "R5" and "all-reduce" in findings[0].op
    # census + wire both matching -> clean
    ok = R.check_collectives("tick", _SYN_AR, 2, {"all-reduce": 1}, 4096.0)
    assert ok == []
    # census matches but wire prediction is off -> wire finding
    wire = R.check_collectives("tick", _SYN_AR, 2, {"all-reduce": 1}, 1.0)
    assert len(wire) == 1 and "wire" in wire[0].message


# ---------------------------------------------------------------------------
# satellites: budget-source provenance, sync_log
# ---------------------------------------------------------------------------


def test_vmem_budget_source_recorded():
    b, src = tiling.resolve_vmem_budget()
    assert b == tiling.detect_vmem_budget()
    assert src == "default" or src == "memory_stats" or src.startswith("platform:")
    plan = compile_plan(_tiny_spec(encoder="gru", fused=True, block_b="auto"))
    assert plan.lowering.vmem_budget_source == src
    explicit = compile_plan(
        _tiny_spec(encoder="gru", fused=True, block_b="auto", vmem_budget_bytes=1 << 22)
    )
    assert explicit.lowering.vmem_budget_source == "explicit"
    assert explicit.lowering.vmem_budget_bytes == 1 << 22
    # unfused plans resolve no budget and record no source
    assert compile_plan(_tiny_spec()).lowering.vmem_budget_source is None


def test_service_sync_log_per_tick():
    plan = compile_plan(_tiny_spec())
    svc = plan.make_service()
    rng = np.random.default_rng(0)
    svc.submit(0, rng.normal(size=(TINY_STREAM.buf_len, 2)).astype(np.float32))
    svc.fill_slots()
    for _ in range(3):
        svc.tick_once(rng.normal(size=(2, TINY_STREAM.chunk, 2)).astype(np.float32))
    assert len(svc.sync_log) == 3
    assert all(s >= 0 for s in svc.sync_log)
    assert sum(svc.sync_log) <= svc.counters["host_syncs"]
    assert float(np.median(svc.sync_log)) >= 1.0  # every tick reads back scalars
