"""Encoder registry (core/encoders.py): dispatch, parity, failure modes."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoders
from repro.core.merinda import MRConfig, init_mr, mr_forward

PAPER_SET = {"gru_flow", "gru", "ltc", "node"}
KERNEL_SET = {"gru_flow_kernel", "gru_kernel"}


def test_registry_covers_paper_comparison_set():
    names = set(encoders.encoder_names())
    assert PAPER_SET | KERNEL_SET <= names


def test_unknown_encoder_lists_registered_names():
    with pytest.raises(ValueError, match="gru_flow"):
        encoders.get_encoder("transformer")


def test_registry_flags():
    """fusable/kernel/flow/int8 flags drive mr_step + dispatch decisions."""
    for name in PAPER_SET | KERNEL_SET:
        spec = encoders.get_encoder(name)
        assert spec.name == name
        # every built-in family has a fused mr_step lowering (the GRU
        # single-update kernels or the multi-substep LTC/NODE variants)
        assert spec.fusable
        assert spec.kernel == name.endswith("_kernel")
        # the fixed-point serving stage exists exactly where the cell's
        # nonlinearities have a PWL mapping: standard GRU + LTC substep
        assert spec.int8 == (name in {"gru", "gru_kernel", "ltc"})
    assert encoders.get_encoder("gru_flow").flow is True
    assert encoders.get_encoder("gru").flow is False
    assert encoders.get_encoder("ltc").flow is None
    assert set(encoders.fusable_names()) >= PAPER_SET | KERNEL_SET
    assert set(encoders.int8_names()) == {"gru", "gru_kernel", "ltc"}


@pytest.mark.parametrize("name", sorted(PAPER_SET | KERNEL_SET))
def test_init_and_encode_all_registered(name):
    """Every row initializes and encodes with the expected shapes."""
    cfg = MRConfig(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder=name)
    params = init_mr(jax.random.key(0), cfg)
    xs = jax.random.normal(jax.random.key(1), (2, 6, 3), jnp.float32)
    h = encoders.get_encoder(name).encode(params.encoder, cfg, xs)
    assert h.shape == (2, 8)
    assert bool(jnp.isfinite(h).all())


@pytest.mark.parametrize("base", ["gru_flow", "gru"])
def test_kernel_variant_shares_init_and_forward(base):
    """Registry-resolved kernel backend: same params, same forward."""
    mk = lambda enc: MRConfig(  # noqa: E731
        state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder=enc
    )
    p_ref = init_mr(jax.random.key(0), mk(base))
    p_ker = init_mr(jax.random.key(0), mk(base + "_kernel"))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), p_ref, p_ker
    )
    xs = jax.random.normal(jax.random.key(1), (2, 6, 3), jnp.float32)
    th_r, _ = mr_forward(p_ref, mk(base), xs, None)
    th_k, _ = mr_forward(p_ker, mk(base + "_kernel"), xs, None)
    np.testing.assert_allclose(np.asarray(th_r), np.asarray(th_k), atol=1e-5, rtol=1e-5)


def test_engine_rejects_unknown_encoder_eagerly():
    from repro.core import engine

    cfg = MRConfig(state_dim=2, order=2, hidden=8, dense_hidden=16, encoder="nope")
    ys = jnp.zeros((4, 8, 2))
    with pytest.raises(ValueError, match="unknown encoder"):
        engine.train_mr_scan(cfg, ys, steps=1)
    with pytest.raises(ValueError, match="unknown encoder"):
        engine.recover_many(cfg, ys[None], steps=1)


def test_register_encoder_roundtrip():
    """Custom rows plug into init_mr/mr_forward with no other changes."""
    spec = encoders.EncoderSpec(
        name="mean_pool_test",
        init=lambda key, d_in, hidden, dtype=jnp.float32: {"w": jnp.ones((d_in, hidden), dtype)},
        encode=lambda p, cfg, xs: jnp.mean(xs, axis=1) @ p["w"],
        flow=None,
        fusable=False,
        kernel=False,
    )
    encoders.register_encoder(spec)
    try:
        cfg = MRConfig(state_dim=3, order=2, hidden=8, dense_hidden=16, encoder="mean_pool_test")
        params = init_mr(jax.random.key(0), cfg)
        th, _ = mr_forward(params, cfg, jnp.ones((2, 5, 3)), None)
        assert th.shape == (2, cfg.n_terms, 3)
    finally:
        encoders._REGISTRY.pop("mean_pool_test", None)
