"""Model Recovery core: MERINDA training, SINDy, baselines, quantization.

These are the paper's own claims in miniature:
- MERINDA (GRU-flow) recovers dynamics with low reconstruction error,
- comparable to / better than the LTC path while running feed-forward,
- SINDy recovers exact sparse coefficients on clean data,
- the fixed-point (QAT) configuration preserves accuracy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.merinda import (
    MRConfig,
    init_mr,
    mr_forward,
    recover_coefficients,
    reconstruct,
    train_mr,
)
from repro.core.quant import QuantConfig
from repro.core.sindy import fit_sindy, sindy_dynamics
from repro.data.dynamics import SYSTEMS, generate_trajectory, get_system
from repro.data.windows import make_windows


@pytest.fixture(scope="module")
def lorenz_windows():
    ts, ys, us = generate_trajectory("lorenz")
    yw, uw, norm = make_windows(ys, us, window=32, stride=4)
    return jnp.asarray(yw), norm


def _train(cfg, yw, steps=150, lr=3e-3, seed=0):
    params, hist = train_mr(cfg, yw, None, steps=steps, lr=lr, seed=seed,
                            batch_size=64, log_every=steps - 1)
    return params, hist


def test_merinda_gru_flow_learns_lorenz(lorenz_windows):
    yw, _ = lorenz_windows
    cfg = MRConfig(state_dim=3, order=2, hidden=32, dense_hidden=64, dt=0.01, encoder="gru_flow")
    params, hist = _train(cfg, yw)
    assert hist[-1]["recon_mse"] < 0.1 * hist[0]["recon_mse"], hist
    assert hist[-1]["recon_mse"] < 0.08


@pytest.mark.parametrize("encoder", ["gru", "ltc", "node"])
def test_baseline_encoders_train(lorenz_windows, encoder):
    """All comparison encoders run and reduce the loss (paper Table 5 set)."""
    yw, _ = lorenz_windows
    cfg = MRConfig(state_dim=3, order=2, hidden=32, dense_hidden=64, dt=0.01, encoder=encoder)
    params, hist = _train(cfg, yw, steps=100)
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["recon_mse"] < 0.6 * hist[0]["recon_mse"], (encoder, hist)


def test_merinda_kernel_path_equals_reference(lorenz_windows):
    """The registry's kernel-backed encoder must not change the forward."""
    yw, _ = lorenz_windows
    base = dict(state_dim=3, order=2, hidden=32, dense_hidden=64, dt=0.01)
    cfg_r = MRConfig(**base, encoder="gru_flow")
    cfg_k = MRConfig(**base, encoder="gru_flow_kernel")
    params = init_mr(jax.random.key(0), cfg_r)
    th_r, _ = mr_forward(params, cfg_r, yw[:8], None)
    th_k, _ = mr_forward(params, cfg_k, yw[:8], None)
    np.testing.assert_allclose(np.asarray(th_r), np.asarray(th_k), atol=1e-4, rtol=1e-4)


def test_merinda_fused_stage_equals_unfused(lorenz_windows):
    """cfg.fused=True (kernels/mr_step) must not change the forward."""
    yw, _ = lorenz_windows
    base = dict(state_dim=3, order=2, hidden=32, dense_hidden=64, dt=0.01)
    cfg_u = MRConfig(**base, encoder="gru_flow")
    cfg_f = MRConfig(**base, encoder="gru_flow", fused=True)
    params = init_mr(jax.random.key(0), cfg_u)
    th_u, sh_u = mr_forward(params, cfg_u, yw[:8], None)
    th_f, sh_f = mr_forward(params, cfg_f, yw[:8], None)
    np.testing.assert_allclose(np.asarray(th_u), np.asarray(th_f), atol=1e-4, rtol=1e-4)
    assert sh_f.shape == sh_u.shape


def test_merinda_quantized_accuracy_budget(lorenz_windows):
    """Paper's fixed-point claim: QAT config stays close to float accuracy."""
    yw, _ = lorenz_windows
    q = QuantConfig(act_int_bits=4, act_frac_bits=10, weight_int_bits=2, weight_frac_bits=12)
    cfg = MRConfig(state_dim=3, order=2, hidden=32, dense_hidden=64, dt=0.01,
                   encoder="gru_flow", quant=q)
    params, hist = _train(cfg, yw)
    assert hist[-1]["recon_mse"] < 0.12, hist


def test_sindy_exact_recovery_lorenz():
    ts, ys, us = generate_trajectory("lorenz")
    fit = fit_sindy(jnp.asarray(ys), dt=0.01, order=2, threshold=0.1)
    true = get_system("lorenz").true_coef()
    err = np.abs(np.asarray(fit.coef) - true).max()
    assert err < 0.35, f"SINDy coefficient error {err}"
    # sparsity structure: exactly the true terms survive
    assert ((np.abs(true) > 0) == np.asarray(fit.mask)).all()


@pytest.mark.parametrize("system", ["lotka_volterra", "pathogen"])
def test_sindy_recovery_other_systems(system):
    spec = get_system(system)
    ts, ys, us = generate_trajectory(system)
    fit = fit_sindy(jnp.asarray(ys), dt=spec.dt, order=2, threshold=0.02)
    true = spec.true_coef()
    err = np.abs(np.asarray(fit.coef) - true).max()
    assert err < 0.15, f"{system}: coefficient error {err}"


def test_sindy_dynamics_forward():
    """Recovered model must reproduce the trajectory when re-integrated."""
    from repro.core.ode import odeint

    ts, ys, us = generate_trajectory("lotka_volterra")
    fit = fit_sindy(jnp.asarray(ys), dt=0.05, order=2, threshold=0.02)
    f = sindy_dynamics(order=2)
    t = jnp.asarray(ts[:200])
    y_sim = odeint(f, jnp.asarray(ys[0]), t, args=fit.coef, method="rk4")
    rel = float(
        jnp.mean((y_sim - jnp.asarray(ys[:200])) ** 2) / jnp.mean(jnp.asarray(ys[:200]) ** 2)
    )
    assert rel < 0.05, rel


def test_recover_coefficients_prunes_to_k(lorenz_windows):
    yw, _ = lorenz_windows
    cfg = MRConfig(state_dim=3, order=2, hidden=16, dense_hidden=32, dt=0.01)
    params = init_mr(jax.random.key(0), cfg)
    theta = recover_coefficients(params, cfg, yw[:4], None, n_active=7)
    assert int((np.abs(np.asarray(theta)) > 0).sum()) <= 7


def test_reconstruct_shapes(lorenz_windows):
    yw, _ = lorenz_windows
    cfg = MRConfig(state_dim=3, order=2, hidden=16, dense_hidden=32, dt=0.01)
    params = init_mr(jax.random.key(0), cfg)
    y_est, theta = reconstruct(params, cfg, yw[:4], None)
    assert y_est.shape == yw[:4].shape
    assert theta.shape == (4, cfg.n_terms, 3)
    assert bool(jnp.isfinite(y_est).all())


def test_recover_physical_coefficients_lotka():
    """Quickstart path: physical-unit recovery identifies the true terms."""
    import jax.numpy as jnp

    from repro.core.merinda import recover_physical_coefficients

    spec = get_system("lotka_volterra")
    ts, ys, us = generate_trajectory("lotka_volterra")
    yw, uw, norm = make_windows(ys, us, window=32, stride=4)
    cfg = MRConfig(state_dim=2, order=2, hidden=32, dense_hidden=64, dt=spec.dt)
    params, hist = train_mr(
        cfg, jnp.asarray(yw), None, steps=250, lr=3e-3, batch_size=64, log_every=249, norm=norm
    )
    theta = recover_physical_coefficients(params, cfg, jnp.asarray(yw), None, norm, n_active=4)
    true = spec.true_coef()
    # the two dominant linear terms must be recovered with the right sign
    # and within 50% magnitude (h -> dh/dt positive, l -> dl/dt negative)
    i_h = 1, 0
    i_l = 2, 1
    assert theta[i_h] > 0.5 * true[i_h], (theta[i_h], true[i_h])
    assert theta[i_l] < 0.5 * true[i_l], (theta[i_l], true[i_l])
    assert np.abs(theta - true).max() < 0.5


def test_all_benchmark_systems_generate():
    for name, spec in SYSTEMS.items():
        ts, ys, us = generate_trajectory(name, n_samples=100)
        assert ys.shape == (101, spec.state_dim)
        assert np.isfinite(ys).all(), name
        if spec.true_coef is not None:
            c = spec.true_coef()
            from repro.core.library import n_library_terms

            assert c.shape == (
                n_library_terms(spec.state_dim + spec.input_dim, spec.order),
                spec.state_dim,
            )
