"""Shared test helpers.

NOTE: tests run with the REAL device count (1 CPU device). Multi-device
sharding behaviour is tested via subprocesses that set
XLA_FLAGS=--xla_force_host_platform_device_count BEFORE jax imports — never
set that flag here (it would leak into every test).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_devices(snippet: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a fresh interpreter with N host devices.

    The snippet must print PASS on success; returns captured stdout.
    """
    prog = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"\n'
        + textwrap.dedent(snippet)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    p = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    if p.returncode != 0 or "PASS" not in p.stdout:
        raise AssertionError(
            f"subprocess failed (rc={p.returncode})\nstdout:\n{p.stdout[-3000:]}\n"
            f"stderr:\n{p.stderr[-3000:]}"
        )
    return p.stdout


@pytest.fixture(scope="session")
def rng_key():
    import jax

    return jax.random.key(0)
