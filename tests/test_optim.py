"""Optimizer substrate: AdamW reference math, clipping, schedules."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.clip import global_norm
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine


def test_adamw_matches_reference_formula():
    """One step against the hand-computed Adam(W) update."""
    p = {"w": jnp.asarray([1.0, -2.0]), "b": jnp.asarray([0.5])}
    g = {"w": jnp.asarray([0.1, 0.2]), "b": jnp.asarray([-0.3])}
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.999, 1e-8, 0.1
    st = adamw_init(p)
    new_p, new_st = adamw_update(g, st, p, lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=wd)
    for k in p:
        m = (1 - b1) * np.asarray(g[k])
        v = (1 - b2) * np.asarray(g[k]) ** 2
        mhat = m / (1 - b1)
        vhat = v / (1 - b2)
        expect = np.asarray(p[k]) - lr * (mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(p[k]))
        np.testing.assert_allclose(np.asarray(new_p[k]), expect, rtol=1e-6)
    assert int(new_st.step) == 1


def test_adamw_bias_correction_over_steps():
    """With constant grads, Adam's step size stays ~lr (bias correction)."""
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.ones((4,))}
    st = adamw_init(p)
    prev = p
    for i in range(5):
        p, st = adamw_update(g, st, p, lr=1e-2, weight_decay=0.0)
        step_size = float(jnp.abs(p["w"] - prev["w"]).max())
        assert 0.9e-2 < step_size < 1.1e-2, (i, step_size)
        prev = p


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-6
    # under the threshold: unchanged
    same, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0])


def test_schedules_shape():
    s = lambda x: jnp.asarray(x)  # schedules take traced steps
    lr = cosine_schedule(1e-3, 100, final_frac=0.1)
    assert abs(float(lr(s(0))) - 1e-3) < 1e-9
    assert abs(float(lr(s(100))) - 1e-4) < 1e-7
    wlr = linear_warmup_cosine(1e-3, 10, 100)
    assert float(wlr(s(0))) < float(wlr(s(5))) < float(wlr(s(10)))
    assert abs(float(wlr(s(10))) - 1e-3) < 1e-7
    assert float(wlr(s(100))) < float(wlr(s(50)))
