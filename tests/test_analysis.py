"""HLO analyzer: trip counts, dot flops, collective wire model, RS detection."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_module, roofline_terms

from conftest import run_devices


def test_scan_equals_unroll_flops():
    """The whole reason this analyzer exists (see analysis/hlo.py docstring)."""

    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        return jax.lax.scan(body, x, None, length=8)[0]

    def f_unroll(x, w):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    expect = 8 * 2 * 256**3
    got = {}
    for name, f in (("scan", f_scan), ("unroll", f_unroll)):
        txt = jax.jit(f).lower(x, w).compile().as_text()
        got[name] = analyze_module(txt, 1).flops
    assert got["scan"] == got["unroll"] == expect, got


def test_dot_flops_counts_batch_dims_once():
    """Batched dot: flops = 2 * prod(result dims) * prod(contracting dims).

    The batch dims already appear in the result-shape product, so the lhs
    contracting product must EXCLUDE lhs_batch_dims — re-multiplying them
    overcounts by the batch size. Hand-computed einsum cases, one and two
    batch dims."""
    a = jnp.zeros((4, 3, 5), jnp.float32)
    b = jnp.zeros((4, 5, 7), jnp.float32)
    txt = jax.jit(lambda x, y: jnp.einsum("bij,bjk->bik", x, y)).lower(a, b).compile().as_text()
    assert analyze_module(txt, 1).flops == 2 * (4 * 3 * 7) * 5

    a = jnp.zeros((2, 3, 4, 5), jnp.float32)
    b = jnp.zeros((2, 3, 5, 6), jnp.float32)
    txt = (
        jax.jit(lambda x, y: jnp.einsum("abij,abjk->abik", x, y)).lower(a, b).compile().as_text()
    )
    assert analyze_module(txt, 1).flops == 2 * (2 * 3 * 4 * 6) * 5


def test_nested_scan_trip_product():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None

            return jax.lax.scan(inner, c, None, length=3)[0], None

        return jax.lax.scan(outer, x, None, length=5)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    assert analyze_module(txt, 1).flops == 15 * 2 * 64**3


def test_collective_wire_model():
    """psum of [N] over 8 devices: AR wire = 2*B*(n-1)/n per device."""
    run_devices(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.hlo import analyze_module
        mesh = jax.make_mesh((8,), ("m",))
        def f(x, w):  # contract the sharded dim -> one all-reduce
            return x @ w
        x = jax.ShapeDtypeStruct((64, 512), jnp.float32)
        w = jax.ShapeDtypeStruct((512, 64), jnp.float32)
        c = jax.jit(f,
            in_shardings=(NamedSharding(mesh, P(None, "m")), NamedSharding(mesh, P("m", None))),
            out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
        a = analyze_module(c.as_text(), 8)
        B = 64 * 64 * 4
        assert a.collective_ops.get("all-reduce", 0) >= 1
        expect = 2 * B * 7 / 8
        assert abs(a.collective_wire_bytes - expect) / expect < 0.01, \
            (a.collective_wire_bytes, expect)
        print("PASS")
        """,
        n_devices=8,
    )


def test_reduce_scatter_recognition():
    """AR + 1/n slice (CPU lowering) must be costed as reduce-scatter (TPU)."""
    run_devices(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.hlo import analyze_module
        mesh = jax.make_mesh((8,), ("m",))
        def f(x, w):
            y = x @ w  # partial over m
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P("m", None)))  # sharded output
        x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        c = jax.jit(f,
            in_shardings=(NamedSharding(mesh, P(None, "m")), NamedSharding(mesh, P("m", None)))
            ).lower(x, w).compile()
        a = analyze_module(c.as_text(), 8)
        assert a.collective_ops.get("reduce-scatter", 0) >= 1, a.collective_ops
        assert a.collective_ops.get("all-reduce", 0) == 0, a.collective_ops
        B = 512 * 512 * 4
        expect = B * 7 / 8
        assert abs(a.collective_wire_bytes - expect) / expect < 0.01
        print("PASS")
        """,
        n_devices=8,
    )


def test_roofline_terms_bottleneck():
    r = roofline_terms(1e12, 1e9, 1e8, model_flops_global=5e11, n_devices=1)
    assert r.bottleneck == "compute"
    assert abs(r.useful_ratio - 0.5) < 1e-9
    r2 = roofline_terms(1e10, 1e12, 1e8)
    assert r2.bottleneck == "memory"
    r3 = roofline_terms(1e10, 1e9, 1e12)
    assert r3.bottleneck == "collective"


def test_kernel_adjusted_ssd_roofline():
    """The fused-kernel memory term must beat the XLA path and leave the
    cell compute-bound (EXPERIMENTS.md §Perf cell 3, reproducible in code)."""
    import pytest

    from benchmarks.roofline import ART, kernel_adjusted_ssd

    if not (ART / "mamba2-130m__train_4k__single__fsdp2d.json").exists():
        pytest.skip("fsdp2d variant artifact not generated")
    k = kernel_adjusted_ssd()
    assert k["t_memory_kernel"] < 0.25 * k["t_memory_xla"]
    assert abs(k["dominant_after"] - k["t_compute"]) < 1e-9  # compute-bound


def test_kernel_adjusted_flash_roofline():
    """Flash kernel must cut the prefill memory term (EXPERIMENTS §Perf)."""
    import pytest

    from benchmarks.roofline import ART, kernel_adjusted_flash

    if not (ART / "minitron-8b__prefill_32k__single.json").exists():
        pytest.skip("dry-run artifact not generated")
    k = kernel_adjusted_flash()
    assert k["t_memory_kernel"] < 0.6 * k["t_memory_xla"]
    assert k["dominant_after"] < k["dominant_before"]


def test_scanned_mr_step_trip_count_recovery():
    """While-loop trip-count recovery on a REAL scanned mr_step program.

    Doubling the window length T doubles the fused stage's scan trips, so
    the analyzer's flop total must scale ~2x — it only can if the while
    loop's trip count was actually recovered (trip=1 fallback would give a
    ~1x ratio)."""
    from repro.core.merinda import MRConfig, init_mr
    from repro.kernels.mr_step import ops as mr_ops

    cfg = MRConfig(state_dim=2, hidden=8, dense_hidden=16, encoder="gru", fused=True)
    params = init_mr(jax.random.key(0), cfg)
    flops = {}
    for T in (8, 16):
        xs = jax.ShapeDtypeStruct((4, T, cfg.state_dim), jnp.float32)
        step = jax.jit(lambda p, x: mr_ops.mr_step(p, cfg, x))
        flops[T] = analyze_module(step.lower(params, xs).compile().as_text(), 1).flops
    ratio = flops[16] / flops[8]
    assert 1.8 <= ratio <= 2.2, (flops, ratio)


def test_nonconstant_trip_count_degrades_gracefully():
    """A while loop whose bound is a TRACED value has no recoverable trip
    count; the analyzer must not crash and must fall back to trip >= 1."""

    def f(x, n):
        def cond(c):
            return c[1] < n

        def body(c):
            return (jnp.tanh(c[0] @ c[0]), c[1] + 1)

        return jax.lax.while_loop(cond, body, (x, jnp.int32(0)))[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    n = jax.ShapeDtypeStruct((), jnp.int32)
    txt = jax.jit(f).lower(x, n).compile().as_text()
    a = analyze_module(txt, 1)
    # one loop-body matmul counted at least once (conservative trip=1)
    assert a.flops >= 2 * 64**3, a.flops


def test_fusion_byte_model_smaller_than_naive():
    """Chained elementwise ops must not each pay full tensor traffic."""

    def f(x):
        for _ in range(16):
            x = jnp.tanh(x) * 1.01 + 0.1
        return x

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    a = analyze_module(txt, 1)
    naive = 16 * 2 * 1024 * 1024 * 4
    # fused estimate should be well under one read+write per op
    assert a.hbm_bytes < naive / 2, (a.hbm_bytes, naive)
