"""Measured-cost autotuner (analysis/tuner.py) + compile_plan tune= wiring.

Pins the tentpole contracts: same spec + device -> the same cache key and
the same chosen candidate in a fresh process; the on-disk decision cache
invalidates on any spec change and survives corruption (fresh search +
warning, never a crash); a warm ``compile_plan(tune="measured")`` performs
ZERO candidate lowerings; the chosen candidate's measured per-step bytes
land inside the R2 audit band of its own prediction; and the tuner never
ranks its choice worse than the static policy's candidate.
"""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import tuner
from repro.api.plan import compile_plan
from repro.api.spec import RecoverySpec

SRC = str(Path(__file__).resolve().parent.parent / "src")


def small_spec(**overrides) -> RecoverySpec:
    base = dict(
        state_dim=2,
        hidden=8,
        dense_hidden=16,
        encoder="gru_flow",
        fused=True,
        block_b="auto",
        mode="batch",
        batch_size=16,
        steps=4,
    )
    base.update(overrides)
    return RecoverySpec(**base)


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------
def test_candidate_table_leads_with_static_policy():
    spec = small_spec()
    cands = tuner.enumerate_candidates(spec)
    assert cands[0] == tuner.static_candidate(spec)
    assert len(cands) == len(set(cands))  # deduplicated
    # block_b axis comes from the SHARED ladder the static policy walks
    from repro.kernels.mr_step import tiling

    tiles = {c.block_b for c in cands if c.fused}
    assert tiles <= set(tiling.block_b_candidates(16))


def test_candidate_axes_respect_spec_pins():
    # explicit int block_b pins the tile axis
    cands = tuner.enumerate_candidates(small_spec(block_b=8, vmem_budget_bytes=None))
    assert {c.block_b for c in cands if c.fused} == {8}
    # int8 serving pins the kernel path: no unfused twin
    cands = tuner.enumerate_candidates(
        small_spec(encoder="gru", precision="int8_pwl", fused=True)
    )
    assert all(c.fused for c in cands)
    # multi-substep family exposes the unroll axis; gru does not
    ltc = tuner.enumerate_candidates(small_spec(encoder="ltc", ltc_substeps=4))
    assert {c.substep_unroll for c in ltc} == {1, 2, 4}
    gru = tuner.enumerate_candidates(small_spec())
    assert {c.substep_unroll for c in gru} == {1}


# ---------------------------------------------------------------------------
# determinism + cache keying
# ---------------------------------------------------------------------------
def test_cache_key_changes_with_spec_fingerprint():
    k1 = tuner.tune_cache_key(small_spec())
    assert k1 == tuner.tune_cache_key(small_spec())  # stable
    assert k1 != tuner.tune_cache_key(small_spec(hidden=16))  # hidden bump
    assert k1 != tuner.tune_cache_key(small_spec(batch_size=32))
    assert k1 != tuner.tune_cache_key(small_spec(), kind="TPU v5e")  # device kind


def test_tuner_deterministic_across_processes():
    """Same spec + device -> identical cache key AND chosen candidate in a
    fresh interpreter (no shared jit caches, no shared tuning cache)."""
    spec = small_spec()
    local = tuner.tune(spec, mode="measured", cache=False)
    snippet = f"""
import sys
sys.path.insert(0, {SRC!r})
from repro.analysis import tuner
from repro.api.spec import RecoverySpec
spec = RecoverySpec(state_dim=2, hidden=8, dense_hidden=16, encoder="gru_flow",
                    fused=True, block_b="auto", mode="batch", batch_size=16, steps=4)
r = tuner.tune(spec, mode="measured", cache=False)
print("KEY=" + r.cache_key)
print("CHOSE=" + r.chosen.candidate.label())
"""
    out = subprocess.run(
        [sys.executable, "-c", snippet], capture_output=True, text=True, timeout=560
    )
    assert out.returncode == 0, out.stderr
    lines = dict(line.split("=", 1) for line in out.stdout.splitlines() if "=" in line)
    assert lines["KEY"] == local.cache_key
    assert lines["CHOSE"] == local.chosen.candidate.label()


# ---------------------------------------------------------------------------
# the on-disk cache
# ---------------------------------------------------------------------------
def test_warm_tune_pays_zero_lowerings(tmp_path):
    spec = small_spec()
    cold = tuner.tune(spec, mode="measured", cache_root=tmp_path)
    assert not cold.cache_hit and cold.n_lowered > 0
    warm = tuner.tune(spec, mode="measured", cache_root=tmp_path)
    assert warm.cache_hit and warm.n_lowered == 0
    assert warm.chosen.candidate == cold.chosen.candidate
    assert warm.cache_key == cold.cache_key
    # a different spec misses: the key embeds the spec fingerprint
    other = tuner.tune(small_spec(hidden=16), mode="measured", cache_root=tmp_path)
    assert not other.cache_hit


def test_corrupted_cache_warns_and_searches_fresh(tmp_path):
    spec = small_spec()
    cold = tuner.tune(spec, mode="measured", cache_root=tmp_path)
    path = tmp_path / f"{cold.cache_key}.json"
    assert path.exists()

    path.write_text("{ not json at all")
    with pytest.warns(UserWarning, match="corrupted"):
        fresh = tuner.tune(spec, mode="measured", cache_root=tmp_path)
    assert not fresh.cache_hit and fresh.n_lowered > 0
    assert fresh.chosen.candidate == cold.chosen.candidate
    # the fresh search REWROTE the cache: next call hits again
    assert tuner.tune(spec, mode="measured", cache_root=tmp_path).cache_hit

    # valid JSON but an unreadable payload degrades the same way
    path.write_text(json.dumps({"version": tuner.TUNER_VERSION, "cache_key": cold.cache_key,
                                "chosen": {"bogus": 1}, "candidates": []}))
    with pytest.warns(UserWarning, match="unreadable"):
        assert not tuner.tune(spec, mode="measured", cache_root=tmp_path).cache_hit


# ---------------------------------------------------------------------------
# compile_plan wiring
# ---------------------------------------------------------------------------
def test_compile_plan_tune_modes(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    spec = small_spec()

    off = compile_plan(spec)
    assert off.lowering.tuned is None and off.lowering.tune_cache_key is None

    static = compile_plan(spec, tune="static")
    assert static.lowering.tuned == "static"
    # static mode must agree with the untuned policy on the lowering itself
    assert static.lowering.block_b == off.lowering.block_b
    assert static.lowering.fused == off.lowering.fused
    assert static.lowering.substep_unroll == off.lowering.substep_unroll

    cold = compile_plan(spec, tune="measured")
    assert cold.lowering.tuned == "measured"
    assert cold.lowering.tune_cache_key
    assert cold.lowering.predicted_bytes and cold.lowering.measured_bytes

    warm = compile_plan(spec, tune="measured")
    assert warm.lowering.tuned == "measured:cached"
    assert warm.lowering.block_b == cold.lowering.block_b
    assert warm.lowering.substep_unroll == cold.lowering.substep_unroll
    # the warm pass re-lowered NOTHING (acceptance: zero candidate lowerings)
    assert tuner.tune(spec, mode="measured").n_lowered == 0

    with pytest.raises(ValueError, match="tune"):
        compile_plan(spec, tune="always")


def test_tuned_plan_passes_residency_audit(tmp_path, monkeypatch):
    """Acceptance: the chosen candidate's measured per-step bytes sit inside
    the R2 tolerance band of its prediction — audit="error" must not raise
    on a measured-tuned plan (R2 re-measures against measured_bytes with
    tiling.TUNED_RESIDENCY_BAND)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    plan = compile_plan(small_spec(), tune="measured", audit="error")
    assert plan.lowering.audit and plan.lowering.audit.startswith("pass")
    assert "R2" in plan.lowering.audit
    from repro.kernels.mr_step import tiling

    lo, hi = tiling.TUNED_RESIDENCY_BAND
    ratio = plan.lowering.measured_bytes / plan.lowering.predicted_bytes
    # the prediction is the VMEM model; the wide per-family band covers it
    flo, fhi = tiling.residency_tolerance("gru")
    assert flo <= ratio <= fhi
    assert lo < hi  # tuned band is a real interval


def test_tuner_never_ranks_choice_worse_than_static():
    """The gated bench claim, asserted directly on the report: the static
    policy's candidate is in the table, so the chosen roofline time is <=
    the static candidate's (ratio >= 1.0)."""
    from benchmarks.bench_stagemap import run_tuned_ratio

    _, metrics = run_tuned_ratio()
    assert metrics["tuned_over_default_step_ratio"] >= 1.0
    assert metrics["info"]["n_lowered_warm"] == 0
    assert metrics["info"]["cache_hits"] == 1 and metrics["info"]["cache_misses"] == 1


# ---------------------------------------------------------------------------
# substep_unroll is a pure lowering knob
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("encoder", ["ltc", "node", "gru_flow"])
def test_substep_unroll_preserves_numerics(encoder):
    import jax
    import jax.numpy as jnp

    from repro.core.merinda import init_mr, mr_forward

    spec = small_spec(encoder=encoder, fused=False, block_b=None, ltc_substeps=4)
    cfg1 = spec.to_mr_config()
    cfg2 = spec.to_mr_config(substep_unroll=4)
    assert cfg1.substep_unroll == 1 and cfg2.substep_unroll == 4
    params = init_mr(jax.random.key(0), cfg1)
    ys = jax.random.normal(jax.random.key(1), (4, 8, 2), jnp.float32)
    t1, s1 = mr_forward(params, cfg1, ys, None)
    t2, s2 = mr_forward(params, cfg2, ys, None)
    assert jnp.allclose(t1, t2, atol=1e-6)
    assert jnp.allclose(s1, s2, atol=1e-6)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_what_if_cli_replays_candidate_table(tmp_path, capsys):
    rc = tuner.main(
        ["--what-if", "--tune", "static", "--fused", "--batch", "12",
         "--vmem-budget", "40000", "--cache-dir", str(tmp_path)]
    )
    assert rc == 0
    out = capsys.readouterr().out
    assert "candidate" in out and "block_b" in out
    assert "tune[static]" in out


def test_what_if_cli_writes_json_report(tmp_path, capsys):
    dest = tmp_path / "report.json"
    rc = tuner.main(
        ["--what-if", "--tune", "static", "--batch", "16", "--no-cache",
         "--json", str(dest)]
    )
    assert rc == 0
    doc = json.loads(dest.read_text())
    assert doc["mode"] == "static" and doc["candidates"]
    assert doc["chosen"]["candidate"]["block_b"] is None or isinstance(
        doc["chosen"]["candidate"]["block_b"], int
    )
