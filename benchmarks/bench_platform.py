"""Paper Table 5 analogue: four MR workloads, runtime / memory / accuracy.

Table 5 compares LTC / SINDY / PINN+SR / MR(MERINDA) across FPGA, mobile GPU
and GPU. Without those devices, the comparison that survives is the
WORKLOAD-structure one on fixed hardware (this CPU, single-thread XLA):
runtime, peak-RSS delta, and reconstruction error on the AID (glucose-
insulin) case study — preserving the paper's relative ordering claims
(MR fastest-of-the-NN-methods; SINDY cheapest but least robust on noisy
inputs; LTC slowest due to the iterative solver).
"""

from __future__ import annotations

import resource
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.merinda import MRConfig, train_mr
from repro.core.pinn_sr import PinnSRConfig, train_pinn_sr
from repro.core.sindy import fit_sindy
from repro.data.dynamics import generate_trajectory, get_system
from repro.data.windows import make_windows


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run(fast: bool = True):
    steps = 120 if fast else 500
    spec = get_system("aid")
    ts, ys, us = generate_trajectory("aid", noise_std=0.01)
    yw, uw, norm = make_windows(ys, us, window=32, stride=2)
    yw, uw = jnp.asarray(yw), jnp.asarray(uw)
    rows = []

    def _mr(encoder: str):
        # dt: integration time base per CGM sample. 0.1 keeps the RK4 window
        # horizon O(3) — recovered Theta absorbs the scale (time-unit choice),
        # while dt=1.0 (horizon 32) lets early bad Theta blow up the solve.
        cfg = MRConfig(
            state_dim=spec.state_dim,
            input_dim=spec.input_dim,
            order=spec.order,
            hidden=32,
            dense_hidden=64,
            dt=0.1,
            encoder=encoder,
            ltc_substeps=6,
        )
        params, hist = train_mr(
            cfg, yw, uw, steps=steps, lr=3e-3, batch_size=64, log_every=steps - 1
        )
        return float(hist[-1]["recon_mse"])

    for workload, fn in (
        ("ltc", lambda: _mr("ltc")),
        ("mr_merinda", lambda: _mr("gru_flow")),
        ("pinn_sr", lambda: _pinn(spec, ts, ys, steps)),
        ("sindy", lambda: _sindy(spec, ys, us)),
    ):
        rss0 = _rss_mb()
        t0 = time.perf_counter()
        err = fn()
        dt = time.perf_counter() - t0
        rows.append(
            (f"platform/aid/{workload}", dt * 1e6,
             f"runtime_s={dt:.2f};rss_delta_mb={max(_rss_mb() - rss0, 0):.0f};err={err:.4f}")
        )
    return rows


def _pinn(spec, ts, ys, steps):
    mu, sd = ys.mean(0), ys.std(0) + 1e-8
    cfg = PinnSRConfig(state_dim=spec.state_dim, order=spec.order, width=64)
    params, hist = train_pinn_sr(cfg, jnp.asarray(ts), jnp.asarray((ys - mu) / sd), steps=steps)
    return float(hist[-1]["data_mse"])


def _sindy(spec, ys, us):
    fit = fit_sindy(jnp.asarray(ys), dt=spec.dt, order=spec.order,
                    u=jnp.asarray(us), threshold=0.005)
    return float(np.abs(np.asarray(fit.coef) - spec.true_coef()).max())


def main(fast: bool = True):
    for name, us, derived in run(fast=fast):
        emit(name, us, derived)


if __name__ == "__main__":
    import sys

    main(fast="--full" not in sys.argv)
