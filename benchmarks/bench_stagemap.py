"""Paper Table 7 analogue: design-space sweep over kernel resource mappings.

Table 7 sweeps which FPGA resource (DSP vs LUT) implements each of the four
GRU pipeline stages, reporting cycles + LUT/FF/DSP/BRAM. The TPU design space
is different but isomorphic: per configuration we choose

  arithmetic   float (MXU bf16/f32) vs int8 weights + PWL activations (the
               ap_fixed + LUT configuration)
  activation   VPU transcendental vs PWL table segments (n_seg)
  batch tile   block_b — the VMEM-banking knob (how many rows stream/step)

and report the exact VMEM bytes each configuration pins (from its BlockSpecs
— the BRAM-usage analogue), per-step FLOPs, per-step HBM stream bytes, and
the estimated steady-state cycles at the v5e clock.

The modeled kernel is the stage-FUSED per-window step (kernels/mr_step):
GRU scan + RMS-norm + dense head in one ``pallas_call``, so the VMEM model
pins the head weights (w1 [H, Dh], w2 [Dh, K] + biases, + per-channel scale
rows when int8) alongside the gate weights — matching the kernel's actual
BlockSpec residency — and the step cost amortizes the head GEMMs over the T
scan steps of each window.

Claims checked (structurally):

- mixed mappings beat uniform ones — the best configuration keeps MACs on
  the MXU and activations on cheap VPU/PWL paths, the same conclusion as the
  paper's s1D_s2L_s3L_s4D row;
- ``fused_over_unfused_step_ratio``: the fused stage map beats the unfused
  two-dispatch pipeline (gru_scan kernel materializing hs [B, T, H] to HBM,
  then a separate XLA head) on the deterministic interval model — the
  paper's "no inter-stage synchronization" dataflow claim. This ratio is
  gated in CI (benchmarks/baselines.json).
"""

from __future__ import annotations

from benchmarks.common import HBM_BW, LAT_VMEM, LAT_XLA, PEAK_FLOPS, TPU_CLOCK_HZ, emit
from repro.kernels.mr_step import tiling

# fused-stage head depth: norm -> GEMM+relu -> GEMM (amortized per window)
HEAD_DEPTH = 3
SCAN_DEPTH = 3  # fused affine -> gates -> blend (bench_cycles DEPTH)


def _vmem_bytes(
    B, D, H, Dh=128, K=32, *, int8: bool, n_seg: int, block_b: int, fused: bool = True
) -> int:
    """Exact VMEM residency from the fused kernel's BlockSpecs.

    Delegates to ``repro.kernels.mr_step.tiling.vmem_bytes`` — the SAME
    model ``repro.api.compile_plan`` budgets ``block_b="auto"`` against, so
    this sweep and the runtime tiling decision can never disagree.
    ``fused=False`` models the bare gru_scan kernel (no head residency) —
    the configuration the unfused pipeline runs.
    """
    return tiling.vmem_bytes(B, D, H, Dh, K, int8=int8, n_seg=n_seg, block_b=block_b, fused=fused)


def _step_cost(
    B, D, H, T=32, Dh=128, K=32, *, int8: bool, n_seg: int, block_b: int, fused: bool = True
) -> dict:
    """Per-input-step cost of the fused stage map (head amortized over T)."""
    bb = block_b or B
    n_tiles = B // bb
    flops = n_tiles * (2 * bb * D * 3 * H + 2 * bb * H * 3 * H)
    # PWL evaluated as n_seg selects+FMAs per element (unrolled) vs ~10 for exp
    act_cost = (3 * n_seg) if int8 else 10
    flops += n_tiles * bb * 3 * H * act_cost
    hbm = n_tiles * bb * D * (1 if int8 else 4)  # streamed x_t
    if fused:
        # head GEMMs fire once per window: amortize over the T scan steps
        flops += n_tiles * (2 * bb * H * Dh + 2 * bb * Dh * K) // T
        hbm += n_tiles * bb * K * 4 // T  # theta out, once per window
    else:
        hbm += n_tiles * bb * H * 4  # h_t streamed to HBM every step
    tc, tm = flops / PEAK_FLOPS, hbm / HBM_BW
    return {"flops": flops, "hbm": hbm, "t": max(tc, tm),
            "bound": "compute" if tc >= tm else "memory"}


def run(B: int = 256, D: int = 8, H: int = 64, Dh: int = 128, K: int = 32):
    rows = []
    best = None
    for int8 in (False, True):
        for n_seg in ((16, 32, 64) if int8 else (0,)):
            for block_b in (0, 64, 128):
                if block_b and B % block_b:
                    continue
                vm = _vmem_bytes(B, D, H, Dh, K, int8=int8, n_seg=n_seg, block_b=block_b)
                c = _step_cost(B, D, H, Dh=Dh, K=K, int8=int8, n_seg=n_seg, block_b=block_b)
                cyc = c["t"] * TPU_CLOCK_HZ
                name = (
                    f"stagemap/{'int8_pwl' + str(n_seg) if int8 else 'float_vpu'}"
                    f"_bb{block_b or B}"
                )
                rows.append(
                    (name, c["t"] * 1e6,
                     f"cycles={cyc:.0f};vmem_bytes={vm};flops={c['flops']};bound={c['bound']}")
                )
                key = (cyc, vm)
                if best is None or key < best[0]:
                    best = (key, name)
    rows.append(("stagemap/best", 0.0, best[1]))
    return rows


def run_fused_ratio(B: int = 256, T: int = 32, D: int = 8, H: int = 64, Dh: int = 128, K: int = 32):
    """Deterministic fused-vs-unfused interval ratio for one recovery window.

    unfused  two dispatches: the gru_scan kernel streams hs [B, T, H] to HBM
             every step, then a separate XLA head reads h_T + its weights
             back from HBM (inter-stage synchronization = HBM round-trip +
             dispatch-dependency hops).
    fused    kernels/mr_step: one dispatch, h stays in VMEM, head weights
             resident, theta is the only output.

    Pure arithmetic over the hardware model (no wall clock), so the ratio is
    deterministic and gateable. Returns (csv_rows, metrics).
    """
    scan_u = _step_cost(B, D, H, T=T, Dh=Dh, K=K, int8=False, n_seg=0, block_b=0, fused=False)
    # unfused head: h_T + weights re-read from HBM, theta written, per window
    head_flops = 2 * B * H * Dh + 2 * B * Dh * K
    head_hbm = (B * H + H * Dh + Dh * K + Dh + K + B * K) * 4
    t_head = max(head_flops / PEAK_FLOPS, head_hbm / HBM_BW)
    # per-window interval: T scan steps + head + dependency hops. The scan
    # chain costs SCAN_DEPTH VMEM hops/step inside the kernel; the unfused
    # pipeline pays XLA (HBM) hops for the head chain + the stage handoff.
    cyc_unfused = (
        T * (scan_u["t"] * TPU_CLOCK_HZ + SCAN_DEPTH * LAT_VMEM)
        + t_head * TPU_CLOCK_HZ
        + (HEAD_DEPTH + 1) * LAT_XLA  # head chain + inter-kernel handoff
    )
    fused = _step_cost(B, D, H, T=T, Dh=Dh, K=K, int8=False, n_seg=0, block_b=0, fused=True)
    cyc_fused = T * (fused["t"] * TPU_CLOCK_HZ + SCAN_DEPTH * LAT_VMEM) + HEAD_DEPTH * LAT_VMEM
    ratio = cyc_unfused / cyc_fused
    vm_fused = _vmem_bytes(B, D, H, Dh, K, int8=False, n_seg=0, block_b=0, fused=True)
    vm_scan = _vmem_bytes(B, D, H, Dh, K, int8=False, n_seg=0, block_b=0, fused=False)
    rows = [
        ("stagemap/window_cycles_unfused", cyc_unfused / TPU_CLOCK_HZ * 1e6,
         f"cycles={cyc_unfused:.0f};hs_hbm_bytes={T * B * H * 4};vmem_bytes={vm_scan}"),
        ("stagemap/window_cycles_fused", cyc_fused / TPU_CLOCK_HZ * 1e6,
         f"cycles={cyc_fused:.0f};hs_hbm_bytes=0;vmem_bytes={vm_fused}"),
        ("stagemap/fused_over_unfused", 0.0,
         f"x{ratio:.2f} (stage-fused dataflow vs 2-dispatch pipeline)"),
    ]
    metrics = {
        "fused_over_unfused_step_ratio": round(ratio, 3),
        "info": {
            "window_cycles_unfused": round(cyc_unfused, 1),
            "window_cycles_fused": round(cyc_fused, 1),
            "vmem_bytes_fused": vm_fused,
            "vmem_bytes_scan_only": vm_scan,
            "sizes": {"B": B, "T": T, "D": D, "H": H, "Dh": Dh, "K": K},
        },
    }
    return rows, metrics


def run_tuned_ratio():
    """Measured-cost tuner vs the default static lowering, gated.

    Runs the tuner (analysis/tuner.py) cold into a throwaway cache and then
    warm, over one smoke spec, and reports the roofline step-time ratio
    t_static / t_tuned from the tuner's OWN scored table. The static
    policy's candidate always leads that table and the chosen candidate
    minimizes the ranked roofline time, so when every candidate fits the
    budget (these smoke shapes fit trivially) the ratio is >= 1.0 by
    construction — the gate (baselines.json floor 1.0) pins "the tuner never
    picks a lowering its own cost model ranks worse than the default". The
    ungated info block carries the candidate-table size and the cold+warm
    cache hit/miss counts (warm must re-lower nothing).
    """
    import tempfile

    from repro.analysis import tuner
    from repro.api.spec import RecoverySpec

    spec = RecoverySpec(
        state_dim=2,
        hidden=8,
        dense_hidden=16,
        encoder="gru_flow",
        fused=True,
        block_b="auto",
        mode="batch",
        batch_size=16,
        steps=4,
    )
    with tempfile.TemporaryDirectory() as d:
        cold = tuner.tune(spec, mode="measured", cache_root=d)
        warm = tuner.tune(spec, mode="measured", cache_root=d)
    static = tuner.static_candidate(spec)
    t_static = next((s.t_step_us for s in cold.candidates if s.candidate == static), None)
    t_tuned = cold.chosen.t_step_us
    ratio = t_static / t_tuned if t_static and t_tuned else 1.0
    rows = [
        ("stagemap/tuned_step_us", t_tuned or 0.0,
         f"chosen={cold.chosen.candidate.label()};lowered={cold.n_lowered}"),
        ("stagemap/static_step_us", t_static or 0.0, f"static={static.label()}"),
        ("stagemap/tuned_over_default", 0.0,
         f"x{ratio:.2f} (measured-cost choice vs static auto policy)"),
    ]
    metrics = {
        "tuned_over_default_step_ratio": round(ratio, 3),
        "info": {
            "candidate_table_size": len(cold.candidates),
            "n_lowered_cold": cold.n_lowered,
            "n_lowered_warm": warm.n_lowered,
            "cache_hits": int(cold.cache_hit) + int(warm.cache_hit),
            "cache_misses": int(not cold.cache_hit) + int(not warm.cache_hit),
            "chosen": cold.chosen.candidate.label(),
            "static": static.label(),
            "cache_key": cold.cache_key,
        },
    }
    return rows, metrics


def main():
    for name, us, derived in run():
        emit(name, us, derived)
    rows, _ = run_fused_ratio()
    for name, us, derived in rows:
        emit(name, us, derived)
    rows, _ = run_tuned_ratio()
    for name, us, derived in rows:
        emit(name, us, derived)


if __name__ == "__main__":
    main()
