"""Paper Table 7 analogue: design-space sweep over kernel resource mappings.

Table 7 sweeps which FPGA resource (DSP vs LUT) implements each of the four
GRU pipeline stages, reporting cycles + LUT/FF/DSP/BRAM. The TPU design space
is different but isomorphic: per configuration we choose

  arithmetic   float (MXU bf16/f32) vs int8 weights + PWL activations (the
               ap_fixed + LUT configuration)
  activation   VPU transcendental vs PWL table segments (n_seg)
  batch tile   block_b — the VMEM-banking knob (how many rows stream/step)

and report the exact VMEM bytes each configuration pins (from its BlockSpecs
— the BRAM-usage analogue), per-step FLOPs, per-step HBM stream bytes, and
the estimated steady-state cycles at the v5e clock.

Claim checked (structurally): mixed mappings beat uniform ones — the best
configuration keeps MACs on the MXU and activations on cheap VPU/PWL paths,
the same conclusion as the paper's s1D_s2L_s3L_s4D row.
"""

from __future__ import annotations


from benchmarks.common import HBM_BW, PEAK_FLOPS, TPU_CLOCK_HZ, emit


def _vmem_bytes(B, D, H, *, int8: bool, n_seg: int, block_b: int) -> int:
    """Exact VMEM residency from the kernel's BlockSpecs (kernel.py)."""
    wbytes = 1 if int8 else 4
    bb = block_b or B
    vm = (D * 3 * H + H * 3 * H) * wbytes  # resident gate weights
    vm += 3 * H * 4 * (3 if int8 else 1)  # bias (+2 scale rows when int8)
    vm += bb * D * 4 + bb * H * 4 * 2  # x_t block + h scratch + h_t out
    vm += H * 4 + 4  # time_scale + dt
    if int8:
        vm += 2 * 2 * n_seg * 4  # sigmoid/tanh PWL tables (slopes+intercepts)
    return vm


def _step_cost(B, D, H, *, int8: bool, n_seg: int, block_b: int) -> dict:
    bb = block_b or B
    n_tiles = B // bb
    flops = n_tiles * (2 * bb * D * 3 * H + 2 * bb * H * 3 * H)
    # PWL evaluated as n_seg selects+FMAs per element (unrolled) vs ~10 for exp
    act_cost = (3 * n_seg) if int8 else 10
    flops += n_tiles * bb * 3 * H * act_cost
    hbm = n_tiles * (bb * D + bb * H) * (1 if int8 else 4)  # streamed x_t/h_t
    tc, tm = flops / PEAK_FLOPS, hbm / HBM_BW
    return {"flops": flops, "hbm": hbm, "t": max(tc, tm),
            "bound": "compute" if tc >= tm else "memory"}


def run(B: int = 256, D: int = 8, H: int = 64):
    rows = []
    best = None
    for int8 in (False, True):
        for n_seg in ((16, 32, 64) if int8 else (0,)):
            for block_b in (0, 64, 128):
                if block_b and B % block_b:
                    continue
                vm = _vmem_bytes(B, D, H, int8=int8, n_seg=n_seg, block_b=block_b)
                c = _step_cost(B, D, H, int8=int8, n_seg=n_seg, block_b=block_b)
                cyc = c["t"] * TPU_CLOCK_HZ
                name = (
                    f"stagemap/{'int8_pwl' + str(n_seg) if int8 else 'float_vpu'}"
                    f"_bb{block_b or B}"
                )
                rows.append(
                    (name, c["t"] * 1e6,
                     f"cycles={cyc:.0f};vmem_bytes={vm};flops={c['flops']};bound={c['bound']}")
                )
                key = (cyc, vm)
                if best is None or key < best[0]:
                    best = (key, name)
    rows.append(("stagemap/best", 0.0, best[1]))
    return rows


def main():
    for name, us, derived in run():
        emit(name, us, derived)


if __name__ == "__main__":
    main()
