"""Paper Table 8 analogue: LTC -> GRU -> Concurrent GRU -> banked GRU.

The paper's four FPGA configurations map to four TPU execution structures of
the same MR encoder workload (B=64, T=200, D=8, H=64):

  LTC (ODE)        iterative fused-solver, 6 sequential sub-steps/input step
  GRU baseline     UNFUSED gates: three separate per-gate matmul chains, h
                   round-trips HBM every step (the "no concurrency" mapping)
  Concurrent GRU   fused [x,h]@W wide GEMM + lax.scan (XLA overlaps: the
                   DATAFLOW analogue)
  Banked GRU       the Pallas fused kernel: weights VMEM-resident across the
                   scan, one pallas_call per sequence (BRAM-banking analogue)
                   -> HBM bytes/step drop to x_t in + h_t out only

Interval model (the paper's "Interval" = steady-state spacing between
outputs): on FPGA it is gated by the slowest pipeline stage; on TPU the
analogue is

    interval = max(t_compute, t_memory) + depth * t_dep

where ``depth`` counts the chain of data-DEPENDENT ops per input step (each
must drain before the next issues — the reason LTC's 6 sequential solver
sub-steps cannot pipeline) and ``t_dep`` is the per-op dependency latency:
~500 cycles for ops that round-trip HBM/dispatch (XLA ops at these sizes),
~50 cycles when the chain stays inside one kernel's VMEM (the fused Pallas
scan — the paper's "one setup, continuous streaming").

Reported per configuration:
  cycles_est   interval cycles per INPUT STEP at the v5e clock
  wall_us      measured CPU wall time per step (relative speedups only)

Claim checked: monotone interval reduction LTC -> GRU -> fused -> kernel,
order-6x+ LTC->kernel (paper Table 8: 1201 -> 190 cycles = 6.3x; interval
12014 -> 107 = 112x).

run_engine() benchmarks the HOST-side analogue of the same claim: the old
per-step Python train_mr loop (one jit re-entry + minibatch-sampling
dispatches per optimizer step — the "per-step kernel launch" anti-pattern)
against core/engine.py's single scan-jitted program. Claim checked: >= 2x
wall-clock for a 500-step recovery run on CPU.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import (
    HBM_BW,
    LAT_VMEM,
    LAT_XLA,
    PEAK_FLOPS,
    TPU_CLOCK_HZ,
    emit,
    hlo_cost_model,
    wall_time,
)
from repro.core.ltc import init_ltc, ltc_scan
from repro.core.neural_flow import gru_scan_ref, init_gru

# modeled LTC solver substeps per input step — ONE constant feeds both
# halves of the cost model (the dependency-depth entries below AND
# _ltc_kernel_cost's per-substep FLOPs), so they cannot silently diverge
LTC_SUBSTEPS = 6

# data-dependent op-chain depth per input step (see module doc); the LAT_*
# dependency latencies live in benchmarks/common.py (shared with stagemap)
DEPTH = {
    "ltc_ode": LTC_SUBSTEPS * 2,  # each sub-step: matvec -> update
    "gru_unfused": 4,        # r -> (r*h) -> candidate matmul -> blend
    "gru_fused_scan": 3,     # fused affine -> gates -> blend
    "gru_kernel_banked": 3,  # same chain, VMEM-resident
    "ltc_fused_kernel": LTC_SUBSTEPS * 2,  # same substep chain, VMEM-resident
}


def _gru_unfused_scan(p, xs, h0):
    """Per-gate separate affines; the GRU-baseline (unfused) structure."""
    D = xs.shape[-1]
    H = h0.shape[-1]
    wx, wh = p.w[:D], p.w[D:]
    wxr, wxz, wxc = wx[:, :H], wx[:, H : 2 * H], wx[:, 2 * H :]
    whr, whz, whc = wh[:, :H], wh[:, H : 2 * H], wh[:, 2 * H :]
    br, bz, bc = p.b[:H], p.b[H : 2 * H], p.b[2 * H :]

    def step(h, x):
        r = jax.nn.sigmoid(x @ wxr + h @ whr + br)
        z = jax.nn.sigmoid(x @ wxz + h @ whz + bz)
        c = jnp.tanh(x @ wxc + (r * h) @ whc + bc)
        h = (1.0 - z) * c + z * h
        return h, None

    h, _ = jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))
    return h


def _kernel_cost(B, T, D, H) -> dict:
    """Analytic HLO-equivalent cost of the fused Pallas kernel per sequence.

    Weights are VMEM-resident (loaded once, amortized over T>>1 steps); per
    step the kernel reads x_t [B,D] and writes h_t [B,H]; compute is the same
    fused GEMM pair as the XLA path. This is the BRAM-banking analogue: the
    memory term loses the per-step weight re-reads.
    """
    flops = T * (2 * B * D * 3 * H + 2 * B * H * 3 * H)  # gate affines
    hbm = 4 * (D + H) * 3 * H + T * (B * D + B * H) * 4  # weights once + stream
    tc, tm = flops / PEAK_FLOPS, hbm / HBM_BW
    t = max(tc, tm)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "t_compute": tc,
        "t_memory": tm,
        "t_est": t,
        "cycles_est": t * TPU_CLOCK_HZ,
        "bound": "compute" if tc >= tm else "memory",
    }


def _ltc_kernel_cost(
    B, T, D, H, n_substeps: int = LTC_SUBSTEPS, Dh: int = 128, K: int = 32
) -> dict:
    """Analytic cost of the fused multi-substep LTC kernel per sequence.

    kernels/mr_step mr_step_ltc_pallas: cell + head weights VMEM-resident
    (loaded once, amortized over T steps), the input drive computed once per
    step, n_substeps recurrent matvecs + fused-solver updates per step with
    the hidden state in a VMEM scratch; HBM traffic is x_t in and theta out
    only (the head fires once per window).
    """
    flops = T * (
        2 * B * D * H  # input drive, once per step
        + n_substeps * (2 * B * H * H + 6 * B * H)  # recurrent sigmoid + update
    )
    flops += 2 * B * H * Dh + 2 * B * Dh * K  # head, once per window
    hbm = 4 * (D * H + H * H + 3 * H + H * Dh + Dh * K + Dh + K)  # weights once
    hbm += T * B * D * 4 + B * K * 4  # x_t stream in + theta out
    tc, tm = flops / PEAK_FLOPS, hbm / HBM_BW
    t = max(tc, tm)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "t_compute": tc,
        "t_memory": tm,
        "t_est": t,
        "cycles_est": t * TPU_CLOCK_HZ,
        "bound": "compute" if tc >= tm else "memory",
    }


def run(B: int = 64, T: int = 200, D: int = 8, H: int = 64):
    key = jax.random.key(0)
    ltc = init_ltc(key, D, H)
    gru = init_gru(key, D, H)
    xs = jax.random.normal(key, (B, T, D))
    h0 = jnp.zeros((B, H))
    a_xs = jax.ShapeDtypeStruct(xs.shape, xs.dtype)
    a_h0 = jax.ShapeDtypeStruct(h0.shape, h0.dtype)

    configs = {
        "ltc_ode": jax.jit(lambda xs, h0: ltc_scan(ltc, xs, h0, n_substeps=LTC_SUBSTEPS)[0]),
        "gru_unfused": jax.jit(lambda xs, h0: _gru_unfused_scan(gru, xs, h0)),
        "gru_fused_scan": jax.jit(lambda xs, h0: gru_scan_ref(gru, xs, h0, flow=False)[0]),
    }
    rows = []
    cycles = {}
    for name, fn in configs.items():
        cost = hlo_cost_model(fn, a_xs, a_h0)
        wall = wall_time(fn, xs, h0)
        per_step = cost["cycles_est"] / T + DEPTH[name] * LAT_XLA
        cycles[name] = per_step
        rows.append(
            (f"cycles/{name}", wall * 1e6 / T,
             f"interval_cycles={per_step:.0f};pipelined={cost['cycles_est']/T:.0f}"
             f";dep={DEPTH[name]*LAT_XLA};bound={cost['bound']}")
        )
    kc = _kernel_cost(B, T, D, H)
    per_step = kc["cycles_est"] / T + DEPTH["gru_kernel_banked"] * LAT_VMEM
    cycles["gru_kernel_banked"] = per_step
    rows.append(
        ("cycles/gru_kernel_banked", kc["t_est"] * 1e6 / T,
         f"interval_cycles={per_step:.0f};pipelined={kc['cycles_est']/T:.0f}"
         f";dep={DEPTH['gru_kernel_banked']*LAT_VMEM};bound={kc['bound']};analytic")
    )
    order = ["ltc_ode", "gru_unfused", "gru_fused_scan", "gru_kernel_banked"]
    assert all(cycles[a] > cycles[b] for a, b in zip(order, order[1:])), cycles
    speedup = cycles["ltc_ode"] / cycles["gru_kernel_banked"]
    rows.append(("cycles/ltc_over_kernel_speedup", 0.0,
                 f"x{speedup:.1f} (paper cycles: 6.3x, interval: 112x)"))
    # fused multi-substep LTC (kernels/mr_step ltc variant) vs the unfused
    # host-scanned ODE stepping it replaces: same substep chain, but every
    # dependency hop is a VMEM hop inside one kernel instead of an XLA
    # dispatch — the paper's actual comparison point (LTC baseline), fused
    lkc = _ltc_kernel_cost(B, T, D, H)
    per_step_lf = lkc["cycles_est"] / T + DEPTH["ltc_fused_kernel"] * LAT_VMEM
    cycles["ltc_fused_kernel"] = per_step_lf
    rows.append(
        ("cycles/ltc_fused_kernel", lkc["t_est"] * 1e6 / T,
         f"interval_cycles={per_step_lf:.0f};pipelined={lkc['cycles_est']/T:.0f}"
         f";dep={DEPTH['ltc_fused_kernel']*LAT_VMEM};bound={lkc['bound']};analytic")
    )
    ltc_fused_speedup = cycles["ltc_ode"] / per_step_lf
    rows.append(("cycles/ltc_fused_over_ode_speedup", 0.0,
                 f"x{ltc_fused_speedup:.1f} (fused LTC substeps vs unfused ODE stepping)"))
    assert ltc_fused_speedup >= 3.0, (
        f"fused LTC speedup {ltc_fused_speedup:.2f}x < 3x — the multi-substep "
        "fusion stopped paying for itself in the interval model"
    )
    # cost-model metrics are deterministic (HLO analysis + analytic kernel
    # model, no wall clock) — the gateable part of this suite (see run.py)
    metrics = {
        "ltc_over_kernel_interval_ratio": round(speedup, 3),
        "ltc_fused_over_ode_speedup": round(ltc_fused_speedup, 3),
        "interval_cycles": {k: round(v, 1) for k, v in cycles.items()},
    }
    return rows, metrics


def run_engine(steps: int = 500, n_windows: int = 64, T: int = 4, repeats: int = 3):
    """Per-step Python train_mr loop vs the scan-jitted engine (one program).

    Sizes put the run in the dispatch-bound regime the paper targets (small
    MR models, many optimizer steps) — exactly where per-step launches hurt.
    """
    from repro.core import engine
    from repro.core.merinda import MRConfig, init_mr, mr_train_step
    from repro.optim import adamw_init

    cfg = MRConfig(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01)
    bs = 8
    key = jax.random.key(0)
    ys = jax.random.normal(key, (n_windows, T, 3)) * 0.5

    def python_loop(n_steps):
        # the pre-engine train_mr structure: per-step jit re-entry + separate
        # key-split / randint / gather dispatches from Python
        k = jax.random.key(0)
        params = init_mr(k, cfg)
        opt = adamw_init(params)
        for step in range(n_steps):
            k, sub = jax.random.split(k)
            idx = jax.random.randint(sub, (bs,), 0, n_windows)
            lr_t = 3e-3 * min(1.0, (step + 1) / 50)
            params, opt, _ = mr_train_step(params, opt, cfg, ys[idx], None, lr_t, None)
        jax.block_until_ready(params)

    def scan_engine():
        k = jax.random.key(0)
        params = init_mr(k, cfg)
        opt = adamw_init(params)
        params, _, _ = engine.run_epoch(
            params, opt, ys, None, k, 3e-3, None, cfg=cfg, steps=steps, batch_size=bs
        )
        jax.block_until_ready(params)

    def best_of(fn, *args):
        fn(*args)  # compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(*args)
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_loop = best_of(python_loop, steps)
    t_scan = best_of(scan_engine)
    speedup = t_loop / t_scan
    rows = [
        ("engine/train_mr_python_loop", t_loop * 1e6 / steps, f"steps={steps};per-step jit"),
        ("engine/train_mr_scan_jitted", t_scan * 1e6 / steps, f"steps={steps};one program"),
        ("engine/loop_over_scan_speedup", 0.0, f"x{speedup:.2f} (claim: >=2x)"),
    ]
    assert speedup >= 2.0, (
        f"scan engine speedup {speedup:.2f}x < 2x — per-step dispatch overhead "
        "is back on the hot path"
    )
    metrics = {
        "loop_over_scan_speedup": round(speedup, 3),
        "info": {
            "python_loop_us_per_step": round(t_loop * 1e6 / steps, 1),
            "scan_jitted_us_per_step": round(t_scan * 1e6 / steps, 1),
        },
    }
    return rows, metrics


def main():
    rows, _ = run()
    for name, us, derived in rows:
        emit(name, us, derived)
    rows, _ = run_engine()
    for name, us, derived in rows:
        emit(name, us, derived)


if __name__ == "__main__":
    main()
