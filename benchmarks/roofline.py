"""§Roofline table generator: reads artifacts/dryrun/*.json -> markdown.

For every (arch x shape) cell on the single-pod mesh (and any recorded
variants) it prints: the three roofline terms, bottleneck, model-FLOPs
ratio, memory/device — the §Roofline deliverable. Also emits the multi-pod
compile confirmation table for §Dry-run.
"""

from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh: str = "single", variant: str | None = None) -> list[dict]:
    recs = []
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") != mesh:
            continue
        if (variant or "baseline") != r.get("variant", "baseline"):
            continue
        if r.get("rules", "default") != "default" and variant is None:
            continue
        recs.append(r)
    return recs


def roofline_table(mesh: str = "single", variant: str | None = None) -> str:
    recs = load(mesh, variant)
    lines = [
        "| arch | shape | GiB/dev | tc (ms) | tm (ms) | tl (ms) | bottleneck | 6ND/HLO |",
        "|---|---|---:|---:|---:|---:|---|---:|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped: {r['reason'][:40]} | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | {r['error'][:40]} | |")
            continue
        rf = r["roofline"]
        m = r["memory"]
        gib = (max(m["argument_bytes"], m["output_bytes"]) + m["temp_bytes"]) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {gib:.1f} | {rf['t_compute']*1e3:.1f} "
            f"| {rf['t_memory']*1e3:.1f} | {rf['t_collective']*1e3:.1f} "
            f"| {rf['bottleneck']} | {rf['useful_ratio']:.2f} |"
        )
    return "\n".join(lines)


def dryrun_table() -> str:
    lines = [
        "| arch | shape | single-pod (256) | multi-pod (512) |",
        "|---|---|---|---|",
    ]
    by_key: dict[tuple, dict] = {}
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("variant", "baseline") != "baseline" or r.get("rules", "default") != "default":
            continue
        by_key.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = r
    for (arch, shape), d in sorted(by_key.items()):
        cells = []
        for mesh in ("single", "multi"):
            r = d.get(mesh)
            if r is None:
                cells.append("missing")
            elif r["status"] == "ok":
                m = r["memory"]
                gib = (max(m["argument_bytes"], m["output_bytes"]) + m["temp_bytes"]) / 2**30
                cells.append(f"ok ({gib:.1f} GiB/dev)")
            elif r["status"] == "skipped":
                cells.append("skip (full attention @500k)")
            else:
                cells.append("ERROR")
        lines.append(f"| {arch} | {shape} | {cells[0]} | {cells[1]} |")
    return "\n".join(lines)


def summarize_perf(cells: list[tuple[str, str]], variants: list[str]) -> str:
    """Before/after table for the hillclimbed cells (§Perf)."""
    lines = [
        "| arch | shape | variant | tc (ms) | tm (ms) | tl (ms) | dominant | Δ dominant |",
        "|---|---|---|---:|---:|---:|---|---:|",
    ]
    for arch, shape in cells:
        base_dom = None
        for v in variants:
            tag = f"{arch}__{shape}__single" + ("" if v == "baseline" else f"__{v}")
            p = ART / f"{tag}.json"
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | {v} | ERROR | | | | |")
                continue
            rf = r["roofline"]
            dom = max(rf["t_compute"], rf["t_memory"], rf["t_collective"])
            delta = "" if base_dom is None else f"{(1 - dom / base_dom) * 100:+.0f}%"
            if v == "baseline":
                base_dom = dom
            lines.append(
                f"| {arch} | {shape} | {v} | {rf['t_compute']*1e3:.0f} | {rf['t_memory']*1e3:.0f} "
                f"| {rf['t_collective']*1e3:.0f} | {rf['bottleneck']} | {delta} |"
            )
    return "\n".join(lines)


def kernel_adjusted_ssd(arch: str = "mamba2-130m", shape: str = "train_4k",
                        rules: str = "fsdp2d") -> dict:
    """Fused-SSD-kernel roofline for an ssm cell (EXPERIMENTS.md §Perf it2).

    The XLA path materializes the chunked scan's intra-chunk tensors (decay
    masks, L matrices, per-chunk states); the Pallas kernel keeps them in
    VMEM, so its HBM traffic is exactly its BlockSpec streams. We derive the
    memory term from the kernel geometry (per-device shapes from the cell's
    sharding) and keep tc/tl from the measured XLA record — the kernel
    changes data movement, not FLOPs or collectives.
    """
    import json

    from repro.analysis.hlo import HBM_BW
    from repro.configs.base import get_config, get_shape

    tag = f"{arch}__{shape}__single" + (f"__{rules}" if rules != "default" else "")
    rec = json.loads((ART / f"{tag}.json").read_text())
    cfg = get_config(arch)
    sh = get_shape(shape)
    n_dev = rec["n_devices"]

    H, P = cfg.ssm_heads, cfg.ssm.head_dim
    G, N = cfg.ssm.num_groups, cfg.ssm.state_dim
    S = sh.seq_len
    tokens_dev = sh.global_batch * S // n_dev  # batch fully sharded (fsdp2d)
    nchunks = S // cfg.ssm.chunk

    # per-layer kernel streams (bytes/device): see kernels/ssd_scan BlockSpecs
    bf2, f4 = 2, 4
    per_layer = (
        2 * tokens_dev * H * P * bf2      # x in, z gate in
        + 2 * tokens_dev * G * N * bf2    # B, C
        + tokens_dev * H * f4             # dt
        + tokens_dev * H * P * bf2        # y out
        + (tokens_dev // S) * nchunks * H * N * P * f4  # inter-chunk states
    )
    layer_weights = 0
    for _name, spec_shape in (("inproj", 2 * cfg.d_model * H * P),
                             ("bc", 2 * cfg.d_model * G * N),
                             ("dt", cfg.d_model * H),
                             ("out", H * P * cfg.d_model)):
        layer_weights += spec_shape * bf2
    fwd = per_layer + layer_weights
    total = cfg.num_layers * 3 * fwd  # fwd + recompute + bwd streams
    # embedding + CE (chunked): logits touched ~2x in f32-equivalent bf16
    total += 3 * tokens_dev * cfg.vocab_padded * bf2
    tm = total / HBM_BW
    rf = rec["roofline"]
    return {
        "cell": tag,
        "t_compute": rf["t_compute"],
        "t_memory_xla": rf["t_memory"],
        "t_memory_kernel": tm,
        "t_collective": rf["t_collective"],
        "dominant_before": max(rf["t_compute"], rf["t_memory"], rf["t_collective"]),
        "dominant_after": max(rf["t_compute"], tm, rf["t_collective"]),
    }


def kernel_adjusted_flash(arch: str = "minitron-8b", shape: str = "prefill_32k") -> dict:
    """Flash-attention-kernel roofline for a prefill cell.

    The XLA blockwise path materializes per-chunk score/softmax tensors
    (f32 [B, H, Sq, chunk] x chunks x layers); the Pallas kernel
    (kernels/flash_attention) keeps them in VMEM scratch, so attention HBM
    traffic collapses to q/k/v in + o out per layer. Everything outside
    attention (QKV/out projections, MLP, embed, norms) is kept from the
    measured record by subtracting the score-path bytes computed from the
    cell geometry.
    """
    import json

    from repro.analysis.hlo import HBM_BW
    from repro.configs.base import get_config, get_shape

    tag = f"{arch}__{shape}__single"
    rec = json.loads((ART / f"{tag}.json").read_text())
    cfg = get_config(arch)
    sh = get_shape(shape)
    n_dev = rec["n_devices"]

    a = cfg.attn
    B, S = sh.global_batch, sh.seq_len
    # default rules: batch over data (16), heads over model (16)
    B_d = max(B // 16, 1)
    H_d = max(a.num_heads // 16, 1)
    chunk = cfg.attn_chunk
    nchunks = S // chunk
    f2 = 2  # f32 counted at bf16 per the normalization correction
    # XLA path materializes per (layer, chunk): scores + exp + running acc
    # reads/writes ~4 tensor passes of [B_d, H_d, S, chunk]
    score_bytes = cfg.num_layers * nchunks * 4 * (B_d * H_d * S * chunk) * f2
    # kernel path: q,k,v read + o written once per layer
    qkv_bytes = cfg.num_layers * 4 * (B_d * S * H_d * a.head_dim) * 2
    rf = rec["roofline"]
    tm_kernel = max(rf["t_memory"] - score_bytes / HBM_BW, 0.0) + qkv_bytes / HBM_BW
    return {
        "cell": tag,
        "t_compute": rf["t_compute"],
        "t_memory_xla": rf["t_memory"],
        "t_memory_kernel": tm_kernel,
        "t_collective": rf["t_collective"],
        "dominant_before": max(rf["t_compute"], rf["t_memory"], rf["t_collective"]),
        "dominant_after": max(rf["t_compute"], tm_kernel, rf["t_collective"]),
    }


def main():
    print("## §Dry-run (80 cells)\n")
    print(dryrun_table())
    print("\n## §Roofline (single-pod baseline)\n")
    print(roofline_table())
    try:
        k = kernel_adjusted_ssd()
        print(
            f"\n## Fused-SSD kernel adjustment ({k['cell']})\n\n"
            f"tm(XLA path) = {k['t_memory_xla']*1e3:.1f} ms -> "
            f"tm(kernel streams) = {k['t_memory_kernel']*1e3:.1f} ms; "
            f"dominant term {k['dominant_before']*1e3:.1f} -> "
            f"{k['dominant_after']*1e3:.1f} ms"
        )
    except FileNotFoundError:
        pass
    try:
        k = kernel_adjusted_flash()
        print(
            f"\n## Flash-attention kernel adjustment ({k['cell']})\n\n"
            f"tm(XLA path) = {k['t_memory_xla']*1e3:.1f} ms -> "
            f"tm(kernel) = {k['t_memory_kernel']*1e3:.1f} ms; "
            f"dominant term {k['dominant_before']*1e3:.1f} -> "
            f"{k['dominant_after']*1e3:.1f} ms"
        )
    except FileNotFoundError:
        pass


if __name__ == "__main__":
    main()
