"""Shared benchmark utilities: timing, HLO-derived cycle model, CSV output."""

from __future__ import annotations

import time

import jax
import numpy as np

# v5e-class hardware model (same constants as analysis/hlo.py)
from repro.analysis.hlo import HBM_BW, PEAK_FLOPS, analyze_module

TPU_CLOCK_HZ = 940e6  # v5e nominal clock: converts seconds -> "cycles"

# Interval-model dependency latencies (single source of truth for the gated
# cost models in bench_cycles.py and bench_stagemap.py): each data-DEPENDENT
# op in a per-step chain must drain before the next issues.
LAT_XLA = 500  # cycles: hop between separate XLA ops (HBM round-trip/dispatch)
LAT_VMEM = 50  # cycles: hop inside one fused kernel (VMEM-resident chain)


def wall_time(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall seconds per call of a jitted fn (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def hlo_cost_model(fn, *abstract_args, f32_as_bf16: bool = False) -> dict:
    """Lower+compile fn, run the trip-count-aware analyzer, add time terms.

    Returns flops, hbm_bytes, t_compute, t_memory, est seconds (max of terms)
    and est cycles at the v5e clock — the structural stand-in for the paper's
    cycle counts (no TPU present; see EXPERIMENTS.md §Cycles).
    """
    compiled = jax.jit(fn).lower(*abstract_args).compile()
    costs = analyze_module(compiled.as_text(), 1, f32_as_bf16=f32_as_bf16)
    tc = costs.flops / PEAK_FLOPS
    tm = costs.hbm_bytes / HBM_BW
    t = max(tc, tm)
    return {
        "flops": costs.flops,
        "hbm_bytes": costs.hbm_bytes,
        "t_compute": tc,
        "t_memory": tm,
        "t_est": t,
        "cycles_est": t * TPU_CLOCK_HZ,
        "bound": "compute" if tc >= tm else "memory",
    }


def emit(name: str, us_per_call: float, derived: str = ""):
    """One CSV row in the harness-required format."""
    print(f"{name},{us_per_call:.3f},{derived}")
