"""Paper Table 6 analogue: MERINDA vs EMILY(NODE-MR) vs PINN+SR vs SINDy.

Reconstruction MSE (normalized windows) on the four benchmark systems, with
seed std-dev — the paper's accuracy-parity claim. SINDy is additionally
scored on exact coefficient recovery.

Budget knob: ``fast=True`` (default under benchmarks.run) trains fewer steps
with fewer seeds; the EXPERIMENTS.md table uses ``fast=False``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.merinda import MRConfig, train_mr
from repro.core.pinn_sr import PinnSRConfig, train_pinn_sr
from repro.core.sindy import fit_sindy
from repro.data.dynamics import generate_trajectory, get_system
from repro.data.windows import make_windows

SYSTEMS = ["lotka_volterra", "lorenz", "f8", "pathogen"]


def _mr_mse(system: str, encoder: str, steps: int, seed: int) -> float:
    spec = get_system(system)
    ts, ys, us = generate_trajectory(system)
    yw, uw, norm = make_windows(ys, us, window=32, stride=4)
    cfg = MRConfig(
        state_dim=spec.state_dim,
        order=spec.order,
        hidden=32,
        dense_hidden=64,
        dt=spec.dt,
        encoder=encoder,
    )
    params, hist = train_mr(
        cfg,
        jnp.asarray(yw),
        None,
        steps=steps,
        lr=3e-3,
        seed=seed,
        batch_size=64,
        log_every=max(steps - 1, 1),
    )
    return float(hist[-1]["recon_mse"])


def _pinn_sr_mse(system: str, steps: int, seed: int) -> float:
    spec = get_system(system)
    ts, ys, us = generate_trajectory(system)
    mu, sd = ys.mean(0), ys.std(0) + 1e-8
    ysn = (ys - mu) / sd
    cfg = PinnSRConfig(state_dim=spec.state_dim, order=spec.order, width=64)
    params, hist = train_pinn_sr(
        cfg, jnp.asarray(ts), jnp.asarray(ysn), steps=max(steps * 4, 800), seed=seed
    )
    return float(hist[-1]["data_mse"])


def run(fast: bool = True):
    steps = 150 if fast else 600
    seeds = [0, 1] if fast else [0, 1, 2, 3]
    rows = []
    for system in SYSTEMS:
        for method, fn in (
            ("merinda", lambda s: _mr_mse(system, "gru_flow", steps, s)),
            ("emily_node", lambda s: _mr_mse(system, "node", steps, s)),
            ("pinn_sr", lambda s: _pinn_sr_mse(system, steps, s)),
        ):
            vals = [fn(s) for s in seeds]
            rows.append(
                (f"accuracy/{system}/{method}", 0.0,
                 f"recon_mse={np.mean(vals):.4f};std={np.std(vals):.4f}")
            )
        # SINDy: coefficient recovery error (threshold tuned per system scale)
        spec = get_system(system)
        ts, ys, us = generate_trajectory(system)
        thr = 0.1 if system in ("lorenz", "f8") else 0.02
        fit = fit_sindy(jnp.asarray(ys), dt=spec.dt, order=spec.order, threshold=thr)
        err = float(np.abs(np.asarray(fit.coef) - spec.true_coef()).max())
        rows.append((f"accuracy/{system}/sindy", 0.0, f"coef_maxerr={err:.4f}"))
    return rows


def main(fast: bool = True):
    for name, us, derived in run(fast=fast):
        emit(name, us, derived)


if __name__ == "__main__":
    import sys

    main(fast="--full" not in sys.argv)
