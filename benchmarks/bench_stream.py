"""Streaming-service throughput: batched slots vs serial per-stream recovery.

The service claim (core/stream.py): running K recovery steps for S slots as
ONE vmapped, jit-cached tick program beats ticking S single-slot services
sequentially — at MR sizes every XLA op is tiny, so per-op dispatch overhead
dominates and batching S streams into each op amortizes it (the host-side
analogue of the paper's spatial parallelism across concurrent recoveries).
Both sides are the REAL RecoveryService end to end, including the per-tick
host readback of the convergence scalars: the batched service pays it once
per tick, a per-stream deployment pays it per stream per tick.

Measured:
  stream/ticks_per_sec_batched   S-slot service ticks per second
  stream/ticks_per_sec_serial    equivalent tick rate of S sequential
                                 single-slot services (same per-stream work)
  stream/batched_over_serial     speedup (claim: >= 2x at 4+ slots)
  stream/latency_*               per-stream recovery latency for a fixed
                                 step budget, service vs the sequential
                                 (one-system-at-a-time) recover_many baseline
  stream/banked_tick_over_composite  wall ratio of the banked one-kernel
                                 serve tick (TickSpec tick_kernel="banked":
                                 kernels/mr_step/tick.py ingest + substeps +
                                 EMA readout as ONE program, one packed host
                                 readback) over the composite stage-sequence
                                 tick, both through plan-compiled services
                                 end to end (run_banked_tick). GATED: this
                                 replaced the info-only
                                 fused_tick_over_unfused wall row — the
                                 banked tick is a structural change (fewer
                                 programs, fewer host syncs), so the ratio
                                 is real wall clock even off-TPU.

Sizes are deliberately small (the paper's regime: tiny models, many
iterative updates) and fixed-seed; timing is best-of-``repeats`` (the
run_engine methodology — a background-load spike in one repeat otherwise
dominates on small CI boxes). Wall numbers land in the JSON "info" section;
only dimensionless ratios are gated (benchmarks/gate.py).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import engine
from repro.core.merinda import MRConfig
from repro.core.stream import RecoveryService, StreamConfig
from repro.data.windows import make_windows

REPO_ROOT = Path(__file__).resolve().parent.parent


def run(slots: int = 8, n_ticks: int = 8, repeats: int = 3, smoke: bool = False):
    """Returns (csv_rows, metrics dict). Fixed seeds; see module docstring."""
    if smoke:
        n_ticks, repeats = 6, 2
    from repro.data.dynamics import generate_trajectory

    cfg = MRConfig(state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder="gru")
    scfg = StreamConfig(
        buf_len=32,
        window=8,
        stride=8,
        chunk=8,
        steps_per_tick=8,
        min_steps=10**9,  # no eviction: fixed recovery work per tick
        max_steps=10**9,
    )
    n_samples = scfg.buf_len + scfg.chunk * (n_ticks + 2)
    _, ys, _ = generate_trajectory("lorenz", n_samples=n_samples)
    L, C = scfg.buf_len, scfg.chunk
    chunks = [
        np.repeat(ys[L + t * C : L + (t + 1) * C][None], slots, axis=0) for t in range(n_ticks)
    ]

    def run_batched(service_cfg: MRConfig = cfg) -> float:
        svc = RecoveryService(service_cfg, scfg, slots)
        for i in range(slots):
            svc.submit(i, ys[:L])
        svc.fill_slots()
        svc.tick_once(chunks[0])  # compile
        t0 = time.perf_counter()
        for t in range(1, n_ticks):
            svc.tick_once(chunks[t])
        return time.perf_counter() - t0

    def run_serial() -> float:
        svcs = []
        for s in range(slots):
            svc = RecoveryService(cfg, scfg, 1, seed=s)
            svc.submit(s, ys[:L])
            svc.fill_slots()
            svcs.append(svc)
        svcs[0].tick_once(chunks[0][:1])  # compile (shared jit cache)
        t0 = time.perf_counter()
        for t in range(1, n_ticks):
            for s in range(slots):
                svcs[s].tick_once(chunks[t][:1])
        return time.perf_counter() - t0

    t_batched = min(run_batched() for _ in range(repeats))
    t_serial = min(run_serial() for _ in range(repeats))
    timed = n_ticks - 1
    tps_batched = timed / t_batched
    tps_serial = timed / t_serial
    speedup = t_serial / t_batched

    # --- per-stream recovery latency vs sequential recover_many -----------
    # fixed budget of `lat_steps` optimizer steps per stream. Service latency
    # = ticks needed at K steps/tick (all S streams finish together); the
    # baseline recovers one system at a time through the scan-jitted engine.
    lat_steps = 64 if smoke else 128
    lat_ticks = lat_steps // scfg.steps_per_tick
    t_service = lat_ticks / tps_batched
    yw, _, _ = make_windows(ys[:L], None, window=scfg.window, stride=scfg.stride)
    yw_b = np.asarray(yw)[None]
    jax.block_until_ready(engine.recover_many(cfg, yw_b, steps=lat_steps, seed=0))  # compile
    t0 = time.perf_counter()
    for s in range(slots):
        jax.block_until_ready(engine.recover_many(cfg, yw_b, steps=lat_steps, seed=s))
    t_recover_serial = time.perf_counter() - t0

    rows = [
        (
            "stream/ticks_per_sec_batched",
            1e6 / tps_batched,
            f"slots={slots};K={scfg.steps_per_tick}",
        ),
        (
            "stream/ticks_per_sec_serial",
            1e6 / tps_serial,
            f"slots={slots};1-slot service x{slots}",
        ),
        ("stream/batched_over_serial", 0.0, f"x{speedup:.2f} (claim: >=2x at 4+ slots)"),
        (
            "stream/latency_service_per_stream",
            t_service / slots * 1e6,
            f"{lat_steps} steps; {slots} streams concurrent",
        ),
        (
            "stream/latency_recover_many_serial",
            t_recover_serial / slots * 1e6,
            f"{lat_steps} steps; one stream at a time",
        ),
    ]
    # gated: the one dimensionless ratio with real margin (~2.5-3x measured
    # vs a 1.5 floor). The latency ratio is informational only — its margin
    # over 1.0 is too thin to gate without flaking on loaded CI runners.
    metrics = {
        "batched_over_serial_speedup": round(speedup, 3),
        "info": {
            "slots": slots,
            "steps_per_tick": scfg.steps_per_tick,
            "n_ticks": timed,
            "latency_speedup_vs_recover_many": round(t_recover_serial / max(t_service, 1e-9), 3),
            "ticks_per_sec_batched": round(tps_batched, 2),
            "ticks_per_sec_serial": round(tps_serial, 2),
            "latency_service_per_stream_s": round(t_service / slots, 4),
            "latency_recover_many_per_stream_s": round(t_recover_serial / slots, 4),
        },
    }
    return rows, metrics


# ---------------------------------------------------------------------------
# banked one-kernel serve tick vs the composite stage sequence
# ---------------------------------------------------------------------------
def run_banked_tick(slots: int = 8, n_ticks: int = 16, repeats: int = 3, smoke: bool = False):
    """Banked one-kernel serve tick vs the composite stage-sequence serving.

    K = 0 serve/monitor ticks — the configuration the banked ``mr_tick``
    kernel collapses into ONE program (ring ingest + window substeps + head
    + EMA readout for ALL slots, one packed [S, 4] status readback).

    The GATED comparator is the composite per-slot stage sequence: ring
    ingest as its own program, then per slot a windows + ``readout_theta``
    program dispatch with its own device->host Theta readback and the EMA /
    delta update on the host — the serving structure a deployment paid
    before the banked kernel existed (the eviction-path readout, run every
    tick), and the "no banking, stages composed separately" baseline of the
    paper's one-kernel claim. At MR sizes each stage's math is microseconds,
    so S per-slot dispatches + S readbacks dominate and the wall ratio is a
    REAL structural speedup even on CPU (measured ~4x at 8 slots).

    For transparency the info section also carries the ratio against the
    one-program composite tick (``TickSpec(tick_kernel="composite")`` with
    K=0 — added alongside the banked kernel): both are single XLA
    executables of the same math, so that ratio sits near 1.0 off-TPU and
    is NOT the gated claim (banked still does it in 1 host sync vs 5).

    Returns (csv_rows, metrics) with gated ``banked_tick_over_composite_wall``.
    """
    if smoke:
        n_ticks, repeats = 10, 2
    from repro import api
    from repro.core.stream import _slot_windows, readout_theta, roll_buffer
    from repro.data.dynamics import generate_trajectory

    scfg = StreamConfig(
        buf_len=32,
        window=8,
        stride=8,
        chunk=8,
        steps_per_tick=0,  # pure serve tick: readout only, no optimizer steps
        min_steps=10**9,
        max_steps=10**9,
    )
    _, ys, _ = generate_trajectory("lorenz", n_samples=32 + 8 * (n_ticks + 2))
    chunks = [
        np.repeat(ys[32 + t * 8 : 32 + (t + 1) * 8][None], slots, axis=0) for t in range(n_ticks)
    ]
    timed = n_ticks - 1

    def make_plan(kind):
        return api.compile_plan(
            api.RecoverySpec(
                state_dim=3,
                order=2,
                hidden=8,
                dense_hidden=16,
                dt=0.01,
                encoder="gru",
                mode="stream",
                n_slots=slots,
                stream=scfg,
                tick=api.TickSpec(steps_per_tick=0, tick_kernel=kind),
            )
        )

    def fresh_service(plan):
        svc = plan.make_service()
        for i in range(slots):
            svc.submit(i, ys[:32])
        svc.fill_slots()
        return svc

    def run_service_ticks(plan):
        """One-program tick loop through the real service (banked or composite)."""
        best, syncs = float("inf"), 0.0
        for _ in range(repeats):
            svc = fresh_service(plan)
            svc.tick_once(chunks[0])  # compile
            t0 = time.perf_counter()
            for t in range(1, n_ticks):
                svc.tick_once(chunks[t])
            best = min(best, time.perf_counter() - t0)
            syncs = float(np.median(svc.sync_log[1:]))
        return best, syncs

    plan_b, plan_c = make_plan("banked"), make_plan("composite")
    t_banked, syncs_banked = run_service_ticks(plan_b)
    t_ctick, syncs_ctick = run_service_ticks(plan_c)

    # composite per-slot stage sequence (the gated baseline): ingest program,
    # then per slot a windows+readout program and its own Theta readback,
    # EMA + convergence delta on the host. Per-slot params are hoisted OUT of
    # the loop (K=0 freezes them) — the baseline is not handicapped with
    # avoidable per-tick work.
    cfg = plan_c.cfg
    ingest = jax.jit(
        lambda by, bu, ny, nu: (roll_buffer(by, ny), roll_buffer(bu, nu)),
        donate_argnums=(0, 1),
    )

    @jax.jit
    def slot_read(p, by, bu, mu, sd):
        yw, uw = _slot_windows(by, bu, mu, sd, scfg)
        return readout_theta(p, cfg, yw, uw)

    no_u = np.zeros((slots, scfg.chunk, cfg.input_dim), np.float32)
    best_seq = float("inf")
    for _ in range(repeats):
        svc = fresh_service(plan_c)
        st = svc.state
        slot_params = [jax.tree.map(lambda a: a[s], st.params) for s in range(slots)]
        mean, scale = st.mean, st.scale
        buf_y, buf_u, theta_h = st.buf_y, st.buf_u, np.asarray(st.theta)

        def tick_stage_seq(buf_y, buf_u, chunk, theta_h):
            buf_y, buf_u = ingest(buf_y, buf_u, jnp.asarray(chunk), jnp.asarray(no_u))
            raw = np.stack(
                [
                    np.asarray(slot_read(slot_params[s], buf_y[s], buf_u[s], mean[s], scale[s]))
                    for s in range(slots)
                ]
            )
            theta_new = scfg.ema * theta_h + (1.0 - scfg.ema) * raw
            delta = np.max(np.abs(theta_new - theta_h), axis=(1, 2))
            delta /= np.max(np.abs(theta_new), axis=(1, 2)) + 1e-3  # noqa: F841
            return buf_y, buf_u, theta_new

        buf_y, buf_u, theta_h = tick_stage_seq(buf_y, buf_u, chunks[0], theta_h)  # compile
        t0 = time.perf_counter()
        for t in range(1, n_ticks):
            buf_y, buf_u, theta_h = tick_stage_seq(buf_y, buf_u, chunks[t], theta_h)
        best_seq = min(best_seq, time.perf_counter() - t0)

    ratio = best_seq / t_banked
    rows = [
        (
            "stream/banked_tick_over_composite",
            1e6 / (timed / t_banked),
            f"x{ratio:.2f} wall, K=0 serve ticks: one banked program + 1 sync "
            f"vs ingest + {slots} per-slot readout dispatches + {slots} syncs "
            f"(one-program composite tick: x{t_ctick / t_banked:.2f}, "
            f"{syncs_ctick:.0f} syncs/tick)",
        ),
    ]
    metrics = {
        "banked_tick_over_composite_wall": round(ratio, 3),
        "info": {
            "slots": slots,
            "n_ticks": timed,
            "banked_ticks_per_sec": round(timed / t_banked, 2),
            "composite_stage_seq_ticks_per_sec": round(timed / best_seq, 2),
            "composite_tick_ticks_per_sec": round(timed / t_ctick, 2),
            "banked_over_composite_tick_wall": round(t_ctick / t_banked, 3),
            "banked_host_syncs_per_tick": syncs_banked,
            "composite_tick_host_syncs_per_tick": syncs_ctick,
        },
    }
    return rows, metrics


# ---------------------------------------------------------------------------
# sharded-slot mesh scaling (repro.api plan surface)
# ---------------------------------------------------------------------------
# Runs in a SUBPROCESS because the virtual-device count must be pinned via
# XLA_FLAGS before any jax import; the parent process already holds a
# single-device jax. One subprocess measures every mesh size so the three
# configurations share identical CPU conditions.
_MESH_SNIPPET = """\
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={device_count}"
import json
import time

import numpy as np

from repro import api
from repro.core.stream import StreamConfig
from repro.data.dynamics import generate_trajectory


def ticks_per_sec(mesh_slots, slots, n_ticks, repeats):
    scfg = StreamConfig(
        buf_len=32, window=8, stride=8, chunk=8, steps_per_tick=8,
        min_steps=10**9, max_steps=10**9,
    )
    spec = api.RecoverySpec(
        state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder="gru",
        mode="stream", n_slots=slots, stream=scfg, mesh_slots=mesh_slots,
    )
    plan = api.compile_plan(spec)
    _, ys, _ = generate_trajectory("lorenz", n_samples=32 + 8 * (n_ticks + 2))
    chunks = [
        np.repeat(ys[32 + t * 8 : 32 + (t + 1) * 8][None], slots, axis=0)
        for t in range(n_ticks)
    ]
    best = 0.0
    for _ in range(repeats):
        svc = plan.make_service()
        for i in range(slots):
            svc.submit(i, ys[:32])
        svc.fill_slots()
        svc.tick_once(chunks[0])  # compile
        t0 = time.perf_counter()
        for t in range(1, n_ticks):
            svc.tick_once(chunks[t])
        best = max(best, (n_ticks - 1) / (time.perf_counter() - t0))
    # host-boundary accounting (deterministic): every device->host readback
    # is a sync point, every post-admission shard re-pin is a reshard. The
    # per-tick figure is the MEDIAN of the service's sync_log — the first
    # (compile) tick and eviction ticks read extra scalars, and a mean over
    # so few ticks let those outliers move the row between runs.
    return {{
        "tps": best,
        "host_syncs_per_tick": float(np.median(svc.sync_log)),
        "reshards": svc.counters["reshards"],
    }}


def device_plane(mesh_slots, slots, n_ticks, repeats):
    # Device-resident control plane under churn: 2*slots streams over
    # `slots` slots with a hard 16-step budget (2 ticks at K=8), so every
    # slot evicts and refills from the shard-local on-device queue mid-run
    # (>= 2*slots admissions total, half of them via in-program refill).
    # Steady-state host boundary = median of sync_log AFTER the compile
    # tick: only the periodic snapshot (every snapshot_period ticks) reads
    # anything back, and admission never re-pins the slot axis (reshards
    # stays 0 by construction — gated as a ceiling).
    scfg = StreamConfig(
        buf_len=32, window=8, stride=8, chunk=8, steps_per_tick=8,
        min_steps=16, max_steps=16,
    )
    streams = 2 * slots
    spec = api.RecoverySpec(
        state_dim=3, order=2, hidden=8, dense_hidden=16, dt=0.01, encoder="gru",
        mode="stream", n_slots=slots, stream=scfg, mesh_slots=mesh_slots,
        tick=api.TickSpec(
            steps_per_tick=8, control="device",
            queue_capacity=streams, snapshot_period=4, warm_capacity=slots,
        ),
    )
    plan = api.compile_plan(spec)
    _, ys, _ = generate_trajectory("lorenz", n_samples=32 + 8 * (n_ticks + 2))
    chunks = [
        np.repeat(ys[32 + t * 8 : 32 + (t + 1) * 8][None], slots, axis=0)
        for t in range(n_ticks)
    ]
    best, syncs, reshards, completed, done = 0.0, 0.0, 0, 0, False
    for _ in range(repeats):
        svc = plan.make_service()
        for i in range(streams):
            svc.submit(i, ys[:32])
        svc.fill_slots()
        svc.tick_once(chunks[0])  # compile
        t0 = time.perf_counter()
        for t in range(1, n_ticks):
            svc.tick_once(chunks[t])
        best = max(best, (n_ticks - 1) / (time.perf_counter() - t0))
        syncs = float(np.median(svc.sync_log[1:]))
        reshards = svc.counters["reshards"]
        svc.fill_slots()  # final snapshot: flush the event log
        completed = len(svc.drain())
        done = svc.done
    return {{
        "tps": best,
        "host_syncs_per_tick": syncs,
        "reshards": reshards,
        "admissions": streams,
        "completed": completed,
        "done": bool(done),
    }}


out = {{}}
for m in (1, 2, 4):
    out[str(m)] = ticks_per_sec(m, slots={slots}, n_ticks={n_ticks}, repeats={repeats})
    out[str(m)]["device"] = device_plane(
        m, slots={slots}, n_ticks={n_ticks}, repeats={repeats}
    )
print("MESHBENCH " + json.dumps(out))
"""


def run_mesh_scaling(
    slots: int = 8,
    n_ticks: int = 8,
    repeats: int = 3,
    device_count: int = 4,
    smoke: bool = False,
):
    """Sharded-SlotState service throughput at mesh sizes 1/2/4.

    The plan surface (repro.api) shards the slot axis over a CPU
    virtual-device mesh; measured is ticks/sec (and slots/sec = ticks/sec x
    slots) per mesh size. On CPU the devices share the same cores, so the
    gateable claim is CONSERVATIVE: sharding must not collapse throughput
    (``mesh_slots_per_sec_scaling`` = mesh-2 over mesh-1 ticks/sec stays
    above a floor), while real scaling lives on multi-chip hardware.

    Each mesh size also runs the device-resident control plane
    (``TickSpec(control="device")``) under admission/eviction churn —
    2*slots streams with a 2-tick budget, so every slot refills from the
    shard-local on-device queue mid-run. Gated (ceilings, deterministic):
    ``device_host_syncs_per_tick`` <= 1 steady-state (only the periodic
    snapshot reads back) and ``device_reshards`` == 0 (admission appends to
    device rings; the slot axis is never re-pinned). Returns
    (csv_rows, metrics).
    """
    if smoke:
        n_ticks, repeats = 6, 2
    prog = _MESH_SNIPPET.format(
        device_count=device_count, slots=slots, n_ticks=n_ticks, repeats=repeats
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        env=env,
        cwd=REPO_ROOT,
        timeout=900,
    )
    marker = [ln for ln in p.stdout.splitlines() if ln.startswith("MESHBENCH ")]
    if p.returncode != 0 or not marker:
        raise RuntimeError(
            f"mesh-scaling subprocess failed (rc={p.returncode})\n"
            f"stdout:\n{p.stdout[-2000:]}\nstderr:\n{p.stderr[-2000:]}"
        )
    stats = {int(k): v for k, v in json.loads(marker[0][len("MESHBENCH ") :]).items()}
    tps = {m: s["tps"] for m, s in stats.items()}
    dev = {m: s["device"] for m, s in stats.items()}
    scaling = tps[2] / tps[1]
    rows = [
        (
            f"stream/mesh{m}_ticks_per_sec",
            1e6 / tps[m],
            f"slots={slots};{slots * tps[m]:.1f} slots/s;{device_count} virtual devices;"
            f"host_syncs/tick={stats[m]['host_syncs_per_tick']:.1f};"
            f"reshards={stats[m]['reshards']}",
        )
        for m in sorted(tps)
    ]
    rows += [
        (
            f"stream/mesh{m}_device_ticks_per_sec",
            1e6 / dev[m]["tps"],
            f"control=device;slots={slots};{dev[m]['admissions']} admissions "
            f"({dev[m]['completed']} completed);"
            f"host_syncs/tick={dev[m]['host_syncs_per_tick']:.1f};"
            f"reshards={dev[m]['reshards']}",
        )
        for m in sorted(dev)
    ]
    rows.append(
        (
            "stream/mesh_slots_per_sec_scaling",
            0.0,
            f"x{scaling:.2f} mesh-2 over mesh-1 (CPU virtual devices share cores; "
            "conservative no-collapse floor)",
        )
    )
    # device-resident control plane (core/control.py): gated CEILINGS on the
    # worst mesh size — steady-state median syncs/tick must stay <= 1 (the
    # periodic snapshot is the only readback) and the slot axis must never
    # be re-pinned on admission (reshards == 0). Both are structural, so
    # they are deterministic counters, not wall measurements.
    dev_syncs = max(d["host_syncs_per_tick"] for d in dev.values())
    dev_reshards = max(d["reshards"] for d in dev.values())
    metrics = {
        "mesh_slots_per_sec_scaling": round(scaling, 3),
        "device_host_syncs_per_tick": round(dev_syncs, 3),
        "device_reshards": dev_reshards,
        "info": {
            "device_count": device_count,
            "slots": slots,
            "n_ticks": n_ticks - 1,
            **{
                f"mesh{m}_slots_per_sec": round(slots * tps[m], 2) for m in sorted(tps)
            },
            "mesh4_over_mesh1": round(tps[4] / tps[1], 3),
            # host-plane baseline the device-resident control plane replaces:
            # ALL admissions funnel through one host queue, so every
            # readback/reshard is a cross-mesh sync the sharded service pays.
            **{
                f"mesh{m}_host_syncs_per_tick": round(stats[m]["host_syncs_per_tick"], 2)
                for m in sorted(stats)
            },
            **{f"mesh{m}_reshards": stats[m]["reshards"] for m in sorted(stats)},
            **{
                f"mesh{m}_device_host_syncs_per_tick": round(
                    dev[m]["host_syncs_per_tick"], 2
                )
                for m in sorted(dev)
            },
            **{f"mesh{m}_device_reshards": dev[m]["reshards"] for m in sorted(dev)},
            **{
                f"mesh{m}_device_ticks_per_sec": round(dev[m]["tps"], 2)
                for m in sorted(dev)
            },
            "device_admissions": dev[min(dev)]["admissions"],
            "device_all_completed": all(
                d["completed"] == d["admissions"] and d["done"] for d in dev.values()
            ),
        },
    }
    return rows, metrics


# ---------------------------------------------------------------------------
# chaos drill: kill a shard mid-stream, restore onto the shrunken mesh
# ---------------------------------------------------------------------------
# Subprocess for the same reason as the mesh sweep: the 2-virtual-device
# XLA flag must be set before any jax import. The drill is the resilience
# subsystem end to end (runtime/resilience.py): a 2-shard device-control
# service snapshots SlotState + ControlState every checkpoint_period ticks;
# at tick `kill_at` one shard "fails" (SimulatedFailure), the supervisor
# re-plans the slot mesh on the survivor, recompiles, restores the latest
# snapshot with resharding, re-enqueues in-flight streams, and every
# stream must still converge.
_CHAOS_SNIPPET = """\
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={device_count}"
import json
import tempfile
import time

import numpy as np

from repro.api import RecoverySpec, TickSpec
from repro.core.stream import StreamConfig
from repro.data.dynamics import generate_trajectory
from repro.runtime import ServiceSupervisor, kill_shard_once

scfg = StreamConfig(
    buf_len=32, window=8, stride=8, chunk=8, steps_per_tick=8,
    min_steps=16, max_steps=32, delta_tol=0.0,
)
spec = RecoverySpec(
    state_dim=3, input_dim=0, order=2, hidden=8, dense_hidden=16, dt=0.01,
    mode="stream", n_slots={slots}, stream=scfg, seed=0, mesh_slots=2,
    tick=TickSpec(steps_per_tick=8, control="device",
                  queue_capacity={streams}, snapshot_period=1,
                  warm_capacity={slots}),
)
ys = np.stack([
    generate_trajectory("lorenz", n_samples=400, noise_std=0.01, seed=i)[1]
    for i in range({streams})
]).astype(np.float32)
sup = ServiceSupervisor(spec, tempfile.mkdtemp(prefix="bench_chaos_"),
                        checkpoint_period={checkpoint_period},
                        chaos=kill_shard_once({kill_at}, n_lost=1))
t0 = time.perf_counter()
out = sup.serve(ys, max_ticks={max_ticks})
wall = time.perf_counter() - t0
print("CHAOSBENCH " + json.dumps({{
    "recovered_streams_fraction": out["recovered_streams_fraction"],
    "restarts": out["restarts"],
    "final_mesh": list(out["final_mesh"]),
    "ticks": out["ticks"],
    "p50_tick_ms": out["p50_tick_ms"],
    "p99_tick_ms": out["p99_tick_ms"],
    "wall_s": round(wall, 3),
    "n_streams": {streams},
}}))
"""


def run_chaos(
    slots: int = 4,
    streams: int = 6,
    kill_at: int = 3,
    checkpoint_period: int = 2,
    device_count: int = 2,
    smoke: bool = False,
):
    """Shard-loss recovery drill; gated ``recovered_streams_fraction``.

    A 2-shard device-control service loses one virtual device mid-stream;
    the ServiceSupervisor (runtime/resilience.py) restores the latest
    SlotState+ControlState snapshot onto the re-planned 1-device mesh and
    re-enqueues the in-flight streams. The gated metric is the fraction of
    submitted streams that still complete — pinned to EXACTLY 1.0 (floor
    AND ceiling in baselines.json): below means recovery dropped a stream,
    above means the accounting is broken. Deterministic (fixed seeds, no
    wall clock in the gated row); wall numbers land in info. Returns
    (csv_rows, metrics).
    """
    del smoke  # the drill is already smoke-sized; flag kept for symmetry
    prog = _CHAOS_SNIPPET.format(
        device_count=device_count,
        slots=slots,
        streams=streams,
        kill_at=kill_at,
        checkpoint_period=checkpoint_period,
        max_ticks=60,
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        env=env,
        cwd=REPO_ROOT,
        timeout=900,
    )
    marker = [ln for ln in p.stdout.splitlines() if ln.startswith("CHAOSBENCH ")]
    if p.returncode != 0 or not marker:
        raise RuntimeError(
            f"chaos-drill subprocess failed (rc={p.returncode})\n"
            f"stdout:\n{p.stdout[-2000:]}\nstderr:\n{p.stderr[-2000:]}"
        )
    stats = json.loads(marker[0][len("CHAOSBENCH ") :])
    frac = stats["recovered_streams_fraction"]
    rows = [
        (
            "stream/chaos_recovered_fraction",
            stats["wall_s"] * 1e6,
            f"{frac:.2f} of {stats['n_streams']} streams after losing 1/"
            f"{device_count} shards at tick {kill_at}; {stats['restarts']} "
            f"restart(s); final mesh {tuple(stats['final_mesh'])}; "
            f"p50={stats['p50_tick_ms']:.1f}ms p99={stats['p99_tick_ms']:.1f}ms",
        ),
    ]
    metrics = {
        "recovered_streams_fraction": frac,
        "info": {
            "n_streams": stats["n_streams"],
            "slots": slots,
            "kill_at_tick": kill_at,
            "checkpoint_period": checkpoint_period,
            "restarts": stats["restarts"],
            "final_mesh": stats["final_mesh"],
            "ticks": stats["ticks"],
            "p50_tick_ms": stats["p50_tick_ms"],
            "p99_tick_ms": stats["p99_tick_ms"],
            "wall_s": stats["wall_s"],
        },
    }
    return rows, metrics


def main(smoke: bool = False):
    rows, metrics = run(smoke=smoke)
    for name, us, derived in rows:
        emit(name, us, derived)
    banked_rows, banked_metrics = run_banked_tick(smoke=smoke)
    for name, us, derived in banked_rows:
        emit(name, us, derived)
    metrics["banked_tick"] = banked_metrics
    mesh_rows, mesh_metrics = run_mesh_scaling(smoke=smoke)
    for name, us, derived in mesh_rows:
        emit(name, us, derived)
    metrics["mesh"] = mesh_metrics
    chaos_rows, chaos_metrics = run_chaos(smoke=smoke)
    for name, us, derived in chaos_rows:
        emit(name, us, derived)
    metrics["chaos"] = chaos_metrics
    return metrics


if __name__ == "__main__":
    main()
