"""Paper Tables 1-2 analogue: forward-pass + per-ODE-step profile.

Table 1 splits an LTC-based MR forward pass into sensory processing vs the
iterative ODE solve; Table 2 breaks one solver sub-step into recurrent
sigmoid / weight+reversal activations / sum ops / Euler update. We reproduce
the measurement on the same computation (core/ltc.py implements the same
fused solver as the paper's base code [5]) with jitted stage functions, and
report both wall time shares and the HLO cost model.

Claim checked: the ODE solve dominates (paper: 87.7%) and the recurrent
sigmoid is the largest per-step item (paper: 46.7%).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, wall_time
from repro.core.ltc import init_ltc, ltc_cell, ltc_scan


def run(B: int = 512, T: int = 100, D: int = 8, H: int = 256, n_substeps: int = 6):
    key = jax.random.key(0)
    p = init_ltc(key, D, H)
    xs = jax.random.normal(key, (B, T, D))
    h0 = jnp.zeros((B, H))
    x_t = xs[:, 0]
    h = jnp.zeros((B, H))
    # dispatch-overhead floor: measured on a null jitted fn and subtracted
    # from stage timings (CPU dispatch would otherwise swamp micro-stages)
    null = jax.jit(lambda h: h)
    overhead = wall_time(null, h)

    # --- Table 1: sensory processing vs ODE solver over the full pass -------
    sensory = jax.jit(lambda xs: xs @ p.w_in + p.bias)
    full = jax.jit(lambda xs, h0: ltc_scan(p, xs, h0, n_substeps=n_substeps)[0])
    t_sens = wall_time(sensory, xs)
    t_full = wall_time(full, xs, h0)
    t_solver = max(t_full - t_sens, 0.0)
    rows = [
        ("profile/sensory_processing", t_sens, f"share={t_sens / t_full:.1%}"),
        (f"profile/ode_solver_{n_substeps}step", t_solver, f"share={t_solver / t_full:.1%}"),
        ("profile/total_forward", t_full, "share=100%"),
    ]

    # --- Table 2: one ODE sub-step broken into the paper's stages -----------
    drive = x_t @ p.w_in + p.bias
    sub_dt = 1.0 / n_substeps

    stage_fns = {
        "recurrent_sigmoid": jax.jit(lambda h: jax.nn.sigmoid(drive + h @ p.w_rec)),
        "weight_activation": jax.jit(lambda x: x @ p.w_in + p.bias),  # input affine
        "reversal_activation": jax.jit(lambda f: f * p.a),
        "sum_operations": jax.jit(lambda h, f: h + sub_dt * f * p.a),
        "euler_update": jax.jit(
            lambda h, f: (h + sub_dt * f * p.a) / (1.0 + sub_dt * (p.inv_tau + f))
        ),
    }
    f = jax.nn.sigmoid(drive + h @ p.w_rec)
    times = {
        "recurrent_sigmoid": max(wall_time(stage_fns["recurrent_sigmoid"], h) - overhead, 0.0),
        "weight_activation": max(wall_time(stage_fns["weight_activation"], x_t) - overhead, 0.0),
        "reversal_activation": max(wall_time(stage_fns["reversal_activation"], f) - overhead, 0.0),
        "sum_operations": max(wall_time(stage_fns["sum_operations"], h, f) - overhead, 0.0),
        "euler_update": max(wall_time(stage_fns["euler_update"], h, f) - overhead, 0.0),
    }
    step_total = wall_time(jax.jit(lambda h: ltc_cell(p, x_t, h, n_substeps=1)), h)
    for name, t in times.items():
        rows.append((f"profile/step_{name}", t, f"share={t / step_total:.1%}"))
    rows.append(("profile/single_ode_step", step_total, "share=100%"))
    return rows


def main():
    for name, secs, derived in run():
        emit(name, secs * 1e6, derived)


if __name__ == "__main__":
    main()
