"""Benchmark aggregator — one suite per paper table.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (stdout), one suite at a time:

    profile    paper Tables 1-2 (forward-pass + per-ODE-step shares)
    cycles     paper Table 8    (LTC -> GRU -> fused -> banked kernel)
    stagemap   paper Table 7    (kernel resource-mapping sweep)
    accuracy   paper Table 6    (MERINDA vs EMILY vs PINN+SR vs SINDy)
    platform   paper Table 5    (workload runtime/memory/error on AID)
    stream     streaming service (batched slots vs serial recovery)
    roofline   §Roofline        (40-cell dry-run table, markdown to stderr)

``--smoke`` runs the reduced-size GATED subset (cycles + engine + stagemap
+ stream) and writes ``BENCH_cycles.json`` / ``BENCH_stagemap.json`` /
``BENCH_stream.json`` at the repo root, then checks them against
``benchmarks/baselines.json`` (benchmarks/gate.py) — the CI bench-smoke
job. The JSON files are deterministic: keys sorted, all seeds fixed, and
the gated section carries only dimensionless ratios (deterministic
cost-model ratios — including the fused-vs-unfused stage ratio from
bench_stagemap — or speedups) — absolute wall times and other
machine-dependent numbers stay in the ungated "info" section.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def write_bench_json(path: Path, suite: str, gated: dict, info: dict, smoke: bool) -> None:
    """Deterministic BENCH_*.json: sorted keys, no timestamps, fixed layout."""
    doc = {
        "meta": {"suite": suite, "smoke": smoke, "seed": 0},
        "gated": gated,
        "info": info,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


def run_smoke() -> int:
    """Reduced gated subset -> BENCH_*.json at the repo root -> gate check."""
    from benchmarks import bench_cycles, bench_stagemap, bench_stream, gate
    from benchmarks.common import emit

    print("# suite: cycles (smoke)", flush=True)
    rows, m_cycles = bench_cycles.run()
    for name, us, derived in rows:
        emit(name, us, derived)
    rows, m_engine = bench_cycles.run_engine(steps=300)
    for name, us, derived in rows:
        emit(name, us, derived)
    write_bench_json(
        REPO_ROOT / "BENCH_cycles.json",
        "cycles",
        gated={
            "ltc_over_kernel_interval_ratio": m_cycles["ltc_over_kernel_interval_ratio"],
            "ltc_fused_over_ode_speedup": m_cycles["ltc_fused_over_ode_speedup"],
            "engine_loop_over_scan_speedup": m_engine["loop_over_scan_speedup"],
        },
        info={
            "interval_cycles": m_cycles["interval_cycles"],
            "engine": m_engine["info"],
        },
        smoke=True,
    )

    print("# suite: stagemap (smoke)", flush=True)
    rows, m_stage = bench_stagemap.run_fused_ratio()
    for name, us, derived in rows:
        emit(name, us, derived)
    rows, m_tuned = bench_stagemap.run_tuned_ratio()
    for name, us, derived in rows:
        emit(name, us, derived)
    info = m_stage.pop("info")
    info["tuner"] = m_tuned.pop("info")
    write_bench_json(
        REPO_ROOT / "BENCH_stagemap.json",
        "stagemap",
        gated={**m_stage, **m_tuned},
        info=info,
        smoke=True,
    )

    print("# suite: stream (smoke)", flush=True)
    rows, m_stream = bench_stream.run(smoke=True)
    for name, us, derived in rows:
        emit(name, us, derived)
    rows, m_banked = bench_stream.run_banked_tick(smoke=True)
    for name, us, derived in rows:
        emit(name, us, derived)
    rows, m_mesh = bench_stream.run_mesh_scaling(smoke=True)
    for name, us, derived in rows:
        emit(name, us, derived)
    rows, m_chaos = bench_stream.run_chaos(smoke=True)
    for name, us, derived in rows:
        emit(name, us, derived)
    info = m_stream.pop("info")
    info["banked_tick"] = m_banked.pop("info")
    info["mesh"] = m_mesh.pop("info")
    info["chaos"] = m_chaos.pop("info")
    write_bench_json(
        REPO_ROOT / "BENCH_stream.json",
        "stream",
        gated={**m_stream, **m_banked, **m_mesh, **m_chaos},
        info=info,
        smoke=True,
    )

    failures = gate.check_all(REPO_ROOT)
    if failures:
        for msg in failures:
            print(f"# GATE REGRESSION: {msg}", file=sys.stderr)
        return 1
    print("# gate: all gated metrics at or above committed floors", flush=True)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale budgets")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="reduced gated subset; writes + gates BENCH_*.json at the repo root",
    )
    args = ap.parse_args()

    if args.smoke:
        return run_smoke()

    from benchmarks import (
        bench_accuracy,
        bench_cycles,
        bench_platform,
        bench_profile,
        bench_stagemap,
        bench_stream,
    )

    suites = {
        "profile": lambda: bench_profile.main(),
        "cycles": lambda: bench_cycles.main(),
        "stagemap": lambda: bench_stagemap.main(),
        "accuracy": lambda: bench_accuracy.main(fast=not args.full),
        "platform": lambda: bench_platform.main(fast=not args.full),
        "stream": lambda: bench_stream.main(),
    }
    failures = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"# suite: {name}", flush=True)
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"# suite {name} done in {time.time() - t0:.1f}s", flush=True)

    if args.only in (None, "roofline"):
        try:
            from benchmarks import roofline

            print("# suite: roofline (markdown)", flush=True)
            roofline.main()
        except Exception:
            failures.append("roofline")
            traceback.print_exc()

    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
