"""Benchmark aggregator — one suite per paper table.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (stdout), one suite at a time:

    profile    paper Tables 1-2 (forward-pass + per-ODE-step shares)
    cycles     paper Table 8    (LTC -> GRU -> fused -> banked kernel)
    stagemap   paper Table 7    (kernel resource-mapping sweep)
    accuracy   paper Table 6    (MERINDA vs EMILY vs PINN+SR vs SINDy)
    platform   paper Table 5    (workload runtime/memory/error on AID)
    roofline   §Roofline        (40-cell dry-run table, markdown to stderr)
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale budgets")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_accuracy,
        bench_cycles,
        bench_platform,
        bench_profile,
        bench_stagemap,
    )

    suites = {
        "profile": lambda: bench_profile.main(),
        "cycles": lambda: bench_cycles.main(),
        "stagemap": lambda: bench_stagemap.main(),
        "accuracy": lambda: bench_accuracy.main(fast=not args.full),
        "platform": lambda: bench_platform.main(fast=not args.full),
    }
    failures = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"# suite: {name}", flush=True)
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"# suite {name} done in {time.time() - t0:.1f}s", flush=True)

    if args.only in (None, "roofline"):
        try:
            from benchmarks import roofline

            print("# suite: roofline (markdown)", flush=True)
            roofline.main()
        except Exception:
            failures.append("roofline")
            traceback.print_exc()

    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
