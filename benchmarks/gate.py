"""Benchmark regression gate: BENCH_*.json vs the committed floors.

``benchmarks/baselines.json`` maps suite -> gated metric -> {"floor": x}
and/or {"ceiling": x}. ``run.py --smoke`` writes ``BENCH_<suite>.json``
files at the repo root and calls :func:`check_all`; CI uploads the JSONs as
artifacts and fails the bench-smoke job when any gated metric lands below
its floor or above its ceiling.

Gated metrics are dimensionless ratios or deterministic counters only —
cost-model ratios (cycles suite), speedups with conservative floors
(engine/stream suites), or host-boundary counts with hard ceilings
(device-resident control plane: syncs/tick and reshards). Absolute wall
times live in each file's "info" section and are never gated, so the gate
is stable across runner hardware.

Standalone usage (after a smoke run has produced the JSONs):

    PYTHONPATH=src python -m benchmarks.gate
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
BASELINES = Path(__file__).resolve().parent / "baselines.json"


def check(bench: dict, floors: dict, name: str) -> list[str]:
    """Compare one suite's gated metrics against its floors/ceilings."""
    failures = []
    gated = bench.get("gated", {})
    for metric, spec in floors.items():
        value = gated.get(metric)
        if not isinstance(value, (int, float)):
            failures.append(f"{name}: gated metric {metric!r} missing from BENCH json")
            continue
        floor = spec.get("floor")
        ceiling = spec.get("ceiling")
        if floor is not None and value < floor:
            failures.append(f"{name}: {metric} = {value} < committed floor {floor}")
        if ceiling is not None and value > ceiling:
            failures.append(f"{name}: {metric} = {value} > committed ceiling {ceiling}")
    return failures


def check_all(
    bench_dir: Path | str = REPO_ROOT, baselines_path: Path | str = BASELINES
) -> list[str]:
    """Check every suite named in baselines.json; returns failure messages."""
    bench_dir = Path(bench_dir)
    with open(baselines_path) as f:
        baselines = json.load(f)
    failures = []
    for suite, floors in sorted(baselines.items()):
        path = bench_dir / f"BENCH_{suite}.json"
        if not path.exists():
            failures.append(f"{suite}: {path} missing (run `python -m benchmarks.run --smoke`)")
            continue
        with open(path) as f:
            failures.extend(check(json.load(f), floors, suite))
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--bench-dir", default=str(REPO_ROOT))
    ap.add_argument("--baselines", default=str(BASELINES))
    args = ap.parse_args(argv)
    failures = check_all(args.bench_dir, args.baselines)
    if failures:
        for msg in failures:
            print(f"[gate] REGRESSION: {msg}")
        return 1
    print("[gate] all gated benchmark metrics at or above committed floors")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
