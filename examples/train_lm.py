"""End-to-end LM training driver example (~100M-class model, few hundred steps).

Runs the REAL distributed code path on host devices: sharded train step,
deterministic data pipeline, async checkpoints, supervisor with elastic
restart. The mamba2-130m smoke config (attention-free — the paper-technique
family) trains visibly in a few minutes on CPU.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/train_lm.py --steps 200

For a failure drill mid-run add:  --chaos-step 60
"""

import sys

from repro.launch.train import main as train_main


def main():
    argv = [
        "train_lm",
        "--arch",
        "mamba2-130m",
        "--steps",
        "200",
        "--batch",
        "8",
        "--seq",
        "128",
        "--data",
        "2",
        "--model",
        "2",
        "--lr",
        "1e-3",
        "--save-every",
        "50",
        "--log-every",
        "20",
    ] + sys.argv[1:]
    sys.argv = argv
    return train_main()


if __name__ == "__main__":
    raise SystemExit(main())
