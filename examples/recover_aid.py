"""AID case study: MERINDA vs LTC vs SINDy on glucose-insulin dynamics.

The paper's edge-AI application: recover the Bergman minimal model (the
OhioT1D stand-in — see DESIGN.md §8) from CGM+insulin traces, comparing the
paper's three workload families head-to-head — each declared as one
``repro.api.RecoverySpec`` and compiled into a ``RecoveryPlan``, including
the fixed-point (quantization-aware) MERINDA configuration that maps to the
int8+PWL kernel.

    PYTHONPATH=src python examples/recover_aid.py [--steps 300]
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.quant import QuantConfig
from repro.core.sindy import fit_sindy
from repro.data.dynamics import generate_trajectory, get_system
from repro.data.windows import make_windows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    spec_sys = get_system("aid")
    ts, ys, us = generate_trajectory("aid", noise_std=0.01)
    yw, uw, norm = make_windows(ys, us, window=32, stride=2)
    yw, uw = jnp.asarray(yw), jnp.asarray(uw)
    print(f"AID traces: {ys.shape} (5-min CGM samples), windows {yw.shape}")

    results = {}
    for name, encoder, qat, fused in (
        ("MERINDA (gru_flow)", "gru_flow", None, False),
        (
            "MERINDA int8-QAT",
            "gru_flow",
            QuantConfig(act_int_bits=4, act_frac_bits=10, weight_int_bits=2, weight_frac_bits=12),
            False,
        ),
        # the paper's primary baseline runs through the fused multi-substep
        # mr_step variant: solver substeps + head in ONE stage (reference
        # math off-TPU; the fused-solver Pallas kernel on TPU)
        ("LTC (fused substeps)", "ltc", None, True),
    ):
        plan = api.compile_plan(
            api.RecoverySpec(
                state_dim=spec_sys.state_dim,
                input_dim=spec_sys.input_dim,
                order=spec_sys.order,
                hidden=32,
                dense_hidden=64,
                dt=0.1,
                encoder=encoder,
                qat=qat,
                fused=fused,
                mode="offline",
                steps=args.steps,
                lr=3e-3,
                batch_size=64,
            )
        )
        t0 = time.time()
        params, metrics = plan.run_offline(yw, uw)
        hist = api.history_from_metrics(metrics, log_every=args.steps - 1)
        results[name] = (hist[-1]["recon_mse"], time.time() - t0)

    t0 = time.time()
    fit = fit_sindy(
        jnp.asarray(ys), dt=spec_sys.dt, order=spec_sys.order, u=jnp.asarray(us), threshold=0.005
    )
    coef_err = float(np.abs(np.asarray(fit.coef) - spec_sys.true_coef()).max())
    results["SINDy (STLSQ)"] = (coef_err, time.time() - t0)

    print(f"\n{'method':24s} {'error':>10s} {'seconds':>9s}")
    for name, (err, dt) in results.items():
        print(f"{name:24s} {err:10.4f} {dt:9.1f}")
    print(
        "\n(MERINDA errors = window recon MSE; SINDy = max coefficient error."
        "\n Paper claim reproduced: the GRU-flow path matches LTC accuracy"
        "\n while replacing the iterative solver with one gated update/step.)"
    )


if __name__ == "__main__":
    main()
