"""Quickstart: recover a sparse dynamical model with MERINDA in ~60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Generates a Lotka-Volterra (predator-prey) trajectory, declares the recovery
as ONE ``repro.api.RecoverySpec``, compiles it into a ``RecoveryPlan``
(every execution decision — encoder, precision, fusion, tiling — resolved
up front), trains on sliding windows, prunes to the true sparsity, and
prints the recovered vs true coefficient matrix.
"""

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.library import term_names
from repro.data.dynamics import generate_trajectory, get_system
from repro.data.windows import make_windows


def main():
    spec_sys = get_system("lotka_volterra")
    ts, ys, us = generate_trajectory("lotka_volterra")
    yw, uw, norm = make_windows(ys, us, window=32, stride=4)
    print(f"system: {spec_sys.name}  trajectory: {ys.shape}  windows: {yw.shape}")

    spec = api.RecoverySpec(
        state_dim=2,
        order=2,
        hidden=32,
        dense_hidden=64,
        dt=spec_sys.dt,
        encoder="gru_flow",
        fused=True,  # stage-fused per-window step (kernels/mr_step)
        block_b="auto",  # batch tile fitted to the auto-detected VMEM budget
        mode="offline",
        steps=300,
        lr=3e-3,
        batch_size=64,
    )
    plan = api.compile_plan(spec)
    print(f"compiled: {plan.lowering}")

    # norm=... applies the L1 penalty to physical-unit coefficients
    params, metrics = plan.run_offline(jnp.asarray(yw), norm=norm)
    for h in api.history_from_metrics(metrics, log_every=50):
        print(f"  step {h['step']:4d}  recon_mse {h['recon_mse']:.4f}")

    theta = plan.readout(params, jnp.asarray(yw), norm=norm, n_active=4)
    names = term_names(2, 2, ["h", "l"])
    true = spec_sys.true_coef()
    print(f"\n{'term':>8s}  {'rec dh/dt':>10s} {'true':>8s}   {'rec dl/dt':>10s} {'true':>8s}")
    for i, n in enumerate(names):
        print(
            f"{n:>8s}  {float(theta[i, 0]):10.3f} {true[i, 0]:8.3f}   "
            f"{float(theta[i, 1]):10.3f} {true[i, 1]:8.3f}"
        )
    err = float(np.abs(theta - true).max())
    print(f"\nmax |recovered - true| = {err:.3f} (physical units)")


if __name__ == "__main__":
    main()
