"""Batched serving example: continuous batching over a request queue.

    PYTHONPATH=src python examples/serve_lm.py

Drives launch/serve.py (slot-based continuous batching: one compiled prefill
+ one compiled decode program; finished slots are refilled from the queue —
the "one setup, then continuous streaming" execution the paper targets).
"""

import sys

from repro.launch.serve import main as serve_main


def main():
    argv = [
        "serve_lm",
        "--arch",
        "qwen2.5-3b",
        "--requests",
        "16",
        "--slots",
        "4",
        "--prompt-len",
        "32",
        "--max-new",
        "24",
        "--cache-len",
        "128",
    ] + sys.argv[1:]
    sys.argv = argv
    return serve_main()


if __name__ == "__main__":
    raise SystemExit(main())
